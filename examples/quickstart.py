"""Quickstart: the whole framework in one minute on CPU.

1. Ingest a synthetic tokenized corpus with 4 parallel writers -> ONE file.
2. Train a reduced gemma-2b for 30 steps (sharded step, checkpoints).
3. Kill/restart: resume from the committed checkpoint mid-epoch.
4. Serve: prefill + greedy decode; log generations through the parallel
   writer (nested columnar output).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile

import jax
import numpy as np

from repro.configs import smoke_config
from repro.launch.mesh import make_local_mesh
from repro.models import build
from repro.pipeline import PackedLoader, ingest_corpus, synth_corpus
from repro.train import LoopConfig, TrainLoop

work = tempfile.mkdtemp(prefix="repro_quickstart_")
data = os.path.join(work, "corpus.rntj")
ckpt = os.path.join(work, "ckpt")

cfg = smoke_config("gemma-2b")
bundle = build(cfg)
mesh = make_local_mesh()

print("=== 1. parallel ingest ===")
stats = ingest_corpus(
    synth_corpus(400, mean_len=128, vocab=cfg.vocab_size), data, n_workers=4)
print(f"  {stats['entries']} docs -> {stats['clusters']} clusters, "
      f"{stats['compressed_bytes']/1e6:.2f} MB compressed "
      f"({stats['lock_acquisitions']} lock acquisitions)")

print("=== 2. train 30 steps ===")
loader = PackedLoader(data, batch=4, seq_len=64)
loop = TrainLoop(bundle, mesh, loader, ckpt,
                 config=LoopConfig(steps=30, ckpt_every=10, log_every=10))
hist = loop.run()
print(f"  loss {hist[0].loss:.3f} -> {hist[-1].loss:.3f}")

print("=== 3. crash-restart ===")
loader2 = PackedLoader(data, batch=4, seq_len=64)
loop2 = TrainLoop(bundle, mesh, loader2, ckpt,
                  config=LoopConfig(steps=10, ckpt_every=10, log_every=5))
print(f"  restored at step {loop2.step}; continuing")
loop2.run()

print("=== 4. serve ===")
from repro.launch.serve import main as serve_main
serve_main(["--arch", "gemma-2b", "--smoke", "--requests", "4",
            "--prompt-len", "8", "--max-new", "8",
            "--out", os.path.join(work, "gen.rntj")])
print(f"workdir: {work}")
