"""Batched serving demo: concurrent decode workers, one output file.

Multiple worker threads each serve a batch of requests (prefill + greedy
decode on a reduced model) and write their generations through fill
contexts of ONE ParallelWriter — inference output as nested columnar data,
written with the paper's protocol.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""

import os
import tempfile
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core import Collection, ColumnBatch, Leaf, ParallelWriter, RNTJReader, Schema
from repro.launch.serve import GEN_SCHEMA, generate
from repro.models import build

cfg = smoke_config("deepseek-67b")
bundle = build(cfg)
params = bundle.init(jax.random.PRNGKey(0))

work = tempfile.mkdtemp(prefix="repro_serve_")
out = os.path.join(work, "generations.rntj")
writer = ParallelWriter(GEN_SCHEMA, out)

N_WORKERS, BATCH, PLEN, NEW = 3, 4, 12, 16


def worker(wid: int):
    rng = np.random.default_rng(wid)
    ctx = writer.create_fill_context()
    prompts = rng.integers(0, cfg.vocab_size, (BATCH, PLEN)).astype(np.int32)
    gen = generate(bundle, params, jnp.asarray(prompts), NEW)
    ctx.fill_batch(ColumnBatch.from_arrays(GEN_SCHEMA, BATCH, {
        "request_id": np.arange(wid * 100, wid * 100 + BATCH, dtype=np.int64),
        "prompt_len": np.full(BATCH, PLEN, np.int32),
        "tokens": np.full(BATCH, gen.shape[1], np.int64),
        "tokens._0": gen.reshape(-1).astype(np.int32),
    }))
    ctx.close()
    print(f"  worker {wid}: served {BATCH} requests x {NEW} tokens")


threads = [threading.Thread(target=worker, args=(w,)) for w in range(N_WORKERS)]
for t in threads:
    t.start()
for t in threads:
    t.join()
writer.close()

r = RNTJReader(out)
print(f"\noutput file: {r.n_entries} generations in {r.n_clusters} clusters")
ids = sorted(int(i) for i in r.read_column("request_id"))
print(f"request ids: {ids}")
assert r.n_entries == N_WORKERS * BATCH
print(f"workdir: {work}")
