"""Parallel single-file checkpointing demo (the paper's technique on
training state).

Saves a ~200MB model with 1/2/4/8 writer threads into one file each,
reports wall time, lock counts and critical-section share, verifies all
restore identically, and demonstrates elastic restore (file written by 8
writers restored and re-sharded without any merge step).

Run:  PYTHONPATH=src python examples/parallel_checkpoint.py
"""

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import load_checkpoint, save_checkpoint
from repro.core import RNTJReader

rng = np.random.default_rng(0)
tree = {
    f"layer_{i}": {
        "w": jnp.asarray(rng.normal(size=(512, 2048)).astype(np.float32)),
        "b": jnp.zeros((2048,), jnp.float32),
    }
    for i in range(48)
}
nbytes = sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))
print(f"state: {nbytes/2**20:.0f} MiB")

work = tempfile.mkdtemp(prefix="repro_ckpt_")
print(f"\n{'writers':>8s} {'time':>8s} {'MB/s':>8s} {'locks':>7s} "
      f"{'lock-held':>10s} {'clusters':>9s}")
paths = {}
for n in (1, 2, 4, 8):
    p = os.path.join(work, f"ckpt_w{n}.rntj")
    t0 = time.perf_counter()
    stats = save_checkpoint(p, tree, n_writers=n, row_block_bytes=2 << 20)
    dt = time.perf_counter() - t0
    paths[n] = p
    held_frac = stats["lock_held_ms"] / (dt * 1e3)
    print(f"{n:8d} {dt:7.2f}s {nbytes/2**20/dt:8.1f} "
          f"{stats['lock_acquisitions']:7d} {held_frac:9.1%} "
          f"{stats['clusters']:9d}")

print("\nverifying all layouts restore identically...")
ref, _ = load_checkpoint(paths[1], target_tree=tree)
for n, p in paths.items():
    got, _ = load_checkpoint(p, target_tree=tree)
    for a, b in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("OK: single self-describing file per run, no merge step, "
      "restore is writer-count-agnostic (elastic).")
print(f"workdir: {work}")
