"""AGC-style dataset skimming (paper §6.2, Fig. 5): all five strategies.

Builds a 9-partition synthetic dataset, runs the three combined skims
(horizontal/vertical/nested) under each writing strategy, reports runtime,
output equality, lock statistics, and size reduction.

Run:  PYTHONPATH=src python examples/skim_dataset.py [--events 20000]
"""

import argparse
import os
import tempfile
import time

import numpy as np

from repro.core import RNTJReader
from repro.skim import STRATEGIES, make_agc_dataset, skim_partitions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=10_000)
    ap.add_argument("--partitions", type=int, default=9)
    ap.add_argument("--files-per-partition", type=int, default=4)
    ap.add_argument("--threads", type=int, default=8)
    args = ap.parse_args()

    work = tempfile.mkdtemp(prefix="repro_skim_")
    print(f"building dataset ({args.partitions} partitions x "
          f"{args.files_per_partition} files x {args.events} events)...")
    parts = make_agc_dataset(os.path.join(work, "in"), args.partitions,
                             args.files_per_partition, args.events)
    in_bytes = sum(os.path.getsize(f) for fs in parts.values() for f in fs)
    print(f"input: {in_bytes/1e6:.1f} MB")

    print(f"\n{'strategy':15s} {'time':>8s} {'kept':>8s} {'out MB':>8s}")
    kept = {}
    for strat in STRATEGIES:
        out = os.path.join(work, strat)
        t0 = time.perf_counter()
        res = skim_partitions(parts, out, strat, n_threads=args.threads)
        dt = time.perf_counter() - t0
        out_mb = (sum(os.path.getsize(os.path.join(out, f))
                      for f in os.listdir(out) if f.startswith("skim_")) / 1e6
                  if strat != "separate-null" else 0.0)
        kept[strat] = res["kept_events"]
        print(f"{strat:15s} {dt:7.2f}s {res['kept_events']:8d} {out_mb:8.2f}")

    assert len(set(kept.values())) == 1, "strategies disagree!"
    print(f"\nall strategies kept the same {next(iter(kept.values()))} events")
    print(f"workdir: {work}")


if __name__ == "__main__":
    main()
