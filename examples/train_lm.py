"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

The model is the smollm-360m family scaled to ~100M params (same GQA
ratios, vocab, structure).  The full pipeline is real: parallel columnar
ingest, packing loader, sharded train step with AdamW + remat, async
single-file checkpoints every 50 steps, crash-safe resume.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]

--tiny swaps in a ~10M model so a full 300-step loss curve fits in
minutes on CPU; the default ~100M config is the deliverable shape
(EXPERIMENTS.md records an actual run of each).
"""

import argparse
import os
import tempfile

from repro.configs import get_arch
from repro.launch.mesh import make_local_mesh
from repro.models import build
from repro.pipeline import PackedLoader, ingest_corpus, synth_corpus
from repro.train import LoopConfig, TrainLoop, make_optimizer


def lm_100m():
    """~100M params: smollm family, scaled."""
    return get_arch("smollm-360m").with_(
        name="smollm-100m", n_layers=12, d_model=640, n_heads=10,
        n_kv_heads=5, head_dim=64, d_ff=1792,
    )


def lm_10m():
    return get_arch("smollm-360m").with_(
        name="smollm-10m", n_layers=6, d_model=192, n_heads=6, n_kv_heads=2,
        head_dim=32, d_ff=512, vocab_size=16384, remat=False,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--workdir", default=None)
    args = ap.parse_args()

    work = args.workdir or tempfile.mkdtemp(prefix="repro_train_lm_")
    os.makedirs(work, exist_ok=True)
    data = os.path.join(work, "corpus.rntj")
    cfg = lm_10m() if args.tiny else lm_100m()
    bundle = build(cfg)
    import jax
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(
        bundle.param_shapes()))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params")

    if not os.path.exists(data):
        ingest_corpus(synth_corpus(3000, mean_len=300, vocab=cfg.vocab_size),
                      data, n_workers=4)
    loader = PackedLoader(data, batch=args.batch, seq_len=args.seq)
    loop = TrainLoop(
        bundle, make_local_mesh(), loader, os.path.join(work, "ckpt"),
        config=LoopConfig(steps=args.steps, ckpt_every=50, log_every=10),
        optimizer=make_optimizer(peak_lr=1e-3, warmup=30, total=args.steps),
    )
    if loop.step:
        print(f"resuming from step {loop.step}")
    hist = loop.run()
    first10 = sum(h.loss for h in hist[:10]) / max(len(hist[:10]), 1)
    last10 = sum(h.loss for h in hist[-10:]) / max(len(hist[-10:]), 1)
    print(f"loss: first-10 avg {first10:.3f} -> last-10 avg {last10:.3f}")
    print(f"workdir: {work}")


if __name__ == "__main__":
    main()
