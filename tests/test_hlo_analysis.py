"""Unit tests for the HLO collective parser and roofline math."""

import pytest

from repro.launch.hlo_analysis import (
    CollectiveStats, parse_collectives, roofline_terms, shape_bytes,
    PEAK_FLOPS, HBM_BW, ICI_BW,
)


def test_shape_bytes():
    assert shape_bytes("f32[8,128]") == 8 * 128 * 4
    assert shape_bytes("bf16[2,4,8]{2,1,0}") == 64 * 2
    assert shape_bytes("pred[16]") == 16
    assert shape_bytes("(f32[2], bf16[4])") == 8 + 8
    assert shape_bytes("u8[0]") == 0
    assert shape_bytes("s64[3,3]") == 72


HLO_SAMPLE = """
HloModule test

ENTRY %main (p0: f32[64,128]) -> f32[64,128] {
  %p0 = f32[64,128]{1,0} parameter(0)
  %mul = f32[64,128]{1,0} multiply(%p0, %p0)
  %ag = f32[128,128]{1,0} all-gather(%mul), dimensions={0}
  %ar = f32[64,128]{1,0} all-reduce(%mul), to_apply=%add
  %rs = f32[32,128]{1,0} reduce-scatter(%mul), dimensions={0}
  %a2a = f32[64,128]{1,0} all-to-all(%mul), dimensions={0}
  %cp = f32[64,128]{1,0} collective-permute(%mul), source_target_pairs={{0,1}}
  ROOT %out = f32[64,128]{1,0} add(%ar, %cp)
}
"""


def test_parse_collectives_counts_and_bytes():
    stats = parse_collectives(HLO_SAMPLE)
    assert stats.total_count == 5
    assert set(stats.count_by_kind) == {
        "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute"}
    # every collective's operand is %mul: f32[64,128] = 32768 bytes
    for kind, nbytes in stats.bytes_by_kind.items():
        assert nbytes == 64 * 128 * 4, kind


def test_parse_collectives_ignores_non_collectives():
    stats = parse_collectives("""
ENTRY %m (p: f32[4]) -> f32[4] {
  %p = f32[4]{0} parameter(0)
  ROOT %r = f32[4]{0} add(%p, %p)
}
""")
    assert stats.total_count == 0
    assert stats.total_bytes == 0


def test_parse_collectives_start_variant():
    stats = parse_collectives("""
ENTRY %m (p: f32[8]) -> f32[8] {
  %p = f32[8]{0} parameter(0)
  %ag = f32[16]{0} all-gather-start(%p), dimensions={0}
}
""")
    assert stats.count_by_kind.get("all-gather") == 1
    assert stats.bytes_by_kind["all-gather"] == 32


def test_roofline_terms_dominance():
    t = roofline_terms(hlo_flops=PEAK_FLOPS, hlo_bytes=0, collective_bytes=0,
                       n_chips=1)
    assert t["dominant"] == "compute" and t["compute_s"] == pytest.approx(1.0)
    t = roofline_terms(0, HBM_BW * 2, 0, 4)
    assert t["dominant"] == "memory" and t["memory_s"] == pytest.approx(2.0)
    t = roofline_terms(0, 0, ICI_BW * 3, 4)
    assert t["dominant"] == "collective" and t["collective_s"] == pytest.approx(3.0)
