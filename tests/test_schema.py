"""Schema decomposition and entry (de)composition tests."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (
    Collection, ColumnBatch, Leaf, Record, Schema,
    KIND_LEAF, KIND_OFFSET, decompose_entry, recompose_entries,
)
from repro.core.encoding import sizes_to_offsets


def paper_schema():
    return Schema([
        Leaf("fId", "int32"),
        Collection("fTracks", Record("_0", [
            Leaf("fEnergy", "float32"),
            Collection("fIds", Leaf("_0", "int32")),
        ])),
    ])


def test_paper_table1_columns():
    s = paper_schema()
    paths = [c.path for c in s.columns]
    assert paths == ["fId", "fTracks", "fTracks._0.fEnergy",
                     "fTracks._0.fIds", "fTracks._0.fIds._0"]
    kinds = [c.kind for c in s.columns]
    assert kinds == [KIND_LEAF, KIND_OFFSET, KIND_LEAF, KIND_OFFSET, KIND_LEAF]
    assert s.parent == [-1, -1, 1, 1, 3]


def test_schema_json_roundtrip():
    s = paper_schema()
    s2 = Schema.from_json(s.to_json())
    assert s == s2
    assert [c.to_dict() for c in s.columns] == [c.to_dict() for c in s2.columns]


def test_projection():
    s = paper_schema()
    p = s.project(["fId"])
    assert p.n_columns == 1
    with pytest.raises(KeyError):
        s.project(["nope"])


def test_decompose_paper_table1():
    """Reproduce paper Table 1 exactly."""
    s = paper_schema()
    entries = [
        {"fId": 6873, "fTracks": [
            {"fEnergy": 25.4, "fIds": [42, 27]},
            {"fEnergy": 32.8, "fIds": [16]},
        ]},
        {"fId": 6874, "fTracks": [
            {"fEnergy": 14.7, "fIds": [21, 8]},
        ]},
    ]
    batch = ColumnBatch.from_entries(s, entries)
    np.testing.assert_array_equal(batch.data[0], [6873, 6874])
    np.testing.assert_array_equal(batch.data[1], [2, 1])          # sizes
    np.testing.assert_allclose(batch.data[2], [25.4, 32.8, 14.7], rtol=1e-6)
    np.testing.assert_array_equal(batch.data[3], [2, 1, 2])       # sizes
    np.testing.assert_array_equal(batch.data[4], [42, 27, 16, 21, 8])
    # on-disk (cluster-relative) offsets per Table 1
    np.testing.assert_array_equal(sizes_to_offsets(batch.data[1]), [2, 3])
    np.testing.assert_array_equal(sizes_to_offsets(batch.data[3]), [2, 3, 5])


# hypothesis: random nested entries survive decompose -> recompose

@st.composite
def entry_strategy(draw):
    return {
        "fId": draw(st.integers(-(2**31), 2**31 - 1)),
        "fTracks": [
            {
                "fEnergy": draw(st.floats(0, 100, width=32)),
                "fIds": draw(st.lists(st.integers(-(2**31), 2**31 - 1), max_size=5)),
            }
            for _ in range(draw(st.integers(0, 4)))
        ],
    }


@given(st.lists(entry_strategy(), max_size=8))
@settings(max_examples=60, deadline=None)
def test_decompose_recompose_roundtrip(entries):
    s = paper_schema()
    batch = ColumnBatch.from_entries(s, entries)
    arrays = []
    for col in s.columns:
        a = batch.data[col.index]
        arrays.append(sizes_to_offsets(a) if col.kind == KIND_OFFSET else a)
    back = recompose_entries(s, arrays, len(entries))
    assert len(back) == len(entries)
    for g, e in zip(back, entries):
        assert g["fId"] == e["fId"]
        assert len(g["fTracks"]) == len(e["fTracks"])
        for gt, et in zip(g["fTracks"], e["fTracks"]):
            assert gt["fIds"] == et["fIds"]
            assert gt["fEnergy"] == pytest.approx(et["fEnergy"], rel=1e-6)


def test_batch_validation_catches_mismatch():
    s = Schema([Collection("v", Leaf("_0", "float32"))])
    with pytest.raises(ValueError):
        ColumnBatch.from_arrays(
            s, 2, {"v": np.array([2, 2]), "v._0": np.zeros(3, np.float32)}
        )


def test_duplicate_field_names_rejected():
    with pytest.raises(ValueError):
        Schema([Leaf("x", "int32"), Leaf("x", "int64")])
