"""Fault injection, retrying I/O, and crash recovery (ISSUE 6).

Covers the three tentpole layers — the :mod:`repro.core.faults` sink, the
engine's :class:`RetryPolicy` chokepoint + degradation paths, and the
envelope/journal format with :mod:`repro.core.recover` — plus the
satellite regressions: idempotent close after a poisoned commit, fsync
errors never swallowed, and the crash matrix (salvage is byte-identical
and maximal at every kill point).
"""

import errno
import os
import random
import struct
import subprocess
import sys
import zlib
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    Collection,
    ColumnBatch,
    FaultInjectingSink,
    FaultSpec,
    Leaf,
    MemorySink,
    ParallelWriter,
    ProcessKilled,
    ReadOptions,
    RecoveryError,
    RNTJReader,
    RetryPolicy,
    Schema,
    SequentialWriter,
    WriteOptions,
    merge_files,
    recover_container,
    scan_container,
)
from repro.core.faults import crashed_file_bytes, memory_sink_from_bytes
from repro.core.ioengine import IOEngine, _ExtentGroup
from repro.core import metadata as md
from repro.core.pages import PageDesc

REPO_ROOT = Path(__file__).resolve().parent.parent

SCHEMA = Schema([
    Leaf("id", "int64"),
    Collection("vals", Leaf("_0", "float32")),
])

# fast deterministic backoff: tests must not sleep for real
FAST = RetryPolicy(max_attempts=6, backoff_base=0.0001, backoff_cap=0.0005)


def make_entries(n, seed=0):
    rng = np.random.default_rng(seed)
    lens = rng.integers(0, 6, size=n)
    return [
        {"id": int(i),
         "vals": [float(v) for v in rng.random(lens[i], dtype=np.float32)]}
        for i in range(n)
    ]


def write_seq(sink, entries, **kw):
    opts = WriteOptions(cluster_bytes=kw.pop("cluster_bytes", 2048),
                        retry_policy=kw.pop("retry_policy", FAST), **kw)
    w = SequentialWriter(SCHEMA, sink, opts)
    for e in entries:
        w.fill(e)
    w.close()
    return w


def read_all(sink):
    r = RNTJReader(sink)
    try:
        return list(r.iter_entries())
    finally:
        r.close()


# ---------------------------------------------------------------------------
# FaultInjectingSink units


def test_fault_sink_transparent_without_rules():
    fs = FaultInjectingSink(MemorySink())
    off = fs.reserve(10)
    fs.pwrite(off, b"0123456789")
    assert fs.pread(off, 10) == b"0123456789"
    assert fs.persisted_bytes == 10
    assert fs.faults.injected == 0


def test_fault_sink_at_call_and_count():
    fs = FaultInjectingSink(MemorySink(), [
        FaultSpec.transient_error(at_call=1, count=1),
    ])
    fs.reserve(30)
    fs.pwrite(0, b"aaaaaaaaaa")                     # call 0: fine
    with pytest.raises(OSError):
        fs.pwrite(10, b"bbbbbbbbbb")                # call 1: EIO, no bytes
    assert fs.persisted_bytes == 10
    fs.pwrite(10, b"bbbbbbbbbb")                    # call 2: rule exhausted
    assert fs.pread(0, 20) == b"aaaaaaaaaabbbbbbbbbb"


def test_fault_sink_offset_window():
    fs = FaultInjectingSink(MemorySink(), [
        FaultSpec(op="write", kind="error", count=-1, at_offset=(100, 200)),
    ])
    fs.reserve(300)
    fs.pwrite(0, b"x" * 50)                          # below the window
    with pytest.raises(OSError):
        fs.pwrite(150, b"y")                         # inside
    with pytest.raises(OSError):
        fs.pwrite(90, b"z" * 20)                     # overlaps the boundary
    fs.pwrite(200, b"w")                             # past it


def test_fault_sink_short_write_persists_prefix():
    fs = FaultInjectingSink(MemorySink(), [FaultSpec.short_write(fraction=0.3)])
    fs.reserve(100)
    with pytest.raises(OSError):
        fs.pwrite(0, b"A" * 100)
    assert fs.persisted_bytes == 30                  # the torn prefix landed
    assert fs.pread(0, 30) == b"A" * 30
    assert fs.faults.short_writes == 1


def test_fault_sink_kill_at_byte_freezes_file():
    fs = FaultInjectingSink(MemorySink(), [FaultSpec.kill_at(25)])
    fs.reserve(100)
    fs.pwrite(0, b"a" * 20)
    with pytest.raises(ProcessKilled):
        fs.pwrite(20, b"b" * 20)                     # crosses byte 25
    assert fs.persisted_bytes == 25                  # exactly 5 of the 20
    assert fs.killed_at == 25 and fs.dead
    with pytest.raises(ProcessKilled):
        fs.pwrite(60, b"later")                      # dead sink stays dead
    with pytest.raises(ProcessKilled):
        fs.fsync()
    fs.close()                                       # teardown always works
    assert crashed_file_bytes(fs)[:25] == b"a" * 20 + b"b" * 5


def test_fault_sink_seeded_schedule_is_deterministic():
    def run(seed):
        fs = FaultInjectingSink(MemorySink(), seed=seed, error_rate=0.3)
        fs.reserve(1000)
        outcomes = []
        for i in range(50):
            try:
                fs.pwrite(i * 10, b"0123456789")
                outcomes.append(1)
            except OSError:
                outcomes.append(0)
        return outcomes
    assert run(7) == run(7)
    assert run(7) != run(8)


def test_fault_sink_latency_and_fsync_rules():
    fs = FaultInjectingSink(MemorySink(), [
        FaultSpec.latency(0.0, op="write", count=2),
        FaultSpec.fsync_error(count=1),
    ])
    fs.reserve(20)
    fs.pwrite(0, b"x" * 10)
    fs.pwrite(10, b"y" * 10)
    assert fs.faults.latencies == 2
    with pytest.raises(OSError):
        fs.fsync()
    fs.fsync()
    assert fs.faults.fsync_errors == 1


# ---------------------------------------------------------------------------
# RetryPolicy units


def test_retry_policy_retryable_classification():
    pol = RetryPolicy()
    assert pol.retryable(OSError(errno.EIO, "io"))
    assert pol.retryable(OSError(errno.ENOSPC, "nospc"))
    assert not pol.retryable(OSError(errno.EBADF, "badf"))
    assert not pol.retryable(ValueError("nope"))
    assert not pol.retryable(ProcessKilled("dead"))


def test_retry_policy_backoff_grows_and_caps():
    pol = RetryPolicy(backoff_base=0.01, backoff_cap=0.05, jitter=False)
    rng = random.Random(0)
    delays = [pol.backoff(a, rng) for a in range(1, 8)]
    assert delays[0] == pytest.approx(0.01)
    assert delays[1] == pytest.approx(0.02)
    assert all(d <= 0.05 + 1e-9 for d in delays)
    assert delays[-1] == pytest.approx(0.05)
    jit = RetryPolicy(backoff_base=0.01, backoff_cap=0.05, jitter=True)
    for a in range(1, 8):
        d = jit.backoff(a, random.Random(1))
        assert 0 < d <= 0.05 * 1.5


# ---------------------------------------------------------------------------
# engine retry paths


def test_transient_errors_retried_zero_loss():
    entries = make_entries(400)
    fs = FaultInjectingSink(MemorySink(), [
        FaultSpec.transient_error(count=3),
        FaultSpec.short_write(at_call=5),
    ])
    w = write_seq(fs, entries)
    d = w.stats.as_dict()
    assert d["io_retries"] >= 4
    assert d["io_giveups"] == 0
    assert read_all(fs.inner) == entries


def test_permanent_error_poisons_and_counts_giveup():
    entries = make_entries(400)
    fs = FaultInjectingSink(MemorySink(), [
        FaultSpec(op="write", kind="error", err=errno.EIO, count=-1,
                  at_offset=(2000, 1 << 62)),
    ])
    w = SequentialWriter(SCHEMA, fs,
                         WriteOptions(cluster_bytes=2048, retry_policy=FAST))
    with pytest.raises(OSError):
        for e in entries:
            w.fill(e)
        w.close()
    # satellite 1: first close surfaces the poison, any further close is
    # an exception-safe no-op
    with pytest.raises(RuntimeError, match="NOT finalized"):
        w.close()
    w.close()
    w.close()
    d = w.stats.as_dict()
    assert d["io_giveups"] >= 1
    assert d["io_retries"] >= FAST.max_attempts - 1
    # nothing was finalized: the torn file has no valid footer
    with pytest.raises(IOError):
        RNTJReader(memory_sink_from_bytes(crashed_file_bytes(fs)))


def test_non_retryable_errno_fails_fast():
    entries = make_entries(200)
    fs = FaultInjectingSink(MemorySink(), [
        FaultSpec(op="write", kind="error", err=errno.EBADF, count=-1,
                  at_offset=(2000, 1 << 62)),
    ])
    w = SequentialWriter(SCHEMA, fs,
                         WriteOptions(cluster_bytes=2048, retry_policy=FAST))
    with pytest.raises(OSError):
        for e in entries:
            w.fill(e)
        w.close()
    try:
        w.close()
    except RuntimeError:
        pass
    d = w.stats.as_dict()
    assert d["io_retries"] == 0          # EBADF is not in retryable_errnos


def test_retry_deadline_bounds_attempts():
    pol = RetryPolicy(max_attempts=1000, backoff_base=0.05, backoff_cap=0.05,
                      jitter=False, deadline=0.12)
    entries = make_entries(100)
    fs = FaultInjectingSink(MemorySink(), [
        FaultSpec(op="write", kind="error", err=errno.EIO, count=-1,
                  at_offset=(2000, 1 << 62)),
    ])
    w = SequentialWriter(SCHEMA, fs,
                         WriteOptions(cluster_bytes=2048, retry_policy=pol))
    with pytest.raises((OSError, RuntimeError)):
        for e in entries:
            w.fill(e)
        w.close()
    try:
        w.close()
    except RuntimeError:
        pass
    d = w.stats.as_dict()
    assert d["io_giveups"] >= 1
    assert d["io_retries"] <= 6          # the deadline cut the 1000 attempts


def test_write_behind_transient_errors_retried():
    entries = make_entries(400)
    fs = FaultInjectingSink(MemorySink(), [FaultSpec.transient_error(count=4)])
    opts = WriteOptions(cluster_bytes=1024, retry_policy=FAST,
                        io_inflight_bytes=1 << 20, io_ring=0)
    w = ParallelWriter(SCHEMA, fs, opts)
    ctx = w.create_fill_context()
    for e in entries:
        ctx.fill(e)
    ctx.close()
    w.close()
    d = w.stats.as_dict()
    assert d["io_retries"] >= 1
    assert read_all(fs.inner) == entries


def test_striped_failure_degrades_to_monolithic():
    entries = make_entries(600)
    fs = FaultInjectingSink(MemorySink(), [
        FaultSpec.transient_error(err=errno.EBADF, at_call=4, count=1),
    ])
    w = write_seq(fs, entries, cluster_bytes=16384,
                  io_stripe_bytes=2048, io_workers=2)
    d = w.stats.as_dict()
    assert d["io_stripe_fallbacks"] >= 1
    assert read_all(fs.inner) == entries


def test_fsync_transient_retried_permanent_poisons():
    entries = make_entries(300)
    # transient: retried, run completes, zero loss (satellite 2)
    fs = FaultInjectingSink(MemorySink(), [FaultSpec.fsync_error(count=2)])
    w = write_seq(fs, entries, fsync_policy="every_cluster")
    assert w.stats.as_dict()["io_retries"] >= 2
    assert read_all(fs.inner) == entries

    # permanent: mid-run fsync failure must NOT be swallowed
    fs = FaultInjectingSink(MemorySink(), [FaultSpec.fsync_error(count=-1)])
    w = SequentialWriter(SCHEMA, fs, WriteOptions(
        cluster_bytes=2048, retry_policy=FAST, fsync_policy="every_cluster"))
    with pytest.raises((OSError, RuntimeError)):
        for e in entries:
            w.fill(e)
        w.close()
    try:
        w.close()
    except RuntimeError:
        pass
    d = w.stats.as_dict()
    assert d["io_fsync_failures"] >= 1


def test_ring_fallback_executes_live_ops():
    """UringRing._fallback_execute: a broken submission ring runs its
    in-flight ops synchronously through the engine instead of failing
    them (unit-level: the native ring needs liburing + a real fd)."""
    from repro.core.ioengine import UringRing, _RingOp

    sink = MemorySink()
    engine = IOEngine(sink, workers=0, inflight_bytes=1 << 20,
                      retry=FAST, ring="emulated")
    try:
        ring = UringRing.__new__(UringRing)
        ring._engine = engine
        ring._degraded = False
        ring._live = {}
        payload = b"R" * 512
        off = sink.reserve(len(payload))
        # mirror the submit path's accounting so _job_end balances
        with engine._cv:
            engine._inflight += len(payload)
            engine._pending += 1
        group = _ExtentGroup(1, len(payload), None, False)
        op = _RingOp(group, off, [payload], len(payload))
        ring._live[1] = (op, None, None, engine._job_begin())
        ring._fallback_execute(OSError(errno.ENOMEM, "submit broke"))
        assert ring._degraded
        assert not ring._live
        assert engine.ring_fallbacks == 1
        assert sink.pread(off, len(payload)) == payload
        engine.drain()                   # the group completed: no hang
        assert engine.error is None
    finally:
        engine.close()


# ---------------------------------------------------------------------------
# journal format


def test_cluster_envelope_roundtrip_and_corruption():
    env = md.build_cluster_envelope(seq=7, payload_len=1234, desc_crc=0xABCD)
    assert len(env) == md.CLUSTER_ENV_SIZE
    d = md.parse_cluster_envelope(env)
    assert (d["seq"], d["payload_len"], d["desc_crc"]) == (7, 1234, 0xABCD)
    bad = bytearray(env)
    bad[9] ^= 0xFF
    with pytest.raises(IOError):
        md.parse_cluster_envelope(bytes(bad))
    with pytest.raises(IOError):
        md.parse_cluster_envelope(b"XXXX" + env[4:])


def _pages(offsets, base_col=0):
    return [PageDesc(column=base_col, n_elements=10, offset=o, size=40,
                     uncompressed_size=40, checksum=123, codec=0)
            for o in offsets]


def test_journal_record_roundtrip_buffered_offsets():
    pages = _pages([0, 40, 80])
    body = md.build_journal_body([10, 20], pages)
    rec, crc = md.finish_journal_record(
        seq=3, flags=md.JREC_BUFFERED, cluster_off=5000, cluster_size=120,
        first_entry=100, n_entries=10, n_columns=2, body=body)
    assert len(rec) == md.journal_record_size(2, 3)
    jr, end = md.parse_journal_record(rec)
    assert end == len(rec)
    assert jr.seq == 3 and jr.buffered and jr.crc == crc
    assert jr.n_elements == [10, 20]
    # cluster-relative offsets resolved to absolute
    assert [p.offset for p in jr.pages] == [5000, 5040, 5080]


def test_journal_record_unbuffered_keeps_absolute_offsets():
    pages = _pages([9000, 9040])
    body = md.build_journal_body([20], pages)
    rec, _ = md.finish_journal_record(0, 0, 0, 0, 0, 5, 1, body)
    jr, _ = md.parse_journal_record(rec)
    assert not jr.buffered
    assert [p.offset for p in jr.pages] == [9000, 9040]


def test_journal_record_corruption_detected():
    body = md.build_journal_body([10], _pages([0]))
    rec, _ = md.finish_journal_record(1, md.JREC_BUFFERED, 100, 40, 0, 5, 1,
                                      body)
    bad = bytearray(rec)
    bad[20] ^= 0x01
    with pytest.raises(IOError):
        md.parse_journal_record(bytes(bad))
    with pytest.raises(IOError):
        md.parse_journal_record(rec[: len(rec) - 3])   # truncated


def test_v1_anchor_still_parses():
    body = md._ANCHOR.pack(md.MAGIC, 1, 0, 64, 100, 32, 10, 2, 0)
    crc = zlib.crc32(body[:-8])
    anchor = md._ANCHOR.pack(md.MAGIC, 1, 0, 64, 100, 32, 10, 2, crc)
    d = md.parse_anchor(anchor)
    assert d["n_entries"] == 10
    bad = md._ANCHOR.pack(md.MAGIC, 9, 0, 64, 100, 32, 10, 2, crc)
    with pytest.raises(IOError):
        md.parse_anchor(bad)


def test_journal_framing_is_invisible_to_footer_readers():
    """byte_offset/byte_size point at the payload, so a journaled and an
    unjournaled file decode identically (framing = invisible padding)."""
    entries = make_entries(300)
    with_j, without_j = MemorySink(), MemorySink()
    write_seq(with_j, entries, retry_policy=None)
    write_seq(without_j, entries, retry_policy=None, journal=False)
    assert with_j.size > without_j.size         # framing occupies bytes
    assert read_all(with_j) == read_all(without_j) == entries


# ---------------------------------------------------------------------------
# recovery


def torn_copy(sink, cut):
    """The first ``cut`` bytes of a written file, as recovery sees them."""
    return memory_sink_from_bytes(bytes(sink.buf[:cut]))


def test_scan_complete_file_matches_footer():
    entries = make_entries(500)
    sink = MemorySink()
    write_seq(sink, entries)
    r = RNTJReader(sink)
    footer_clusters = [(cm.first_entry, cm.n_entries, cm.byte_offset,
                        cm.byte_size) for cm in r.clusters]
    r.close()
    _schema, _opts, clusters, rep = scan_container(sink)
    assert rep.entries_salvaged == len(entries)
    assert [(cm.first_entry, cm.n_entries, cm.byte_offset, cm.byte_size)
            for cm in clusters] == footer_clusters
    assert not rep.clusters_dropped


def test_recover_truncated_file_and_read_back():
    entries = make_entries(500)
    sink = MemorySink()
    write_seq(sink, entries)
    ms = torn_copy(sink, int(sink.size * 0.6))
    rep = recover_container(ms)
    assert rep.rebuilt and rep.clusters_salvaged > 0
    got = read_all(ms)
    assert got == entries[: len(got)]
    assert len(got) == rep.entries_salvaged > 0


def test_recover_valid_file_is_a_noop():
    entries = make_entries(200)
    sink = MemorySink()
    write_seq(sink, entries)
    size_before = sink.size
    rep = recover_container(sink)
    assert rep.footer_valid and not rep.rebuilt
    assert sink.size == size_before


def test_recover_force_rebuilds_valid_file():
    entries = make_entries(200)
    sink = MemorySink()
    write_seq(sink, entries)
    rep = recover_container(sink, force=True)
    assert rep.rebuilt
    assert read_all(sink) == entries


def test_recover_dry_run_writes_nothing():
    entries = make_entries(300)
    sink = MemorySink()
    write_seq(sink, entries)
    ms = torn_copy(sink, int(sink.size * 0.5))
    size_before = ms.size
    rep = recover_container(ms, dry_run=True)
    assert rep.clusters_salvaged > 0 and not rep.rebuilt
    assert ms.size == size_before
    with pytest.raises(IOError):
        RNTJReader(memory_sink_from_bytes(bytes(ms.buf[:ms.size])))


def test_recover_drops_cluster_with_corrupt_payload():
    entries = make_entries(500)
    sink = MemorySink()
    write_seq(sink, entries)
    _s, _o, clusters, _rep = scan_container(sink)
    assert len(clusters) >= 3
    victim = clusters[1]
    data = bytearray(bytes(sink.buf[: sink.size]))
    data[victim.byte_offset + 5] ^= 0xFF            # flip a payload byte
    ms = memory_sink_from_bytes(bytes(data))
    rep = recover_container(ms, force=True)
    assert any(d["seq"] == 1 for d in rep.clusters_dropped)
    assert rep.clusters_salvaged == len(clusters) - 1
    # surviving entries read back identical; the dropped cluster's range
    # is renumbered away (entry bytes never lie, ranges may shift)
    got = read_all(ms)
    survivors = []
    for i, cm in enumerate(clusters):
        if i != 1:
            survivors.extend(
                entries[cm.first_entry : cm.first_entry + cm.n_entries])
    assert got == survivors


def test_recover_unbuffered_file():
    entries = make_entries(400)
    sink = MemorySink()
    write_seq(sink, entries, buffered=False)
    ms = torn_copy(sink, int(sink.size * 0.7))
    rep = recover_container(ms)
    assert rep.clusters_salvaged > 0
    got = read_all(ms)
    assert got == entries[: len(got)]


def test_recover_merged_file():
    """Merge raw-copies clusters through _commit_raw_cluster: the merged
    output carries the same envelope/journal framing and salvages."""
    a, b = MemorySink(), MemorySink()
    ents_a, ents_b = make_entries(200, seed=1), make_entries(200, seed=2)
    write_seq(a, ents_a, retry_policy=None)
    write_seq(b, ents_b, retry_policy=None)
    out = MemorySink()
    merge_files([a, b], out, options=WriteOptions(cluster_bytes=2048))
    all_entries = ents_a + ents_b
    assert read_all(out) == all_entries
    ms = torn_copy(out, int(out.size * 0.55))
    rep = recover_container(ms)
    assert rep.clusters_salvaged > 0
    got = read_all(ms)
    assert got == all_entries[: len(got)]


def test_recover_header_torn_is_unrecoverable():
    entries = make_entries(100)
    sink = MemorySink()
    write_seq(sink, entries)
    with pytest.raises(RecoveryError):
        recover_container(torn_copy(sink, 40))
    with pytest.raises(RecoveryError):
        recover_container(memory_sink_from_bytes(b"not an rntj file at all"))


def test_recover_file_paths_and_output_copy(tmp_path):
    entries = make_entries(300)
    sink = MemorySink()
    write_seq(sink, entries)
    cut = int(sink.size * 0.6)
    torn = tmp_path / "torn.rntj"
    torn.write_bytes(bytes(sink.buf[:cut]))

    out = tmp_path / "recovered.rntj"
    rep = recover_container(str(torn), output=str(out))
    assert rep.rebuilt
    assert torn.stat().st_size == cut               # source untouched
    r = RNTJReader(str(out))
    got = list(r.iter_entries())
    r.close()
    assert got == entries[: len(got)] and got

    rep2 = recover_container(str(torn))             # now in place
    assert rep2.rebuilt
    r = RNTJReader(str(torn))
    assert list(r.iter_entries()) == got
    r.close()


def test_tolerant_reader_salvages_torn_file():
    entries = make_entries(400)
    sink = MemorySink()
    write_seq(sink, entries)
    ms = torn_copy(sink, int(sink.size * 0.6))
    with pytest.raises(IOError):
        RNTJReader(ms)
    r = RNTJReader(ms, options=ReadOptions(tolerant=True))
    assert r.salvage is not None and r.salvage.clusters_salvaged > 0
    got = list(r.iter_entries())
    r.close()
    assert got == entries[: len(got)] and got
    # a healthy file opened tolerant reports no salvage
    r = RNTJReader(sink, options=ReadOptions(tolerant=True))
    assert r.salvage is None
    r.close()


# ---------------------------------------------------------------------------
# the crash matrix (satellite 3)


def _journal_ends(sink):
    """Per-cluster journal-record end offsets of a cleanly written file,
    in commit order — cluster seq is fully durable iff the file reaches
    its record's end."""
    ends = {}
    _m, _t, plen = md._ENV_HDR.unpack(sink.pread(0, md._ENV_HDR.size))
    pos = md._ENV_HDR.size + plen + 4
    size = sink.size
    while pos + 4 <= size:
        magic = bytes(sink.pread(pos, 4))
        if magic == md.CLUSTER_ENV_MAGIC:
            env = md.parse_cluster_envelope(sink.pread(pos, md.CLUSTER_ENV_SIZE))
            pos += md.CLUSTER_ENV_SIZE + env["payload_len"]
        elif magic == md.JOURNAL_MAGIC:
            jr, end_rel = md.parse_journal_record(
                sink.pread(pos, size - pos), 0)
            ends[jr.seq] = pos + end_rel
            pos = ends[jr.seq]
        elif magic == md._ENV_MAGIC:
            _m2, _t2, plen2 = md._ENV_HDR.unpack(
                sink.pread(pos, md._ENV_HDR.size))
            pos += md._ENV_HDR.size + plen2 + 4
        elif magic == md.MAGIC:
            pos += md.ANCHOR_SIZE
        else:
            raise AssertionError(f"unexpected bytes at {pos} in clean file")
    return ends


def test_crash_matrix_salvage_is_byte_identical_and_maximal():
    entries = make_entries(700, seed=3)
    ref = MemorySink()
    write_seq(ref, entries, cluster_bytes=1024, retry_policy=None)
    size = ref.size
    ends = _journal_ends(ref)
    r = RNTJReader(ref)
    ranges = {i: (cm.first_entry, cm.n_entries)
              for i, cm in enumerate(r.clusters)}
    r.close()
    assert len(ranges) >= 8, "workload too small for a meaningful matrix"

    hdr_end = min(cm_end for cm_end in ends.values())
    kill_points = sorted(set(
        [int(k) for k in np.linspace(600, size + 128, 14)]
        + [hdr_end - 4, hdr_end, hdr_end + 1]        # around the 1st record
        + [size - 80, size - 8]                      # inside footer/anchor
    ))
    assert len(kill_points) >= 18

    for K in kill_points:
        fs = FaultInjectingSink(MemorySink(), [FaultSpec.kill_at(K)])
        crashed = False
        try:
            write_seq(fs, entries, cluster_bytes=1024, retry_policy=None)
        except (ProcessKilled, OSError, RuntimeError):
            crashed = True
        data = crashed_file_bytes(fs)
        # single producer, no write-behind: bytes persisted before the
        # kill are exactly the reference file's prefix; anything past the
        # kill byte is a reserved-but-unwritten (all-zero) sparse tail
        kbyte = fs.killed_at if crashed and fs.killed_at is not None else len(data)
        if crashed:
            assert data[:kbyte] == bytes(ref.buf[:kbyte]), f"K={K}: divergence"
        expected = sum(1 for e in ends.values() if e <= kbyte)
        ms = memory_sink_from_bytes(data)
        try:
            rep = recover_container(ms)
        except RecoveryError:
            assert expected == 0, (
                f"K={K}: unrecoverable but {expected} clusters were durable")
            continue
        if rep.footer_valid:                         # kill never fired
            assert not crashed and read_all(ms) == entries
            continue
        assert rep.clusters_salvaged == expected, (
            f"K={K}: salvaged {rep.clusters_salvaged}, journal says "
            f"{expected} were fully committed")
        assert not rep.clusters_dropped, f"K={K}: dropped {rep.clusters_dropped}"
        got = read_all(ms)
        assert got == entries[: len(got)], f"K={K}: salvage not identical"
        assert len(got) == sum(
            ranges[s][1] for s in range(rep.clusters_salvaged))


def test_crash_during_parallel_write_recovers_committed_prefix():
    """Write-behind + kill: every salvaged cluster must read back
    byte-identical (the salvage count is whatever was durable)."""
    entries = make_entries(600, seed=5)
    fs = FaultInjectingSink(MemorySink(), [FaultSpec.kill_at(6000)])
    opts = WriteOptions(cluster_bytes=1024, io_inflight_bytes=1 << 20,
                        io_ring=0)
    w = ParallelWriter(SCHEMA, fs, opts)
    try:
        ctx = w.create_fill_context()
        for e in entries:
            ctx.fill(e)
        ctx.close()
        w.close()
    except (ProcessKilled, OSError, RuntimeError):
        pass
    try:
        w.close()
    except (ProcessKilled, OSError, RuntimeError):
        pass
    data = crashed_file_bytes(fs)
    ms = memory_sink_from_bytes(data)
    try:
        rep = recover_container(ms)
    except RecoveryError:
        return                                       # killed before header
    got = read_all(ms)
    assert len(got) == rep.entries_salvaged
    assert got == entries[: len(got)]                # sequential fill order


# ---------------------------------------------------------------------------
# CLI smoke


def test_recover_cli(tmp_path):
    entries = make_entries(300)
    sink = MemorySink()
    write_seq(sink, entries)
    torn = tmp_path / "torn.rntj"
    torn.write_bytes(bytes(sink.buf[: int(sink.size * 0.6)]))
    env = dict(os.environ, PYTHONPATH=str(REPO_ROOT / "src"))

    out = subprocess.run(
        [sys.executable, str(REPO_ROOT / "tools" / "recover.py"),
         str(torn), "--dry-run", "--json"],
        capture_output=True, text=True, env=env)
    assert out.returncode == 0, out.stderr
    assert '"rebuilt": false' in out.stdout

    out = subprocess.run(
        [sys.executable, str(REPO_ROOT / "tools" / "recover.py"), str(torn)],
        capture_output=True, text=True, env=env)
    assert out.returncode == 0, out.stderr
    assert "salvaged" in out.stdout
    r = RNTJReader(str(torn))
    got = list(r.iter_entries())
    r.close()
    assert got == entries[: len(got)] and got


def test_chaos_cli_single_scenario():
    out = subprocess.run(
        [sys.executable, str(REPO_ROOT / "tools" / "chaos.py"),
         "--scenario", "transient", "--entries", "300"],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "ok   transient" in out.stdout


# ---------------------------------------------------------------------------
# RetryPolicy x fault interaction on the fsync path (ISSUE 8 satellite):
# a footer must never cover clusters whose fsync did not succeed


def test_fsync_transient_failure_retries_then_seals(tmp_path):
    """fsync fails transiently under a RetryPolicy: the engine retries to
    success and close() seals a VALID footer (the data really is synced)."""
    fs = FaultInjectingSink(MemorySink(), [FaultSpec.fsync_error(count=2)])
    w = SequentialWriter(SCHEMA, fs, WriteOptions(
        cluster_bytes=2048, retry_policy=FAST, fsync_policy="on_close"))
    entries = make_entries(200, 0)
    for e in entries:
        w.fill(e)
    w.close()  # the close-time fsync absorbs both injected failures
    d = w.stats.as_dict()
    assert d["io_retries"] >= 2 and d["io_giveups"] == 0
    assert fs.faults.fsync_errors == 2
    rep = recover_container(fs.inner, dry_run=True)
    assert rep.footer_valid
    verify = RNTJReader(fs.inner)
    assert list(verify.iter_entries()) == entries
    verify.close()


def test_fsync_permanent_failure_never_seals_footer(tmp_path):
    """fsync fails permanently: retries exhaust, close() poisons — and the
    file must NOT end in a valid footer (its clusters were never synced).
    The journal still makes every committed cluster salvageable."""
    fs = FaultInjectingSink(MemorySink(), [FaultSpec.fsync_error(count=-1)])
    w = SequentialWriter(SCHEMA, fs, WriteOptions(
        cluster_bytes=2048, retry_policy=FAST, fsync_policy="every_cluster"))
    entries = make_entries(200, 0)
    with pytest.raises((OSError, RuntimeError)):
        for e in entries:
            w.fill(e)
        w.close()
    with pytest.raises((OSError, RuntimeError)):
        w.close()  # surfaces the latched poison (and merges engine stats)
    d = w.stats.as_dict()
    assert d["io_fsync_failures"] >= 1 and d["io_giveups"] >= 1

    rep = recover_container(fs.inner, dry_run=True)
    assert not rep.footer_valid, (
        "footer sealed over clusters whose fsync never succeeded")
    # the journaled prefix is still salvageable after the crash
    ms = memory_sink_from_bytes(crashed_file_bytes(fs))
    rep = recover_container(ms)
    assert rep.rebuilt
    r = RNTJReader(ms)
    got = list(r.iter_entries())
    r.close()
    assert got == entries[: len(got)]


def test_mp_participant_fsync_failure_withholds_done(tmp_path):
    """Multi-writer flavor: a participant whose finalize-fsync fails must
    not report DONE — the coordinator fences it and page-verifies its
    clusters instead of trusting the missing durability handshake."""
    from repro.core import (FencedError, MultiWriterCoordinator,
                            join_container, open_sink)
    from repro.core.extents import ExtentLog

    path = str(tmp_path / "mp.rntj")
    opts = WriteOptions(cluster_bytes=1024, retry_policy=FAST,
                        lease_interval=0.3, rendezvous_timeout=5.0,
                        mpw_log_fsync=False)
    coord = MultiWriterCoordinator(SCHEMA, path, opts)
    fs = FaultInjectingSink(open_sink(path, create=False),
                            [FaultSpec.fsync_error(count=-1)])
    w = join_container(path, schema=SCHEMA, options=opts, sink=fs)
    ctx = w.create_fill_context()
    entries = make_entries(60, 0)
    for e in entries:
        ctx.fill(e)
    with pytest.raises((OSError, RuntimeError)):
        ctx.close()
        w.close()

    log = ExtentLog(ExtentLog.sidecar_path(path), fsync=False)
    st = log.snapshot()
    log.close()
    assert not st.writers[w.writer_id].done, (
        "DONE reported despite a failed durability fsync")

    report = coord.seal(expect_writers=1)
    coord.close()
    assert report["fenced"] == [w.writer_id]
    r = RNTJReader(path)
    got = list(r.iter_entries())
    r.close()
    assert got == entries[: len(got)] and got, "committed clusters lost"
