"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles.

Sweeps shapes and dtypes per kernel; also cross-checks the encoder kernels
against the numpy host implementations in repro.core.encoding (the writer's
actual serialization path must be bit-identical to the TPU kernels).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import encoding as E
from repro.kernels import ref
from repro.kernels.byteshuffle import byteshuffle
from repro.kernels.decode_attention import decode_attention
from repro.kernels.delta_zigzag import delta_zigzag
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba2_ssd import mamba2_ssd
from repro.kernels.offsets_scan import offsets_scan
from repro.kernels.rwkv6_scan import rwkv6_scan

jax.config.update("jax_platform_name", "cpu")

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# columnar encoder kernels


@pytest.mark.parametrize("n", [1, 7, 128, 1000, 5000])
@pytest.mark.parametrize("block", [128, 4096])
def test_offsets_scan_matches_ref_and_host(n, block):
    lengths = jnp.asarray(RNG.poisson(5, n), dtype=jnp.int32)
    out = offsets_scan(lengths, block=block, interpret=True)
    np.testing.assert_array_equal(out, ref.offsets_scan_ref(lengths))
    host = E.sizes_to_offsets(np.asarray(lengths))
    np.testing.assert_array_equal(np.asarray(out, dtype=np.int64), host)


@pytest.mark.parametrize("n", [1, 64, 999, 4096])
def test_delta_zigzag_matches_ref_and_host(n):
    sizes = RNG.poisson(5, n)
    offs32 = np.cumsum(sizes).astype(np.int32)
    out = delta_zigzag(jnp.asarray(offs32), block=256, interpret=True)
    np.testing.assert_array_equal(out, ref.delta_zigzag_ref(jnp.asarray(offs32)))
    # host path: zigzag(delta(x)) on int64 then downcast pattern
    host = E.zigzag_encode(E.delta_encode(offs32.astype(np.int64)))
    np.testing.assert_array_equal(
        np.asarray(out, dtype=np.uint64), host & np.uint64(0xFFFFFFFF)
    )


@pytest.mark.parametrize("itemsize", [2, 4, 8])
@pytest.mark.parametrize("n", [1, 100, 2048, 6000])
def test_byteshuffle_matches_ref_and_host(itemsize, n):
    planes = jnp.asarray(RNG.integers(0, 256, (n, itemsize)), dtype=jnp.uint8)
    out = byteshuffle(planes, block=512, interpret=True)
    np.testing.assert_array_equal(out, ref.byteshuffle_ref(planes))
    # host split_encode of an array with this itemsize
    arr = np.frombuffer(np.asarray(planes).tobytes(), dtype=f"<u{itemsize}")
    host = E.split_encode(arr)
    assert np.asarray(out).tobytes() == host


# ---------------------------------------------------------------------------
# flash attention


@pytest.mark.parametrize("b,h,g,sq,sk,d", [
    (1, 4, 4, 128, 128, 64),      # MHA square
    (2, 8, 2, 256, 256, 64),      # GQA
    (1, 8, 1, 128, 128, 128),     # MQA
    (1, 4, 4, 64, 256, 64),       # decode-ish: short q, long kv
    (1, 4, 2, 200, 200, 80),      # non-divisible by blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(b, h, g, sq, sk, d, dtype):
    q = jnp.asarray(RNG.normal(0, 1, (b, h, sq, d)), dtype=dtype)
    k = jnp.asarray(RNG.normal(0, 1, (b, g, sk, d)), dtype=dtype)
    v = jnp.asarray(RNG.normal(0, 1, (b, g, sk, d)), dtype=dtype)
    out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                          interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [64, 128, 1024])
def test_flash_attention_sliding_window(window):
    b, h, g, s, d = 1, 4, 2, 256, 64
    q = jnp.asarray(RNG.normal(0, 1, (b, h, s, d)), dtype=jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, g, s, d)), dtype=jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, g, s, d)), dtype=jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_flash_attention_matches_naive_softmax():
    """Independent oracle: hand-rolled masked softmax."""
    b, h, s, d = 1, 2, 64, 32
    q = jnp.asarray(RNG.normal(0, 1, (b, h, s, d)), dtype=jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, h, s, d)), dtype=jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, h, s, d)), dtype=jnp.float32)
    logits = np.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(d)
    mask = np.tril(np.ones((s, s), bool))
    logits = np.where(mask, logits, -np.inf)
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    expect = np.einsum("bhqk,bhkd->bhqd", p, v)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                          interpret=True)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# decode attention


@pytest.mark.parametrize("b,h,g,s,d", [
    (2, 4, 4, 512, 64),
    (2, 8, 2, 1024, 64),
    (1, 8, 1, 777, 128),     # MQA, ragged length
])
def test_decode_attention_full(b, h, g, s, d):
    q = jnp.asarray(RNG.normal(0, 1, (b, h, d)), dtype=jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, g, s, d)), dtype=jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, g, s, d)), dtype=jnp.float32)
    out = decode_attention(q, k, v, block_k=256, interpret=True)
    expect = ref.decode_attention_ref(q, k, v)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_decode_attention_lengths_and_window():
    b, h, g, s, d = 3, 4, 2, 640, 64
    q = jnp.asarray(RNG.normal(0, 1, (b, h, d)), dtype=jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, g, s, d)), dtype=jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, g, s, d)), dtype=jnp.float32)
    length = jnp.asarray([100, 640, 333], dtype=jnp.int32)
    for window in (None, 64):
        out = decode_attention(q, k, v, length=length, window=window,
                               block_k=128, interpret=True)
        expect = ref.decode_attention_ref(q, k, v, length=length, window=window)
        np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# rwkv6


@pytest.mark.parametrize("b,h,t,dk,dv,chunk", [
    (1, 2, 64, 32, 32, 16),
    (2, 2, 128, 64, 64, 32),
    (1, 4, 96, 48, 64, 32),
])
def test_rwkv6_scan_vs_ref(b, h, t, dk, dv, chunk):
    r = jnp.asarray(RNG.normal(0, 1, (b, h, t, dk)), dtype=jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, h, t, dk)), dtype=jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, h, t, dv)), dtype=jnp.float32)
    # realistic rwkv6 decay range: w = exp(-exp(x)), x in [-4, 1]
    w = jnp.exp(-jnp.exp(jnp.asarray(RNG.uniform(-4, 1, (b, h, t, dk)),
                                     dtype=jnp.float32)))
    u = jnp.asarray(RNG.normal(0, 1, (h, dk)), dtype=jnp.float32)
    out, state = rwkv6_scan(r, k, v, w, u, chunk=chunk, interpret=True)
    expect, state_ref = ref.rwkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(out, expect, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(state, state_ref, atol=5e-4, rtol=5e-4)


def test_rwkv6_strong_decay_stable():
    """Near-zero decay must not overflow (the naive factorization does)."""
    b, h, t, dk, dv = 1, 1, 64, 16, 16
    r = jnp.ones((b, h, t, dk)) * 0.1
    k = jnp.ones((b, h, t, dk)) * 0.1
    v = jnp.ones((b, h, t, dv))
    w = jnp.full((b, h, t, dk), 1e-6)       # extremely strong decay
    u = jnp.zeros((h, dk))
    out, _ = rwkv6_scan(r, k, v, w, u, chunk=32, interpret=True)
    expect, _ = ref.rwkv6_ref(r, k, v, w, u)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(out, expect, atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# mamba2 SSD


@pytest.mark.parametrize("b,h,t,p,n,chunk", [
    (1, 2, 128, 32, 16, 32),
    (2, 4, 128, 64, 64, 64),
    (1, 2, 256, 64, 32, 64),
])
def test_mamba2_ssd_vs_ref(b, h, t, p, n, chunk):
    x = jnp.asarray(RNG.normal(0, 1, (b, h, t, p)), dtype=jnp.float32)
    log_a = -jnp.exp(jnp.asarray(RNG.uniform(-3, 0.5, (b, h, t)), dtype=jnp.float32))
    Bm = jnp.asarray(RNG.normal(0, 1, (b, t, n)), dtype=jnp.float32)
    Cm = jnp.asarray(RNG.normal(0, 1, (b, t, n)), dtype=jnp.float32)
    out, state = mamba2_ssd(x, log_a, Bm, Cm, chunk=chunk, interpret=True)
    D0 = jnp.zeros((h,), jnp.float32)
    expect, state_ref = ref.mamba2_ref(x, log_a, Bm, Cm, D0)
    np.testing.assert_allclose(out, expect, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(state, state_ref, atol=1e-3, rtol=1e-3)


def test_mamba2_state_continuity():
    """Chunked kernel must equal ref across chunk boundaries (state carry)."""
    b, h, t, p, n = 1, 1, 192, 16, 8
    x = jnp.asarray(RNG.normal(0, 1, (b, h, t, p)), dtype=jnp.float32)
    log_a = jnp.full((b, h, t), -0.05)
    Bm = jnp.asarray(RNG.normal(0, 1, (b, t, n)), dtype=jnp.float32)
    Cm = jnp.asarray(RNG.normal(0, 1, (b, t, n)), dtype=jnp.float32)
    out_c64, _ = mamba2_ssd(x, log_a, Bm, Cm, chunk=64, interpret=True)
    out_c32, _ = mamba2_ssd(x, log_a, Bm, Cm, chunk=32, interpret=True)
    np.testing.assert_allclose(out_c64, out_c32, atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# hypothesis: offsets kernel == host encoder over random size distributions


@given(st.lists(st.integers(0, 1000), min_size=1, max_size=500))
@settings(max_examples=20, deadline=None)
def test_offsets_scan_property(sizes):
    lengths = jnp.asarray(sizes, dtype=jnp.int32)
    out = offsets_scan(lengths, block=64, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out, np.int64), E.sizes_to_offsets(np.asarray(sizes))
    )


# ---------------------------------------------------------------------------
# chunked (online-softmax) attention — the §Perf pure-JAX flash variant


@pytest.mark.parametrize("b,h,g,sq,sk,d,window", [
    (1, 4, 2, 128, 128, 32, None),
    (2, 2, 1, 64, 192, 16, None),
    (1, 2, 2, 100, 100, 32, 48),
    (1, 8, 8, 256, 256, 64, None),
])
def test_flash_chunked_matches_ref(b, h, g, sq, sk, d, window):
    q = jnp.asarray(RNG.normal(0, 1, (b, h, sq, d)), dtype=jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, g, sk, d)), dtype=jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, g, sk, d)), dtype=jnp.float32)
    a = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    c = ref.flash_attention_chunked(q, k, v, causal=True, window=window,
                                    block=32)
    np.testing.assert_allclose(a, c, atol=3e-5, rtol=3e-5)


def test_flash_chunked_never_materializes_full_scores():
    """Structural check: peak temp of chunked << ref for long sequences."""
    b, h, s, d, blk = 1, 2, 2048, 32, 256
    q = jnp.zeros((b, h, s, d), jnp.float32)
    k = jnp.zeros((b, h, s, d), jnp.float32)
    v = jnp.zeros((b, h, s, d), jnp.float32)
    cref = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v)).lower(
        q, k, v).compile()
    cchk = jax.jit(lambda q, k, v: ref.flash_attention_chunked(
        q, k, v, block=blk)).lower(q, k, v).compile()
    t_ref = cref.memory_analysis().temp_size_in_bytes
    t_chk = cchk.memory_analysis().temp_size_in_bytes
    assert t_chk < t_ref / 2, (t_chk, t_ref)


def test_flash_chunked_mla_dims():
    """v head-dim may differ from q/k head-dim (MLA): d_v != d_qk."""
    b, h, s, dqk, dv = 1, 4, 96, 24, 16
    q = jnp.asarray(RNG.normal(0, 1, (b, h, s, dqk)), dtype=jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, h, s, dqk)), dtype=jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, h, s, dv)), dtype=jnp.float32)
    a = ref.flash_attention_ref(q, k, v, causal=True)
    c = ref.flash_attention_chunked(q, k, v, causal=True, block=32)
    assert c.shape == (b, h, s, dv)
    np.testing.assert_allclose(a, c, atol=3e-5, rtol=3e-5)
