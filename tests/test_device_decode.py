"""Device decode chain (DESIGN.md §9): bit-identity vs the host path.

The fused Pallas decode kernels (``kernels/decode_pages.py``) and the
reader's device path (``read_cluster_device`` / ``iter_clusters_device``)
must reproduce the numpy reference decode
(``encoding.unprecondition_pages_into`` driving ``read_cluster``)
bit-for-bit — offset columns after int32 -> int64 widening, everything
else exactly.  Runs on CPU: ``pallas`` mode exercises the kernels in
interpret mode, ``auto`` the XLA-compiled jnp oracle ops.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
jnp = jax.numpy

from repro.core import (
    Collection, ColumnBatch, Leaf, RNTJReader, ReadOptions, Schema,
    SequentialWriter, WriteOptions,
)
from repro.core.encoding import precondition_column_pages, unprecondition_pages_into
from repro.kernels import ref
from repro.kernels.decode_pages import (
    decode_offset_pages, device_decode_none, device_decode_offsets,
    device_decode_split, unsplit_pages,
)

MODES = ["auto", "pallas"]


# ---------------------------------------------------------------------------
# kernel-level identity (pallas interpret vs jnp oracle vs numpy)


def test_unsplit_pages_matches_numpy():
    rng = np.random.default_rng(0)
    planes = rng.integers(0, 256, (5, 4, 1000), dtype=np.uint8)
    want = np.swapaxes(planes, 1, 2)
    got_pal = np.asarray(unsplit_pages(jnp.asarray(planes), interpret=True))
    got_ref = np.asarray(ref.unsplit_pages_ref(jnp.asarray(planes)))
    np.testing.assert_array_equal(got_pal, want)
    np.testing.assert_array_equal(got_ref, want)


def test_decode_offset_pages_matches_numpy():
    """Per-page delta restart: each page integrates independently."""
    rng = np.random.default_rng(1)
    n_pages, per = 4, 2048
    sizes = rng.poisson(7, n_pages * per).reshape(n_pages, per).astype(np.int64)
    ends = np.cumsum(sizes, axis=1)  # per-page end offsets (the ground truth)
    deltas = np.diff(np.concatenate([np.zeros((n_pages, 1), np.int64), ends], axis=1))
    zz = ((deltas << 1) ^ (deltas >> 63)).astype(np.uint64)
    planes = zz[:, None, :].view(np.uint8).reshape(n_pages, per, 8)
    planes = np.ascontiguousarray(np.swapaxes(planes, 1, 2))  # (P, 8, per)
    got_pal = np.asarray(decode_offset_pages(jnp.asarray(planes), interpret=True))
    got_ref = np.asarray(ref.decode_offset_pages_ref(jnp.asarray(planes)))
    np.testing.assert_array_equal(got_pal.astype(np.int64), ends)
    np.testing.assert_array_equal(got_ref.astype(np.int64), ends)


@pytest.mark.parametrize("dtype", ["int32", "uint16", "float32"])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_device_decode_split_vs_host_reference(dtype, use_pallas):
    """Full driver (whole pages + partial tail) vs unprecondition_pages_into."""
    rng = np.random.default_rng(2)
    n, per = 10_000, 4096  # 2 full pages + a partial tail page
    dt = np.dtype(dtype)
    arr = rng.integers(0, 1 << 15, n).astype(dt)
    raw = precondition_column_pages(arr, "split", per)
    want = np.empty(n, dt)
    unprecondition_pages_into(raw, "split", per, want)
    got = np.asarray(device_decode_split(
        jnp.asarray(np.asarray(raw, np.uint8)), n, per, dtype,
        use_pallas=use_pallas, interpret=use_pallas,
    ))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_device_decode_offsets_vs_host_reference(use_pallas):
    rng = np.random.default_rng(3)
    n, per = 9_000, 4096
    sizes = rng.poisson(6, n)
    ends = np.cumsum(sizes).astype(np.int64)
    raw = precondition_column_pages(ends, "dzs", per)
    want = np.empty(n, np.int64)
    unprecondition_pages_into(raw, "dzs", per, want)
    got = np.asarray(device_decode_offsets(
        jnp.asarray(np.asarray(raw, np.uint8)), n, per,
        use_pallas=use_pallas, interpret=use_pallas,
    ))
    np.testing.assert_array_equal(got.astype(np.int64), want)


def test_device_decode_none_bitcast():
    rng = np.random.default_rng(4)
    arr = rng.uniform(-1, 1, 5000).astype(np.float32)
    got = np.asarray(device_decode_none(
        jnp.asarray(arr.view(np.uint8)), 5000, 4096, "float32"))
    np.testing.assert_array_equal(got, arr)


# ---------------------------------------------------------------------------
# reader-level identity


def _write_mixed(tmp_path, codec="zlib", n=25_000):
    schema = Schema([
        Leaf("id", "int64"),                          # 8-byte: host fallback
        Leaf("x", "float32"),
        Collection("v", Leaf("_0", "int32")),
        Collection("f", Leaf("_0", "uint8")),         # enc "none" values
    ])
    rng = np.random.default_rng(5)
    sv = rng.poisson(4, n).astype(np.int64)
    sf = rng.poisson(2, n).astype(np.int64)
    x = rng.uniform(0, 1, n).astype(np.float32)
    vv = rng.integers(0, 1 << 20, int(sv.sum())).astype(np.int32)
    fv = rng.integers(0, 256, int(sf.sum())).astype(np.uint8)
    ev, ef = np.cumsum(sv), np.cumsum(sf)
    path = str(tmp_path / f"mix_{codec}.rntj")
    # fill in slices so the writer seals several clusters
    with SequentialWriter(schema, path, WriteOptions(
            codec=codec, cluster_bytes=128 * 1024, page_size=16 * 1024)) as w:
        for s in range(0, n, 3000):
            e = min(s + 3000, n)
            w.fill_batch(ColumnBatch.from_arrays(schema, e - s, {
                "id": np.arange(s, e, dtype=np.int64),
                "x": x[s:e],
                "v": sv[s:e], "v._0": vv[(0 if not s else ev[s-1]):ev[e-1]],
                "f": sf[s:e], "f._0": fv[(0 if not s else ef[s-1]):ef[e-1]],
            }))
    return path


def _assert_cols_equal(dev_cols, host_cols, schema):
    assert set(dev_cols) == set(host_cols)
    for ci, a in dev_cols.items():
        ref_arr = host_cols[ci]
        a = np.asarray(a)
        if a.dtype != ref_arr.dtype:  # int32 device offsets widen exactly
            np.testing.assert_array_equal(a.astype(ref_arr.dtype), ref_arr)
        else:
            np.testing.assert_array_equal(a, ref_arr)


@pytest.mark.parametrize("codec", ["none", "zlib"])
@pytest.mark.parametrize("mode", MODES)
def test_read_cluster_device_bit_identical(tmp_path, codec, mode):
    path = _write_mixed(tmp_path, codec)
    with RNTJReader(path) as r:
        host = [r.read_cluster(i) for i in range(r.n_clusters)]
        assert r.n_clusters >= 2
    with RNTJReader(path, options=ReadOptions(device_decode=mode)) as r:
        for i in range(r.n_clusters):
            cols = r.read_cluster_device(i)
            _assert_cols_equal(cols, host[i], r.schema)
            # the 8-byte leaf decoded through the host fallback
            assert isinstance(cols[r.schema.column_of_path["id"]], np.ndarray)
        assert r.stats.device_clusters == r.n_clusters


@pytest.mark.parametrize("mode", MODES)
def test_iter_clusters_device_overlap_identity(tmp_path, mode):
    """Prefetch overlap must not corrupt earlier clusters: the staging
    buffer may be ALIASED by the device bytes (zero-copy device_put), so
    it recycles only after the device half — regression test for the
    clobber race."""
    path = _write_mixed(tmp_path, "zlib")
    with RNTJReader(path) as r:
        host = [r.read_cluster(i) for i in range(r.n_clusters)]
    for _trial in range(3):
        with RNTJReader(path, options=ReadOptions(
                device_decode=mode, prefetch_clusters=2,
                decode_workers=2)) as r:
            seen = []
            for i, cols in r.iter_clusters_device():
                seen.append(i)
                _assert_cols_equal(cols, host[i], r.schema)
            assert seen == list(range(r.n_clusters))
            assert r.stats.h2d_ns >= 0 and r.stats.device_clusters == r.n_clusters


def test_device_decode_off_raises(tmp_path):
    path = _write_mixed(tmp_path, "none", n=2_000)
    with RNTJReader(path, options=ReadOptions(device_decode="off")) as r:
        with pytest.raises(RuntimeError):
            r.read_cluster_device(0)
        with pytest.raises(RuntimeError):
            next(r.iter_clusters_device())
        # the host path never consults the knob
        r.read_cluster(0)
