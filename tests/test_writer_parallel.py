"""Parallel-writer behaviour: sequential-equivalence, relocatability,
lock-granularity (the paper's §4–§6.1 claims as executable properties)."""

import os
import threading

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (
    Collection, ColumnBatch, Leaf, ParallelWriter, RNTJReader, Schema,
    SequentialWriter, WriteOptions, write_entries,
)
from repro.core.cluster import ClusterBuilder
from repro.core.container import MemorySink
from repro.core.pages import read_page


def vec_schema():
    return Schema([Leaf("id", "int64"), Collection("vals", Leaf("_0", "float32"))])


def make_batch(schema, rng, n, id0=0):
    sizes = rng.poisson(5, n).astype(np.int64)
    vals = rng.uniform(0, 100, int(sizes.sum())).astype(np.float32)
    return ColumnBatch.from_arrays(
        schema, n, {"id": np.arange(id0, id0 + n), "vals": sizes, "vals._0": vals}
    )


def run_parallel(path, schema, opts, n_threads=4, entries_per_thread=200):
    w = ParallelWriter(schema, path, opts)
    def worker(tid):
        rng = np.random.default_rng(tid)
        ctx = w.create_fill_context()
        ctx.fill_batch(make_batch(schema, rng, entries_per_thread,
                                  id0=tid * 10_000))
        ctx.close()
    ts = [threading.Thread(target=worker, args=(t,)) for t in range(n_threads)]
    for t in ts: t.start()
    for t in ts: t.join()
    w.close()
    return w


@pytest.mark.parametrize("opts", [
    WriteOptions(cluster_bytes=4096),
    WriteOptions(cluster_bytes=4096, buffered=False, page_size=512),
    WriteOptions(cluster_bytes=4096, fallocate=True),
    WriteOptions(cluster_bytes=4096, write_outside_lock=True),
    WriteOptions(cluster_bytes=4096, fallocate=True, write_outside_lock=True),
    WriteOptions(cluster_bytes=4096, codec="lzma"),
    WriteOptions(cluster_bytes=4096, codec="bz2"),
    WriteOptions(cluster_bytes=4096, codec="none"),
])
def test_parallel_roundtrip_all_modes(tmp_path, opts):
    schema = vec_schema()
    path = str(tmp_path / "f.rntj")
    w = run_parallel(path, schema, opts)
    r = RNTJReader(path)
    assert r.n_entries == 800
    ids = np.sort(r.read_column("id"))
    expect = np.sort(np.concatenate([np.arange(t * 10_000, t * 10_000 + 200)
                                     for t in range(4)]))
    np.testing.assert_array_equal(ids, expect)
    # per-entry content must match what its producer filled
    offs = r.read_column("vals")
    vals = r.read_column("vals._0")
    ids_raw = r.read_column("id")
    by_id = {}
    starts = np.concatenate([[0], offs[:-1]])
    for i, eid in enumerate(ids_raw):
        by_id[int(eid)] = vals[starts[i]:offs[i]]
    for tid in range(4):
        rng = np.random.default_rng(tid)
        sizes = rng.poisson(5, 200).astype(np.int64)
        expect_vals = rng.uniform(0, 100, int(sizes.sum())).astype(np.float32)
        ends = np.cumsum(sizes)
        for j in range(200):
            got = by_id[tid * 10_000 + j]
            np.testing.assert_array_equal(got, expect_vals[ends[j]-sizes[j]:ends[j]])


def test_sequential_equivalence_of_metadata(tmp_path):
    """A parallel file must look sequential to the reader: contiguous entry
    ranges in commit order and consistent column ranges (paper §4.3)."""
    schema = vec_schema()
    path = str(tmp_path / "f.rntj")
    run_parallel(path, schema, WriteOptions(cluster_bytes=2048), n_threads=8)
    r = RNTJReader(path)
    expect_first = 0
    for i, cm in enumerate(r.clusters):
        assert cm.first_entry == expect_first
        expect_first += cm.n_entries
        # column ranges: each cluster's element counts are self-consistent
        n_vals = cm.n_elements[r.schema.column_of_path["vals._0"]]
        offs = r.read_cluster(i, [1])[1]
        assert (offs[-1] if len(offs) else 0) == n_vals
    assert expect_first == r.n_entries


def test_lock_granularity_buffered_vs_unbuffered(tmp_path):
    """Paper §6.1: page-granular locking takes orders of magnitude more lock
    acquisitions than cluster-granular (futex 300 vs 27,000)."""
    schema = vec_schema()
    buffered = run_parallel(str(tmp_path / "b.rntj"), schema,
                            WriteOptions(cluster_bytes=16384))
    unbuffered = run_parallel(str(tmp_path / "u.rntj"), schema,
                              WriteOptions(cluster_bytes=16384, buffered=False,
                                           page_size=256))
    assert buffered.stats.lock.acquisitions < unbuffered.stats.lock.acquisitions / 5
    # both files identical logical content
    a = np.sort(RNTJReader(str(tmp_path / "b.rntj")).read_column("id"))
    b = np.sort(RNTJReader(str(tmp_path / "u.rntj")).read_column("id"))
    np.testing.assert_array_equal(a, b)


def test_relocatability_property():
    """A sealed cluster's bytes decode identically at ANY byte offset —
    the enabling property for lock-free serialization (paper §4.1)."""
    schema = vec_schema()
    rng = np.random.default_rng(7)
    builder = ClusterBuilder(schema, page_size=512, codec=1)
    builder.fill_batch(make_batch(schema, rng, 100))
    sealed = builder.seal()
    sink = MemorySink()
    for base in [0, 17, 4096, 123457]:
        sink.pwrite(base, sealed.blob)
        for desc_rel in sealed.pages:
            desc = desc_rel.rebase(base)
            col = schema.columns[desc.column]
            buf = sink.pread(desc.offset, desc.size)
            arr = read_page(buf, desc, col)
            assert len(arr) == desc.n_elements  # decodes fine anywhere


@given(st.integers(1, 6), st.integers(0, 150), st.integers(256, 8192))
@settings(max_examples=20, deadline=None)
def test_parallel_entry_conservation(n_threads, n_entries, cluster_bytes):
    """No entries lost or duplicated for any thread count / cluster size."""
    schema = vec_schema()
    sink = MemorySink()
    w = ParallelWriter(schema, sink, WriteOptions(cluster_bytes=cluster_bytes))
    def worker(tid):
        rng = np.random.default_rng(tid)
        ctx = w.create_fill_context()
        if n_entries:
            ctx.fill_batch(make_batch(schema, rng, n_entries, id0=tid * 1000))
        ctx.close()
    ts = [threading.Thread(target=worker, args=(t,)) for t in range(n_threads)]
    for t in ts: t.start()
    for t in ts: t.join()
    w.close()
    r = RNTJReader(sink)
    assert r.n_entries == n_threads * n_entries
    ids = np.sort(r.read_column("id"))
    expect = np.sort(np.concatenate(
        [np.arange(t * 1000, t * 1000 + n_entries) for t in range(n_threads)]
    )) if n_entries else np.empty(0, np.int64)
    np.testing.assert_array_equal(ids, expect)


def test_checksum_detects_corruption(tmp_path):
    schema = vec_schema()
    path = str(tmp_path / "c.rntj")
    rng = np.random.default_rng(0)
    with SequentialWriter(schema, path, WriteOptions()) as w:
        w.fill_batch(make_batch(schema, rng, 500))
    r = RNTJReader(path)
    page0 = r.clusters[0].pages[0]
    with open(path, "r+b") as f:
        f.seek(page0.offset + page0.size // 2)
        f.write(b"\xff\xfe")
    r2 = RNTJReader(path)
    with pytest.raises(IOError):
        r2.read_cluster(0)


def test_compression_fallback_to_store():
    """Incompressible pages are stored raw, like ROOT."""
    schema = Schema([Collection("v", Leaf("_0", "uint8"))])
    rng = np.random.default_rng(3)
    n = 8192
    batch = ColumnBatch.from_arrays(
        schema, 1, {"v": np.array([n]), "v._0": rng.integers(0, 256, n, dtype=np.uint8)}
    )
    sink = MemorySink()
    with SequentialWriter(schema, sink, WriteOptions(codec="zlib")) as w:
        w.fill_batch(batch)
    r = RNTJReader(sink)
    data_pages = [p for c in r.clusters for p in c.pages if p.column == 1]
    assert any(p.codec == 0 for p in data_pages)  # stored uncompressed
    np.testing.assert_array_equal(r.read_column("v._0"), batch.data[1])
