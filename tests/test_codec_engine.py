"""Codec-engine behaviour: registry errors, framed chunking, adaptive
per-column policy, per-column overrides, checksum edge cases, pool-worker
error propagation, and the Pallas byteshuffle dispatch."""

import threading
import zlib

import numpy as np
import pytest

from repro.core import (
    Collection, ColumnBatch, Leaf, ParallelWriter, RNTJReader, ReadOptions,
    Schema, SequentialWriter, WriteOptions,
)
from repro.core import compression as comp
from repro.core import encoding as E
from repro.core.container import MemorySink
from repro.core.pages import read_page


def vec_schema():
    return Schema([Leaf("id", "int64"), Collection("vals", Leaf("_0", "float32"))])


def make_batch(schema, rng, n, id0=0, compressible=False):
    sizes = rng.poisson(5, n).astype(np.int64)
    k = int(sizes.sum())
    if compressible:
        vals = (np.round(rng.gamma(2.0, 15.0, k) * 64) / 64).astype(np.float32)
    else:
        vals = rng.uniform(0, 100, k).astype(np.float32)
    return ColumnBatch.from_arrays(
        schema, n, {"id": np.arange(id0, id0 + n), "vals": sizes, "vals._0": vals}
    )


def roundtrip_ids(sink, n):
    r = RNTJReader(sink)
    try:
        np.testing.assert_array_equal(np.sort(r.read_column("id")), np.arange(n))
    finally:
        r.close()
    return r


# ---------------------------------------------------------------------------
# registry: errors and optional codecs


def test_unavailable_codec_raises_value_error_with_default_level():
    """Ids 4/5 must raise ValueError (not KeyError) even at level < 0 —
    the availability check precedes any level lookup."""
    for cid, pkg in [(comp.CODEC_LZ4, "lz4"), (comp.CODEC_ZSTD, "zstandard")]:
        if comp.is_available(cid):
            data = b"x" * 1000
            out = comp.compress(data, cid)  # installed: must round-trip
            assert comp.decompress(out, cid, len(data)) == data
        else:
            with pytest.raises(ValueError, match=pkg):
                comp.compress(b"x" * 1000, cid)
            with pytest.raises(ValueError, match=pkg):
                comp.decompress(b"x", cid, 1)


def test_unknown_codec_id_and_name():
    with pytest.raises(ValueError):
        comp.compress(b"x", 99, 1)
    with pytest.raises(ValueError):
        comp.codec_id("snappy")
    # reserved names always resolve to their stable ids
    assert comp.codec_id("lz4") == comp.CODEC_LZ4
    assert comp.codec_id("zstd") == comp.CODEC_ZSTD
    assert comp.codec_name(comp.CODEC_ZLIB) == "zlib"


# ---------------------------------------------------------------------------
# framed chunking


@pytest.mark.parametrize("codec", [comp.CODEC_ZLIB, comp.CODEC_LZMA, comp.CODEC_BZ2])
def test_chunked_members_roundtrip_and_crc(codec):
    rng = np.random.default_rng(0)
    data = rng.integers(0, 50, 300_000, dtype=np.uint8).tobytes()
    parts = comp.compress_parts(data, codec, -1, chunk_bytes=64 * 1024)
    assert len(parts) == 5
    payload = b"".join(parts)
    # the member loop reassembles the exact input
    assert comp.decompress(payload, codec, len(data)) == data
    # incremental member-CRC fold == whole-payload crc32
    assert comp.crc32_parts(parts) == zlib.crc32(payload)
    # single-member path unchanged
    whole = comp.compress(data, codec)
    assert comp.decompress(whole, codec, len(data)) == data


def test_chunk_ranges():
    assert comp.chunk_ranges(10, 0) == [(0, 10)]
    assert comp.chunk_ranges(10, 16) == [(0, 10)]
    assert comp.chunk_ranges(10, 4) == [(0, 4), (4, 8), (8, 10)]


def test_chunked_decompress_size_mismatch_raises():
    data = b"a" * 100_000
    payload = comp.compress(data, comp.CODEC_ZLIB, 1, chunk_bytes=16 * 1024)
    with pytest.raises(IOError, match="size mismatch"):
        comp.decompress(payload, comp.CODEC_ZLIB, len(data) + 1)


def test_chunked_file_roundtrip_and_legacy_page_reader():
    """Chunked pages must decode through the engine AND the unmodified
    page-at-a-time legacy path (read_page)."""
    schema = vec_schema()
    rng = np.random.default_rng(1)
    sink = MemorySink()
    opts = WriteOptions(codec="zlib", page_size=32 * 1024,
                        codec_chunk_bytes=4 * 1024, cluster_bytes=1 << 18)
    with SequentialWriter(schema, sink, opts) as w:
        for i in range(4):
            w.fill_batch(make_batch(schema, rng, 10_000, id0=i * 10_000,
                                    compressible=True))
    r = RNTJReader(sink)
    assert any(
        p.codec == comp.CODEC_ZLIB and p.uncompressed_size > 4 * 1024
        for cm in r.clusters for p in cm.pages
    ), "expected at least one chunk-framed page"
    np.testing.assert_array_equal(np.sort(r.read_column("id")),
                                  np.arange(40_000))
    # legacy page-at-a-time path over the same metadata
    for cm in r.clusters:
        for desc in cm.pages:
            col = r.schema.columns[desc.column]
            buf = sink.pread(desc.offset, desc.size)
            arr = read_page(buf, desc, col, verify=True)
            assert len(arr) == desc.n_elements
    r.close()


@pytest.mark.parametrize("adaptive", [False, True])
def test_pooled_seal_equals_serial_with_chunking(adaptive):
    """Chunk-framed + adaptive seals must stay byte-identical between the
    serial and pooled code paths (single producer)."""
    schema = vec_schema()

    def write(imt):
        rng = np.random.default_rng(7)
        sink = MemorySink()
        opts = WriteOptions(codec="zlib", page_size=16 * 1024,
                            codec_chunk_bytes=2 * 1024,
                            cluster_bytes=1 << 17, imt_workers=imt,
                            adaptive_codec=adaptive,
                            adaptive_sample_pages=2, adaptive_threshold=0.8)
        with SequentialWriter(schema, sink, opts) as w:
            for i in range(4):
                w.fill_batch(make_batch(schema, rng, 5_000, id0=i * 5_000))
        return sink

    assert bytes(write(0).buf) == bytes(write(3).buf)


# ---------------------------------------------------------------------------
# adaptive per-column policy


def test_adaptive_policy_downgrades_incompressible_column():
    schema = vec_schema()
    rng = np.random.default_rng(3)
    sink = MemorySink()
    opts = WriteOptions(codec="zlib", page_size=8 * 1024,
                        cluster_bytes=1 << 17, adaptive_codec=True,
                        adaptive_sample_pages=2, adaptive_threshold=0.8)
    w = SequentialWriter(schema, sink, opts)
    for i in range(8):
        w.fill_batch(make_batch(schema, rng, 5_000, id0=i * 5_000))
    w.close()
    vals_col = schema.column_of_path["vals._0"]
    id_col = schema.column_of_path["id"]
    assert w._policy.decision(vals_col) is False   # uniform floats: raw
    assert w._policy.decision(id_col) is True      # arange: keep zlib
    r = RNTJReader(sink)
    codecs_by_col = {}
    for cm in r.clusters:
        for p in cm.pages:
            codecs_by_col.setdefault(p.column, set()).add(p.codec)
    # after the trial, vals._0 pages are stored raw; id keeps zlib
    assert comp.CODEC_NONE in codecs_by_col[vals_col]
    assert codecs_by_col[id_col] == {comp.CODEC_ZLIB}
    np.testing.assert_array_equal(np.sort(r.read_column("id")),
                                  np.arange(40_000))
    # the per-codec breakdown attributes both codecs
    per = w.stats.as_dict()["per_codec"]
    assert "none" in per and "zlib" in per
    assert per["none"]["pages"] > 0 and per["zlib"]["pages"] > 0
    r.close()


def test_adaptive_policy_shared_across_parallel_producers():
    schema = vec_schema()
    sink = MemorySink()
    opts = WriteOptions(codec="zlib", page_size=8 * 1024,
                        cluster_bytes=1 << 16, adaptive_codec=True,
                        adaptive_sample_pages=2, adaptive_threshold=0.8)
    w = ParallelWriter(schema, sink, opts)

    def worker(tid):
        rng = np.random.default_rng(tid)
        ctx = w.create_fill_context()
        for i in range(4):
            ctx.fill_batch(make_batch(schema, rng, 2_000,
                                      id0=tid * 10**6 + i * 2_000))
        ctx.close()

    ts = [threading.Thread(target=worker, args=(t,)) for t in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    w.close()
    assert w._policy.decision(schema.column_of_path["vals._0"]) is False
    r = RNTJReader(sink)
    assert r.n_entries == 4 * 4 * 2_000
    ids = r.read_column("id")
    assert len(ids) == r.n_entries
    r.close()


def test_codec_policy_unit():
    p = comp.CodecPolicy(2, sample_pages=2, threshold=0.5)
    assert p.decision(0) is None
    assert p.remaining_sample(0) == 2
    assert p.effective_codec(0, comp.CODEC_ZLIB) == comp.CODEC_ZLIB
    p.record(0, 100, 90)
    p.record(0, 100, 95)   # ratio 0.925 > 0.5 -> raw
    assert p.decision(0) is False
    assert p.effective_codec(0, comp.CODEC_ZLIB) == comp.CODEC_NONE
    assert p.remaining_sample(0) == 0
    p.record(1, 100, 10)
    p.record(1, 100, 10)   # ratio 0.1 <= 0.5 -> keep
    assert p.decision(1) is True
    assert p.effective_codec(1, comp.CODEC_ZLIB) == comp.CODEC_ZLIB
    d = p.as_dict()
    assert d["columns"][0]["keep"] is False


# ---------------------------------------------------------------------------
# per-column codec overrides


def test_write_options_column_codec_override():
    schema = vec_schema()
    rng = np.random.default_rng(5)
    sink = MemorySink()
    opts = WriteOptions(codec="zlib", page_size=8 * 1024,
                        column_codecs={"vals._0": "none",
                                       "vals": ("bz2", 5)})
    with SequentialWriter(schema, sink, opts) as w:
        w.fill_batch(make_batch(schema, rng, 20_000, compressible=True))
    r = RNTJReader(sink)
    by_col = {}
    for cm in r.clusters:
        for p in cm.pages:
            by_col.setdefault(r.schema.columns[p.column].path, set()).add(p.codec)
    assert by_col["vals._0"] == {comp.CODEC_NONE}
    assert comp.CODEC_BZ2 in by_col["vals"]
    assert comp.CODEC_ZLIB in by_col["id"]
    np.testing.assert_array_equal(np.sort(r.read_column("id")),
                                  np.arange(20_000))
    r.close()


def test_column_spec_codec_override():
    schema = vec_schema().set_column_codec("vals._0", "none")
    assert schema.columns[schema.column_of_path["vals._0"]].codec == "none"
    rng = np.random.default_rng(6)
    sink = MemorySink()
    with SequentialWriter(schema, sink, WriteOptions(codec="zlib")) as w:
        w.fill_batch(make_batch(schema, rng, 10_000))
    r = RNTJReader(sink)
    vals_col = schema.column_of_path["vals._0"]
    assert all(p.codec == comp.CODEC_NONE
               for cm in r.clusters for p in cm.pages if p.column == vals_col)
    np.testing.assert_array_equal(np.sort(r.read_column("id")),
                                  np.arange(10_000))
    r.close()
    # overrides survive the spec (de)serialization used by tools
    spec = schema.columns[vals_col]
    assert type(spec).from_dict(spec.to_dict()) == spec


def test_precondition_off_roundtrips_and_header_flag():
    schema = vec_schema()
    rng = np.random.default_rng(8)
    sink = MemorySink()
    with SequentialWriter(schema, sink,
                          WriteOptions(precondition=False)) as w:
        w.fill_batch(make_batch(schema, rng, 10_000))
    r = RNTJReader(sink)
    assert r.options["precondition"] is False
    # the parsed schema dropped the derived encodings
    assert all(c.encoding == "none" for c in r.schema.columns)
    np.testing.assert_array_equal(np.sort(r.read_column("id")),
                                  np.arange(10_000))
    rng = np.random.default_rng(8)
    expect = make_batch(schema, rng, 10_000)
    np.testing.assert_array_equal(r.read_column("vals._0"), expect.data[2])
    r.close()


# ---------------------------------------------------------------------------
# checksum edge cases


def test_checksum_false_pages_roundtrip():
    schema = vec_schema()
    rng = np.random.default_rng(9)
    sink = MemorySink()
    opts = WriteOptions(codec="zlib", checksum=False, page_size=8 * 1024,
                        codec_chunk_bytes=2 * 1024)
    with SequentialWriter(schema, sink, opts) as w:
        w.fill_batch(make_batch(schema, rng, 10_000))
    r = RNTJReader(sink)  # verify_checksums=True must be a no-op here
    assert all(p.checksum == 0 for cm in r.clusters for p in cm.pages)
    np.testing.assert_array_equal(np.sort(r.read_column("id")),
                                  np.arange(10_000))
    r.close()


def _chunked_file(checksum=True):
    schema = vec_schema()
    rng = np.random.default_rng(10)
    sink = MemorySink()
    opts = WriteOptions(codec="zlib", page_size=32 * 1024,
                        codec_chunk_bytes=4 * 1024, checksum=checksum,
                        cluster_bytes=1 << 19)
    with SequentialWriter(schema, sink, opts) as w:
        for i in range(4):
            w.fill_batch(make_batch(schema, rng, 10_000, id0=i * 10_000,
                                    compressible=True))
    return schema, sink


def _find_chunked_page(reader):
    for cm in reader.clusters:
        for p in cm.pages:
            if p.codec == comp.CODEC_ZLIB and p.uncompressed_size > 4 * 1024:
                return p
    raise AssertionError("no chunk-framed page found")


def test_mid_page_chunk_corruption_detected():
    """Flipping a byte inside a later member of a chunked page must fail
    the (incrementally folded) page checksum."""
    schema, sink = _chunked_file(checksum=True)
    r = RNTJReader(sink)
    p = _find_chunked_page(r)
    sink.buf[p.offset + p.size // 2] ^= 0xFF  # mid-page: not the 1st member
    with pytest.raises(IOError, match="checksum mismatch"):
        for _ci, _cols in r.iter_clusters(columns=[p.column]):
            pass
    r.close()


def test_corrupt_chunk_without_checksum_fails_decode():
    """With checksum=False the member loop itself must surface corruption
    (zlib error or size mismatch) — from decode-pool workers too."""
    schema, sink = _chunked_file(checksum=False)
    r = RNTJReader(sink, options=ReadOptions(decode_workers=2))
    p = _find_chunked_page(r)
    sink.buf[p.offset + p.size // 2] ^= 0xFF
    with pytest.raises(Exception):
        for _ci, _cols in r.iter_clusters(columns=[p.column]):
            pass
    r.close()


# ---------------------------------------------------------------------------
# errors propagating out of pool workers


def test_decompressed_size_mismatch_propagates_from_decode_pool():
    schema, sink = _chunked_file(checksum=False)
    r = RNTJReader(sink, options=ReadOptions(decode_workers=2))
    p = _find_chunked_page(r)
    p.uncompressed_size += 8  # poison the in-memory descriptor
    with pytest.raises(IOError, match="size mismatch"):
        for _ci, _cols in r.iter_clusters(columns=[p.column]):
            pass
    r.close()


def test_compress_error_propagates_from_writer_pool_sequential():
    schema = vec_schema()
    rng = np.random.default_rng(11)
    w = SequentialWriter(schema, MemorySink(),
                         WriteOptions(imt_workers=2))
    w.fill_batch(make_batch(schema, rng, 2_000))
    w._builder.codec = 99  # pool workers must surface the ValueError
    with pytest.raises(ValueError):
        w.flush_cluster()
    with pytest.raises(RuntimeError, match="NOT finalized"):
        w.close()


def test_compress_error_propagates_from_writer_pool_parallel():
    schema = vec_schema()
    rng = np.random.default_rng(12)
    w = ParallelWriter(schema, MemorySink(),
                       WriteOptions(imt_workers=2, pipelined_seal=True))
    ctx = w.create_fill_context()
    ctx.fill_batch(make_batch(schema, rng, 2_000))
    ctx.builder.codec = 99
    with pytest.raises(Exception):
        ctx.close()
    with pytest.raises(RuntimeError, match="NOT finalized"):
        w.close()


# ---------------------------------------------------------------------------
# header-recorded encodings: merge + schema reuse must never mis-decode


def _write_tmp(tmp_path, name, opts, n=5_000, seed=20):
    schema = vec_schema()
    rng = np.random.default_rng(seed)
    path = str(tmp_path / name)
    with SequentialWriter(schema, path, opts) as w:
        w.fill_batch(make_batch(schema, rng, n))
    rng = np.random.default_rng(seed)
    return path, make_batch(schema, rng, n)


def test_merge_raw_path_honors_source_encodings(tmp_path):
    """A precondition=False source raw-merged without a target codec must
    read back exactly (the output header records the real encodings)."""
    from repro.core import merge_files

    src, expect = _write_tmp(tmp_path, "src.rntj",
                             WriteOptions(codec="none", precondition=False))
    out = str(tmp_path / "out.rntj")
    merge_files([src], out)
    with RNTJReader(out) as r:
        np.testing.assert_array_equal(r.read_column("id"), expect.data[0])
        np.testing.assert_array_equal(r.read_column("vals._0"), expect.data[2])
        # verbatim copy: still stored with no preconditioning
        assert all(c.encoding == "none" for c in r.schema.columns)


def test_merge_reencode_path_on_encoding_mismatch(tmp_path):
    """Merging a precondition=False source with a preconditioned one must
    re-encode (not raw-copy) the mismatching input."""
    from repro.core import merge_files

    a, ea = _write_tmp(tmp_path, "a.rntj", WriteOptions(codec="zlib"), seed=21)
    b, eb = _write_tmp(tmp_path, "b.rntj",
                       WriteOptions(codec="zlib", precondition=False), seed=22)
    out = str(tmp_path / "out.rntj")
    merge_files([a, b], out, options=WriteOptions(codec="zlib"))
    with RNTJReader(out) as r:
        got = np.sort(r.read_column("id"))
        want = np.sort(np.concatenate([ea.data[0], eb.data[0]]))
        np.testing.assert_array_equal(got, want)
        vals = np.sort(r.read_column("vals._0"))
        np.testing.assert_array_equal(
            vals, np.sort(np.concatenate([ea.data[2], eb.data[2]]))
        )


def test_parsed_schema_reuse_for_new_writer(tmp_path):
    """Writing with a schema parsed from a precondition=False file must
    produce a self-consistent file (header records the ENC_NONE specs)."""
    src, expect = _write_tmp(tmp_path, "src.rntj",
                             WriteOptions(codec="zlib", precondition=False))
    with RNTJReader(src) as r:
        reused = r.schema
    sink = MemorySink()
    with SequentialWriter(reused, sink, WriteOptions(codec="zlib")) as w:
        rng = np.random.default_rng(20)
        w.fill_batch(make_batch(reused, rng, 5_000))
    with RNTJReader(sink) as r2:
        np.testing.assert_array_equal(r2.read_column("id"), expect.data[0])
        np.testing.assert_array_equal(r2.read_column("vals._0"),
                                      expect.data[2])


def test_unknown_column_codecs_path_raises():
    schema = vec_schema()
    with pytest.raises(KeyError, match="vals.0"):
        SequentialWriter(schema, MemorySink(),
                         WriteOptions(column_codecs={"vals.0": "none"}))


def test_unbuffered_per_codec_time_attributed():
    schema = vec_schema()
    rng = np.random.default_rng(23)
    sink = MemorySink()
    opts = WriteOptions(codec="zlib", buffered=False, page_size=8 * 1024,
                        cluster_bytes=1 << 18)
    with ParallelWriter(schema, sink, opts) as w:
        ctx = w.create_fill_context()
        for i in range(4):
            ctx.fill_batch(make_batch(schema, rng, 5_000, id0=i * 5_000))
        ctx.close()
    per = w.stats.as_dict()["per_codec"]
    assert per["zlib"]["pages"] > 0 and per["zlib"]["ms"] > 0


# ---------------------------------------------------------------------------
# reader per-codec stats


def test_reader_per_codec_breakdown():
    schema = vec_schema()
    rng = np.random.default_rng(13)
    sink = MemorySink()
    opts = WriteOptions(codec="zlib", adaptive_codec=True,
                        adaptive_sample_pages=1, adaptive_threshold=0.8,
                        page_size=8 * 1024, cluster_bytes=1 << 17)
    with SequentialWriter(schema, sink, opts) as w:
        for i in range(4):
            w.fill_batch(make_batch(schema, rng, 5_000, id0=i * 5_000))
    r = RNTJReader(sink)
    for _ci, _cols in r.iter_clusters():
        pass
    per = r.stats.as_dict()["per_codec"]
    assert "zlib" in per and "none" in per
    assert per["zlib"]["bytes_out"] > per["zlib"]["bytes_in"]  # it decompressed
    total_pages = sum(v["pages"] for v in per.values())
    assert total_pages == r.stats.pages
    r.close()


# ---------------------------------------------------------------------------
# Pallas byteshuffle dispatch


def test_forced_pallas_byteshuffle_matches_numpy(monkeypatch):
    """REPRO_SHUFFLE_BACKEND=pallas must be bit-identical to the numpy
    split (runs the kernel in interpret mode on CPU backends)."""
    pytest.importorskip("jax")
    monkeypatch.setattr(E._SHUFFLE, "backend", "pallas")
    monkeypatch.setattr(E._SHUFFLE, "_kernel", None)  # re-resolve
    rng = np.random.default_rng(14)
    for dtype, per in [(np.float32, 64), (np.int64, 100), (np.float64, 33)]:
        arr = rng.uniform(0, 100, 257).astype(dtype)
        got = bytes(E.precondition_column_pages(arr, "split", per))
        monkeypatch.setattr(E._SHUFFLE, "backend", "numpy")
        want = bytes(E.precondition_column_pages(arr, "split", per))
        monkeypatch.setattr(E._SHUFFLE, "backend", "pallas")
        assert got == want, f"pallas byteshuffle differs for {dtype}"
    assert E._SHUFFLE._kernel not in (None, False)  # the kernel actually ran


def test_shuffle_auto_backend_stays_numpy_on_cpu():
    """The auto dispatch must not engage on CPU-only jax (and never pay a
    cold jax import inside the seal path)."""
    rng = np.random.default_rng(15)
    arr = rng.uniform(0, 1, 200_000).astype(np.float64)  # above threshold
    out = bytes(E.precondition_column_pages(arr, "split", 8192))
    ref = bytes(E.split_encode(arr[:8192]))
    assert out[: len(ref)] == ref
