"""Checkpoint substrate: parallel single-file save/restore, fault tolerance,
elastic restart (different writer counts), async saves, multi-process saves."""

import os
import stat
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (
    CheckpointManager,
    load_checkpoint,
    save_checkpoint,
    save_checkpoint_mp,
)
from repro.core import RNTJReader, WriteOptions

MP_OPTS = WriteOptions(codec="zlib", level=1, cluster_bytes=1 << 20,
                       lease_interval=0.5, rendezvous_timeout=15.0,
                       mpw_log_fsync=False)


def make_tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "embed": jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32)),
        "layers": {
            "w": jnp.asarray(rng.normal(size=(4, 64, 64)).astype(np.float32)),
            "b": jnp.zeros((4, 64), jnp.bfloat16),
        },
        "step": jnp.asarray(123, jnp.int32),
    }


def assert_trees_equal(a, b):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        np.testing.assert_array_equal(
            np.asarray(x, np.float32), np.asarray(y, np.float32))


@pytest.mark.parametrize("n_writers", [1, 2, 7])
def test_save_restore_roundtrip(tmp_path, n_writers):
    tree = make_tree()
    p = str(tmp_path / "c.rntj")
    save_checkpoint(p, tree, n_writers=n_writers, row_block_bytes=4096)
    back, meta = load_checkpoint(p, target_tree=tree)
    assert_trees_equal(tree, back)


def test_restore_without_target_tree(tmp_path):
    tree = make_tree()
    p = str(tmp_path / "c.rntj")
    save_checkpoint(p, tree, n_writers=2)
    back, _ = load_checkpoint(p)
    assert_trees_equal(tree, back)


def test_elastic_restart_across_writer_counts(tmp_path):
    """File written by N writers restores identically regardless of N —
    the paper's reader-compatibility guarantee enables elastic rescale."""
    tree = make_tree(1)
    paths = []
    for n in (1, 3, 8):
        p = str(tmp_path / f"c{n}.rntj")
        save_checkpoint(p, tree, n_writers=n, row_block_bytes=2048)
        paths.append(p)
    restored = [load_checkpoint(p, target_tree=tree)[0] for p in paths]
    for r in restored:
        assert_trees_equal(tree, r)
    # logical equality even though cluster layouts differ
    layouts = {RNTJReader(p).n_clusters for p in paths}
    assert len(layouts) > 1  # genuinely different parallel layouts


def test_manager_rotation_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = make_tree()
    for step in (10, 20, 30):
        mgr.save(step, tree, {"loss": float(step)})
    assert mgr.steps() == [20, 30]
    back, meta = mgr.restore(target_tree=tree)
    assert meta["step"] == 30 and meta["loss"] == 30.0


def test_crash_mid_write_is_invisible(tmp_path):
    """A .tmp left by a crash is ignored and GC'd; committed ckpts survive."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = make_tree()
    mgr.save(10, tree)
    # simulate a crash: partial uncommitted file
    (tmp_path / "step_0000000020.rntj.tmp").write_bytes(b"partial garbage")
    mgr2 = CheckpointManager(str(tmp_path), keep=3)
    assert mgr2.latest_step() == 10
    assert not list(tmp_path.glob("*.tmp"))
    back, meta = mgr2.restore(target_tree=tree)
    assert meta["step"] == 10


def test_corrupt_committed_checkpoint_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = make_tree()
    mgr.save(10, tree)
    p = mgr.path_for(10)
    data = bytearray(p.read_bytes())
    data[len(data) // 2] ^= 0xFF
    p.write_bytes(bytes(data))
    with pytest.raises(IOError):
        mgr.restore(target_tree=tree)


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = make_tree()
    mgr.save_async(5, tree)
    mgr.wait()
    assert mgr.latest_step() == 5
    back, _ = mgr.restore(target_tree=tree)
    assert_trees_equal(tree, back)


def test_concurrent_writers_thread_safety(tmp_path):
    """Many writers, small row blocks: stress the critical section."""
    rng = np.random.default_rng(3)
    tree = {f"p{i}": jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
            for i in range(20)}
    p = str(tmp_path / "big.rntj")
    save_checkpoint(p, tree, n_writers=8, row_block_bytes=512)
    back, _ = load_checkpoint(p, target_tree=tree)
    assert_trees_equal(tree, back)


# ---------------------------------------------------------------------------
# durability of the directory (commit/prune are rename/unlink, not writes)


def test_manager_fsyncs_directory_after_commit_and_prune(tmp_path, monkeypatch):
    import repro.ckpt.manager as mgr_mod

    events = []
    real_fsync, real_replace = os.fsync, os.replace

    def spy_fsync(fd):
        if stat.S_ISDIR(os.fstat(fd).st_mode):
            events.append("dirsync")
        return real_fsync(fd)

    def spy_replace(a, b):
        events.append("replace")
        return real_replace(a, b)

    monkeypatch.setattr(mgr_mod.os, "fsync", spy_fsync)
    monkeypatch.setattr(mgr_mod.os, "replace", spy_replace)

    mgr = CheckpointManager(str(tmp_path), keep=1)
    tree = make_tree()
    mgr.save(10, tree)
    # the rename is durable only once the directory entry is: a dirsync
    # must FOLLOW the replace (crash between them loses the commit)
    assert "replace" in events
    assert "dirsync" in events[events.index("replace"):], (
        f"no directory fsync after rename: {events}")

    events.clear()
    mgr.save(20, tree)  # prunes step 10
    assert mgr.steps() == [20]
    assert events.count("dirsync") >= 2, (
        f"prune's unlink needs its own directory fsync: {events}")

    # gc of crash leftovers is also a directory mutation
    (tmp_path / "step_0000000099.rntj.tmp").write_bytes(b"junk")
    events.clear()
    mgr.gc_tmp()
    assert "dirsync" in events


# ---------------------------------------------------------------------------
# async-save synchronization (restore/steps vs in-flight save)


def test_restore_and_steps_wait_for_async_save(tmp_path, monkeypatch):
    import repro.ckpt.manager as mgr_mod

    started = threading.Event()
    release = threading.Event()
    real_save = mgr_mod.save_checkpoint

    def slow_save(path, tree, **kw):
        started.set()
        assert release.wait(timeout=30)
        return real_save(path, tree, **kw)

    monkeypatch.setattr(mgr_mod, "save_checkpoint", slow_save)
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = make_tree()
    mgr.save_async(7, tree)
    assert started.wait(timeout=30)
    # un-synchronized, these would race the rename and miss step 7
    threading.Timer(0.05, release.set).start()
    assert mgr.steps() == [7]
    back, meta = mgr.restore(target_tree=tree)
    assert meta["step"] == 7
    assert_trees_equal(tree, back)


def test_restore_surfaces_async_save_error(tmp_path, monkeypatch):
    import repro.ckpt.manager as mgr_mod

    def boom(path, tree, **kw):
        raise RuntimeError("injected save failure")

    monkeypatch.setattr(mgr_mod, "save_checkpoint", boom)
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save_async(7, make_tree())
    with pytest.raises(RuntimeError, match="injected save failure"):
        mgr.restore()


def test_wait_self_join_guard(tmp_path):
    # save() -> _prune() -> steps() runs ON the async thread: wait() must
    # detect it and return instead of self-joining (deadlock)
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr._async_thread = threading.current_thread()
    mgr.wait()  # returns immediately; a join here would deadlock
    assert mgr._async_thread is threading.current_thread()
    mgr._async_thread = None

    # and the integration: back-to-back async saves with prune enabled
    mgr2 = CheckpointManager(str(tmp_path / "x"), keep=1)
    tree = make_tree()
    mgr2.save_async(1, tree)
    mgr2.save_async(2, tree)
    mgr2.wait()
    assert mgr2.steps() == [2]


# ---------------------------------------------------------------------------
# multi-process saves (the DESIGN.md §8.6 proof workload)


def test_mp_save_restore_roundtrip(tmp_path):
    tree = make_tree()
    p = str(tmp_path / "mp.rntj")
    report = save_checkpoint_mp(p, tree, n_processes=2,
                                row_block_bytes=4096, options=MP_OPTS,
                                metadata={"step": 1})
    assert not report["degraded"], report
    assert report["worker_exitcodes"] == [0, 0]
    assert not os.path.exists(p + ".mpwlog")
    back, meta = load_checkpoint(p, target_tree=tree)
    assert_trees_equal(tree, back)
    assert meta["step"] == 1


def test_mp_save_worker_killed_restore_succeeds(tmp_path):
    """Kill one of N writer processes mid-save: the seal degrades, strict
    restore refuses, strict=False restores every surviving parameter."""
    tree = make_tree(2)
    p = str(tmp_path / "mp.rntj")
    report = save_checkpoint_mp(p, tree, n_processes=2,
                                row_block_bytes=4096, options=MP_OPTS,
                                metadata={"step": 2},
                                crash_worker=1, crash_after_units=2)
    assert report["degraded"]
    assert report["worker_exitcodes"][1] != 0
    assert len(report["fenced"]) == 1

    with pytest.raises(IOError, match="incomplete"):
        load_checkpoint(p)

    back, meta = load_checkpoint(p, target_tree=tree, strict=False)
    missing = set(meta.get("restore_missing", []))
    assert missing, "a killed writer must leave at least one gap"
    flat_src, _ = jax.tree_util.tree_flatten_with_path(tree)
    flat_got = jax.tree_util.tree_leaves(back)
    for (path_, src), got in zip(flat_src, flat_got):
        if jax.tree_util.keystr(path_) not in missing:
            np.testing.assert_array_equal(
                np.asarray(src, np.float32), np.asarray(got, np.float32))


def test_manager_refuses_degraded_mp_save(tmp_path, monkeypatch):
    import repro.ckpt.manager as mgr_mod

    real = mgr_mod.save_checkpoint_mp

    def crashing(path, tree, **kw):
        return real(path, tree, crash_worker=0, crash_after_units=1, **kw)

    monkeypatch.setattr(mgr_mod, "save_checkpoint_mp", crashing)
    mgr = CheckpointManager(str(tmp_path), keep=3, processes=2,
                            mp_options=MP_OPTS)
    tree = make_tree()
    with pytest.raises(IOError, match="degraded"):
        mgr.save(5, tree)
    assert mgr.steps() == []  # nothing committed
    assert not list(tmp_path.glob("*.tmp"))      # tmp dropped
    assert not list(tmp_path.glob("*.mpwlog"))   # side-car dropped

    # explicit opt-in commits the salvaged file; restore needs strict=False
    mgr2 = CheckpointManager(str(tmp_path), keep=3, processes=2,
                             mp_options=MP_OPTS, allow_degraded=True)
    stats = mgr2.save(6, tree)
    assert stats["degraded"]
    assert mgr2.steps() == [6]
    back, meta = mgr2.restore(target_tree=tree, strict=False)
    assert meta.get("restore_missing")


def test_manager_mp_save_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, processes=2,
                            mp_options=MP_OPTS)
    tree = make_tree()
    stats = mgr.save(11, tree, {"loss": 0.5})
    assert not stats["degraded"]
    back, meta = mgr.restore(target_tree=tree)
    assert meta["step"] == 11 and meta["loss"] == 0.5
    assert_trees_equal(tree, back)
