"""Checkpoint substrate: parallel single-file save/restore, fault tolerance,
elastic restart (different writer counts), async saves."""

import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, load_checkpoint, save_checkpoint
from repro.core import RNTJReader


def make_tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "embed": jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32)),
        "layers": {
            "w": jnp.asarray(rng.normal(size=(4, 64, 64)).astype(np.float32)),
            "b": jnp.zeros((4, 64), jnp.bfloat16),
        },
        "step": jnp.asarray(123, jnp.int32),
    }


def assert_trees_equal(a, b):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        np.testing.assert_array_equal(
            np.asarray(x, np.float32), np.asarray(y, np.float32))


@pytest.mark.parametrize("n_writers", [1, 2, 7])
def test_save_restore_roundtrip(tmp_path, n_writers):
    tree = make_tree()
    p = str(tmp_path / "c.rntj")
    save_checkpoint(p, tree, n_writers=n_writers, row_block_bytes=4096)
    back, meta = load_checkpoint(p, target_tree=tree)
    assert_trees_equal(tree, back)


def test_restore_without_target_tree(tmp_path):
    tree = make_tree()
    p = str(tmp_path / "c.rntj")
    save_checkpoint(p, tree, n_writers=2)
    back, _ = load_checkpoint(p)
    assert_trees_equal(tree, back)


def test_elastic_restart_across_writer_counts(tmp_path):
    """File written by N writers restores identically regardless of N —
    the paper's reader-compatibility guarantee enables elastic rescale."""
    tree = make_tree(1)
    paths = []
    for n in (1, 3, 8):
        p = str(tmp_path / f"c{n}.rntj")
        save_checkpoint(p, tree, n_writers=n, row_block_bytes=2048)
        paths.append(p)
    restored = [load_checkpoint(p, target_tree=tree)[0] for p in paths]
    for r in restored:
        assert_trees_equal(tree, r)
    # logical equality even though cluster layouts differ
    layouts = {RNTJReader(p).n_clusters for p in paths}
    assert len(layouts) > 1  # genuinely different parallel layouts


def test_manager_rotation_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = make_tree()
    for step in (10, 20, 30):
        mgr.save(step, tree, {"loss": float(step)})
    assert mgr.steps() == [20, 30]
    back, meta = mgr.restore(target_tree=tree)
    assert meta["step"] == 30 and meta["loss"] == 30.0


def test_crash_mid_write_is_invisible(tmp_path):
    """A .tmp left by a crash is ignored and GC'd; committed ckpts survive."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = make_tree()
    mgr.save(10, tree)
    # simulate a crash: partial uncommitted file
    (tmp_path / "step_0000000020.rntj.tmp").write_bytes(b"partial garbage")
    mgr2 = CheckpointManager(str(tmp_path), keep=3)
    assert mgr2.latest_step() == 10
    assert not list(tmp_path.glob("*.tmp"))
    back, meta = mgr2.restore(target_tree=tree)
    assert meta["step"] == 10


def test_corrupt_committed_checkpoint_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = make_tree()
    mgr.save(10, tree)
    p = mgr.path_for(10)
    data = bytearray(p.read_bytes())
    data[len(data) // 2] ^= 0xFF
    p.write_bytes(bytes(data))
    with pytest.raises(IOError):
        mgr.restore(target_tree=tree)


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = make_tree()
    mgr.save_async(5, tree)
    mgr.wait()
    assert mgr.latest_step() == 5
    back, _ = mgr.restore(target_tree=tree)
    assert_trees_equal(tree, back)


def test_concurrent_writers_thread_safety(tmp_path):
    """Many writers, small row blocks: stress the critical section."""
    rng = np.random.default_rng(3)
    tree = {f"p{i}": jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
            for i in range(20)}
    p = str(tmp_path / "big.rntj")
    save_checkpoint(p, tree, n_writers=8, row_block_bytes=512)
    back, _ = load_checkpoint(p, target_tree=tree)
    assert_trees_equal(tree, back)
