"""Read engine: coalescing + parallel decode + prefetch vs the seed path.

Property-based round-trips of random nested schemas through
SequentialWriter/ParallelWriter and back through the rebuilt read engine,
asserting byte- and value-identity against the seed's per-page read path
(one pread per page, serial ``read_page``, ``np.concatenate`` per column
— reimplemented verbatim in :func:`seed_read_cluster`).
"""

import threading
from pathlib import Path

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (
    Collection, ColumnBatch, Leaf, MemorySink, ParallelWriter, RNTJReader,
    ReadOptions, Record, Schema, SequentialWriter, WriteOptions,
)
from repro.core.pages import read_page
from repro.core.schema import KIND_OFFSET


# ---------------------------------------------------------------------------
# the seed's per-page read path (the pre-refactor reference)


def seed_read_cluster(r, ci, columns=None):
    """One pread per page, serial decode, concatenate — the old hot path."""
    cm = r.clusters[ci]
    want = set(columns) if columns is not None else None
    parts = {}
    for desc in cm.pages:
        if want is not None and desc.column not in want:
            continue
        col = r.schema.columns[desc.column]
        buf = r.sink.pread(desc.offset, desc.size)
        parts.setdefault(desc.column, []).append(read_page(buf, desc, col, True))
    out = {}
    targets = want if want is not None else range(r.schema.n_columns)
    for idx in targets:
        col = r.schema.columns[idx]
        chunks = parts.get(idx, [])
        if chunks:
            out[idx] = np.concatenate(chunks) if len(chunks) > 1 else chunks[0]
        else:
            out[idx] = np.empty(0, dtype=col.dtype)
    return out


# ---------------------------------------------------------------------------
# random nested schemas + matching random data

LEAF_TYPES = ["int64", "int32", "uint8", "float32", "float64"]


@st.composite
def schemas(draw):
    def make_field(name, depth):
        kinds = ["leaf"] if depth == 0 else ["leaf", "coll", "rec"]
        kind = draw(st.sampled_from(kinds))
        if kind == "leaf":
            return Leaf(name, draw(st.sampled_from(LEAF_TYPES)))
        if kind == "coll":
            return Collection(name, make_field("_0", depth - 1))
        n_sub = draw(st.integers(1, 3))
        return Record(name, [make_field(f"r{i}", depth - 1)
                             for i in range(n_sub)])

    n_top = draw(st.integers(1, 4))
    return Schema([make_field(f"f{i}", 2) for i in range(n_top)])


def random_batch(schema, n, rng):
    """Random entries for ``schema`` in decomposed columnar (sizes) form."""
    counts, data = {}, {}
    for col in schema.columns:
        p = schema.parent[col.index]
        m = n if p == -1 else counts[p]
        if col.kind == KIND_OFFSET:
            sizes = rng.integers(0, 4, m).astype(np.int64)
            counts[col.index] = int(sizes.sum())
            data[col.index] = sizes
        else:
            counts[col.index] = m
            dt = col.dtype
            if dt.kind == "f":
                data[col.index] = rng.uniform(-100, 100, m).astype(dt)
            elif dt.kind == "u":
                data[col.index] = rng.integers(0, 200, m).astype(dt)
            else:
                data[col.index] = rng.integers(-1000, 1000, m).astype(dt)
    batch = ColumnBatch(schema, n, data)
    batch.validate()
    return batch


READ_OPTION_VARIANTS = [
    ReadOptions(prefetch_clusters=0, decode_workers=0, coalesce_gap=-1),
    ReadOptions(prefetch_clusters=0, decode_workers=0, coalesce_gap=0),
    ReadOptions(prefetch_clusters=2, decode_workers=2),
]


def assert_engine_matches_seed(sink, schema):
    """Every ReadOptions variant must decode byte-identically to the seed
    per-page path, for full reads and for column projections."""
    for ropts in READ_OPTION_VARIANTS:
        r = RNTJReader(sink, options=ropts)
        proj = [0, schema.n_columns - 1]
        for ci, cols in r.iter_clusters():
            ref = seed_read_cluster(r, ci)
            for i in range(schema.n_columns):
                assert cols[i].dtype == ref[i].dtype
                assert cols[i].tobytes() == ref[i].tobytes()
            sub = r.read_cluster(ci, columns=proj)
            for i in proj:
                assert sub[i].tobytes() == ref[i].tobytes()
        r.close()


@given(schemas(), st.integers(0, 300), st.sampled_from(["none", "zlib"]),
       st.integers(0, 2**31 - 1))
@settings(max_examples=12, deadline=None)
def test_roundtrip_sequential_matches_seed_path(schema, n, codec, seed):
    rng = np.random.default_rng(seed)
    batch = random_batch(schema, n, rng)
    sink = MemorySink()
    opts = WriteOptions(codec=codec, cluster_bytes=4096, page_size=512)
    with SequentialWriter(schema, sink, opts) as w:
        if n:
            w.fill_batch(batch)
    assert_engine_matches_seed(sink, schema)
    # value identity against the source batch, through the pipeline
    r = RNTJReader(sink, options=ReadOptions(prefetch_clusters=2,
                                             decode_workers=2))
    assert r.n_entries == n
    for col in schema.columns:
        got = r.read_column(col.path)
        if col.kind == KIND_OFFSET:
            np.testing.assert_array_equal(got, np.cumsum(batch.data[col.index]))
        else:
            np.testing.assert_array_equal(got, batch.data[col.index])
    r.close()


@given(schemas(), st.integers(1, 150), st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_roundtrip_parallel_matches_seed_path(schema, n, seed):
    rng = np.random.default_rng(seed)
    batches = [random_batch(schema, n, rng) for _ in range(2)]
    sink = MemorySink()
    w = ParallelWriter(schema, sink, WriteOptions(codec="zlib",
                                                  cluster_bytes=2048,
                                                  page_size=512))

    def producer(b):
        ctx = w.create_fill_context()
        ctx.fill_batch(b)
        ctx.close()

    ts = [threading.Thread(target=producer, args=(b,)) for b in batches]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    w.close()
    assert_engine_matches_seed(sink, schema)
    # entry conservation: leaf multisets survive regardless of cluster order
    r = RNTJReader(sink, options=ReadOptions(prefetch_clusters=1))
    assert r.n_entries == 2 * n
    for col in schema.columns:
        if col.kind != KIND_OFFSET:
            expect = np.sort(np.concatenate([b.data[col.index]
                                             for b in batches]))
            np.testing.assert_array_equal(np.sort(r.read_column(col.path)),
                                          expect)
    r.close()


# ---------------------------------------------------------------------------
# edges: empty files, empty collections, projection, PathLike


def test_empty_file_reads_cleanly():
    schema = Schema([Leaf("id", "int64"), Collection("v", Leaf("_0", "float32"))])
    sink = MemorySink()
    SequentialWriter(schema, sink, WriteOptions()).close()
    for ropts in READ_OPTION_VARIANTS:
        r = RNTJReader(sink, options=ropts)
        assert r.n_entries == 0 and r.n_clusters == 0
        assert list(r.iter_clusters()) == []
        assert len(r.read_column("v._0")) == 0
        assert list(r.iter_entries()) == []
        r.close()


def test_all_empty_collections_cluster():
    """A cluster whose collection column is all zeros has NO pages for the
    child column; the engine must still return an empty child array."""
    schema = Schema([Leaf("id", "int64"), Collection("v", Leaf("_0", "float32"))])
    sink = MemorySink()
    n = 50
    batch = ColumnBatch.from_arrays(schema, n, {
        "id": np.arange(n, dtype=np.int64),
        "v": np.zeros(n, dtype=np.int64),
        "v._0": np.empty(0, dtype=np.float32),
    })
    with SequentialWriter(schema, sink, WriteOptions(codec="none")) as w:
        w.fill_batch(batch)
    for ropts in READ_OPTION_VARIANTS:
        r = RNTJReader(sink, options=ropts)
        _, cols = next(iter(r.iter_clusters()))
        assert len(cols[2]) == 0 and cols[2].dtype == np.float32
        np.testing.assert_array_equal(cols[1], np.zeros(n, dtype=np.int64))
        entries = list(r.iter_entries())
        assert all(e["v"] == [] for e in entries)
        r.close()


def test_column_projection_reads_only_requested_pages(tmp_path):
    schema = Schema([Leaf("id", "int64"), Collection("v", Leaf("_0", "float32"))])
    rng = np.random.default_rng(5)
    n = 4000
    sizes = rng.poisson(6, n).astype(np.int64)
    batch = ColumnBatch.from_arrays(schema, n, {
        "id": np.arange(n, dtype=np.int64), "v": sizes,
        "v._0": rng.uniform(0, 1, int(sizes.sum())).astype(np.float32),
    })
    path = str(tmp_path / "p.rntj")
    with SequentialWriter(schema, path, WriteOptions(codec="none")) as w:
        w.fill_batch(batch)
    r = RNTJReader(path, options=ReadOptions(prefetch_clusters=0))
    cols = r.read_cluster(0, columns=[0])
    assert set(cols) == {0}
    # only column 0's pages were read: fewer bytes than the whole cluster
    full_bytes = sum(p.size for p in r.clusters[0].pages)
    col0_bytes = sum(p.size for p in r.clusters[0].pages if p.column == 0)
    assert col0_bytes < full_bytes
    assert r.stats.compressed_bytes == col0_bytes
    r.close()


def test_pathlike_reader_and_writers(tmp_path):
    schema = Schema([Leaf("id", "int64")])
    p = tmp_path / "pathlike.rntj"  # a pathlib.Path, not str
    with SequentialWriter(schema, p, WriteOptions()) as w:
        w.fill({"id": 1})
    with RNTJReader(p) as r:
        assert r.n_entries == 1
    p2 = tmp_path / "pathlike2.rntj"
    w = ParallelWriter(schema, p2, WriteOptions())
    ctx = w.create_fill_context()
    ctx.fill({"id": 2})
    ctx.close()
    w.close()
    with RNTJReader(p2) as r:
        assert list(r.iter_entries()) == [{"id": 2}]


def test_reader_stats_phases(tmp_path):
    schema = Schema([Leaf("id", "int64"), Collection("v", Leaf("_0", "float32"))])
    rng = np.random.default_rng(1)
    n = 20_000
    sizes = rng.poisson(5, n).astype(np.int64)
    batch = ColumnBatch.from_arrays(schema, n, {
        "id": np.arange(n, dtype=np.int64), "v": sizes,
        "v._0": rng.uniform(0, 1, int(sizes.sum())).astype(np.float32),
    })
    path = str(tmp_path / "s.rntj")
    with SequentialWriter(schema, path,
                          WriteOptions(codec="zlib", cluster_bytes=256 * 1024,
                                       page_size=8192)) as w:
        w.fill_batch(batch)
    r = RNTJReader(path, options=ReadOptions(prefetch_clusters=1,
                                             decode_workers=2))
    for _ci, _cols in r.iter_clusters():
        pass
    s = r.stats
    assert s.clusters == r.n_clusters
    assert s.pages == sum(len(c.pages) for c in r.clusters)
    assert 0 < s.coalesced_reads <= s.pages  # coalescing actually merged
    assert s.decompress_ns > 0 and s.decode_ns > 0
    assert s.uncompressed_bytes >= s.compressed_bytes
    assert set(s.phases_ms()) == {"io", "decompress", "decode", "wait", "h2d"}
    r.close()
    assert s.io.bytes_read >= s.compressed_bytes  # merged on close


def test_reader_init_failure_closes_file(tmp_path):
    """A corrupt file must not leak the fd the reader opened itself."""
    import os
    p = tmp_path / "bad.rntj"
    p.write_bytes(b"\x00" * 256)  # garbage anchor
    fds_before = len(os.listdir("/proc/self/fd"))
    for _ in range(5):
        with pytest.raises(Exception):
            RNTJReader(str(p))
    assert len(os.listdir("/proc/self/fd")) <= fds_before


def test_checksum_verification_via_engine(tmp_path):
    """Corruption must be detected on the coalesced + pooled path too."""
    schema = Schema([Leaf("id", "int64"), Collection("v", Leaf("_0", "float32"))])
    rng = np.random.default_rng(2)
    n = 2000
    sizes = rng.poisson(5, n).astype(np.int64)
    batch = ColumnBatch.from_arrays(schema, n, {
        "id": np.arange(n, dtype=np.int64), "v": sizes,
        "v._0": rng.uniform(0, 1, int(sizes.sum())).astype(np.float32),
    })
    path = str(tmp_path / "c.rntj")
    with SequentialWriter(schema, path, WriteOptions()) as w:
        w.fill_batch(batch)
    r = RNTJReader(path)
    page0 = r.clusters[0].pages[0]
    r.close()
    with open(path, "r+b") as f:
        f.seek(page0.offset + page0.size // 2)
        f.write(b"\xff\xfe")
    for ropts in READ_OPTION_VARIANTS:
        r = RNTJReader(path, options=ropts)
        with pytest.raises(IOError):
            for _ in r.iter_clusters():
                pass
        r.close()
