"""Training loop: loss goes down, exact restart, stragglers, compression."""

import time

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.launch.mesh import make_local_mesh
from repro.models import build
from repro.pipeline import PackedLoader, ingest_corpus, synth_corpus
from repro.train import LoopConfig, TrainLoop, make_optimizer


def tiny_cfg():
    return get_arch("smollm-360m").with_(
        name="tiny", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256, remat=False,
    )


@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    p = str(tmp_path_factory.mktemp("data") / "c.rntj")
    ingest_corpus(synth_corpus(300, seed=0, mean_len=80, vocab=256), p,
                  n_workers=2)
    return p


def make_loop(corpus, ckpt_dir, steps=20, **cfg_kw):
    bundle = build(tiny_cfg())
    loader = PackedLoader(corpus, batch=4, seq_len=32)
    return TrainLoop(
        bundle, make_local_mesh(), loader, ckpt_dir,
        config=LoopConfig(steps=steps, ckpt_every=10, log_every=1000,
                          ckpt_async=False, **cfg_kw),
        optimizer=make_optimizer(peak_lr=5e-3, warmup=5, total=200),
    )


def test_loss_decreases(corpus, tmp_path):
    loop = make_loop(corpus, str(tmp_path / "ck"), steps=60)
    hist = loop.run()
    first = np.mean([h.loss for h in hist[:5]])
    last = np.mean([h.loss for h in hist[-5:]])
    assert last < first - 0.05, (first, last)


def test_restart_is_exact(corpus, tmp_path):
    """20 straight steps == 10 steps + crash + 10 resumed steps."""
    a = make_loop(corpus, str(tmp_path / "a"), steps=20)
    a.run()
    ref = jax.tree_util.tree_leaves(a.params)

    b1 = make_loop(corpus, str(tmp_path / "b"), steps=10)
    b1.run()
    del b1  # "crash" after the step-10 checkpoint
    b2 = make_loop(corpus, str(tmp_path / "b"), steps=10)
    assert b2.step == 10  # restored
    b2.run()
    got = jax.tree_util.tree_leaves(b2.params)
    for x, y in zip(ref, got):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   atol=1e-6, rtol=1e-6)


def test_straggler_detection(corpus, tmp_path):
    events = []
    loop = make_loop(corpus, str(tmp_path / "s"), steps=10)
    loop.on_straggler = events.append
    loop.run()                       # warm: builds timing baseline
    orig = loop._step_fn

    def slow(*a):
        time.sleep(max(0.2, 10 * np.median(loop._step_times)))
        return orig(*a)

    loop._step_fn = slow
    loop.run(steps=1)
    assert events and events[-1].straggler


def test_grad_compression_runs(corpus, tmp_path):
    loop = make_loop(corpus, str(tmp_path / "g"), steps=10,
                     grad_compression=True)
    hist = loop.run()
    assert all(np.isfinite(h.loss) for h in hist)


def test_microbatched_matches_plain(corpus, tmp_path):
    """Gradient accumulation matches the single-batch step (absolute tol:
    bf16 reduction-order differences pass through Adam's 1/sqrt(v) early)."""
    a = make_loop(corpus, str(tmp_path / "m1"), steps=3)
    a.run()
    b = make_loop(corpus, str(tmp_path / "m2"), steps=3, microbatches=2)
    b.run()
    for x, y in zip(jax.tree_util.tree_leaves(a.params),
                    jax.tree_util.tree_leaves(b.params)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=2e-2)
    assert abs(a.history[-1].loss - b.history[-1].loss) < 0.05
