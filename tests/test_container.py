"""Container sinks and metadata envelope edge cases."""

import struct
import time

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import DevNullSink, FileSink, MemorySink, ThrottledSink
from repro.core.metadata import (
    ANCHOR_SIZE, build_anchor, build_footer, build_header, build_pagelist,
    parse_anchor, parse_footer, parse_header, parse_pagelist,
    wrap_envelope, unwrap_envelope, ClusterMeta, ENV_HEADER,
)
from repro.core.pages import PageDesc
from repro.core.schema import Leaf, Schema


def test_memory_sink_positioned_writes():
    s = MemorySink()
    off1 = s.reserve(4)
    off2 = s.reserve(4)
    s.pwrite(off2, b"wxyz")       # out of order on purpose
    s.pwrite(off1, b"abcd")
    assert s.pread(0, 8) == b"abcdwxyz"
    assert s.size == 8


def test_devnull_counts_bytes():
    s = DevNullSink()
    s.reserve(100)
    s.pwrite(0, b"x" * 100)
    assert s.io.bytes_written == 100
    assert s.io.write_calls == 1
    with pytest.raises(IOError):
        s.pread(0, 1)


def test_file_sink_roundtrip(tmp_path):
    p = str(tmp_path / "f.bin")
    s = FileSink(p)
    off = s.reserve(6)
    s.pwrite(off, b"hello!")
    s.fallocate(off, 6)
    assert s.io.fallocate_calls == 1
    assert s.pread(0, 6) == b"hello!"
    s.close()
    s2 = FileSink(p, create=False)
    assert s2.size == 6
    s2.close()


def test_throttled_sink_enforces_bandwidth():
    inner = DevNullSink()
    s = ThrottledSink(inner, bw=1e6)      # 1 MB/s
    t0 = time.perf_counter()
    s.pwrite(s.reserve(200_000), b"x" * 200_000)
    dt = time.perf_counter() - t0
    assert dt >= 0.15                     # ~0.2 s at 1 MB/s


def test_throttled_prealloc_bandwidth():
    inner = DevNullSink()
    s = ThrottledSink(inner, bw=1e6, bw_prealloc=10e6)
    off = s.reserve(200_000)
    s.fallocate(off, 200_000)
    t0 = time.perf_counter()
    s.pwrite(off, b"x" * 200_000)
    dt = time.perf_counter() - t0
    assert dt < 0.1                       # 10x faster on preallocated extent


# ---------------------------------------------------------------------------
# metadata envelopes


@given(st.binary(max_size=2000))
@settings(max_examples=50, deadline=None)
def test_envelope_roundtrip(payload):
    buf = wrap_envelope(ENV_HEADER, payload)
    assert unwrap_envelope(buf, ENV_HEADER) == payload


def test_envelope_detects_corruption():
    buf = bytearray(wrap_envelope(ENV_HEADER, b"payload-data"))
    buf[20] ^= 0xFF
    with pytest.raises(IOError):
        unwrap_envelope(bytes(buf), ENV_HEADER)


def test_anchor_roundtrip_and_corruption():
    a = build_anchor((10, 20), (30, 40), 1000, 7)
    assert len(a) == ANCHOR_SIZE
    d = parse_anchor(a)
    assert d["header"] == (10, 20) and d["footer"] == (30, 40)
    assert d["n_entries"] == 1000 and d["n_clusters"] == 7
    bad = bytearray(a)
    bad[5] ^= 1
    with pytest.raises(IOError):
        parse_anchor(bytes(bad))


def test_header_roundtrip():
    schema = Schema([Leaf("x", "int32")])
    buf = build_header(schema, {"codec": 1})
    s2, opts = parse_header(buf)
    assert s2 == schema and opts["codec"] == 1


@given(st.lists(st.tuples(
    st.integers(0, 100), st.integers(0, 10_000), st.integers(0, 2**31),
    st.integers(0, 2**20), st.integers(0, 2**20),
), max_size=20))
@settings(max_examples=40, deadline=None)
def test_pagelist_roundtrip(pages):
    descs = [PageDesc(column=c % 3, n_elements=n, offset=o, size=s,
                      uncompressed_size=u, checksum=123, codec=1)
             for c, n, o, s, u in pages]
    cm = ClusterMeta(first_entry=5, n_entries=17, n_elements=[1, 2, 3],
                     pages=descs, byte_offset=99, byte_size=1234)
    buf = build_pagelist([cm], 3)
    back = parse_pagelist(buf)
    assert len(back) == 1
    b = back[0]
    assert (b.first_entry, b.n_entries, b.n_elements) == (5, 17, [1, 2, 3])
    assert len(b.pages) == len(descs)
    for p, q in zip(b.pages, descs):
        assert (p.column, p.n_elements, p.offset, p.size,
                p.uncompressed_size, p.codec) == (
            q.column, q.n_elements, q.offset, q.size,
            q.uncompressed_size, q.codec)
