"""Hypothesis fallback shim.

The test suite is written against the real ``hypothesis`` API; this module
re-exports it when installed (``pip install -r requirements-dev.txt``) and
otherwise provides a tiny deterministic random-example runner implementing
the subset the suite uses: ``given``, ``settings``, ``assume`` and the
``integers / floats / lists / binary / tuples / sampled_from / composite``
strategies.  No shrinking and no database — just seeded example generation
so the suite still collects and runs without the dependency.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised when hypothesis is installed
    from hypothesis import HealthCheck, assume, given, settings
    from hypothesis import strategies
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import random
    import zlib as _zlib

    _DEFAULT_MAX_EXAMPLES = 30

    class _Unsatisfied(Exception):
        pass

    def assume(condition):
        if not condition:
            raise _Unsatisfied()
        return True

    class HealthCheck:  # placeholder namespace
        all = staticmethod(lambda: [])
        too_slow = "too_slow"
        filter_too_much = "filter_too_much"

    class _Strategy:
        def example(self, rng: random.Random):
            raise NotImplementedError

        def map(self, fn):
            return _MappedStrategy(self, fn)

    class _MappedStrategy(_Strategy):
        def __init__(self, inner, fn):
            self.inner, self.fn = inner, fn

        def example(self, rng):
            return self.fn(self.inner.example(rng))

    class _Integers(_Strategy):
        def __init__(self, min_value=None, max_value=None):
            self.lo = -(2**64) if min_value is None else min_value
            self.hi = 2**64 if max_value is None else max_value

        def example(self, rng):
            # bias toward boundaries: they carry most of the bug-finding power
            r = rng.random()
            if r < 0.05:
                return self.lo
            if r < 0.10:
                return self.hi
            if r < 0.20 and self.lo <= 0 <= self.hi:
                return 0
            return rng.randint(self.lo, self.hi)

    class _Floats(_Strategy):
        def __init__(self, min_value=None, max_value=None, width=64,
                     allow_nan=None, allow_infinity=None):
            self.lo = -1e308 if min_value is None else float(min_value)
            self.hi = 1e308 if max_value is None else float(max_value)
            self.width = width

        def example(self, rng):
            r = rng.random()
            if r < 0.05:
                v = self.lo
            elif r < 0.10:
                v = self.hi
            elif r < 0.15 and self.lo <= 0.0 <= self.hi:
                v = 0.0
            elif self.hi - self.lo == float("inf"):
                # rng.uniform overflows to inf when the span does; draw
                # magnitude and sign separately instead
                v = rng.uniform(0.0, min(abs(self.lo), abs(self.hi), 1e308))
                v = -v if rng.random() < 0.5 and self.lo <= -v else v
                v = min(max(v, self.lo), self.hi)
            else:
                v = rng.uniform(self.lo, self.hi)
            if self.width == 32:
                import numpy as np

                v = float(np.float32(v))
                # float32 rounding may step outside a tight [lo, hi]
                v = min(max(v, self.lo), self.hi)
            elif self.width == 16:
                import numpy as np

                v = float(np.float16(v))
                v = min(max(v, self.lo), self.hi)
            return v

    class _Lists(_Strategy):
        def __init__(self, elements, min_size=0, max_size=None, unique=False):
            self.elements = elements
            self.min_size = min_size
            self.max_size = max_size if max_size is not None else min_size + 20
            self.unique = unique

        def example(self, rng):
            n = rng.randint(self.min_size, self.max_size)
            out = [self.elements.example(rng) for _ in range(n)]
            if self.unique:
                seen, uniq = set(), []
                for v in out:
                    if v not in seen:
                        seen.add(v)
                        uniq.append(v)
                out = uniq
            return out

    class _Binary(_Strategy):
        def __init__(self, min_size=0, max_size=None):
            self.min_size = min_size
            self.max_size = max_size if max_size is not None else min_size + 100

        def example(self, rng):
            n = rng.randint(self.min_size, self.max_size)
            return rng.randbytes(n) if hasattr(rng, "randbytes") else bytes(
                rng.getrandbits(8) for _ in range(n)
            )

    class _Tuples(_Strategy):
        def __init__(self, *strategies):
            self.strategies = strategies

        def example(self, rng):
            return tuple(s.example(rng) for s in self.strategies)

    class _SampledFrom(_Strategy):
        def __init__(self, elements):
            self.elements = list(elements)

        def example(self, rng):
            return rng.choice(self.elements)

    class _Composite(_Strategy):
        def __init__(self, fn, args, kwargs):
            self.fn, self.args, self.kwargs = fn, args, kwargs

        def example(self, rng):
            draw = lambda strategy: strategy.example(rng)
            return self.fn(draw, *self.args, **self.kwargs)

    class _StrategiesModule:
        @staticmethod
        def integers(min_value=None, max_value=None):
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value=None, max_value=None, **kw):
            return _Floats(min_value, max_value, **kw)

        @staticmethod
        def lists(elements, min_size=0, max_size=None, unique=False):
            return _Lists(elements, min_size, max_size, unique)

        @staticmethod
        def binary(min_size=0, max_size=None):
            return _Binary(min_size, max_size)

        @staticmethod
        def tuples(*args):
            return _Tuples(*args)

        @staticmethod
        def sampled_from(elements):
            return _SampledFrom(elements)

        @staticmethod
        def composite(fn):
            def make(*args, **kwargs):
                return _Composite(fn, args, kwargs)

            return make

    strategies = _StrategiesModule()

    def settings(**kwargs):
        def apply(fn):
            merged = dict(getattr(fn, "_compat_settings", {}))
            merged.update(kwargs)
            fn._compat_settings = merged
            return fn

        return apply

    def given(*arg_strategies, **kw_strategies):
        def decorate(fn):
            import inspect

            # hypothesis fills the RIGHTMOST positional parameters from
            # the strategies; leftover leftmost params stay visible in the
            # signature so pytest still injects fixtures for them
            params = [
                p for p in inspect.signature(fn).parameters.values()
                if p.name not in kw_strategies
            ]
            leftover = params[: len(params) - len(arg_strategies)]

            @functools.wraps(fn)
            def runner(*fixture_args, **fixture_kwargs):
                conf = getattr(runner, "_compat_settings",
                               getattr(fn, "_compat_settings", {}))
                max_examples = conf.get("max_examples", _DEFAULT_MAX_EXAMPLES)
                # deterministic per-test seed, stable across runs
                seed = _zlib.crc32(fn.__qualname__.encode())
                rng = random.Random(seed)
                ran = 0
                attempts = 0
                while ran < max_examples and attempts < max_examples * 20:
                    attempts += 1
                    args = [s.example(rng) for s in arg_strategies]
                    kwargs = {k: s.example(rng) for k, s in kw_strategies.items()}
                    try:
                        fn(*fixture_args, *args, **fixture_kwargs, **kwargs)
                    except _Unsatisfied:
                        continue
                    ran += 1
                if ran == 0 and attempts:
                    raise AssertionError(
                        f"{fn.__qualname__}: assume() rejected all "
                        f"{attempts} generated examples — property never "
                        f"checked (unsatisfiable assumption?)"
                    )

            runner.hypothesis_compat = True
            runner.__signature__ = inspect.Signature(leftover)
            return runner

        return decorate


# the canonical import spelling used by the test modules
st = strategies

__all__ = ["HAVE_HYPOTHESIS", "HealthCheck", "assume", "given", "settings",
           "st", "strategies"]
