"""Object-store sink failure semantics (ISSUE 9, DESIGN.md §10).

The tentpole matrix: byte-identity under zero faults, retry-until-success
vs retry-exhaustion, per-attempt deadline enforcement, hedge-wins-race
determinism, interrupted-multipart salvage round-trips, degraded-mode
fallback — all over the hermetic :class:`FakeTransport`.  Plus the
satellite regressions: :class:`FaultInjectingSink` injecting on the
zero-copy ``pread_into`` path, and the reader-level retry chokepoint
(``ReadOptions.retry_policy`` → ``ReaderStats.retries``).
"""

import errno
import sys
import threading
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    Collection,
    FaultInjectingSink,
    FaultSchedule,
    FaultSpec,
    Leaf,
    MemorySink,
    ParallelWriter,
    ProcessKilled,
    ReadOptions,
    RecoveryError,
    RNTJReader,
    RetryPolicy,
    Schema,
    SequentialWriter,
    WriteOptions,
    open_sink,
    recover_container,
)
from repro.core.faults import memory_sink_from_bytes
from repro.core.remote import (
    FakeTransport,
    ObjectBucket,
    ObjectStoreSink,
    RemoteOptions,
    _add_interval,
    mem_bucket,
    parse_remote_url,
    reset_mem_buckets,
    salvage_remote,
)

REPO_ROOT = Path(__file__).resolve().parent.parent

SCHEMA = Schema([
    Leaf("id", "int64"),
    Collection("vals", Leaf("_0", "float32")),
])

# fast deterministic backoff: tests must not sleep for real
FAST = RetryPolicy(max_attempts=6, backoff_base=0.0001, backoff_cap=0.0005)
FAST_OPTS = RemoteOptions(part_bytes=256, retry_policy=FAST)


def make_entries(n, seed=0):
    rng = np.random.default_rng(seed)
    lens = rng.integers(0, 6, size=n)
    return [
        {"id": int(i),
         "vals": [float(v) for v in rng.random(lens[i], dtype=np.float32)]}
        for i in range(n)
    ]


def write_seq(sink, entries, **kw):
    opts = WriteOptions(cluster_bytes=kw.pop("cluster_bytes", 2048),
                        retry_policy=kw.pop("retry_policy", FAST), **kw)
    w = SequentialWriter(SCHEMA, sink, opts)
    for e in entries:
        w.fill(e)
    w.close()
    return w


def reference_bytes(entries, **kw):
    ms = MemorySink()
    write_seq(ms, entries, **kw)
    data = bytes(ms.buf[: ms.size])
    ms.close()
    return data


def read_all(sink_or_path, options=None):
    r = RNTJReader(sink_or_path, options=options)
    try:
        return list(r.iter_entries())
    finally:
        r.close()


# ---------------------------------------------------------------------------
# Part-interval bookkeeping unit
# ---------------------------------------------------------------------------


def test_add_interval_merges():
    iv = []
    _add_interval(iv, 10, 20)
    _add_interval(iv, 30, 40)
    assert iv == [(10, 20), (30, 40)]
    _add_interval(iv, 20, 30)          # bridges the gap
    assert iv == [(10, 40)]
    _add_interval(iv, 0, 5)
    _add_interval(iv, 5, 10)           # touching merges
    assert iv == [(0, 40)]
    _add_interval(iv, 50, 60)
    _add_interval(iv, 45, 55)
    assert iv == [(0, 40), (45, 60)]


def test_parse_remote_url():
    scheme, bucket, key, opts, params = parse_remote_url(
        "mem-s3://bkt/dir/file.rntj?part_bytes=4096&remote_hedge_ms=5&rtt_ms=10")
    assert (scheme, bucket, key) == ("mem-s3", "bkt", "dir/file.rntj")
    assert opts.part_bytes == 4096 and opts.hedge_ms == 5.0
    assert params == {"rtt_ms": "10"}
    with pytest.raises(ValueError):
        parse_remote_url("mem-s3://bucketonly")
    with pytest.raises(ValueError):
        open_sink("no-such-scheme://b/k")


# ---------------------------------------------------------------------------
# Byte identity under zero faults
# ---------------------------------------------------------------------------


def test_byte_identity_zero_faults(tmp_path):
    entries = make_entries(400)
    ref = reference_bytes(entries)

    # vs FileSink
    from repro.core import FileSink
    fsink = FileSink(str(tmp_path / "ref.rntj"))
    write_seq(fsink, entries)
    fsink.close()
    assert (tmp_path / "ref.rntj").read_bytes() == ref

    # remote multipart
    t = FakeTransport(ObjectBucket())
    s = ObjectStoreSink(t, "k", FAST_OPTS)
    write_seq(s, entries)
    s.close()
    assert t.bucket.objects["k"] == ref
    # zero faults -> zero retries, hedges, degradations
    assert s.io.retries == 0 and s.io.giveups == 0
    assert s.io.hedges == 0 and s.io.degradations == 0
    # multipart actually ran: nothing left dangling
    assert t.bucket.uploads.get("k", {}) == {}

    # remote serial-put mode is identical too
    t2 = FakeTransport(ObjectBucket())
    s2 = ObjectStoreSink(t2, "k", RemoteOptions(part_bytes=256,
                                                retry_policy=FAST,
                                                multipart=False))
    write_seq(s2, entries)
    s2.close()
    assert t2.bucket.objects["k"] == ref
    assert s2.io.degradations == 0


def test_url_roundtrip_and_reader_routing():
    reset_mem_buckets()
    entries = make_entries(300, seed=3)
    sink = open_sink("mem-s3://rt/test.rntj?part_bytes=512")
    assert isinstance(sink, ObjectStoreSink)
    write_seq(sink, entries)
    sink.close()
    assert mem_bucket("rt").objects["test.rntj"] == reference_bytes(entries)
    # RNTJReader routes URLs through open_sink(create=False)
    got = read_all("mem-s3://rt/test.rntj")
    assert [dict(e) for e in got] == entries


def test_write_mode_local_reads_and_flush():
    t = FakeTransport(ObjectBucket())
    s = ObjectStoreSink(t, "k", FAST_OPTS)
    off = s.reserve(600)
    s.pwrite(off, b"x" * 600)
    # write-mode preads serve from retained buffers, holes read as zeros
    assert s.pread(0, 600) == b"x" * 600
    assert s.pread(600, 10) == b"\x00" * 10
    # parts 0 and 1 are fully covered by the 600-byte write: flush (and the
    # pwrite itself) ships them
    s.flush()
    parts = next(iter(t.bucket.uploads["k"].values()))
    assert sorted(parts) == [1, 2]
    s.close()
    assert t.bucket.objects["k"] == b"x" * 600


# ---------------------------------------------------------------------------
# Retry semantics
# ---------------------------------------------------------------------------


def test_retry_until_success():
    entries = make_entries(400)
    ref = reference_bytes(entries)
    sched = FaultSchedule([FaultSpec.transient_error(op="part", count=3)])
    t = FakeTransport(ObjectBucket(), schedule=sched)
    s = ObjectStoreSink(t, "k", FAST_OPTS)
    write_seq(s, entries)
    s.close()
    assert t.bucket.objects["k"] == ref
    assert s.io.retries >= 3
    assert s.io.giveups == 0 and s.io.degradations == 0


def test_torn_part_retried_idempotently():
    entries = make_entries(400)
    ref = reference_bytes(entries)
    # two torn part uploads: a prefix lands in the store, the call fails,
    # the retry re-uploads the full part over the same part number
    sched = FaultSchedule([FaultSpec(op="part", kind="short", count=2,
                                     fraction=0.5)])
    t = FakeTransport(ObjectBucket(), schedule=sched)
    s = ObjectStoreSink(t, "k", FAST_OPTS)
    write_seq(s, entries)
    s.close()
    assert t.bucket.objects["k"] == ref
    assert sched.stats.short_writes == 2
    assert s.io.retries >= 2


def test_read_retry_exhaustion_counts_giveup():
    ref = reference_bytes(make_entries(200))
    sched = FaultSchedule([FaultSpec.permanent_error(op="get")])
    t = FakeTransport(ObjectBucket(), schedule=sched)
    t.bucket.objects["k"] = ref
    s = ObjectStoreSink(t, "k", RemoteOptions(retry_policy=FAST),
                        create=False)
    with pytest.raises(OSError):
        s.pread(0, 100)
    assert s.io.retries == FAST.max_attempts - 1
    assert s.io.giveups == 1
    s.close()


def test_torn_get_retried():
    ref = reference_bytes(make_entries(200))
    sched = FaultSchedule([FaultSpec.short_read(op="get", count=2,
                                                fraction=0.25)])
    t = FakeTransport(ObjectBucket(), schedule=sched)
    t.bucket.objects["k"] = ref
    s = ObjectStoreSink(t, "k", RemoteOptions(retry_policy=FAST),
                        create=False)
    assert s.pread(0, 200) == ref[:200]
    assert sched.stats.short_reads == 2
    assert s.io.retries >= 2
    s.close()


def test_deadline_enforcement():
    ref = reference_bytes(make_entries(200))
    # one slow GET (80 ms service) against a 20 ms per-attempt deadline:
    # the attempt burns its deadline, fails with ETIMEDOUT (retryable),
    # and the retry — no longer hit by the latency rule — succeeds
    sched = FaultSchedule([FaultSpec.latency(0.08, op="get", count=1)])
    t = FakeTransport(ObjectBucket(), schedule=sched)
    t.bucket.objects["k"] = ref
    s = ObjectStoreSink(t, "k", RemoteOptions(deadline_ms=20,
                                              retry_policy=FAST),
                        create=False)
    assert s.pread(0, 128) == ref[:128]
    assert s.io.retries >= 1
    s.close()

    # permanent slowness exhausts the retry budget with ETIMEDOUT
    sched = FaultSchedule([FaultSpec.latency(0.05, op="get", count=-1)])
    t = FakeTransport(ObjectBucket(), schedule=sched)
    t.bucket.objects["k"] = ref
    s = ObjectStoreSink(t, "k",
                        RemoteOptions(deadline_ms=10,
                                      retry_policy=RetryPolicy(
                                          max_attempts=3,
                                          backoff_base=0.0001,
                                          backoff_cap=0.0005)),
                        create=False)
    with pytest.raises(OSError) as ei:
        s.pread(0, 64)
    assert ei.value.errno == errno.ETIMEDOUT
    assert s.io.giveups == 1
    s.close()


def test_hedge_wins_race():
    entries = make_entries(300)
    ref = reference_bytes(entries)
    # scripted slow tail on the FIRST ranged GET only: the primary stalls
    # 200 ms, the hedge (the second "get" call) is instant and wins —
    # deterministic because the schedule is scripted, not sampled
    sched = FaultSchedule([FaultSpec.latency(0.2, op="get", count=1)])
    t = FakeTransport(ObjectBucket(), schedule=sched)
    t.bucket.objects["k"] = ref
    s = ObjectStoreSink(t, "k", RemoteOptions(hedge_ms=10,
                                              retry_policy=FAST),
                        create=False)
    r = RNTJReader(s)
    got = list(r.iter_entries())
    r.close()
    assert [dict(e) for e in got] == entries
    d = r.stats.as_dict()
    assert d["io_hedges"] >= 1
    assert d["io_hedge_wins"] >= 1
    assert d["retries"] == 0  # the hedge raced, nothing had to fail


def test_hedge_survives_failing_primary():
    # the hedged pair tolerates one of the two attempts erroring outright
    ref = reference_bytes(make_entries(100))
    sched = FaultSchedule([
        FaultSpec.latency(0.2, op="get", count=1),
        FaultSpec.transient_error(op="get", count=1, at_call=1),
    ])
    t = FakeTransport(ObjectBucket(), schedule=sched)
    t.bucket.objects["k"] = ref
    s = ObjectStoreSink(t, "k", RemoteOptions(hedge_ms=10,
                                              retry_policy=FAST),
                        create=False)
    # hedge (call 1) errors; the slow primary (call 0) still answers
    assert s.pread(0, 100) == ref[:100]
    assert s.io.hedges >= 1
    s.close()


# ---------------------------------------------------------------------------
# Degraded mode
# ---------------------------------------------------------------------------


def test_degraded_fallback_is_lossless():
    entries = make_entries(400)
    ref = reference_bytes(entries)
    sched = FaultSchedule([FaultSpec.permanent_error(op="part")])
    t = FakeTransport(ObjectBucket(), schedule=sched)
    s = ObjectStoreSink(t, "k", FAST_OPTS)
    write_seq(s, entries)
    s.close()
    # multipart never succeeded; the serial put carried the bytes
    assert t.bucket.objects["k"] == ref
    assert s.io.degradations == 1
    assert s.io.retries > 0
    # the dangling upload was aborted during close
    assert t.bucket.uploads.get("k", {}) == {}
    assert [dict(e) for e in
            read_all(ObjectStoreSink(FakeTransport(t.bucket), "k",
                                     create=False))] == entries


def test_degraded_create_multipart():
    entries = make_entries(200)
    ref = reference_bytes(entries)
    sched = FaultSchedule([FaultSpec.permanent_error(op="create")])
    t = FakeTransport(ObjectBucket(), schedule=sched)
    s = ObjectStoreSink(t, "k", FAST_OPTS)
    assert s.io.degradations == 1  # degraded at open, before any write
    write_seq(s, entries)
    s.close()
    assert t.bucket.objects["k"] == ref


def test_degraded_complete_multipart():
    entries = make_entries(300)
    ref = reference_bytes(entries)
    sched = FaultSchedule([FaultSpec.permanent_error(op="complete")])
    t = FakeTransport(ObjectBucket(), schedule=sched)
    s = ObjectStoreSink(t, "k", FAST_OPTS)
    write_seq(s, entries)
    s.close()
    assert t.bucket.objects["k"] == ref
    assert s.io.degradations == 1


# ---------------------------------------------------------------------------
# Interrupted multipart -> salvage
# ---------------------------------------------------------------------------


def _kill_mid_multipart(entries, at_call=10, part_bytes=256):
    sched = FaultSchedule([FaultSpec(op="part", kind="kill",
                                     at_call=at_call)])
    bkt = ObjectBucket()
    t = FakeTransport(bkt, schedule=sched)
    s = ObjectStoreSink(t, "k", RemoteOptions(part_bytes=part_bytes,
                                              retry_policy=FAST))
    with pytest.raises((ProcessKilled, RuntimeError)):
        write_seq(s, entries)
    s.close()  # poisoned teardown must not raise
    assert "k" not in bkt.objects
    assert bkt.uploads["k"], "interrupted upload must survive the crash"
    return bkt


def test_interrupted_multipart_salvage_roundtrip():
    entries = make_entries(2000, seed=11)
    bkt = _kill_mid_multipart(entries)
    # a fresh transport over the same bucket is the recovery process
    report = salvage_remote(FakeTransport(bkt), "k")
    assert report.remote["mode"] == "multipart"
    assert report.remote["parts_salvaged"] >= 10
    assert report.rebuilt
    assert report.entries_salvaged > 0
    # the rebuilt object is a readable container with a salvaged prefix
    assert "k" in bkt.objects
    assert bkt.uploads.get("k", {}) == {}, "dangling upload aborted"
    got = read_all(ObjectStoreSink(FakeTransport(bkt), "k", create=False))
    assert [dict(e) for e in got] == entries[: len(got)]
    assert len(got) == report.entries_salvaged


def test_salvage_dry_run_leaves_store_untouched():
    entries = make_entries(2000, seed=11)
    bkt = _kill_mid_multipart(entries)
    report = salvage_remote(FakeTransport(bkt), "k", dry_run=True)
    assert report.entries_salvaged > 0 and not report.rebuilt
    assert "k" not in bkt.objects
    assert bkt.uploads["k"]


def test_recover_container_routes_remote_urls():
    reset_mem_buckets()
    entries = make_entries(2000, seed=5)
    sched = FaultSchedule([FaultSpec(op="part", kind="kill", at_call=10)])
    bkt = mem_bucket("rec")
    t = FakeTransport(bkt, schedule=sched)
    s = ObjectStoreSink(t, "file.rntj", RemoteOptions(part_bytes=256,
                                                      retry_policy=FAST))
    with pytest.raises((ProcessKilled, RuntimeError)):
        write_seq(s, entries)
    s.close()
    with pytest.raises(ValueError):
        recover_container("mem-s3://rec/file.rntj", output="/tmp/x")
    report = recover_container("mem-s3://rec/file.rntj")
    assert report.remote["mode"] == "multipart"
    assert report.rebuilt and report.entries_salvaged > 0
    got = read_all("mem-s3://rec/file.rntj")
    assert [dict(e) for e in got] == entries[: len(got)]


def test_salvage_existing_object_with_valid_footer_is_noop():
    entries = make_entries(300)
    bkt = ObjectBucket()
    s = ObjectStoreSink(FakeTransport(bkt), "k", FAST_OPTS)
    write_seq(s, entries)
    s.close()
    before = bkt.objects["k"]
    report = salvage_remote(FakeTransport(bkt), "k")
    assert report.remote["mode"] == "object"
    assert report.footer_valid and not report.rebuilt
    assert bkt.objects["k"] == before


def test_salvage_nothing_there():
    with pytest.raises(RecoveryError):
        salvage_remote(FakeTransport(ObjectBucket()), "missing")


# ---------------------------------------------------------------------------
# Satellite: FaultInjectingSink covers pread_into (zero-copy read path)
# ---------------------------------------------------------------------------


def test_fault_sink_pread_into_injects():
    ref = b"0123456789" * 20
    fs = FaultInjectingSink(memory_sink_from_bytes(ref),
                            faults=[FaultSpec.transient_error(op="read")])
    buf = bytearray(50)
    with pytest.raises(OSError):
        fs.pread_into(0, buf)
    assert fs.faults.errors == 1
    # next call goes through (count=1 consumed) and lands real bytes
    assert fs.pread_into(0, buf) == 50
    assert bytes(buf) == ref[:50]


def test_fault_sink_pread_into_torn_fills_prefix():
    ref = bytes(range(200))
    fs = FaultInjectingSink(memory_sink_from_bytes(ref),
                            faults=[FaultSpec.short_read(fraction=0.5)])
    buf = bytearray(b"\xff" * 100)
    with pytest.raises(OSError):
        fs.pread_into(0, buf)
    assert fs.faults.short_reads == 1
    # the torn response delivered exactly the prefix; the tail is the
    # caller's stale buffer — the contract recycled-pool readers must
    # survive
    assert bytes(buf[:50]) == ref[:50]
    assert bytes(buf[50:]) == b"\xff" * 50


def test_fault_sink_pread_torn_raises_without_prefix():
    ref = bytes(range(100))
    fs = FaultInjectingSink(memory_sink_from_bytes(ref),
                            faults=[FaultSpec.short_read(fraction=0.5)])
    with pytest.raises(OSError):
        fs.pread(0, 64)
    assert fs.faults.short_reads == 1
    assert fs.pread(0, 64) == ref[:64]


def test_fault_sink_pwritev_decomposition_sees_every_part():
    # base-class pwritev decomposes into pwrites, so per-part faults fire
    fs = FaultInjectingSink(MemorySink(),
                            faults=[FaultSpec.transient_error(at_call=1)])
    fs.reserve(8)
    with pytest.raises(OSError):
        fs.pwritev(0, [b"aaaa", b"bbbb"])
    assert fs.faults.errors == 1
    assert fs.persisted_bytes == 4  # first part landed before the fault


# ---------------------------------------------------------------------------
# Satellite: reader-level retry policy
# ---------------------------------------------------------------------------


def test_reader_retries_transient_pread_faults():
    entries = make_entries(400)
    ref = reference_bytes(entries)
    fs = FaultInjectingSink(memory_sink_from_bytes(ref),
                            faults=[FaultSpec.transient_error(op="read",
                                                              count=3)])
    got = read_all(fs, options=ReadOptions(retry_policy=FAST))
    assert [dict(e) for e in got] == entries
    r = RNTJReader(memory_sink_from_bytes(ref))
    r.close()


def test_reader_retry_stats_and_default_fail_fast():
    entries = make_entries(400)
    ref = reference_bytes(entries)

    fs = FaultInjectingSink(memory_sink_from_bytes(ref),
                            faults=[FaultSpec.transient_error(op="read",
                                                              count=2)])
    r = RNTJReader(fs, options=ReadOptions(retry_policy=FAST))
    list(r.iter_entries())
    r.close()
    d = r.stats.as_dict()
    assert d["retries"] >= 2 and d["giveups"] == 0

    # default ReadOptions: first transient error raises (fail fast)
    fs2 = FaultInjectingSink(memory_sink_from_bytes(ref),
                             faults=[FaultSpec.transient_error(op="read")])
    with pytest.raises((IOError, OSError)):
        read_all(fs2)


def test_reader_gives_up_on_permanent_faults():
    entries = make_entries(200)
    ref = reference_bytes(entries)
    fs = FaultInjectingSink(memory_sink_from_bytes(ref),
                            faults=[FaultSpec.permanent_error(op="read")])
    r = None
    with pytest.raises((IOError, OSError)):
        r = RNTJReader(fs, options=ReadOptions(retry_policy=FAST))
        list(r.iter_entries())
    if r is not None:
        r.close()


# ---------------------------------------------------------------------------
# Acceptance: 100 ms RTT, seeded transient faults, parallel write
# ---------------------------------------------------------------------------


def test_acceptance_high_rtt_faulty_parallel_write():
    entries = make_entries(600, seed=42)

    sched = FaultSchedule(seed=1234, error_rate=0.05,
                          errnos=(errno.EIO, errno.ETIMEDOUT),
                          random_ops=("put", "part", "get", "create",
                                      "complete"))
    t = FakeTransport(ObjectBucket(), schedule=sched, rtt_s=0.1)
    s = ObjectStoreSink(t, "k", RemoteOptions(part_bytes=256,
                                              retry_policy=FAST))
    w = ParallelWriter(SCHEMA, s, WriteOptions(cluster_bytes=4096,
                                               retry_policy=FAST))

    def fill(tid):
        ctx = w.create_fill_context()
        for e in entries[tid::4]:
            ctx.fill(e)
        ctx.close()

    threads = [threading.Thread(target=fill, args=(i,)) for i in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    w.close()

    stats = w.stats.as_dict()
    # faults were actually sampled, and every one shows up as a retry:
    # zero retries ≠ zero faults, in both directions
    assert sched.stats.random_errors > 0, "fault schedule never fired"
    assert stats["io_retries"] > 0
    assert stats["io_retries"] >= sched.stats.random_errors - \
        stats["io_degradations"] * FAST.max_attempts
    assert stats["io_giveups"] == 0 or stats["io_degradations"] > 0

    # parallel commit order (and hence cluster packing) is nondeterministic,
    # so verify losslessness through the readers rather than byte equality
    # with the sequential reference
    assert t.bucket.objects["k"]

    sys.path.insert(0, str(REPO_ROOT / "benchmarks"))
    try:
        from _legacy_seed_reader import SeedRNTJReader
    finally:
        sys.path.pop(0)
    seed_r = SeedRNTJReader(
        ObjectStoreSink(FakeTransport(t.bucket), "k", create=False))
    assert seed_r.n_entries == len(entries)
    ids = np.concatenate(
        [seed_r.read_cluster(i)[0] for i in range(seed_r.n_clusters)]
    )
    seed_r.close()
    assert ids.dtype == np.int64
    assert sorted(ids.tolist()) == [e["id"] for e in entries]

    got = read_all(ObjectStoreSink(FakeTransport(t.bucket), "k",
                                   create=False))
    assert sorted(e["id"] for e in got) == [e["id"] for e in entries]

    # and the inverse direction: a clean transport reports zero retries
    t2 = FakeTransport(ObjectBucket(), rtt_s=0.0)
    s2 = ObjectStoreSink(t2, "k", FAST_OPTS)
    write_seq(s2, entries, cluster_bytes=4096)
    s2.close()
    assert s2.io.retries == 0


def test_idempotent_reupload_skips_unchanged_parts():
    t = FakeTransport(ObjectBucket())
    s = ObjectStoreSink(t, "k", RemoteOptions(part_bytes=128,
                                              retry_policy=FAST))
    s.reserve(256)
    s.pwrite(0, b"a" * 256)   # ships parts 1 and 2
    sched_calls_before = len(next(iter(t.bucket.uploads["k"].values())))
    assert sched_calls_before == 2
    s.flush()                  # nothing new: CRC-keyed skip
    s.close()                  # close re-walks all parts; unchanged -> skip
    assert t.bucket.objects["k"] == b"a" * 256


def test_rewritten_part_reuploads_under_same_number():
    t = FakeTransport(ObjectBucket())
    s = ObjectStoreSink(t, "k", RemoteOptions(part_bytes=128,
                                              retry_policy=FAST))
    s.reserve(300)
    s.pwrite(0, b"a" * 300)
    s.pwrite(0, b"b" * 64)     # dirties part 0 after it was shipped
    s.close()
    obj = t.bucket.objects["k"]
    assert obj == b"b" * 64 + b"a" * 236
