"""Crash-consistent multi-process writing (DESIGN.md §8.6).

Covers the three layers of the tentpole: the side-car reservation log
(leases, fencing epochs, torn-tail replay), the footer-assembly
rendezvous (clean seal, degraded seal, straggler fencing), and recovery
of multi-writer files (interleaved journals, orphaned reservations,
mid-rendezvous crashes).  Real multi-process cells run through worker
subprocesses; everything else exercises the protocol in-process.

This module stays jax-free so its worker subprocesses import only
``repro.core``.
"""

import os
import struct
import subprocess
import sys
import textwrap
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    Collection,
    FencedError,
    Leaf,
    MemorySink,
    MultiWriterCoordinator,
    RNTJReader,
    RetryPolicy,
    Schema,
    SequentialWriter,
    StaleLogError,
    WriteOptions,
    join_container,
    recover_container,
    scan_container,
    open_sink,
)
from repro.core.extents import (
    ExtentLog,
    XREC_SEAL,
    iter_records,
    replay_log,
)
from repro.core.metadata import (
    JREC_VERSION_MP,
    finish_journal_record,
    journal_record_size,
    parse_journal_record,
    build_journal_body,
)

REPO = Path(__file__).resolve().parent.parent

SCHEMA = Schema([
    Leaf("id", "int64"),
    Collection("vals", Leaf("_0", "float32")),
])

FAST = RetryPolicy(max_attempts=6, backoff_base=0.0001, backoff_cap=0.0005)


def mp_options(**kw):
    base = dict(cluster_bytes=2048, retry_policy=FAST, lease_interval=0.3,
                rendezvous_timeout=5.0, mpw_log_fsync=False)
    base.update(kw)
    return WriteOptions(**base)


def make_entries(n, seed=0):
    rng = np.random.default_rng(seed)
    lens = rng.integers(0, 6, size=n)
    return [
        {"id": int(i),
         "vals": [float(v) for v in rng.random(lens[i], dtype=np.float32)]}
        for i in range(n)
    ]


def read_all(source):
    r = RNTJReader(source)
    got = list(r.iter_entries())
    r.close()
    return got


# ---------------------------------------------------------------------------
# side-car reservation log


def test_xlog_join_reserve_commit(tmp_path):
    c = str(tmp_path / "f.rntj")
    log = ExtentLog.create(c, data_start=100, fsync=False)
    s1 = log.join(1.0)
    s2 = log.join(1.0)
    assert (s1.writer_id, s2.writer_id) == (1, 2)
    assert s2.epoch > s1.epoch  # epochs are globally monotonic

    r1 = s1.reserve(50)
    r2 = s2.reserve(30)
    assert r1.offset == 100 and r2.offset == 150  # disjoint, frontier-ordered
    assert (r1.seq, r2.seq) == (0, 1)
    s1.commit(r1.rid)
    st = log.snapshot()
    assert st.reservations[r1.rid].committed
    assert not st.reservations[r2.rid].committed
    s2.release(r2.rid)
    st = log.snapshot()
    assert st.reservations[r2.rid].released
    # released extents are permanent holes: the frontier never rolls back
    r3 = s1.reserve(10)
    assert r3.offset == 180
    log.close()


def test_xlog_fencing_is_terminal(tmp_path):
    c = str(tmp_path / "f.rntj")
    log = ExtentLog.create(c, data_start=64, fsync=False)
    s = log.join(1.0)
    r = s.reserve(10)
    log.fence(s.writer_id, "test")
    with pytest.raises(FencedError):
        s.reserve(10)
    with pytest.raises(FencedError):
        s.commit(r.rid)
    with pytest.raises(FencedError):
        s.heartbeat()
    with pytest.raises(FencedError):
        s.done()
    log.close()


def test_xlog_done_is_terminal(tmp_path):
    c = str(tmp_path / "f.rntj")
    log = ExtentLog.create(c, data_start=64, fsync=False)
    s = log.join(1.0)
    s.done()
    # a post-DONE reservation would race the coordinator's seal
    with pytest.raises(FencedError):
        s.reserve(10)
    log.close()


def test_xlog_seal_refuses_everything(tmp_path):
    c = str(tmp_path / "f.rntj")
    log = ExtentLog.create(c, data_start=64, fsync=False)
    s = log.join(1.0)
    log.seal({"by": "test"})
    with pytest.raises(FencedError):
        s.reserve(10)
    with pytest.raises(FencedError):
        log.join(1.0)
    st = log.snapshot()
    assert st.sealed and st.seal_info["by"] == "test"
    log.close()


def test_xlog_torn_tail_replay(tmp_path):
    c = str(tmp_path / "f.rntj")
    log = ExtentLog.create(c, data_start=64, fsync=False)
    s = log.join(1.0)
    s.reserve(10)
    log.close()
    raw = Path(ExtentLog.sidecar_path(c)).read_bytes()
    # a crash mid-append tears the last record: every truncation of the
    # final record must replay to the pre-append state
    whole = replay_log(raw)
    assert len(whole.reservations) == 1
    records = list(iter_records(raw))
    assert len(records) == 3  # CREATE, JOIN, RESERVE
    for cut in range(1, 40):
        torn = replay_log(raw[:-cut])
        assert len(torn.reservations) <= 1
        assert torn.data_start == 64  # the intact prefix survives verbatim
    # corrupt tail CRC: record dropped, prefix intact
    bad = bytearray(raw)
    bad[-1] ^= 0xFF
    assert len(replay_log(bytes(bad)).reservations) == 0


def test_xlog_lease_expiry(tmp_path):
    c = str(tmp_path / "f.rntj")
    log = ExtentLog.create(c, data_start=64, fsync=False)
    s = log.join(0.05)
    time.sleep(0.15)
    st = log.snapshot()
    # lease deadlines are wall-clock: they cross process boundaries
    assert st.writers[s.writer_id].expired(time.time())
    s.heartbeat()  # not fenced yet: the lease can still be renewed
    st = log.snapshot()
    assert not st.writers[s.writer_id].expired(time.time())
    log.close()


def test_xlog_append_after_torn_tail_truncates(tmp_path):
    c = str(tmp_path / "f.rntj")
    log = ExtentLog.create(c, data_start=64, fsync=False)
    s = log.join(1.0)
    s.reserve(10)
    log.close()
    p = Path(ExtentLog.sidecar_path(c))
    raw = bytearray(p.read_bytes())
    raw[-1] ^= 0xFF  # tear the RESERVE record (crash mid-append)
    p.write_bytes(bytes(raw))

    # the next transaction must truncate the torn tail and append at the
    # valid end — a record appended past the tear would be invisible to
    # every replay, freezing next_offset and handing out overlaps
    log = ExtentLog(str(p), fsync=False)
    r = log.reserve(s.writer_id, s.epoch, 20)
    assert r.offset == 64  # the torn RESERVE never happened
    st = replay_log(p.read_bytes())
    assert len(st.reservations) == 1
    assert st.next_offset == 84
    r2 = log.reserve(s.writer_id, s.epoch, 5)
    assert r2.offset == 84  # frontier advanced: no overlapping extents
    log.close()


def test_xlog_create_refuses_leftover_log(tmp_path):
    c = str(tmp_path / "f.rntj")
    log = ExtentLog.create(c, data_start=64, fsync=False)
    log.join(1.0)
    log.close()
    with pytest.raises(StaleLogError):
        ExtentLog.create(c, data_start=64, fsync=False)


def test_xlog_join_checks_generation(tmp_path):
    c = str(tmp_path / "f.rntj")
    log = ExtentLog.create(c, data_start=64, fsync=False, generation="genA")
    log.join(1.0, expect_generation="genA")
    with pytest.raises(StaleLogError):
        log.join(1.0, expect_generation="genB")
    log.close()


# ---------------------------------------------------------------------------
# v3 journal records


def test_v3_journal_record_roundtrip():
    body = build_journal_body([3], [])
    size = journal_record_size(1, 0, multi=True)
    rec, _crc = finish_journal_record(
        7, 1, 4096, 512, 0, 3, 1, body, writer_id=9, epoch=4)
    assert len(rec) == size
    assert size > journal_record_size(1, 0, multi=False)
    jr, _pos = parse_journal_record(rec)
    assert (jr.seq, jr.writer_id, jr.epoch) == (7, 9, 4)
    assert jr.cluster_off == 4096 and jr.n_entries == 3


def test_v2_journal_record_still_parses():
    body = build_journal_body([2], [])
    rec, _ = finish_journal_record(1, 1, 64, 32, 0, 2, 1, body)
    jr, _pos = parse_journal_record(rec)
    assert (jr.writer_id, jr.epoch) == (0, 0)
    assert jr.seq == 1 and jr.n_entries == 2


# ---------------------------------------------------------------------------
# coordinator + participants (in-process)


def test_multiwriter_clean_seal(tmp_path):
    path = str(tmp_path / "mp.rntj")
    entries = make_entries(150)
    opts = mp_options()
    coord = MultiWriterCoordinator(SCHEMA, path, opts)

    def writer(slice_):
        w = coord.participant()
        ctx = w.create_fill_context()
        for e in slice_:
            ctx.fill(e)
        ctx.close()
        w.close()

    threads = [threading.Thread(target=writer, args=(entries[i::3],))
               for i in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    report = coord.seal(expect_writers=3)
    coord.close()

    assert report["writers"] == 3 and not report["fenced"]
    assert report["entries"] == 150
    assert not os.path.exists(ExtentLog.sidecar_path(path)), (
        "clean seal must unlink the side-car log")
    got = read_all(path)
    assert sorted(e["id"] for e in got) == list(range(150))
    by_id = {e["id"]: e for e in entries}
    assert all(e == by_id[e["id"]] for e in got)
    # the sealed footer is a *valid* footer: recovery has nothing to do
    rep = recover_container(path, dry_run=True)
    assert rep.footer_valid


def test_multiwriter_entry_renumbering(tmp_path):
    # interleaved commits from two writers: reader order must follow the
    # global reservation seq with contiguous first_entry ranges
    path = str(tmp_path / "mp.rntj")
    opts = mp_options(cluster_bytes=512)
    coord = MultiWriterCoordinator(SCHEMA, path, opts)
    w1, w2 = coord.participant(), coord.participant()
    c1, c2 = w1.create_fill_context(), w2.create_fill_context()
    entries = make_entries(60)
    for i, e in enumerate(entries):
        (c1 if i % 2 else c2).fill(e)
        if i % 10 == 9:  # force alternating small clusters
            c1.flush_cluster()
            c2.flush_cluster()
    c1.close(); c2.close()
    w1.close(); w2.close()
    report = coord.seal(expect_writers=2)
    coord.close()
    assert report["entries"] == 60
    got = read_all(path)
    assert len(got) == 60
    assert sorted(e["id"] for e in got) == list(range(60))


def test_multiwriter_degraded_seal_salvages_commits(tmp_path):
    path = str(tmp_path / "mp.rntj")
    entries = make_entries(120)
    opts = mp_options(cluster_bytes=1024)
    coord = MultiWriterCoordinator(SCHEMA, path, opts)

    good = coord.participant()
    gctx = good.create_fill_context()
    for e in entries[:60]:
        gctx.fill(e)
    gctx.close()
    good.close()

    # the dying writer commits some clusters, then leaves a dangling
    # reservation and stops heartbeating (= SIGKILL mid-save)
    dead = coord.participant()
    dctx = dead.create_fill_context()
    for e in entries[60:100]:
        dctx.fill(e)
    dctx.flush_cluster()
    dangling = dead._mp_session.reserve(512)  # reserved, never written
    dead._hb_stop.set()
    dead._hb.join()

    report = coord.seal(expect_writers=2)
    # the fenced writer can no longer touch the log
    with pytest.raises(FencedError):
        dead._mp_session.reserve(16)
    coord.close()
    assert report["fenced"] == [dead.writer_id]
    assert any(s["writer"] == dead.writer_id for s in report["salvaged"])
    assert any(a["offset"] == dangling.offset for a in report["abandoned"])
    assert os.path.exists(ExtentLog.sidecar_path(path)), (
        "degraded seal keeps the side-car for forensics")

    got = read_all(path)
    ids = [e["id"] for e in got]
    assert set(range(60)) <= set(ids), "clean writer lost entries"
    assert set(ids) <= set(range(100))
    by_id = {e["id"]: e for e in entries}
    assert all(e == by_id[e["id"]] for e in got)

    # salvage is decode-identical to a single-writer file of the same set
    ref = MemorySink()
    w = SequentialWriter(SCHEMA, ref, mp_options(cluster_bytes=1024))
    for e in got:
        w.fill(e)
    w.close()
    assert read_all(ref) == got


def test_fenced_straggler_cannot_corrupt_sealed_file(tmp_path):
    path = str(tmp_path / "mp.rntj")
    entries = make_entries(80)
    opts = mp_options(rendezvous_timeout=0.5)
    coord = MultiWriterCoordinator(SCHEMA, path, opts)
    w = coord.participant()
    ctx = w.create_fill_context()
    for e in entries[:40]:
        ctx.fill(e)
    ctx.flush_cluster()
    # writer stays alive (heartbeating) but never reports DONE: the
    # rendezvous deadline fences it
    report = coord.seal(expect_writers=1, timeout=0.5)
    assert report["fenced"] == [w.writer_id]
    sealed = read_all(path)

    # late commits from the fenced epoch must be refused...
    with pytest.raises((FencedError, RuntimeError, OSError)):
        for e in entries[40:]:
            ctx.fill(e)
        ctx.flush_cluster()
    # ...and whatever bytes it managed to pwrite can only have landed in
    # its own abandoned extents — the sealed content is untouched
    assert read_all(path) == sealed
    rep = recover_container(path, dry_run=True)
    assert rep.footer_valid
    coord.close()
    w._hb_stop.set()


def test_new_session_replaces_stale_sidecar_log(tmp_path):
    # run 1 ends DEGRADED, which keeps the (sealed) side-car log on disk.
    # run 2 at the same path must not adopt it: a sealed stale log would
    # fence every new join, and its reservations point into a file that
    # the new coordinator just truncated.
    path = str(tmp_path / "mp.rntj")
    entries = make_entries(40)
    opts = mp_options(cluster_bytes=1024)
    coord = MultiWriterCoordinator(SCHEMA, path, opts)
    w = coord.participant()
    ctx = w.create_fill_context()
    for e in entries[:20]:
        ctx.fill(e)
    ctx.flush_cluster()
    w._hb_stop.set()
    w._hb.join()
    report = coord.seal(expect_writers=1)  # lease expiry → degraded
    coord.close()
    assert report["fenced"] == [w.writer_id]
    assert os.path.exists(ExtentLog.sidecar_path(path))

    coord = MultiWriterCoordinator(SCHEMA, path, opts)
    w2 = coord.participant()
    ctx2 = w2.create_fill_context()
    for e in entries:
        ctx2.fill(e)
    ctx2.close()
    w2.close()
    report = coord.seal(expect_writers=1)
    coord.close()
    assert not report["fenced"] and not report["salvaged"]
    assert report["entries"] == 40
    got = read_all(path)
    assert sorted(e["id"] for e in got) == list(range(40))


def test_join_refuses_foreign_generation_log(tmp_path):
    path = str(tmp_path / "mp.rntj")
    coord = MultiWriterCoordinator(SCHEMA, path, mp_options())
    # swap in a log created for a DIFFERENT container instance
    os.unlink(ExtentLog.sidecar_path(path))
    foreign = ExtentLog.create(path, 64, fsync=False,
                               generation="someone-else")
    foreign.close()
    with pytest.raises(StaleLogError):
        join_container(path, schema=SCHEMA, options=mp_options())
    coord.sink.close()
    coord.log.close()


class _SlowFsyncSink:
    """Delegating sink whose data fsync stalls — models a close whose
    final drain/fsync of large buffered clusters outlasts the fencing
    grace (~2x lease_interval)."""

    def __init__(self, inner, delay):
        self.inner = inner
        self.delay = delay

    def fsync(self):
        time.sleep(self.delay)
        self.inner.fsync()

    def __getattr__(self, name):
        return getattr(self.inner, name)


def test_slow_close_is_not_fenced(tmp_path):
    # the lease heartbeat must keep running through close's drain + data
    # fsync: a healthy writer whose final fsync exceeds the fencing grace
    # would otherwise be fenced mid-close and spuriously degrade the seal
    path = str(tmp_path / "mp.rntj")
    entries = make_entries(40)
    opts = mp_options(lease_interval=0.2)
    coord = MultiWriterCoordinator(SCHEMA, path, opts)
    inner = open_sink(path, create=False)
    w = join_container(path, schema=SCHEMA, options=opts,
                       sink=_SlowFsyncSink(inner, delay=1.2))
    ctx = w.create_fill_context()
    for e in entries:
        ctx.fill(e)

    def closer():
        ctx.close()
        w.close()

    t = threading.Thread(target=closer)
    t.start()
    report = coord.seal(expect_writers=1, timeout=30.0)
    coord.close()
    t.join()
    assert not report["fenced"] and not report["salvaged"], (
        "healthy writer fenced during its close-time fsync")
    assert report["entries"] == 40
    got = read_all(path)
    assert sorted(e["id"] for e in got) == list(range(40))


# ---------------------------------------------------------------------------
# recovery of multi-writer files


def _write_unsealed(path, entries, n_writers=2, **opt_kw):
    """Build a multi-writer file whose coordinator died before the seal."""
    opts = mp_options(**opt_kw)
    coord = MultiWriterCoordinator(SCHEMA, path, opts)
    writers = [coord.participant() for _ in range(n_writers)]
    ctxs = [w.create_fill_context() for w in writers]
    for i, e in enumerate(entries):
        ctxs[i % n_writers].fill(e)
    for c in ctxs:
        c.close()
    for w in writers:
        w.close()
    # coordinator crash: no seal record, no footer
    coord.sink.close()
    coord.log.close()


def test_recover_unsealed_multiwriter(tmp_path):
    path = str(tmp_path / "mp.rntj")
    entries = make_entries(100)
    _write_unsealed(path, entries, n_writers=2, cluster_bytes=1024)
    rep = recover_container(path)
    assert rep.rebuilt and not rep.footer_valid
    assert rep.multiwriter is not None
    assert len(rep.multiwriter["writers"]) == 2
    got = read_all(path)
    assert sorted(e["id"] for e in got) == list(range(100))
    by_id = {e["id"]: e for e in entries}
    assert all(e == by_id[e["id"]] for e in got)


def test_recover_mid_rendezvous_crash(tmp_path):
    # the coordinator appended SEAL but died before any footer byte:
    # the file has no footer, the log says sealed — recovery still
    # rebuilds from the journal records + reservations
    path = str(tmp_path / "mp.rntj")
    entries = make_entries(80)
    opts = mp_options(cluster_bytes=1024)
    coord = MultiWriterCoordinator(SCHEMA, path, opts)
    w = coord.participant()
    ctx = w.create_fill_context()
    for e in entries:
        ctx.fill(e)
    ctx.close()
    w.close()
    coord.log.seal({"coordinator_pid": os.getpid()})  # SEAL, then "crash"
    coord.sink.close()
    coord.log.close()

    rep = recover_container(path)
    assert rep.rebuilt
    assert rep.multiwriter is not None and rep.multiwriter["sealed"]
    got = read_all(path)
    assert sorted(e["id"] for e in got) == list(range(80))


def test_recover_drops_unreserved_and_stale_epoch_extents(tmp_path):
    path = str(tmp_path / "mp.rntj")
    entries = make_entries(60)
    _write_unsealed(path, entries, n_writers=2, cluster_bytes=1024)
    sink = open_sink(path, create=False)
    log = ExtentLog(ExtentLog.sidecar_path(path), fsync=False)
    state = log.snapshot()
    log.close()

    # sanity: with the true log state every cluster is attributed
    _sch, _opts, clusters, rep = scan_container(sink, xlog_state=state)
    full = rep.clusters_salvaged
    assert full >= 2 and not rep.clusters_dropped

    # forge a stale epoch on one reservation: its (pristine, CRC-valid)
    # cluster must now be rejected as a fenced writer's late write
    rid = min(state.reservations)
    state.reservations[rid].epoch += 1
    _sch, _opts, clusters, rep = scan_container(sink, xlog_state=state)
    assert rep.clusters_salvaged == full - 1
    assert any("fenced epoch" in d["reason"] for d in rep.clusters_dropped)

    # drop the reservation entirely: same rejection, different reason
    del state.reservations[rid]
    _sch, _opts, clusters, rep = scan_container(sink, xlog_state=state)
    assert rep.clusters_salvaged == full - 1
    assert any("no reservation" in d["reason"] for d in rep.clusters_dropped)
    sink.close()


def test_recover_ignores_stale_foreign_log(tmp_path):
    # a single-writer file written at a path where a crashed multi-writer
    # run left its side-car log behind: fencing enforcement from that log
    # would drop every valid cluster ("no reservation"), so recovery must
    # detect the generation mismatch and fall back to a plain scan
    path = str(tmp_path / "f.rntj")
    entries = make_entries(30)
    w = SequentialWriter(SCHEMA, open_sink(path, create=True),
                         mp_options(cluster_bytes=1024))
    for e in entries:
        w.fill(e)
    w.close()
    stale = ExtentLog.create(path, 64, fsync=False, generation="dead-run")
    stale.close()

    rep = recover_container(path, force=True)
    assert rep.multiwriter == {"stale_log_ignored": True}
    assert rep.clusters_salvaged >= 1 and not rep.clusters_dropped
    got = read_all(path)
    assert sorted(e["id"] for e in got) == list(range(30))


def test_recover_orphaned_reservations_reported(tmp_path):
    path = str(tmp_path / "mp.rntj")
    entries = make_entries(40)
    opts = mp_options(cluster_bytes=1024)
    coord = MultiWriterCoordinator(SCHEMA, path, opts)
    w = coord.participant()
    ctx = w.create_fill_context()
    for e in entries:
        ctx.fill(e)
    ctx.close()
    w._mp_session.reserve(999)  # orphan: reserved, never committed
    w._hb_stop.set()
    w._hb.join()
    coord.sink.close()
    coord.log.close()

    rep = recover_container(path)
    assert len(rep.multiwriter["orphaned_reservations"]) >= 1
    got = read_all(path)
    assert sorted(e["id"] for e in got) == list(range(40))


# ---------------------------------------------------------------------------
# real multi-process crash cells


_WORKER_PROG = textwrap.dedent("""\
    import os, sys
    sys.path.insert(0, {src!r})
    from repro.core import (Collection, Leaf, RetryPolicy, Schema,
                            WriteOptions, join_container)
    SCHEMA = Schema([Leaf("id", "int64"),
                     Collection("vals", Leaf("_0", "float32"))])
    opts = WriteOptions(cluster_bytes=1024, lease_interval=0.3,
                        mpw_log_fsync=False,
                        retry_policy=RetryPolicy(max_attempts=6,
                                                 backoff_base=0.0001,
                                                 backoff_cap=0.0005))
    lo, hi, crash_at = {lo}, {hi}, {crash_at}
    w = join_container({path!r}, schema=SCHEMA, options=opts)
    ctx = w.create_fill_context()
    for i in range(lo, hi):
        ctx.fill({{"id": i, "vals": [float(i), float(i) * 0.5]}})
        if crash_at is not None and i == crash_at:
            ctx.flush_cluster()
            os._exit(9)   # SIGKILL-equivalent: no DONE, no close
    ctx.close()
    w.close()
""")


def _spawn_worker(path, lo, hi, crash_at=None):
    prog = _WORKER_PROG.format(src=str(REPO / "src"), path=path,
                               lo=lo, hi=hi, crash_at=crash_at)
    return subprocess.Popen([sys.executable, "-c", prog],
                            stdout=subprocess.PIPE, stderr=subprocess.PIPE)


@pytest.mark.parametrize("n_writers", [2, 4])
def test_real_processes_clean_seal(tmp_path, n_writers):
    path = str(tmp_path / "mp.rntj")
    per = 60
    coord = MultiWriterCoordinator(SCHEMA, path, mp_options())
    procs = [_spawn_worker(path, w * per, (w + 1) * per)
             for w in range(n_writers)]
    report = coord.seal(expect_writers=n_writers, timeout=30.0)
    coord.close()
    for p in procs:
        _out, err = p.communicate(timeout=30)
        assert p.returncode == 0, err.decode()
    assert report["entries"] == n_writers * per and not report["fenced"]
    got = read_all(path)
    assert sorted(e["id"] for e in got) == list(range(n_writers * per))


def test_real_process_killed_mid_save_is_salvaged(tmp_path):
    path = str(tmp_path / "mp.rntj")
    coord = MultiWriterCoordinator(SCHEMA, path, mp_options())
    ok = _spawn_worker(path, 0, 60)
    bad = _spawn_worker(path, 60, 120, crash_at=90)  # dies halfway
    report = coord.seal(expect_writers=2, timeout=30.0)
    coord.close()
    ok.communicate(timeout=30)
    bad.communicate(timeout=30)
    assert ok.returncode == 0 and bad.returncode == 9

    assert len(report["fenced"]) == 1
    got = read_all(path)
    ids = [e["id"] for e in got]
    assert set(range(60)) <= set(ids), "live writer lost entries"
    dead_ids = sorted(i for i in ids if i >= 60)
    # the dead writer's salvage is a prefix of its commit order
    assert dead_ids == list(range(60, 60 + len(dead_ids)))
    assert all(e["vals"] == [float(e["id"]), e["id"] * 0.5] for e in got)
    # byte-level: decode-identical to a single-writer file of the same set
    ref = MemorySink()
    w = SequentialWriter(SCHEMA, ref, mp_options(cluster_bytes=1024))
    for e in got:
        w.fill(e)
    w.close()
    assert read_all(ref) == got


def test_chaos_cli_mp_scenarios():
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "chaos.py"),
         "--scenario", "mprecover", "--entries", "200"],
        capture_output=True, timeout=300)
    assert out.returncode == 0, out.stdout.decode() + out.stderr.decode()
