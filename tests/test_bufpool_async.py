"""Buffer pool + async submission engine tests (ISSUE 5): size-class
bounds, completion-driven recycling (a buffer is never handed out while
a queued write still references it), ring submission byte-identity and
ordering, poisoning through the emulated ring, and the pooled merge /
unbuffered / reader paths."""

import threading
import time

import numpy as np
import pytest

from repro.core import (
    AsyncFileSink,
    BufferPool,
    Collection,
    ColumnBatch,
    ColumnBuffer,
    FileSink,
    Leaf,
    MemorySink,
    ReadOptions,
    RNTJReader,
    Schema,
    SequentialWriter,
    ThrottledSink,
    WriteOptions,
    merge_files,
    open_sink,
)
from repro.core.bufpool import Recyclable, _class_bytes
from repro.core.ioengine import (
    EmulatedRing,
    IOEngine,
    UringRing,
    load_liburing,
    make_ring,
)


def vec_schema():
    return Schema([
        Leaf("id", "int64"),
        Collection("vals", Leaf("_0", "float32")),
    ])


def make_batch(schema, rng, n, id0=0):
    sizes = rng.poisson(5, n).astype(np.int64)
    vals = rng.uniform(0, 100, int(sizes.sum())).astype(np.float32)
    return ColumnBatch.from_arrays(
        schema, n,
        {"id": np.arange(id0, id0 + n), "vals": sizes, "vals._0": vals},
    )


def write_file(sink, opts, entries=4000, seed=0, batches=4):
    schema = vec_schema()
    rng = np.random.default_rng(seed)
    per = entries // batches
    with SequentialWriter(schema, sink, opts) as w:
        for i in range(batches):
            w.fill_batch(make_batch(schema, rng, per, id0=i * per))
        stats = w.stats
    return stats


BASE = dict(codec="none", cluster_bytes=1 << 16, page_size=8 * 1024)


# ---------------------------------------------------------------------------
# BufferPool unit behavior


def test_pool_power_of_two_classes():
    assert _class_bytes(1) == 4096          # minimum class
    assert _class_bytes(4096) == 4096
    assert _class_bytes(4097) == 8192
    assert _class_bytes(100_000) == 131072
    pool = BufferPool(limit_bytes=1 << 20)
    a = pool.take(5000)
    assert a.nbytes == 8192 and a.dtype == np.uint8


def test_pool_hit_miss_return_cycle():
    pool = BufferPool(limit_bytes=1 << 20)
    a = pool.take(10_000)
    assert pool.stats.pool_misses == 1
    pool.put(a)
    assert pool.stats.pool_returns == 1
    assert pool.resident_bytes == a.nbytes
    b = pool.take(10_000)
    assert b is a and pool.stats.pool_hits == 1
    assert pool.resident_bytes == 0
    # a different class does not hit
    c = pool.take(100_000)
    assert c is not a and pool.stats.pool_misses == 2


def test_pool_residency_bound_drops():
    pool = BufferPool(limit_bytes=8192)
    a, b = pool.take(8192), pool.take(8192)
    pool.put(a)
    pool.put(b)  # over the bound: dropped
    assert pool.stats.pool_drops == 1
    assert pool.resident_bytes == 8192
    assert pool.take(8192) is a


def test_pool_put_walks_views_to_base():
    pool = BufferPool(limit_bytes=1 << 20)
    a = pool.take(4096)
    view = memoryview(a.view(np.int64)[:100])
    pool.put(view)
    assert pool.take(4096) is a


def test_pool_rejects_foreign_and_odd_buffers():
    pool = BufferPool(limit_bytes=1 << 20)
    pool.put(np.empty(5000, np.uint8))   # non-power-of-two: never pooled
    assert pool.stats.pool_drops == 1
    pool.put(b"not an array")            # ignored entirely
    pool.put(None)
    assert pool.resident_bytes == 0


def test_pool_thread_safety_smoke():
    pool = BufferPool(limit_bytes=1 << 22)

    def worker():
        for _ in range(200):
            pool.put(pool.take(8192))

    ts = [threading.Thread(target=worker) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    s = pool.stats
    assert s.pool_hits + s.pool_misses == 800
    assert s.pool_returns == 800


def test_column_buffer_draws_from_pool():
    pool = BufferPool(limit_bytes=1 << 20)
    buf = ColumnBuffer(np.int64, capacity=512, pool=pool)
    buf.extend(np.arange(512))
    first = buf.detach()
    np.testing.assert_array_equal(first, np.arange(512))
    pool.put(first)
    buf.extend(np.arange(10))
    # the replacement storage installed by detach() came from the pool,
    # and the recycled array backs the next detach
    assert pool.stats.pool_hits + pool.stats.pool_misses >= 2


# ---------------------------------------------------------------------------
# completion-driven recycling: the engine returns buffers only when the
# extent's last write has landed


class _GateSink(MemorySink):
    """Writes block until the test releases the gate."""

    def __init__(self):
        super().__init__()
        self.gate = threading.Event()

    def pwrite(self, offset, data):
        assert self.gate.wait(10.0)
        super().pwrite(offset, data)


def test_buffer_not_recycled_while_queued_write_references_it():
    pool = BufferPool(limit_bytes=1 << 22)
    sink = _GateSink()
    engine = IOEngine(sink, workers=1, inflight_bytes=1 << 20,
                      ring="emulated", buffer_pool=pool)
    arr = pool.take(8192)
    memoryview(arr)[:5] = b"hello"
    owner = Recyclable([arr])
    engine.admit(8192)
    engine.write_extent(0, [memoryview(arr)], 8192, owner=owner)
    time.sleep(0.05)  # the queued write is (blocked) in flight
    assert pool.take(8192) is not arr  # never handed out while referenced
    assert pool.resident_bytes == 0
    sink.gate.set()
    engine.drain()
    engine.close()
    # landed: the buffer is back in its class now
    assert pool.take(8192) is arr
    assert bytes(sink.buf[:5]) == b"hello"


def test_sync_write_recycles_after_completion():
    pool = BufferPool(limit_bytes=1 << 22)
    sink = MemorySink()
    engine = IOEngine(sink, buffer_pool=pool)
    arr = pool.take(4096)
    engine.write_extent(0, [memoryview(arr)[:4096]], 4096,
                        owner=Recyclable([arr]))
    assert pool.take(4096) is arr
    engine.close()


# ---------------------------------------------------------------------------
# ring submission: byte-identity, ordering, poisoning


def test_ring_write_behind_byte_identical_to_sync():
    a, b, c = MemorySink(), MemorySink(), MemorySink()
    write_file(a, WriteOptions(**BASE))  # synchronous reference
    write_file(b, WriteOptions(**BASE, io_inflight_bytes=1 << 20,
                               io_ring="emulated"))
    write_file(c, WriteOptions(**BASE, io_inflight_bytes=1 << 20,
                               io_ring="emulated", io_stripe_bytes=4096,
                               pipelined_seal=True))
    assert bytes(a.buf) == bytes(b.buf)
    assert bytes(a.buf) == bytes(c.buf)


def test_ring_off_keeps_executor_path_identical():
    a, b = MemorySink(), MemorySink()
    write_file(a, WriteOptions(**BASE, io_inflight_bytes=1 << 20,
                               io_ring="off"))
    write_file(b, WriteOptions(**BASE, io_inflight_bytes=1 << 20,
                               io_ring="emulated"))
    assert bytes(a.buf) == bytes(b.buf)


def test_ring_completion_ordering_vs_drain():
    """close() (via engine.drain) must not finalize before every queued
    ring write has landed."""
    sink = _GateSink()
    schema = vec_schema()
    rng = np.random.default_rng(3)
    opts = WriteOptions(**BASE, io_inflight_bytes=4 << 20,
                        io_ring="emulated", io_workers=2)
    sink.gate.set()  # the header write (writer construction) may pass
    w = SequentialWriter(schema, sink, opts)
    w.fill_batch(make_batch(schema, rng, 2000))
    sink.gate.clear()
    w.flush_cluster()  # queued behind the gate
    done = threading.Event()

    def closer():
        w.close()
        done.set()

    t = threading.Thread(target=closer)
    t.start()
    assert not done.wait(0.2)  # drain-before-footer is blocked on the gate
    sink.gate.set()
    t.join(10.0)
    assert done.is_set()
    r = RNTJReader(sink)
    assert r.n_entries == 2000
    np.testing.assert_array_equal(r.read_column("id"), np.arange(2000))


class _FailingSink(MemorySink):
    def __init__(self, fail_after: int):
        super().__init__()
        self.fail_after = fail_after
        self._writes = 0

    def pwrite(self, offset, data):
        self._writes += 1
        if self._writes > self.fail_after:
            raise IOError("injected ring failure")
        super().pwrite(offset, data)


def test_poisoning_through_emulated_ring():
    sink = _FailingSink(fail_after=1)  # header lands, clusters fail
    schema = vec_schema()
    rng = np.random.default_rng(5)
    w = SequentialWriter(schema, sink, WriteOptions(
        **BASE, io_inflight_bytes=4 << 20, io_ring="emulated"))
    w.fill_batch(make_batch(schema, rng, 2000))
    with pytest.raises(RuntimeError, match="NOT finalized") as ei:
        w.flush_cluster()
        w.close()
    assert isinstance(ei.value.__cause__, IOError)


def test_detached_buffers_survive_ring_write_behind_with_pool():
    """The PR-4 detach hazard, now with recycling in the loop: queued raw
    views must stay valid behind a slow sink while the SAME builder
    refills from the pool."""
    inner = MemorySink()
    slow = ThrottledSink(inner, bw=3e6)
    schema = vec_schema()
    rng = np.random.default_rng(7)
    opts = WriteOptions(codec="none", cluster_bytes=1 << 16,
                        io_inflight_bytes=4 << 20, io_ring="emulated",
                        pipelined_seal=True)
    with SequentialWriter(schema, slow, opts) as w:
        for i in range(8):
            w.fill_batch(make_batch(schema, rng, 500, id0=i * 500))
    r = RNTJReader(inner)
    np.testing.assert_array_equal(r.read_column("id"), np.arange(4000))


def test_steady_state_detach_hits_the_pool():
    sink = MemorySink()
    stats = write_file(sink, WriteOptions(**BASE), entries=12000, batches=12)
    d = stats.as_dict()
    assert d["pool_returns"] > 0
    assert d["pool_hits"] > 0  # later clusters recycled earlier buffers
    r = RNTJReader(sink)
    np.testing.assert_array_equal(r.read_column("id"), np.arange(12000))


# ---------------------------------------------------------------------------
# io_uring loader and mode resolution


def test_liburing_loader_is_graceful():
    # on boxes without liburing this is None; with it, a handle — either
    # way no exception escapes
    lib = load_liburing()
    assert lib is None or lib is not None


def test_io_ring_uring_requires_async_sink(tmp_path):
    sink = MemorySink()
    engine = IOEngine(sink, workers=1, inflight_bytes=1)
    with pytest.raises(ValueError, match="AsyncFileSink"):
        make_ring(engine, "uring", 1)
    engine.close()


@pytest.mark.skipif(load_liburing() is not None,
                    reason="liburing present: uring mode would succeed")
def test_io_ring_uring_unavailable_raises_clear_error(tmp_path):
    sink = AsyncFileSink(str(tmp_path / "f.rntj"))
    try:
        with pytest.raises(ValueError, match="liburing"):
            IOEngine(sink, workers=1, inflight_bytes=1 << 20, ring="uring")
    finally:
        sink.close()


@pytest.mark.skipif(load_liburing() is None, reason="needs liburing")
def test_uring_ring_round_trip(tmp_path):
    path = str(tmp_path / "f.rntj")
    sink = AsyncFileSink(path)
    stats = write_file(sink, WriteOptions(
        **BASE, io_inflight_bytes=4 << 20, io_ring="uring"))
    ref = MemorySink()
    write_file(ref, WriteOptions(**BASE))
    with open(path, "rb") as f:
        assert f.read() == bytes(ref.buf)


def test_async_open_sink_spellings(tmp_path):
    a = open_sink(str(tmp_path / "a.rntj"), async_io=True)
    b = open_sink("async:" + str(tmp_path / "b.rntj"))
    try:
        assert isinstance(a, AsyncFileSink) and isinstance(b, AsyncFileSink)
        assert a.native_ring and b.native_ring
    finally:
        a.close()
        b.close()


def test_auto_ring_on_plain_sinks_is_emulated():
    sink = MemorySink()
    engine = IOEngine(sink, workers=1, inflight_bytes=1 << 20, ring="auto")
    try:
        assert isinstance(engine.ring, EmulatedRing)
    finally:
        engine.close()


# ---------------------------------------------------------------------------
# pooled merge and unbuffered page paths


def test_merge_raw_copy_uses_pool_and_stays_identical(tmp_path):
    paths = [str(tmp_path / f"in{i}.rntj") for i in range(3)]
    schema = vec_schema()
    rng = np.random.default_rng(11)
    for i, p in enumerate(paths):
        with SequentialWriter(schema, p, WriteOptions(**BASE)) as w:
            w.fill_batch(make_batch(schema, rng, 1000, id0=i * 1000))
    out_pool = str(tmp_path / "out_pool.rntj")
    out_plain = str(tmp_path / "out_plain.rntj")
    merge_files(paths, out_pool, WriteOptions(**BASE))
    merge_files(paths, out_plain, WriteOptions(**BASE, buffer_pool_bytes=0))
    with open(out_pool, "rb") as f1, open(out_plain, "rb") as f2:
        assert f1.read() == f2.read()
    r = RNTJReader(out_pool)
    np.testing.assert_array_equal(r.read_column("id"), np.arange(3000))
    r.close()


def test_unbuffered_pages_route_through_pool():
    sink = MemorySink()
    schema = vec_schema()
    rng = np.random.default_rng(13)
    opts = WriteOptions(codec="none", cluster_bytes=1 << 16, page_size=4096,
                        buffered=False)
    w = SequentialWriter(schema, sink, opts)
    for i in range(8):
        w.fill_batch(make_batch(schema, rng, 500, id0=i * 500))
    w.close()
    d = w.stats.as_dict()
    assert d["pool_returns"] > 0 and d["pool_hits"] > 0
    r = RNTJReader(sink)
    np.testing.assert_array_equal(r.read_column("id"), np.arange(4000))


def test_unbuffered_pool_off_byte_identical():
    a, b = MemorySink(), MemorySink()
    opts = dict(codec="none", cluster_bytes=1 << 16, page_size=4096,
                buffered=False)
    write_file(a, WriteOptions(**opts))
    write_file(b, WriteOptions(**opts, buffer_pool_bytes=0))
    assert bytes(a.buf) == bytes(b.buf)


def test_base_pread_into_raises_on_short_read():
    """A short read into a (possibly recycled) caller buffer must raise,
    never silently leave a stale tail."""
    from repro.core import Sink

    class ShortSink(Sink):
        def pread(self, offset, size):
            return b"x" * (size // 2)

        def readable(self):
            return True

    buf = np.zeros(64, np.uint8)
    with pytest.raises(EOFError, match="short read"):
        ShortSink().pread_into(0, memoryview(buf))


# ---------------------------------------------------------------------------
# docs tooling promised by benchmarks/README.md


def test_benchmarks_run_list_prints_documented_names():
    import subprocess
    import sys
    from pathlib import Path

    import os

    repo = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(repo / "src") + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--list"],
        cwd=repo, capture_output=True, text=True, timeout=120, env=env,
    )
    assert out.returncode == 0, out.stderr
    for name in ("bench_writer", "bench_reader", "bench_codec", "bench_io",
                 "fig2_devnull", "fig5_skim", "BENCH_io.json"):
        assert name in out.stdout


# ---------------------------------------------------------------------------
# reader-side pooling


def test_reader_recycle_buffers_round_trip():
    sink = MemorySink()
    write_file(sink, WriteOptions(**BASE), entries=8000, batches=8)
    ref = RNTJReader(sink)
    want = [cols[0].copy() for _, cols in ref.iter_clusters()]
    ref.close()
    r = RNTJReader(sink, options=ReadOptions(recycle_buffers=True,
                                             prefetch_clusters=0))
    got = []
    for (i, cols) in r.iter_clusters():
        got.append(cols[0].copy())  # valid only until the next iteration
    r.close()
    for a, b in zip(want, got):
        np.testing.assert_array_equal(a, b)
    d = r.stats.as_dict()
    assert d["pool_returns"] > 0
    assert d["pool_hits"] > 0  # later clusters decoded into recycled arrays


def test_reader_member_scratch_recycles():
    sink = MemorySink()
    write_file(sink, WriteOptions(codec="zlib", level=1,
                                  cluster_bytes=1 << 17, page_size=16 * 1024,
                                  codec_chunk_bytes=2 * 1024),
               entries=8000, batches=4)
    r = RNTJReader(sink, options=ReadOptions(decode_workers=2))
    total = sum(len(cols[0]) for _, cols in r.iter_clusters())
    assert total == 8000
    r.close()
    assert r.stats.as_dict()["pool_returns"] > 0


def test_read_column_ignores_recycle_option():
    """read_column holds views across clusters: recycle_buffers must not
    corrupt its output."""
    sink = MemorySink()
    write_file(sink, WriteOptions(**BASE), entries=8000, batches=8)
    r = RNTJReader(sink, options=ReadOptions(recycle_buffers=True))
    np.testing.assert_array_equal(r.read_column("id"), np.arange(8000))
    r.close()
