"""Per-architecture smoke tests (reduced configs, CPU) + decode consistency.

Every assigned arch: one forward/train step asserting output shapes and
finiteness, plus prefill->decode equivalence against the full forward pass
(the KV-cache/state path must reproduce teacher-forced logits).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.configs.base import MoEConfig
from repro.models import build, make_batch

ALL_ARCHS = sorted(ARCHS)
RNG = np.random.default_rng(7)


def _bundle(name, **over):
    cfg = smoke_config(name)
    if cfg.moe is not None:
        # disable capacity dropping so decode consistency is exact
        cfg = cfg.with_(moe=MoEConfig(cfg.moe.n_experts, cfg.moe.top_k,
                                      cfg.moe.n_shared, capacity_factor=8.0))
    if over:
        cfg = cfg.with_(**over)
    return build(cfg)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_train_step_shapes_and_finite(name):
    bundle = _bundle(name)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = make_batch(bundle, RNG, batch=2, seq=32)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(bundle.loss, has_aux=True)
    )(params, batch)
    assert np.isfinite(float(loss))
    assert np.isfinite(float(metrics["ce"]))
    # gradients flow everywhere and are finite
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in leaves)
    nonzero = sum(int(np.any(np.asarray(g) != 0)) for g in leaves)
    assert nonzero > len(leaves) * 0.5, f"{nonzero}/{len(leaves)} grads nonzero"


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_logit_shape(name):
    bundle = _bundle(name)
    cfg = bundle.cfg
    params = bundle.init(jax.random.PRNGKey(1))
    batch = make_batch(bundle, RNG, batch=2, seq=16)
    logits, aux = jax.jit(bundle.forward)(params, batch["tokens"])
    if cfg.n_codebooks > 1:
        assert logits.shape == (2, 16, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_matches_forward(name):
    """logits(decode after prefill(t)) == logits(forward(t+1))[:, -1].

    Runs in f32 compute so the cache path must match teacher forcing to
    tight tolerance (bf16 would only blur the comparison).
    """
    bundle = _bundle(name, compute_dtype="float32")
    params = bundle.init(jax.random.PRNGKey(2))
    seq = 17
    batch = make_batch(bundle, RNG, batch=2, seq=seq)
    toks = batch["tokens"]

    full_logits, _ = jax.jit(bundle.forward)(params, toks)
    _, cache = jax.jit(lambda p, t: bundle.prefill(p, t, max_len=32))(
        params, toks[:, : seq - 1]
    )
    pos = jnp.full((2,), seq - 1, jnp.int32)
    dec_logits, _ = jax.jit(bundle.decode_step)(
        params, toks[:, seq - 1 : seq], cache, pos
    )
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32),
        atol=2e-3, rtol=2e-3,
    )


@pytest.mark.parametrize("name", ["mixtral-8x22b", "zamba2-2.7b"])
def test_windowed_decode_ring_buffer(name):
    """Decoding past the window keeps working (ring-buffer cache)."""
    bundle = _bundle(name, window=8) if name == "mixtral-8x22b" else _bundle(name)
    params = bundle.init(jax.random.PRNGKey(3))
    cache = bundle.init_cache(batch=2, max_len=8 if name == "mixtral-8x22b" else 32)
    step = jax.jit(bundle.decode_step)
    tok_shape = (2, 1) if bundle.cfg.n_codebooks == 1 else (2, 1, bundle.cfg.n_codebooks)
    for t in range(12):
        tok = jnp.asarray(RNG.integers(0, bundle.cfg.vocab_size, tok_shape),
                          dtype=jnp.int32)
        logits, cache = step(params, tok, cache, jnp.full((2,), t, jnp.int32))
        assert np.isfinite(np.asarray(logits, np.float32)).all(), f"t={t}"


@pytest.mark.parametrize("name", ["rwkv6-7b", "zamba2-2.7b"])
def test_ssm_state_decode_is_o1_memory(name):
    """SSM/hybrid cache size must not scale with max_len (long_500k path)."""
    bundle = _bundle(name)
    c_small = jax.eval_shape(lambda: bundle.init_cache(1, 1024))
    c_large = jax.eval_shape(lambda: bundle.init_cache(1, 1 << 19))
    def nbytes(tree, skip_shared=False):
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            if skip_shared and any(getattr(k, "key", None) == "shared" for k in path):
                continue
            total += leaf.size * leaf.dtype.itemsize
        return total
    if name == "rwkv6-7b":
        assert nbytes(c_large) == nbytes(c_small)
    else:
        # zamba2: mamba states O(1); shared-attn cache capped at window 4096
        assert nbytes(c_large, skip_shared=True) == nbytes(c_small, skip_shared=True)
        shared_large = jax.tree_util.tree_leaves(c_large["shared"])[0]
        assert shared_large.shape[3] == 4096  # windowed, not 524288


def test_musicgen_multicodebook_loss():
    bundle = _bundle("musicgen-large")
    params = bundle.init(jax.random.PRNGKey(4))
    batch = make_batch(bundle, RNG, batch=2, seq=16)
    assert batch["tokens"].shape == (2, 16, 4)
    loss, _ = jax.jit(bundle.loss)(params, batch)
    assert np.isfinite(float(loss))


def test_mla_cache_is_compressed():
    """MiniCPM3 decode cache stores latents (kv_rank + d_rope), not full KV."""
    bundle = _bundle("minicpm3-4b")
    cfg = bundle.cfg
    cache = jax.eval_shape(lambda: bundle.init_cache(1, 64))
    leaves = {str(p): l for p, l in
              [(jax.tree_util.keystr(p), l) for p, l
               in jax.tree_util.tree_flatten_with_path(cache)[0]]}
    per_tok = sum(l.shape[-1] for l in leaves.values())
    full_kv = 2 * cfg.n_heads * (cfg.mla.d_nope + cfg.mla.d_rope)
    assert per_tok == cfg.mla.kv_rank + cfg.mla.d_rope
    assert per_tok < full_kv / 4


def test_param_counts_full_configs():
    """Full (non-smoke) configs: abstract param counts near literature sizes."""
    import jax
    expect = {
        "gemma-2b": (2.0e9, 3.5e9),
        "minicpm3-4b": (3.0e9, 5.5e9),
        "deepseek-67b": (60e9, 72e9),
        "smollm-360m": (0.30e9, 0.45e9),
        "rwkv6-7b": (6.0e9, 9.0e9),
        "chameleon-34b": (30e9, 38e9),
        "mixtral-8x22b": (130e9, 150e9),
        "deepseek-moe-16b": (14e9, 20e9),
        "zamba2-2.7b": (2.2e9, 3.4e9),
        # musicgen-large is ~3.3B incl. the T5 text encoder + cross-attn;
        # the assigned backbone (decoder-only, frontend stubbed) is ~2.4B.
        "musicgen-large": (2.2e9, 3.2e9),
    }
    for name, (lo, hi) in expect.items():
        bundle = build(name)
        shapes = jax.eval_shape(lambda b=bundle: b.init(jax.random.PRNGKey(0)))
        n = sum(int(x.size) for x in jax.tree_util.tree_leaves(shapes))
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B params out of [{lo/1e9},{hi/1e9}]"
