"""End-to-end behaviour of the full system.

One test drives the entire framework the way a user would: parallel
columnar ingest -> sharded training with checkpoints -> crash-restart ->
batched serving with parallel output logging -> dataset skim of the
generated outputs.  Every storage artifact in the chain is a single RNT-J
file written with the paper's parallel protocol.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import RNTJReader
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import GEN_SCHEMA, generate
from repro.models import build
from repro.pipeline import PackedLoader, ingest_corpus, synth_corpus
from repro.train import LoopConfig, TrainLoop, make_optimizer


@pytest.fixture(scope="module")
def tiny_bundle():
    cfg = get_arch("smollm-360m").with_(
        name="sys-tiny", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256, remat=False,
    )
    return build(cfg)


def test_full_system_pipeline(tiny_bundle, tmp_path):
    data = str(tmp_path / "corpus.rntj")
    ckpt = str(tmp_path / "ckpt")

    # 1. parallel ingest -> ONE file
    stats = ingest_corpus(synth_corpus(200, seed=1, mean_len=60, vocab=256),
                          data, n_workers=3)
    assert stats["entries"] == 200
    assert stats["clusters"] >= 1

    # 2. train with checkpoints
    mesh = make_local_mesh()
    loader = PackedLoader(data, batch=4, seq_len=32)
    loop = TrainLoop(
        tiny_bundle, mesh, loader, ckpt,
        config=LoopConfig(steps=24, ckpt_every=8, log_every=1000,
                          ckpt_async=False),
        optimizer=make_optimizer(peak_lr=5e-3, warmup=4, total=100),
    )
    hist = loop.run()
    assert len(hist) == 24
    assert all(np.isfinite(h.loss) for h in hist)
    trained_params = loop.params

    # 3. crash-restart resumes at the last committed step
    loader2 = PackedLoader(data, batch=4, seq_len=32)
    loop2 = TrainLoop(tiny_bundle, mesh, loader2, ckpt,
                      config=LoopConfig(steps=4, ckpt_every=8,
                                        log_every=1000, ckpt_async=False))
    assert loop2.step == 24
    loop2.run()
    assert loop2.step == 28

    # 4. serve a batch and log generations through the parallel writer
    from repro.core import ColumnBatch, ParallelWriter
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, 256, (4, 8)).astype(np.int32))
    gen = generate(tiny_bundle, loop2.params, prompts, max_new=8)
    assert gen.shape == (4, 8)
    out = str(tmp_path / "gen.rntj")
    w = ParallelWriter(GEN_SCHEMA, out)
    ctx = w.create_fill_context()
    ctx.fill_batch(ColumnBatch.from_arrays(GEN_SCHEMA, 4, {
        "request_id": np.arange(4, dtype=np.int64),
        "prompt_len": np.full(4, 8, np.int32),
        "tokens": np.full(4, 8, np.int64),
        "tokens._0": gen.reshape(-1).astype(np.int32),
    }))
    ctx.close()
    w.close()

    # 5. the served output is an ordinary columnar dataset: read it back
    r = RNTJReader(out)
    assert r.n_entries == 4
    toks = r.read_column("tokens._0")
    np.testing.assert_array_equal(np.sort(toks), np.sort(gen.reshape(-1)))


def test_training_learns_structure(tiny_bundle, tmp_path):
    """Loss must drop well below ln(vocab) on the phrase corpus."""
    data = str(tmp_path / "c.rntj")
    ingest_corpus(synth_corpus(400, seed=3, mean_len=80, vocab=256,
                               n_phrases=32), data, n_workers=2)
    loader = PackedLoader(data, batch=4, seq_len=48)
    loop = TrainLoop(
        tiny_bundle, make_local_mesh(), loader, str(tmp_path / "ck"),
        config=LoopConfig(steps=80, ckpt_every=1000, log_every=1000,
                          ckpt_async=False),
        optimizer=make_optimizer(peak_lr=8e-3, warmup=8, total=300),
    )
    hist = loop.run()
    first = np.mean([h.loss for h in hist[:8]])
    last = np.mean([h.loss for h in hist[-8:]])
    assert last < first - 0.8, (first, last)
