"""Data pipeline: parallel ingest -> single file -> packing loader."""

import numpy as np
import pytest

from repro.core import RNTJReader
from repro.pipeline import PackedLoader, ingest_corpus, synth_corpus


def test_ingest_conserves_all_docs(tmp_path):
    p = str(tmp_path / "d.rntj")
    stats = ingest_corpus(synth_corpus(300, seed=1, mean_len=64), p,
                          n_workers=5, batch_docs=17)
    assert stats["entries"] == 300
    r = RNTJReader(p)
    ids = np.sort(r.read_column("doc_id"))
    np.testing.assert_array_equal(ids, np.arange(300))
    # content spot check against the generator
    toks = r.read_column("tokens._0")
    total = sum(len(t) for _, t in synth_corpus(300, seed=1, mean_len=64))
    assert len(toks) == total


def test_ingest_matches_sequential_content(tmp_path):
    """Parallel ingest == sequential ingest, up to entry reordering."""
    p1, p2 = str(tmp_path / "par.rntj"), str(tmp_path / "seq.rntj")
    ingest_corpus(synth_corpus(100, seed=2), p1, n_workers=6, batch_docs=7)
    ingest_corpus(synth_corpus(100, seed=2), p2, n_workers=1, batch_docs=7)
    def doc_map(path):
        r = RNTJReader(path)
        out = {}
        for e in r.iter_entries():
            out[e["doc_id"]] = tuple(e["tokens"])
        return out
    assert doc_map(p1) == doc_map(p2)


def test_loader_packing_shapes_and_labels(tmp_path):
    p = str(tmp_path / "d.rntj")
    ingest_corpus(synth_corpus(200, seed=3, mean_len=50), p, n_workers=2)
    ld = PackedLoader(p, batch=8, seq_len=32)
    b = next(ld.batches())
    assert b["tokens"].shape == (8, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_loader_deterministic_and_resumable(tmp_path):
    p = str(tmp_path / "d.rntj")
    ingest_corpus(synth_corpus(150, seed=4, mean_len=40), p, n_workers=3)
    ld = PackedLoader(p, batch=4, seq_len=48)
    it = ld.batches()
    seq = [next(it) for _ in range(5)]
    state = ld.state()
    nxt = next(it)
    # fresh loader from saved state reproduces the exact next batch
    ld2 = PackedLoader(p, batch=4, seq_len=48, state=state)
    nxt2 = next(ld2.batches())
    np.testing.assert_array_equal(nxt["tokens"], nxt2["tokens"])
    # and a fresh run reproduces the whole prefix (determinism)
    ld3 = PackedLoader(p, batch=4, seq_len=48)
    it3 = ld3.batches()
    for b in seq:
        np.testing.assert_array_equal(b["tokens"], next(it3)["tokens"])


def test_loader_epoch_wrap(tmp_path):
    p = str(tmp_path / "tiny.rntj")
    ingest_corpus(synth_corpus(5, seed=5, mean_len=20), p, n_workers=1)
    ld = PackedLoader(p, batch=2, seq_len=64)
    it = ld.batches()
    for _ in range(10):  # far more tokens than one epoch holds
        b = next(it)
        assert b["tokens"].shape == (2, 64)
