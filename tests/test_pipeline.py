"""Data pipeline: parallel ingest -> single file -> packing loader."""

import numpy as np
import pytest

from repro.core import RNTJReader
from repro.pipeline import PackedLoader, ingest_corpus, synth_corpus


def test_ingest_conserves_all_docs(tmp_path):
    p = str(tmp_path / "d.rntj")
    stats = ingest_corpus(synth_corpus(300, seed=1, mean_len=64), p,
                          n_workers=5, batch_docs=17)
    assert stats["entries"] == 300
    r = RNTJReader(p)
    ids = np.sort(r.read_column("doc_id"))
    np.testing.assert_array_equal(ids, np.arange(300))
    # content spot check against the generator
    toks = r.read_column("tokens._0")
    total = sum(len(t) for _, t in synth_corpus(300, seed=1, mean_len=64))
    assert len(toks) == total


def test_ingest_matches_sequential_content(tmp_path):
    """Parallel ingest == sequential ingest, up to entry reordering."""
    p1, p2 = str(tmp_path / "par.rntj"), str(tmp_path / "seq.rntj")
    ingest_corpus(synth_corpus(100, seed=2), p1, n_workers=6, batch_docs=7)
    ingest_corpus(synth_corpus(100, seed=2), p2, n_workers=1, batch_docs=7)
    def doc_map(path):
        r = RNTJReader(path)
        out = {}
        for e in r.iter_entries():
            out[e["doc_id"]] = tuple(e["tokens"])
        return out
    assert doc_map(p1) == doc_map(p2)


def test_loader_packing_shapes_and_labels(tmp_path):
    p = str(tmp_path / "d.rntj")
    ingest_corpus(synth_corpus(200, seed=3, mean_len=50), p, n_workers=2)
    ld = PackedLoader(p, batch=8, seq_len=32)
    b = next(ld.batches())
    assert b["tokens"].shape == (8, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_loader_deterministic_and_resumable(tmp_path):
    p = str(tmp_path / "d.rntj")
    ingest_corpus(synth_corpus(150, seed=4, mean_len=40), p, n_workers=3)
    ld = PackedLoader(p, batch=4, seq_len=48)
    it = ld.batches()
    seq = [next(it) for _ in range(5)]
    state = ld.state()
    nxt = next(it)
    # fresh loader from saved state reproduces the exact next batch
    ld2 = PackedLoader(p, batch=4, seq_len=48, state=state)
    nxt2 = next(ld2.batches())
    np.testing.assert_array_equal(nxt["tokens"], nxt2["tokens"])
    # and a fresh run reproduces the whole prefix (determinism)
    ld3 = PackedLoader(p, batch=4, seq_len=48)
    it3 = ld3.batches()
    for b in seq:
        np.testing.assert_array_equal(b["tokens"], next(it3)["tokens"])


def test_loader_epoch_wrap(tmp_path):
    p = str(tmp_path / "tiny.rntj")
    ingest_corpus(synth_corpus(5, seed=5, mean_len=20), p, n_workers=1)
    ld = PackedLoader(p, batch=2, seq_len=64)
    it = ld.batches()
    for _ in range(10):  # far more tokens than one epoch holds
        b = next(it)
        assert b["tokens"].shape == (2, 64)


# ---------------------------------------------------------------------------
# host/device engine equivalence + exact resumability (ISSUE 7 satellite)


def _corpus_file(tmp_path, n_docs=400, cluster_bytes=64 * 1024):
    from repro.core.writer import WriteOptions
    p = str(tmp_path / "eq.rntj")
    ingest_corpus(synth_corpus(n_docs, seed=9, mean_len=60), p, n_workers=2,
                  options=WriteOptions(codec="zlib", level=1,
                                       cluster_bytes=cluster_bytes))
    return p


def _np(b):
    return {k: np.asarray(v) for k, v in b.items()}


def test_loader_device_stream_byte_identical(tmp_path):
    """The device engine emits the exact host token stream, epoch wraps
    included (the file holds several clusters; 160 batches wrap it)."""
    pytest.importorskip("jax")
    p = _corpus_file(tmp_path)
    lh = PackedLoader(p, batch=4, seq_len=96, device="host")
    ld = PackedLoader(p, batch=4, seq_len=96, device="device")
    assert ld.reader.n_clusters >= 2
    gh, gd = lh.batches(), ld.batches()
    for k in range(160):
        bh, bd = _np(next(gh)), _np(next(gd))
        np.testing.assert_array_equal(bd["tokens"], bh["tokens"], err_msg=str(k))
        np.testing.assert_array_equal(bd["labels"], bh["labels"], err_msg=str(k))
    lh.close(), ld.close()


@pytest.mark.parametrize("engine", ["host", "device"])
@pytest.mark.parametrize("n_warm", [3, 11])
def test_loader_exact_resume_mid_stream(tmp_path, engine, n_warm):
    """Save/restore at arbitrary batch boundaries — both mid-cluster
    (small n_warm: the cursor sits inside cluster 0's documents) and
    mid-leftover (larger n_warm: tokens already pulled but unemitted) —
    continues the byte-identical stream on EITHER engine."""
    if engine == "device":
        pytest.importorskip("jax")
    p = _corpus_file(tmp_path)
    ld = PackedLoader(p, batch=4, seq_len=64, device=engine)
    it = ld.batches()
    for _ in range(n_warm):
        next(it)
    state = ld.state()
    assert isinstance(state["leftover"], np.ndarray)  # host-typed state
    cont = [_np(next(it)) for _ in range(20)]  # the ground-truth continuation
    ld.close()
    for resume_engine in ("host", "device"):
        l2 = PackedLoader(p, batch=4, seq_len=64, state=state,
                          device=resume_engine)
        g2 = l2.batches()
        for k, want in enumerate(cont):
            got = _np(next(g2))
            np.testing.assert_array_equal(got["tokens"], want["tokens"],
                                          err_msg=f"{resume_engine}:{k}")
            np.testing.assert_array_equal(got["labels"], want["labels"],
                                          err_msg=f"{resume_engine}:{k}")
        l2.close()


def test_loader_state_roundtrips_through_load_state(tmp_path):
    """state() -> load_state() is the checkpoint contract: the restored
    loader's next batch equals the saved loader's next batch."""
    p = _corpus_file(tmp_path, n_docs=80)
    ld = PackedLoader(p, batch=2, seq_len=48, device="host")
    it = ld.batches()
    for _ in range(5):
        next(it)
    st = ld.state()
    want = _np(next(it))
    ld2 = PackedLoader(p, batch=2, seq_len=48, device="host")
    ld2.load_state(st)
    got = _np(next(ld2.batches()))
    np.testing.assert_array_equal(got["tokens"], want["tokens"])
    ld.close(), ld2.close()
