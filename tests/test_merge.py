"""Merging baselines: hadd analog and TBufferMerger analog (paper §2, §6.2)."""

import threading

import numpy as np
import pytest

from repro.core import (
    BufferMerger, Collection, ColumnBatch, Leaf, RNTJReader, Schema,
    SequentialWriter, WriteOptions, merge_files,
)


def schema():
    return Schema([Leaf("id", "int64"), Collection("vals", Leaf("_0", "float32"))])


def write_one(path, seed, n=300):
    s = schema()
    rng = np.random.default_rng(seed)
    sizes = rng.poisson(5, n).astype(np.int64)
    vals = rng.uniform(0, 100, int(sizes.sum())).astype(np.float32)
    batch = ColumnBatch.from_arrays(
        s, n, {"id": np.arange(seed * 10_000, seed * 10_000 + n),
               "vals": sizes, "vals._0": vals})
    with SequentialWriter(s, path, WriteOptions(cluster_bytes=4096)) as w:
        w.fill_batch(batch)
    return batch


def test_merge_files_preserves_everything(tmp_path):
    paths = [str(tmp_path / f"in{i}.rntj") for i in range(3)]
    batches = [write_one(p, i) for i, p in enumerate(paths)]
    out = str(tmp_path / "merged.rntj")
    merge_files(paths, out)
    r = RNTJReader(out)
    assert r.n_entries == 900
    ids = np.sort(r.read_column("id"))
    expect = np.sort(np.concatenate([b.data[0] for b in batches]))
    np.testing.assert_array_equal(ids, expect)
    vals = r.read_column("vals._0")
    assert len(vals) == sum(int(b.data[1].sum()) for b in batches)


def test_merge_rejects_schema_mismatch(tmp_path):
    p1 = str(tmp_path / "a.rntj")
    write_one(p1, 0)
    s2 = Schema([Leaf("other", "int32")])
    p2 = str(tmp_path / "b.rntj")
    with SequentialWriter(s2, p2) as w:
        w.fill({"other": 1})
    with pytest.raises(ValueError):
        merge_files([p1, p2], str(tmp_path / "out.rntj"))


def test_merge_is_byte_verbatim(tmp_path):
    """Relocatability means merged clusters keep identical compressed bytes."""
    p = str(tmp_path / "in.rntj")
    write_one(p, 1)
    out = str(tmp_path / "out.rntj")
    merge_files([p], out)
    rin, rout = RNTJReader(p), RNTJReader(out)
    for cin, cout in zip(rin.clusters, rout.clusters):
        bin_ = rin.sink.pread(cin.byte_offset, cin.byte_size)
        bout = rout.sink.pread(cout.byte_offset, cout.byte_size)
        assert bin_ == bout


def test_merge_auto_recompresses_on_codec_mismatch(tmp_path):
    """Asking for zlib output from a codec-none input takes the re-encode
    slow path: values survive, pages come out in the target codec."""
    s = schema()
    p = str(tmp_path / "raw.rntj")
    rng = np.random.default_rng(4)
    n = 3000
    sizes = rng.poisson(5, n).astype(np.int64)
    vals = rng.uniform(0, 100, int(sizes.sum())).astype(np.float32)
    batch = ColumnBatch.from_arrays(
        s, n, {"id": np.arange(n), "vals": sizes, "vals._0": vals})
    with SequentialWriter(s, p, WriteOptions(codec="none",
                                             cluster_bytes=64 * 1024)) as w:
        w.fill_batch(batch)
    out = str(tmp_path / "zl.rntj")
    merge_files([p], out, WriteOptions(codec="zlib", level=1,
                                       cluster_bytes=64 * 1024))
    r = RNTJReader(out)
    np.testing.assert_array_equal(r.read_column("id"), np.arange(n))
    np.testing.assert_array_equal(r.read_column("vals._0"), vals)
    # the compressible id/offset pages really were transcoded to zlib
    assert any(pg.codec == 1 for cm in r.clusters for pg in cm.pages)
    import os
    assert os.path.getsize(out) < os.path.getsize(p)


def test_merge_recompress_false_forces_raw_copy(tmp_path):
    """recompress=False keeps byte-verbatim clusters even when the
    requested codec differs from the input's."""
    s = schema()
    p = str(tmp_path / "raw.rntj")
    write_one(p, 2)  # zlib input
    out = str(tmp_path / "none.rntj")
    merge_files([p], out, WriteOptions(codec="none"), recompress=False)
    rin, rout = RNTJReader(p), RNTJReader(out)
    for cin, cout in zip(rin.clusters, rout.clusters):
        assert (rin.sink.pread(cin.byte_offset, cin.byte_size)
                == rout.sink.pread(cout.byte_offset, cout.byte_size))


def test_merge_missing_input_leaks_nothing(tmp_path):
    """A failed open mid-list must close the readers already opened."""
    import os
    p1 = str(tmp_path / "a.rntj")
    write_one(p1, 0)
    fds_before = len(os.listdir("/proc/self/fd"))
    for _ in range(5):
        with pytest.raises(FileNotFoundError):
            merge_files([p1, str(tmp_path / "missing.rntj")],
                        str(tmp_path / "o.rntj"))
    assert len(os.listdir("/proc/self/fd")) <= fds_before


def test_buffer_merger_threads(tmp_path):
    s = schema()
    out = str(tmp_path / "bm.rntj")
    bm = BufferMerger(s, out, WriteOptions(cluster_bytes=2048))
    N, T = 150, 6
    def worker(tid):
        rng = np.random.default_rng(tid)
        f = bm.get_file()
        sizes = rng.poisson(5, N).astype(np.int64)
        vals = rng.uniform(0, 100, int(sizes.sum())).astype(np.float32)
        batch = ColumnBatch.from_arrays(
            s, N, {"id": np.arange(tid * 1000, tid * 1000 + N),
                   "vals": sizes, "vals._0": vals})
        f.fill_batch(batch)
        f.commit()
        f.close()
    ts = [threading.Thread(target=worker, args=(t,)) for t in range(T)]
    for t in ts: t.start()
    for t in ts: t.join()
    bm.close()
    r = RNTJReader(out)
    assert r.n_entries == N * T
    ids = np.sort(r.read_column("id"))
    expect = np.sort(np.concatenate([np.arange(t * 1000, t * 1000 + N) for t in range(T)]))
    np.testing.assert_array_equal(ids, expect)
