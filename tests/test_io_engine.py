"""I/O engine tests (ISSUE 4): scatter-gather commit, striping,
write-behind, fsync policy, the member side-car, the rate-aware codec
policy, and MemorySink reserve-time growth."""

import threading

import numpy as np
import pytest

from repro.core import (
    Collection,
    ColumnBatch,
    DevNullSink,
    FileSink,
    Leaf,
    MemorySink,
    ParallelWriter,
    ReadOptions,
    RNTJReader,
    Schema,
    SequentialWriter,
    Sink,
    ThrottledSink,
    WriteOptions,
    merge_files,
)
from repro.core.compression import CODEC_NONE, CodecPolicy
from repro.core.ioengine import IOEngine


def vec_schema():
    return Schema([
        Leaf("id", "int64"),
        Collection("vals", Leaf("_0", "float32")),
    ])


def make_batch(schema, rng, n, id0=0, poisson=5):
    sizes = rng.poisson(poisson, n).astype(np.int64)
    vals = rng.uniform(0, 100, int(sizes.sum())).astype(np.float32)
    return ColumnBatch.from_arrays(
        schema, n,
        {"id": np.arange(id0, id0 + n), "vals": sizes, "vals._0": vals},
    )


def write_file(sink, opts, entries=4000, seed=0, batches=4):
    schema = vec_schema()
    rng = np.random.default_rng(seed)
    per = entries // batches
    with SequentialWriter(schema, sink, opts) as w:
        for i in range(batches):
            w.fill_batch(make_batch(schema, rng, per, id0=i * per))
        stats = w.stats
    return stats


BASE = dict(codec="zlib", level=1, cluster_bytes=1 << 17,
            page_size=8 * 1024, codec_chunk_bytes=4 * 1024)


# ---------------------------------------------------------------------------
# scatter-gather commit: byte-identical to the assembled reference path


@pytest.mark.parametrize("codec", ["none", "zlib"])
def test_scatter_commit_byte_identical(codec):
    opts = {**BASE, "codec": codec}
    a, b = MemorySink(), MemorySink()
    write_file(a, WriteOptions(**opts, scatter_commit=False))
    write_file(b, WriteOptions(**opts, scatter_commit=True))
    assert bytes(a.buf) == bytes(b.buf)
    # the scatter path actually used vectored submissions
    assert b.io.writev_calls > 0


def test_scatter_identical_with_striping_and_write_behind():
    a, b = MemorySink(), MemorySink()
    write_file(a, WriteOptions(**BASE, scatter_commit=False))
    write_file(b, WriteOptions(**BASE, scatter_commit=True,
                               io_stripe_bytes=8 * 1024,
                               io_inflight_bytes=1 << 20,
                               pipelined_seal=True, imt_workers=2))
    assert bytes(a.buf) == bytes(b.buf)


def test_scatter_adaptive_raw_pages_roundtrip():
    """Adaptive fallback stores raw pages as zero-copy views of detached
    builder buffers; they must survive builder reuse across clusters."""
    sink = MemorySink()
    write_file(sink, WriteOptions(**BASE, scatter_commit=True,
                                  adaptive_codec=True,
                                  adaptive_sample_pages=2,
                                  adaptive_threshold=0.5))
    r = RNTJReader(sink)
    rng = np.random.default_rng(0)
    exp = [make_batch(vec_schema(), rng, 1000, id0=i * 1000) for i in range(4)]
    vals = np.concatenate([b.data[2] for b in exp])
    np.testing.assert_array_equal(r.read_column("vals._0"), vals)
    codecs = {p.codec for cm in r.clusters for p in cm.pages}
    assert CODEC_NONE in codecs  # the policy did drop something to raw


def test_detached_buffers_survive_queued_write_behind():
    """The detach hazard: with write-behind, a queued scatter commit's raw
    views must stay valid while the SAME builder refills the next cluster
    behind a slow sink."""
    inner = MemorySink()
    slow = ThrottledSink(inner, bw=3e6)  # ~3 MB/s: writes lag the producer
    schema = vec_schema()
    rng = np.random.default_rng(7)
    opts = WriteOptions(codec="none", cluster_bytes=1 << 16,
                        scatter_commit=True, io_inflight_bytes=4 << 20,
                        pipelined_seal=True)
    with SequentialWriter(schema, slow, opts) as w:
        for i in range(8):
            w.fill_batch(make_batch(schema, rng, 500, id0=i * 500))
    rng = np.random.default_rng(7)
    exp = np.concatenate(
        [make_batch(schema, rng, 500, id0=i * 500).data[0] for i in range(8)]
    )
    r = RNTJReader(inner)
    np.testing.assert_array_equal(r.read_column("id"), exp)


# ---------------------------------------------------------------------------
# pwritev: every sink, loop fallback, file correctness


def test_pwritev_file_sink(tmp_path):
    p = tmp_path / "v.bin"
    s = FileSink(str(p))
    off = s.reserve(10)
    parts = [b"abc", b"", b"defg", memoryview(np.frombuffer(b"hij", np.uint8))]
    s.pwritev(off, parts)
    s.close()
    assert p.read_bytes() == b"abcdefghij"


def test_pwritev_memory_and_devnull_accounting():
    m = MemorySink()
    m.reserve(6)
    m.pwritev(0, [b"foo", b"bar"])
    assert bytes(m.buf[:6]) == b"foobar"
    assert m.io.writev_calls == 1 and m.io.bytes_written == 6

    d = DevNullSink()
    d.pwritev(0, [b"xx", b"yyy"])
    assert d.io.writev_calls == 1 and d.io.bytes_written == 5


def test_pwritev_loop_fallback_for_custom_sinks():
    """A bare Sink subclass that only implements pwrite still works (and
    is how fault-injection sinks keep intercepting every byte)."""
    writes = []

    class LoggingSink(Sink):
        def pwrite(self, offset, data):
            writes.append((offset, bytes(data)))
            self._count_write(1, len(data))

    s = LoggingSink()
    s.pwritev(100, [b"ab", b"", b"cde"])
    assert writes == [(100, b"ab"), (102, b"cde")]
    assert s.io.write_calls == 2 and s.io.bytes_written == 5


def test_pwritev_throttled_charges_once():
    inner = MemorySink()
    t = ThrottledSink(inner, bw=1e9)
    t.reserve(8)
    t.pwritev(0, [b"aaaa", b"bbbb"])
    assert bytes(inner.buf[:8]) == b"aaaabbbb"
    assert t.io.writev_calls == 1


# ---------------------------------------------------------------------------
# striping


def test_striped_write_matches_monolithic(tmp_path):
    a, b = MemorySink(), MemorySink()
    write_file(a, WriteOptions(**BASE))
    write_file(b, WriteOptions(**BASE, io_stripe_bytes=4 * 1024))
    assert bytes(a.buf) == bytes(b.buf)


def test_engine_stripes_cover_extent_exactly():
    eng = IOEngine(DevNullSink(), workers=2, stripe_bytes=10)
    parts = [b"a" * 7, b"b" * 9, b"c" * 12]
    stripes = eng._stripes(1000, parts, 28)
    # offsets contiguous from 1000, each stripe <= 10 bytes, total 28
    assert [s[0] for s in stripes] == [1000, 1010, 1020]
    assert [s[2] for s in stripes] == [10, 10, 8]
    flat = b"".join(bytes(mv) for _off, ps, _n in stripes for mv in ps)
    assert flat == b"".join(parts)
    eng.close()


# ---------------------------------------------------------------------------
# write-behind: backpressure, stats, drain-before-footer


def test_write_behind_roundtrip_and_stats():
    inner = MemorySink()
    slow = ThrottledSink(inner, bw=5e6)
    stats = write_file(slow, WriteOptions(**BASE, io_inflight_bytes=1 << 20,
                                          io_stripe_bytes=16 * 1024))
    d = stats.as_dict()
    assert d["io_jobs"] > 0
    assert d["io_inflight_peak_bytes"] > 0
    r = RNTJReader(inner)
    assert r.n_entries == 4000


def test_write_behind_backpressure_blocks_producer():
    """A budget smaller than one cluster forces the producer to stall
    until the previous extent drains: inflight never exceeds one extent
    and the stall shows up in the stats."""
    inner = MemorySink()
    slow = ThrottledSink(inner, bw=2e6)
    stats = write_file(slow, WriteOptions(**{**BASE, "codec": "none"},
                                          io_inflight_bytes=1))
    assert stats.as_dict()["io_stall_ms"] > 0
    assert RNTJReader(inner).n_entries == 4000


def test_drain_before_footer_ordering():
    """Finalization bytes (pagelist/footer/anchor) must be written only
    after every queued cluster extent has landed."""
    order = []

    class OrderSink(MemorySink):
        def pwrite(self, offset, data):
            order.append(("w", offset, len(data)))
            super().pwrite(offset, data)

    sink = OrderSink()
    write_file(sink, WriteOptions(**BASE, io_inflight_bytes=8 << 20))
    r = RNTJReader(sink)
    data_end = max(cm.byte_offset + cm.byte_size for cm in r.clusters)
    # every write at/after data_end (the metadata tail) must come after
    # every cluster write in submission order
    tail_first = min(i for i, (_k, off, _n) in enumerate(order)
                     if off >= data_end)
    last_cluster = max(i for i, (_k, off, _n) in enumerate(order)
                       if off < data_end and off > 0)
    assert last_cluster < tail_first


# ---------------------------------------------------------------------------
# commit-error poisoning under write-behind (buffered + unbuffered)


class _FailingSink(MemorySink):
    """Fails cluster/page-sized writes after the first N."""

    def __init__(self, allowed=0, threshold=512):
        super().__init__()
        self._allowed = allowed
        self._threshold = threshold
        self._mu = threading.Lock()

    def pwrite(self, offset, data):
        if len(data) > self._threshold:
            with self._mu:
                if self._allowed <= 0:
                    raise IOError("injected ENOSPC")
                self._allowed -= 1
        super().pwrite(offset, data)


@pytest.mark.parametrize("buffered", [True, False])
def test_failed_queued_write_poisons_finalization(buffered):
    schema = vec_schema()
    sink = _FailingSink(allowed=1)
    opts = WriteOptions(**BASE, buffered=buffered,
                        io_inflight_bytes=16 << 20)
    w = ParallelWriter(schema, sink, opts)
    ctx = w.create_fill_context()
    rng = np.random.default_rng(0)
    try:
        for i in range(20):
            ctx.fill_batch(make_batch(schema, rng, 500, id0=i * 500))
        ctx.close()
    except Exception:
        pass  # queued mode may or may not surface it here
    with pytest.raises(RuntimeError, match="NOT finalized") as ei:
        w.close()
    assert isinstance(ei.value.__cause__, IOError)  # the original error
    with pytest.raises(Exception):
        RNTJReader(sink)  # no valid footer/anchor


def test_failed_striped_write_poisons_finalization():
    schema = vec_schema()
    sink = _FailingSink(allowed=2, threshold=2048)
    opts = WriteOptions(**{**BASE, "codec": "none"},
                        io_stripe_bytes=4 * 1024,
                        io_inflight_bytes=16 << 20)
    w = SequentialWriter(schema, sink, opts)
    rng = np.random.default_rng(1)
    try:
        for i in range(16):
            w.fill_batch(make_batch(schema, rng, 500, id0=i * 500))
    except Exception:
        pass
    with pytest.raises(RuntimeError, match="NOT finalized"):
        w.close()


def test_failed_synchronous_striped_write_raises_inline():
    schema = vec_schema()
    sink = _FailingSink(allowed=0, threshold=2048)
    opts = WriteOptions(**{**BASE, "codec": "none"}, io_stripe_bytes=4 * 1024)
    w = SequentialWriter(schema, sink, opts)
    rng = np.random.default_rng(1)
    with pytest.raises(IOError, match="ENOSPC"):
        for i in range(16):
            w.fill_batch(make_batch(schema, rng, 500, id0=i * 500))
        w.flush_cluster()
    with pytest.raises(RuntimeError, match="NOT finalized"):
        w.close()


# ---------------------------------------------------------------------------
# fsync policy


def test_fsync_every_cluster():
    sink = MemorySink()
    write_file(sink, WriteOptions(**BASE, fsync_policy="every_cluster"))
    # one per committed cluster + the unconditional close fsync
    r = RNTJReader(sink)
    assert sink.io.fsync_calls == r.n_clusters + 1


def test_fsync_byte_interval():
    sink = MemorySink()
    write_file(sink, WriteOptions(**BASE, fsync_policy=64 * 1024))
    assert sink.io.fsync_calls > 1  # interval fsyncs + close fsync


def test_fsync_on_close_unchanged():
    sink = MemorySink()
    write_file(sink, WriteOptions(**BASE))
    assert sink.io.fsync_calls == 1


# ---------------------------------------------------------------------------
# MemorySink: reserve-time growth, no lock on the write path


def test_memory_sink_grows_at_reserve():
    m = MemorySink()
    off = m.reserve(1000)
    assert len(m.buf) >= off + 1000


def test_memory_sink_no_grow_lock_on_reserved_writes():
    """The contention regression: after reserve(), parallel pwrites never
    touch the grow lock (no serialization on reallocation)."""
    m = MemorySink()
    acquisitions = []

    class CountingLockProxy:
        def __init__(self, inner):
            self._inner = inner

        def __enter__(self):
            acquisitions.append(1)
            return self._inner.__enter__()

        def __exit__(self, *exc):
            return self._inner.__exit__(*exc)

    offs = [m.reserve(10_000) for _ in range(16)]
    m._grow_lock = CountingLockProxy(m._grow_lock)
    ts = [
        threading.Thread(target=m.pwrite, args=(off, bytes([i % 256]) * 10_000))
        for i, off in enumerate(offs)
    ]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert acquisitions == []  # in-bounds writes: lock-free
    for i, off in enumerate(offs):
        assert m.buf[off] == i % 256 and m.buf[off + 9999] == i % 256


def test_memory_sink_unreserved_write_still_grows():
    m = MemorySink()
    m.pwrite(100, b"zz")  # direct use without reserve: fallback grow path
    assert bytes(m.buf[100:102]) == b"zz"


def test_memory_sink_close_keeps_unreserved_writes():
    """Preallocated sink + direct in-bounds writes without reserve():
    close() must trim only padding, never written data."""
    m = MemorySink(capacity=1024)
    m.pwrite(0, b"hello world")
    m.pwritev(11, [b" and", b" more"])
    m.close()
    assert bytes(m.buf) == b"hello world and more"


# ---------------------------------------------------------------------------
# member side-car: parallel member decompression + compatibility


def _member_file(chunk=4 * 1024):
    sink = MemorySink()
    write_file(sink, WriteOptions(**{**BASE, "page_size": 32 * 1024,
                                     "codec_chunk_bytes": chunk}))
    return sink


def test_sidecar_written_and_parsed():
    sink = _member_file()
    r = RNTJReader(sink)
    framed = [p for cm in r.clusters for p in cm.pages if p.members]
    assert framed, "expected chunk-framed pages"
    for p in framed:
        assert sum(p.members) == p.size
        assert p.member_chunk == 4 * 1024


def test_parallel_member_decode_matches_serial():
    sink = _member_file()
    serial = RNTJReader(sink, options=ReadOptions(decode_workers=0))
    par = RNTJReader(
        sink, options=ReadOptions(decode_workers=3, parallel_members=True)
    )
    for path in ("id", "vals", "vals._0"):
        np.testing.assert_array_equal(
            serial.read_column(path), par.read_column(path)
        )
    par.close()


def test_unframed_file_has_no_sidecar_and_roundtrips():
    sink = MemorySink()
    write_file(sink, WriteOptions(**{**BASE, "codec_chunk_bytes": 0}))
    r = RNTJReader(
        sink, options=ReadOptions(decode_workers=2, parallel_members=True)
    )
    assert all(p.members is None for cm in r.clusters for p in cm.pages)
    assert r.n_entries == 4000
    assert len(r.read_column("id")) == 4000


def test_corrupt_sidecar_record_falls_back_to_serial_decode():
    sink = _member_file()
    r = RNTJReader(
        sink, options=ReadOptions(decode_workers=2, parallel_members=True)
    )
    # sabotage the in-memory member records: inconsistent sizes must make
    # the page decode serially, not wrongly
    for cm in r.clusters:
        for p in cm.pages:
            if p.members:
                p.members = [p.size + 1]  # does not tile the payload
    assert len(r.read_column("id")) == 4000


def test_merge_preserves_member_sidecar():
    import tempfile
    import os

    with tempfile.TemporaryDirectory() as d:
        paths = []
        for i in range(2):
            p = os.path.join(d, f"in{i}.rntj")
            write_file(p, WriteOptions(**{**BASE, "page_size": 32 * 1024}),
                       seed=i)
            paths.append(p)
        out = os.path.join(d, "merged.rntj")
        merge_files(paths, out)
        r = RNTJReader(out)
        framed = [p for cm in r.clusters for p in cm.pages if p.members]
        assert framed  # the raw fast path carried the member records over
        serial = RNTJReader(out, options=ReadOptions(decode_workers=0))
        par = RNTJReader(
            out, options=ReadOptions(decode_workers=3, parallel_members=True)
        )
        np.testing.assert_array_equal(
            serial.read_column("vals._0"), par.read_column("vals._0")
        )


# ---------------------------------------------------------------------------
# rate-aware adaptive codec policy


def test_rate_aware_policy_keeps_codec_on_slow_sink():
    pol = CodecPolicy(1, sample_pages=2, threshold=0.5, rate_aware=True)
    pol.observe_drain(1_000_000, int(1e9))  # 1 MB/s drain
    # ratio 0.8 misses the threshold, but saves 200 KB per 0.01 s of CPU
    # (20 MB/s savings rate) — far above the 1 MB/s drain: keep
    pol.record(0, 500_000, 400_000, ns=int(5e6))
    pol.record(0, 500_000, 400_000, ns=int(5e6))
    assert pol.decision(0) is True


def test_rate_aware_policy_drops_codec_on_fast_sink():
    pol = CodecPolicy(1, sample_pages=2, threshold=0.5, rate_aware=True)
    pol.observe_drain(10_000_000_000, int(1e9))  # 10 GB/s drain
    pol.record(0, 500_000, 400_000, ns=int(5e6))
    pol.record(0, 500_000, 400_000, ns=int(5e6))
    assert pol.decision(0) is False


def test_rate_aware_policy_defers_until_drain_observed():
    pol = CodecPolicy(1, sample_pages=2, threshold=0.5, rate_aware=True)
    pol.record(0, 1000, 900, ns=1000)
    pol.record(0, 1000, 900, ns=1000)
    assert pol.decision(0) is None  # would drop, but no bandwidth signal yet
    pol.observe_drain(1000, int(1e9))  # 1 KB/s: pathologically slow
    pol.record(0, 1000, 900, ns=1000)
    assert pol.decision(0) is True


def test_rate_aware_deferral_is_bounded():
    pol = CodecPolicy(1, sample_pages=2, threshold=0.5, rate_aware=True)
    for _ in range(8):  # 4 * sample_pages with no drain signal
        pol.record(0, 1000, 900, ns=1000)
    assert pol.decision(0) is False  # forced ratio-only decision


def test_ratio_rule_unchanged_without_rate_aware():
    pol = CodecPolicy(1, sample_pages=2, threshold=0.5)
    pol.record(0, 1000, 900)
    pol.record(0, 1000, 900)
    assert pol.decision(0) is False


def test_rate_aware_end_to_end_throttled_vs_fast():
    schema = vec_schema()

    def run(sink):
        opts = WriteOptions(codec="zlib", level=1, cluster_bytes=1 << 18,
                            adaptive_codec=True, adaptive_sample_pages=4,
                            adaptive_threshold=0.8, adaptive_rate_aware=True)
        rng = np.random.default_rng(0)
        w = SequentialWriter(schema, sink, opts)
        for i in range(8):
            w.fill_batch(make_batch(schema, rng, 8000, id0=i * 8000))
        keep = w._policy.decision(2)  # the incompressible float column
        w.close()
        return keep

    assert run(DevNullSink()) is False          # fast sink: not worth CPU
    assert run(ThrottledSink(DevNullSink(), bw=2e6)) is True  # slow: worth it
