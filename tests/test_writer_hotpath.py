"""Write hot-path behaviour: ColumnBuffer, unified pooled seal, pipelined
sealing, unbuffered drain edge cases, and the Pallas offsets dispatch."""

import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.core import (
    Collection, ColumnBatch, ColumnBuffer, Leaf, ParallelWriter, RNTJReader,
    Schema, SequentialWriter, WriteOptions,
)
from repro.core.cluster import ClusterBuilder
from repro.core.container import MemorySink
from repro.core import encoding as E


def vec_schema():
    return Schema([Leaf("id", "int64"), Collection("vals", Leaf("_0", "float32"))])


def make_batch(schema, rng, n, id0=0):
    sizes = rng.poisson(5, n).astype(np.int64)
    vals = rng.uniform(0, 100, int(sizes.sum())).astype(np.float32)
    return ColumnBatch.from_arrays(
        schema, n, {"id": np.arange(id0, id0 + n), "vals": sizes, "vals._0": vals}
    )


# ---------------------------------------------------------------------------
# ColumnBuffer


def test_column_buffer_growth_and_views():
    b = ColumnBuffer(np.int64, capacity=4)
    for i in range(10):
        b.extend(np.arange(i * 100, i * 100 + 7))
    assert len(b) == 70
    assert b.nbytes == 70 * 8
    v = b.view()
    assert v.base is not None  # zero-copy: a view, not a fresh array
    np.testing.assert_array_equal(
        v, np.concatenate([np.arange(i * 100, i * 100 + 7) for i in range(10)])
    )
    np.testing.assert_array_equal(b.view(7, 14), np.arange(100, 107))


def test_column_buffer_reserve_and_reset_keeps_storage():
    b = ColumnBuffer(np.int64, capacity=8)
    tail = b.reserve(5)
    tail[:] = np.arange(5)
    np.testing.assert_array_equal(b.view(), np.arange(5))
    cap = b.capacity
    b.reset()
    assert len(b) == 0 and b.capacity == cap
    b.extend(np.arange(3))  # refill reuses storage
    assert b.capacity == cap
    np.testing.assert_array_equal(b.view(), np.arange(3))


def test_column_buffer_empty_view_dtype():
    b = ColumnBuffer(np.float32)
    v = b.view()
    assert len(v) == 0 and v.dtype == np.float32


# ---------------------------------------------------------------------------
# unified seal code path: serial == pooled, builders reusable


def test_seal_pooled_equals_serial():
    schema = vec_schema()
    rng = np.random.default_rng(11)
    batch = make_batch(schema, rng, 500)
    b1 = ClusterBuilder(schema, page_size=512, codec=1)
    b2 = ClusterBuilder(schema, page_size=512, codec=1)
    b1.fill_batch(batch)
    b2.fill_batch(batch)
    sealed_serial = b1.seal()
    with ThreadPoolExecutor(max_workers=3) as pool:
        sealed_pooled = b2.seal(pool)
    assert bytes(sealed_serial.blob) == bytes(sealed_pooled.blob)
    assert sealed_serial.n_elements == sealed_pooled.n_elements
    assert [(p.column, p.offset, p.size, p.checksum) for p in sealed_serial.pages] \
        == [(p.column, p.offset, p.size, p.checksum) for p in sealed_pooled.pages]


def test_builder_reuse_across_clusters():
    schema = vec_schema()
    rng = np.random.default_rng(5)
    builder = ClusterBuilder(schema, page_size=512, codec=1)
    batch = make_batch(schema, rng, 200)
    builder.fill_batch(batch)
    first = builder.seal()
    # refill the SAME builder: offsets must restart cluster-relative
    builder.fill_batch(batch)
    second = builder.seal()
    assert bytes(first.blob) == bytes(second.blob)


# ---------------------------------------------------------------------------
# edge cases: empty flush, partial pages, never-full columns


def test_empty_cluster_flush_is_noop():
    schema = vec_schema()
    sink = MemorySink()
    with SequentialWriter(schema, sink, WriteOptions()) as w:
        w.flush_cluster()
        w.flush_cluster()
    r = RNTJReader(sink)
    assert r.n_entries == 0
    assert r.n_clusters == 0
    assert len(r.read_column("id")) == 0


def test_empty_parallel_context_close():
    schema = vec_schema()
    sink = MemorySink()
    with ParallelWriter(schema, sink, WriteOptions(pipelined_seal=True)) as w:
        ctx = w.create_fill_context()
        ctx.close()
    assert RNTJReader(sink).n_entries == 0


def test_final_partial_page_roundtrip():
    """Element counts that do not divide the page size leave a final
    partial page per column."""
    schema = vec_schema()
    rng = np.random.default_rng(2)
    sink = MemorySink()
    # page 512 B -> 64 int64 / 128 float32 per page; 100 entries won't align
    with SequentialWriter(schema, sink, WriteOptions(page_size=512)) as w:
        w.fill_batch(make_batch(schema, rng, 100))
    r = RNTJReader(sink)
    assert r.n_entries == 100
    rng = np.random.default_rng(2)
    expect = make_batch(schema, rng, 100)
    np.testing.assert_array_equal(r.read_column("id"), expect.data[0])
    np.testing.assert_array_equal(r.read_column("vals._0"), expect.data[2])


def test_unbuffered_column_never_fills_a_page():
    """A column whose elements never reach one full page must be emitted
    entirely by drain_rest at cluster finalization."""
    schema = vec_schema()
    rng = np.random.default_rng(3)
    sink = MemorySink()
    opts = WriteOptions(buffered=False, page_size=64 * 1024, cluster_bytes=1 << 30)
    with ParallelWriter(schema, sink, opts) as w:
        ctx = w.create_fill_context()
        ctx.fill_batch(make_batch(schema, rng, 50))  # far below one page
        ctx.close()
    r = RNTJReader(sink)
    assert r.n_entries == 50
    rng = np.random.default_rng(3)
    expect = make_batch(schema, rng, 50)
    np.testing.assert_array_equal(r.read_column("id"), expect.data[0])
    np.testing.assert_array_equal(r.read_column("vals._0"), expect.data[2])


def test_unbuffered_drain_interleaves_full_and_partial_pages():
    schema = vec_schema()
    rng = np.random.default_rng(4)
    sink = MemorySink()
    opts = WriteOptions(buffered=False, page_size=256, cluster_bytes=16 * 1024)
    with ParallelWriter(schema, sink, opts) as w:
        ctx = w.create_fill_context()
        for i in range(8):
            ctx.fill_batch(make_batch(schema, rng, 300, id0=i * 1000))
        ctx.close()
    r = RNTJReader(sink)
    assert r.n_entries == 8 * 300
    ids = np.sort(r.read_column("id"))
    expect = np.sort(np.concatenate([np.arange(i * 1000, i * 1000 + 300)
                                     for i in range(8)]))
    np.testing.assert_array_equal(ids, expect)


# ---------------------------------------------------------------------------
# pipelined vs synchronous sealing


def _write_sequential(schema, opts, n_batches=12, per=500):
    sink = MemorySink()
    with SequentialWriter(schema, sink, opts) as w:
        rng = np.random.default_rng(9)
        for i in range(n_batches):
            w.fill_batch(make_batch(schema, rng, per, id0=i * per))
    return sink


@pytest.mark.parametrize("imt", [0, 2])
def test_pipelined_seal_bytes_identical_single_producer(imt):
    """One producer, same cluster boundaries: the pipelined file must be
    byte-for-byte identical to the synchronous one."""
    schema = vec_schema()
    base = dict(cluster_bytes=1 << 16, imt_workers=imt)
    sync = _write_sequential(schema, WriteOptions(**base))
    pipe = _write_sequential(schema, WriteOptions(**base, pipelined_seal=True))
    assert bytes(sync.buf) == bytes(pipe.buf)


def test_pipelined_parallel_same_reader_output():
    """Many producers: cluster commit order may differ, but the logical
    reader output must match the synchronous writer's."""
    schema = vec_schema()

    def write(pipelined):
        sink = MemorySink()
        opts = WriteOptions(cluster_bytes=1 << 14, pipelined_seal=pipelined)
        w = ParallelWriter(schema, sink, opts)

        def worker(tid):
            rng = np.random.default_rng(tid)
            ctx = w.create_fill_context()
            for i in range(4):
                ctx.fill_batch(make_batch(schema, rng, 250, id0=tid * 10**6 + i * 250))
            ctx.close()

        ts = [threading.Thread(target=worker, args=(t,)) for t in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        w.close()
        return sink

    sync_sink = write(False)
    pipe_sink = write(True)
    rs, rp = RNTJReader(sync_sink), RNTJReader(pipe_sink)
    assert rs.n_entries == rp.n_entries == 4000
    for colpath in ("id", "vals._0"):
        np.testing.assert_array_equal(
            np.sort(rs.read_column(colpath)), np.sort(rp.read_column(colpath))
        )
    # same total payload modulo cluster order
    assert rs.sink.size == rp.sink.size


class _FailingSink(MemorySink):
    """Fails cluster-sized writes after the first N, like a full disk."""

    def __init__(self, allowed_writes):
        super().__init__()
        self._allowed = allowed_writes

    def pwrite(self, offset, data):
        if len(data) > 256:  # let header/metadata through, fail blobs
            if self._allowed <= 0:
                raise IOError("injected ENOSPC")
            self._allowed -= 1
        super().pwrite(offset, data)


def test_failed_commit_poisons_finalization():
    """A failed blob write must prevent close() from emitting a footer
    that references bytes that never landed."""
    schema = vec_schema()
    sink = _FailingSink(allowed_writes=1)
    w = ParallelWriter(schema, sink,
                       WriteOptions(cluster_bytes=1 << 13, pipelined_seal=True))
    ctx = w.create_fill_context()
    rng = np.random.default_rng(0)
    with pytest.raises(Exception):
        for i in range(40):
            ctx.fill_batch(make_batch(schema, rng, 200, id0=i * 200))
        ctx.close()
    with pytest.raises(RuntimeError, match="NOT finalized"):
        w.close()
    # no valid footer/anchor: the reader must refuse the file
    with pytest.raises(Exception):
        RNTJReader(sink)


def test_failed_context_close_does_not_silently_drop_data():
    """ctx.close() failing must not mark the context closed; the writer's
    close surfaces the error instead of finalizing without the data."""
    schema = vec_schema()
    sink = MemorySink()
    w = ParallelWriter(schema, sink, WriteOptions())
    ctx = w.create_fill_context()
    rng = np.random.default_rng(1)
    ctx.fill_batch(make_batch(schema, rng, 50))
    ctx.builder.codec = 99  # seal will fail
    with pytest.raises(Exception):
        ctx.close()
    assert not ctx._ctx_closed  # retryable, not silently dropped
    with pytest.raises(RuntimeError, match="NOT finalized"):
        w.close()


def test_pipelined_background_error_surfaces():
    """Exceptions raised during a background seal propagate to the producer."""
    schema = vec_schema()
    sink = MemorySink()
    w = ParallelWriter(schema, sink, WriteOptions(pipelined_seal=True))
    ctx = w.create_fill_context()
    rng = np.random.default_rng(0)
    ctx.fill_batch(make_batch(schema, rng, 10))
    ctx.builder.codec = 99  # unknown codec id -> seal must fail
    with pytest.raises(Exception):
        ctx.flush_cluster()
        ctx._sealer.wait()
    w.sink.close()


# ---------------------------------------------------------------------------
# stats phase breakdown


def test_stats_phase_breakdown_reported():
    schema = vec_schema()
    sink = MemorySink()
    with SequentialWriter(schema, sink, WriteOptions(cluster_bytes=1 << 15)) as w:
        rng = np.random.default_rng(1)
        for i in range(4):
            w.fill_batch(make_batch(schema, rng, 500, id0=i * 500))
    d = w.stats.as_dict()
    phases = d["phases_ms"]
    assert set(phases) == {"fill", "seal", "compress", "commit", "io"}
    assert phases["fill"] > 0 and phases["seal"] > 0 and phases["compress"] > 0
    assert d["seal_ms"] >= 0 and d["commit_ms"] > 0
    # compress is the per-page CPU sum inside seal: same order of magnitude
    assert phases["compress"] <= phases["seal"] * 1.5 + 1.0


# ---------------------------------------------------------------------------
# column-batched preconditioning (the serial-seal fast path)


@pytest.mark.parametrize("per", [1, 3, 64, 100, 1000])
@pytest.mark.parametrize("enc,dtype", [
    ("none", np.uint8), ("none", np.float32),
    ("split", np.float32), ("split", np.int64), ("split", np.float16),
    ("dzs", np.int64),
])
def test_precondition_column_pages_matches_per_page(per, enc, dtype):
    rng = np.random.default_rng(42)
    n = 257
    if enc == "dzs":
        arr = np.cumsum(rng.poisson(5, n)).astype(np.int64)
    elif np.dtype(dtype).kind == "f":
        arr = rng.uniform(0, 100, n).astype(dtype)
    else:
        arr = rng.integers(0, 200, n).astype(dtype)
    batched = E.precondition_column_pages(arr, enc, per)
    itemb = arr.dtype.itemsize
    for start in range(0, n, per):
        count = min(per, n - start)
        got = bytes(batched[start * itemb : (start + count) * itemb])
        want = bytes(E.precondition_buffer(arr[start : start + count], enc))
        assert got == want, f"page at {start} differs"


def test_precondition_column_pages_empty():
    assert len(E.precondition_column_pages(np.empty(0, np.int64), "dzs", 64)) == 0


# ---------------------------------------------------------------------------
# integrate_sizes dispatch (numpy reference vs in-place vs Pallas kernel)


def test_integrate_sizes_matches_cumsum_and_base():
    rng = np.random.default_rng(0)
    sizes = rng.poisson(5, 1000).astype(np.int64)
    np.testing.assert_array_equal(
        E.integrate_sizes(sizes), np.cumsum(sizes, dtype=np.int64)
    )
    out = np.empty(1000, np.int64)
    res = E.integrate_sizes(sizes, base=17, out=out)
    assert res is out
    np.testing.assert_array_equal(out, np.cumsum(sizes, dtype=np.int64) + 17)


def test_integrate_sizes_empty():
    assert len(E.integrate_sizes(np.empty(0, np.int64))) == 0


def test_integrate_sizes_forced_pallas_matches_numpy(monkeypatch):
    """REPRO_OFFSETS_BACKEND=pallas must be bit-identical to numpy (runs
    the kernel in interpret mode on CPU backends)."""
    jax = pytest.importorskip("jax")
    monkeypatch.setattr(E._OFFSETS, "backend", "pallas")
    monkeypatch.setattr(E._OFFSETS, "_kernel", None)  # re-resolve under the override
    rng = np.random.default_rng(1)
    sizes = rng.poisson(7, 300).astype(np.int64)
    got = E.integrate_sizes(sizes, base=5)
    np.testing.assert_array_equal(got, np.cumsum(sizes, dtype=np.int64) + 5)
    assert got.dtype == np.int64
