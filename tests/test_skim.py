"""Skimming application: all five Fig.-5 strategies must agree exactly."""

import numpy as np
import pytest

from repro.core import RNTJReader
from repro.skim import (
    Cuts, STRATEGIES, make_agc_dataset, skim_partitions,
)


@pytest.fixture(scope="module")
def dataset(tmp_path_factory):
    d = tmp_path_factory.mktemp("agc")
    parts = make_agc_dataset(str(d), n_partitions=3, files_per_partition=3,
                             events_per_file=3000, seed=11)
    return parts


def _partition_content(out_dir, part):
    r = RNTJReader(f"{out_dir}/skim_{part}.rntj")
    ids = np.asarray(r.read_column("event_id"))
    jets = r.read_column("jets_pt._0")
    order = np.argsort(ids)
    return ids[order], len(jets)


@pytest.mark.parametrize("strategy", [s for s in STRATEGIES if s != "separate-null"])
def test_strategy_equivalence(dataset, tmp_path, strategy):
    base = skim_partitions(dataset, str(tmp_path / "base"), "imt", n_threads=2)
    res = skim_partitions(dataset, str(tmp_path / strategy), strategy,
                          n_threads=6)
    assert res["kept_events"] == base["kept_events"]
    for part in dataset:
        ids_a, nj_a = _partition_content(str(tmp_path / "base"), part)
        ids_b, nj_b = _partition_content(str(tmp_path / strategy), part)
        np.testing.assert_array_equal(ids_a, ids_b)
        assert nj_a == nj_b


def test_skim_semantics(dataset, tmp_path):
    """Kept events satisfy the cuts; dropped elements are below threshold."""
    cuts = Cuts()
    skim_partitions(dataset, str(tmp_path / "o"), "parallel", n_threads=4,
                    cuts=cuts)
    r = RNTJReader(str(tmp_path / "o" / "skim_0.rntj"))
    # horizontal skim: met column is gone
    assert "met" not in r.schema.column_of_path
    for e in r.iter_entries():
        assert len(e["electrons_pt"]) >= cuts.min_electrons
        assert len(e["muons_pt"]) >= cuts.min_muons
        assert len(e["jets_pt"]) >= cuts.min_jets
        for coll in ("electrons_pt", "muons_pt", "jets_pt"):
            assert all(pt > cuts.pt_cut for pt in e[coll])  # nested skim
        if r.n_entries > 500:
            break


@pytest.mark.parametrize("strategy",
                         ["imt", "separate", "buffermerger", "parallel"])
def test_skim_cleanup_on_worker_failure(tmp_path, strategy):
    """A corrupt input makes a worker raise: the exception propagates and
    every pool/writer/merger is shut down instead of leaking threads."""
    import threading
    import time

    parts = make_agc_dataset(str(tmp_path / "in"), n_partitions=2,
                             files_per_partition=2, events_per_file=400,
                             seed=7)
    bad = parts[1][1]
    size = __import__("os").path.getsize(bad)
    with open(bad, "r+b") as f:  # smash the anchor
        f.seek(size - 64)
        f.write(b"\x00" * 64)
    before = threading.active_count()
    with pytest.raises(Exception):
        skim_partitions(parts, str(tmp_path / f"o_{strategy}"), strategy,
                        n_threads=4)
    deadline = time.time() + 10
    while threading.active_count() > before and time.time() < deadline:
        time.sleep(0.05)
    assert threading.active_count() <= before  # no leaked pool threads


def test_skim_reduces_size(dataset, tmp_path):
    import os
    res = skim_partitions(dataset, str(tmp_path / "o"), "parallel", n_threads=4)
    in_bytes = sum(os.path.getsize(f) for fs in dataset.values() for f in fs)
    out_bytes = sum(os.path.getsize(tmp_path / "o" / f"skim_{p}.rntj")
                    for p in dataset)
    assert out_bytes < in_bytes * 0.6  # horizontal+vertical+nested skims bite
