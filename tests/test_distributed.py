"""Distribution substrate: sharding rules, collectives, pipeline parallel,
elastic replanning.  Multi-device cases run in a subprocess with forced
host device count (kept out of this process: smoke tests must see 1 device)."""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.distributed.sharding import AxisRules, _leaf_spec
from jax.sharding import PartitionSpec as P


def run_with_devices(n: int, body: str) -> str:
    """Run `body` in a subprocess with n host devices; returns stdout.

    XLA compilation for many forced host devices is CPU-bound; on small
    CI machines it can exceed any reasonable budget, so a timeout skips
    the case instead of failing it (REPRO_DEVICE_TEST_TIMEOUT overrides).
    """
    prog = (
        f"import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={n}'\n"
        + textwrap.dedent(body)
    )
    budget = int(os.environ.get("REPRO_DEVICE_TEST_TIMEOUT", "240"))
    try:
        res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                             text=True, timeout=budget,
                             env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                  "HOME": "/root"})
    except subprocess.TimeoutExpired:
        pytest.skip(f"{n}-device subprocess exceeded {budget}s on this machine")
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


# ---------------------------------------------------------------------------
# sharding rules (pure logic, no devices needed)


def test_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    rules = AxisRules.__new__(AxisRules)
    rules.mesh = FakeMesh()
    rules.mapping = {"dp": ("data",), "tp": ("model",),
                     "tp_kv": ("model",), "sp_kv": ("model",)}
    # 8 kv heads don't divide model=16 -> head dim replicated, seq takes it
    spec = rules.spec([None, "dp", "tp_kv", "sp_kv", None],
                      (95, 128, 8, 32768, 128))
    assert spec == P(None, "data", None, "model", None)
    # 64 heads divide -> heads sharded, seq left alone (dedup)
    spec = rules.spec([None, "dp", "tp_kv", "sp_kv", None],
                      (95, 128, 64, 32768, 128))
    assert spec == P(None, "data", "model", None, None)
    # nothing divides -> fully replicated but batch
    spec = rules.spec([None, "dp", "tp_kv", "sp_kv", None],
                      (95, 128, 5, 1001, 3))
    assert spec == P(None, "data", None, None, None)


def test_fsdp_param_spec():
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    # (vocab, d_model): vocab -> fsdp(32), d_model -> tp(16)
    spec = _leaf_spec((102400, 8192), FakeMesh(), ("pod", "data"), "model",
                      stacked=False)
    assert spec == P(("pod", "data"), "model")
    # stacked layer param: leading dim untouched
    spec = _leaf_spec((95, 8192, 22016), FakeMesh(), ("pod", "data"), "model",
                      stacked=True)
    assert spec[0] is None
    # 1-D params replicated
    assert _leaf_spec((8192,), FakeMesh(), ("pod", "data"), "model",
                      stacked=False) == P(None)


def test_elastic_replan():
    from repro.distributed.elastic import replan, validate_batch_divisibility
    from repro.models import build
    shapes = build("smollm-360m").param_shapes()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plan = replan(shapes, mesh)
    assert plan.dp_degree == 1
    ok, _ = validate_batch_divisibility(256, plan)
    assert ok


# ---------------------------------------------------------------------------
# multi-device semantics (subprocess)


def test_hierarchical_psum_equals_flat_psum():
    out = run_with_devices(8, """
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed.collectives import hierarchical_psum
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        x = jnp.arange(32.0).reshape(8, 4)
        def flat(v):  return jax.lax.psum(v, ("pod", "data"))
        def hier(v):  return hierarchical_psum(v)
        sm = lambda f: shard_map(f, mesh=mesh,
                                 in_specs=P(("pod","data"), "model"),
                                 out_specs=P(("pod","data"), "model"))
        a = sm(flat)(x); b = sm(hier)(x)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
        print("PSUM_OK")
    """)
    assert "PSUM_OK" in out


def test_pipeline_parallel_matches_sequential():
    out = run_with_devices(4, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline_parallel import pipelined
        mesh = jax.make_mesh((4,), ("stage",))
        L, D = 8, 16
        ks = jax.random.split(jax.random.PRNGKey(0), L)
        params = jnp.stack([jax.random.normal(k, (D, D)) * 0.2 for k in ks])
        def layer(w, x): return jnp.tanh(x @ w)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, D))
        # sequential reference
        ref = x
        for i in range(L): ref = layer(params[i], ref)
        apply = pipelined(layer, mesh, "stage", n_microbatches=4)
        out = apply(params, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)
        print("PP_OK")
    """)
    assert "PP_OK" in out


def test_production_mesh_shapes():
    out = run_with_devices(512, """
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        assert dict(m1.shape) == {"data": 16, "model": 16}
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
        print("MESH_OK")
    """)
    assert "MESH_OK" in out
