"""Property tests for column preconditioning encodings."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import encoding as E

DTYPES = ["int8", "uint8", "int16", "int32", "uint32", "int64", "uint64",
          "float16", "float32", "float64"]


@st.composite
def arrays(draw, dtype=None):
    dt = np.dtype(dtype or draw(st.sampled_from(DTYPES)))
    n = draw(st.integers(0, 300))
    if dt.kind == "f":
        lim = 6e4 if dt == np.float16 else 1e6
        vals = draw(st.lists(st.floats(-lim, lim, width=32), min_size=n, max_size=n))
        return np.asarray(vals, dtype=dt)
    info = np.iinfo(dt)
    vals = draw(st.lists(st.integers(int(info.min), int(info.max)), min_size=n, max_size=n))
    return np.asarray(vals, dtype=dt)


@given(arrays())
@settings(max_examples=150, deadline=None)
def test_split_roundtrip(a):
    buf = E.split_encode(a)
    assert len(buf) == a.nbytes
    back = E.split_decode(buf, a.dtype, len(a))
    np.testing.assert_array_equal(back, a)


@given(st.lists(st.integers(-(2**62), 2**62), max_size=200))
@settings(max_examples=150, deadline=None)
def test_zigzag_roundtrip(vals):
    x = np.asarray(vals, dtype=np.int64)
    u = E.zigzag_encode(x)
    np.testing.assert_array_equal(E.zigzag_decode(u), x)


def test_zigzag_small_values():
    x = np.array([0, -1, 1, -2, 2], dtype=np.int64)
    np.testing.assert_array_equal(E.zigzag_encode(x), [0, 1, 2, 3, 4])


@given(st.lists(st.integers(0, 2**40), max_size=200), st.integers(0, 1000))
@settings(max_examples=150, deadline=None)
def test_delta_roundtrip(vals, ref):
    x = np.asarray(sorted(vals), dtype=np.int64)
    d = E.delta_encode(x, ref)
    np.testing.assert_array_equal(E.delta_decode(d, ref), x)


@given(st.lists(st.integers(0, 1000), max_size=300))
@settings(max_examples=100, deadline=None)
def test_dzs_roundtrip_offsets(sizes):
    offs = E.sizes_to_offsets(np.asarray(sizes, dtype=np.int64))
    buf = E.dzs_encode(offs)
    np.testing.assert_array_equal(E.dzs_decode(buf, len(offs)), offs)


@given(st.lists(st.integers(0, 255), max_size=200))
@settings(max_examples=100, deadline=None)
def test_sizes_offsets_inverse(sizes):
    s = np.asarray(sizes, dtype=np.int64)
    np.testing.assert_array_equal(E.offsets_to_sizes(E.sizes_to_offsets(s)), s)


def test_dzs_compresses_monotonic_offsets():
    """The point of the encoding: monotonic offsets become tiny after zlib."""
    import zlib
    sizes = np.random.default_rng(0).poisson(5, 10000)
    offs = E.sizes_to_offsets(sizes)
    raw = offs.tobytes()
    pre = E.dzs_encode(offs)
    assert len(zlib.compress(pre, 1)) < 0.5 * len(zlib.compress(raw, 1))


@pytest.mark.parametrize("dtype", DTYPES)
def test_precondition_dispatch(dtype):
    rng = np.random.default_rng(1)
    a = (rng.uniform(0, 100, 64)).astype(dtype)
    for enc in ("none", "split"):
        buf = E.precondition(a, enc)
        back = E.unprecondition(buf, enc, a.dtype, len(a))
        np.testing.assert_array_equal(back, a)
