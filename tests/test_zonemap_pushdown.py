"""Zone-map + predicate-pushdown suite (DESIGN.md §11).

Core property, checked by hand-built cases and by randomized
(hypothesis-shimmed) schemas/predicates alike: a pruned filtered read is
**exactly** a full scan followed by the predicate — never a subset, never
a superset — while reading no more pages than the unpruned path.  The
regression half pins the compat surface: files written without zone maps
read unpruned with no warnings, new files stay readable by the vendored
seed reader, merges preserve or recompute the stats, recovery drops them
with an explicit reason instead of serving unattested bounds, and the
skim strategies produce byte-identical outputs pruned vs. unpruned.
"""

import importlib.util
import math
import os
import warnings
from pathlib import Path

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.core import (
    Collection,
    ColumnBatch,
    F,
    Leaf,
    MemorySink,
    ParallelWriter,
    ReadOptions,
    Record,
    RNTJReader,
    Schema,
    SequentialWriter,
    WriteOptions,
    merge_files,
    recompose_entries,
    recover_container,
    write_entries,
)
from repro.core import metadata as md
from repro.core.filter import (
    EvalContext,
    T_FALSE,
    T_MAYBE,
    T_TRUE,
    Zone,
    required_columns,
)

# a page/cluster geometry small enough that modest datasets produce many
# pages per column and several clusters per file
SMALL = dict(page_size=256, cluster_bytes=16 * 1024, codec="none")


def _norm(v):
    """Recursively normalize recomposed entries for equality (NaN-safe)."""
    if isinstance(v, dict):
        return {k: _norm(x) for k, x in v.items()}
    if isinstance(v, (list, tuple, np.ndarray)):
        return [_norm(x) for x in v]
    if isinstance(v, (float, np.floating)):
        f = float(v)
        return "NaN" if math.isnan(f) else f
    if isinstance(v, (int, np.integer, bool, np.bool_)):
        return int(v)
    return v


def _filtered_scan(source, expr, prune, fields=None):
    """-> (normalized matching entries, pages read, stats) for one scan."""
    r = RNTJReader(source, options=ReadOptions(filter=expr, prune=prune))
    try:
        ents = [_norm(e) for e in r.iter_filtered_entries(fields)]
        return ents, r.stats.pages, r.stats
    finally:
        r.close()


def _assert_pruned_equals_full(source, expr, fields=None, expect_prune=None):
    """The tentpole property: pruned ≡ full-scan-then-filter, fewer pages."""
    got, pages_pruned, stats = _filtered_scan(source, expr, True, fields)
    ref, pages_full, _ = _filtered_scan(source, expr, False, fields)
    assert got == ref
    assert pages_pruned <= pages_full
    if expect_prune is not None:
        # zone pruning manifests as skipped clusters or fewer pages read
        # than the unpruned scan (late materialization happens in both)
        pruned = stats.clusters_pruned > 0 or pages_pruned < pages_full
        assert pruned == expect_prune
    return got, stats


# ---------------------------------------------------------------------------
# zone-evaluation unit tests (tri-state logic on hand-built zones)


class TestZoneEval:
    SCHEMA = Schema([Leaf("x", "float64"), Collection("c", Leaf("_0", "float64"))])

    def _z(self, lo, hi, nulls=0, count=8, nested=False):
        return Zone(lo=lo, hi=hi, nulls=nulls, count=count, nested=nested)

    def test_cmp_tristate(self):
        e = F("x")
        z = {"x": self._z(10.0, 20.0)}
        assert (e > 5.0).zone_eval(z) == T_TRUE
        assert (e > 25.0).zone_eval(z) == T_FALSE
        assert (e > 15.0).zone_eval(z) == T_MAYBE
        assert (e < 25.0).zone_eval(z) == T_TRUE
        assert (e < 5.0).zone_eval(z) == T_FALSE
        assert (e == 30.0).zone_eval(z) == T_FALSE
        assert (e == 15.0).zone_eval(z) == T_MAYBE
        # eq is only definitely true when the zone is a single point
        assert (e == 7.0).zone_eval({"x": self._z(7.0, 7.0)}) == T_TRUE
        assert (e != 30.0).zone_eval(z) == T_TRUE
        assert e.between(12.0, 13.0).zone_eval(z) == T_MAYBE
        assert e.between(30.0, 40.0).zone_eval(z) == T_FALSE
        assert e.between(0.0, 100.0).zone_eval(z) == T_TRUE

    def test_null_checks(self):
        e = F("x")
        assert e.is_null().zone_eval({"x": self._z(1.0, 2.0, nulls=0)}) == T_FALSE
        assert e.is_null().zone_eval({"x": self._z(None, None, nulls=8)}) == T_TRUE
        assert e.is_null().zone_eval({"x": self._z(1.0, 2.0, nulls=3)}) == T_MAYBE
        assert e.not_null().zone_eval({"x": self._z(None, None, nulls=8)}) == T_FALSE

    def test_all_nan_zone_never_matches_cmp(self):
        z = {"x": self._z(None, None, nulls=8)}  # every element NaN
        for expr in (F("x") > 0.0, F("x") < 0.0, F("x") == 0.0):
            assert expr.zone_eval(z) == T_FALSE
        # IEEE: NaN != c is TRUE elementwise, and zone_eval agrees
        assert (F("x") != 0.0).zone_eval(z) == T_TRUE

    def test_nan_constant_is_false(self):
        z = {"x": self._z(1.0, 2.0)}
        assert (F("x") == float("nan")).zone_eval(z) == T_FALSE
        assert (F("x") > float("nan")).zone_eval(z) == T_FALSE

    def test_nested_atom_never_definitely_true(self):
        # existential semantics: a nested zone covering the constant still
        # says nothing definite about ANY single entry — must stay MAYBE,
        # else NOT over it would wrongly prune
        z = {"c._0": self._z(10.0, 20.0, nested=True)}
        assert (F("c._0") > 5.0).zone_eval(z) == T_MAYBE
        assert (F("c._0") > 25.0).zone_eval(z) == T_FALSE
        assert (~(F("c._0") > 5.0)).zone_eval(z) == T_MAYBE

    def test_kleene_connectives(self):
        zt = {"x": self._z(10.0, 20.0)}
        t, f, m = F("x") > 0.0, F("x") > 99.0, F("x") > 15.0
        assert (t & m).zone_eval(zt) == T_MAYBE
        assert (f & t).zone_eval(zt) == T_FALSE
        assert (t | m).zone_eval(zt) == T_TRUE
        assert (f | m).zone_eval(zt) == T_MAYBE
        assert (f | f).zone_eval(zt) == T_FALSE
        assert (~t).zone_eval(zt) == T_FALSE
        assert (~f).zone_eval(zt) == T_TRUE
        assert (~m).zone_eval(zt) == T_MAYBE

    def test_empty_zone(self):
        # an entry range with zero elements in the column: comparisons and
        # null-checks are vacuously false / existentially false
        z = {"c._0": Zone.empty(nested=True)}
        assert (F("c._0") > 0.0).zone_eval(z) == T_FALSE
        assert F("c._0").is_null().zone_eval(z) == T_FALSE

    def test_validate_rejects(self):
        with pytest.raises(ValueError):
            (F("nope") > 1).validate(self.SCHEMA)
        with pytest.raises(ValueError):
            (F("c") > 1).validate(self.SCHEMA)  # offset column, not a leaf
        s8 = Schema([Leaf("b", "int8")])
        with pytest.raises(ValueError):
            (F("b") > 300).validate(s8)  # constant outside int8's range


# ---------------------------------------------------------------------------
# footer round-trip + defensive decoding


class TestFooterCodec:
    def test_roundtrip(self):
        per = [{0: {"fe": [0, 3], "le": [2, 7], "lo": [1.0, -2.0],
                    "hi": [5.0, 9.0], "nn": [0, 1]},
                1: {"fe": [0], "le": [7]}},
               None]
        enc = md.encode_zonemaps(per)
        assert enc is not None and enc["v"] == 1
        dec = md.decode_zonemaps(enc, 2)
        assert dec[1] is None
        assert dec[0][0]["lo"] == [1.0, -2.0]
        assert dec[0][1] == {"fe": [0], "le": [7]}

    def test_all_none_encodes_to_nothing(self):
        assert md.encode_zonemaps([None, None]) is None
        assert md.encode_zonemaps([]) is None

    def test_unknown_version_rejected(self):
        enc = md.encode_zonemaps([{0: {"fe": [0], "le": [1]}}])
        enc["v"] = 99
        assert md.decode_zonemaps(enc, 1) is None

    def test_cluster_count_mismatch_rejected(self):
        enc = md.encode_zonemaps([{0: {"fe": [0], "le": [1]}}])
        assert md.decode_zonemaps(enc, 3) is None

    def test_inconsistent_column_dropped(self):
        enc = md.encode_zonemaps([{0: {"fe": [0, 1], "le": [1]},  # ragged
                                   1: {"fe": [0], "le": [4]}}])
        dec = md.decode_zonemaps(enc, 1)
        assert dec is not None and 0 not in dec[0] and 1 in dec[0]


# ---------------------------------------------------------------------------
# write-then-read integration


def _flat_file(sink, n=4000, codec="none", zone_maps=True, buffered=True):
    """Monotonic id + noisy float, small pages, several clusters."""
    schema = Schema([Leaf("id", "int64"), Leaf("val", "float32")])
    rng = np.random.default_rng(7)
    opts = WriteOptions(**{**SMALL, "codec": codec}, zone_maps=zone_maps,
                        buffered=buffered)
    with SequentialWriter(schema, sink, opts) as w:
        step = 257
        for a in range(0, n, step):
            b = min(a + step, n)
            w.fill_batch(ColumnBatch(schema, b - a, {
                0: np.arange(a, b, dtype=np.int64),
                1: rng.normal(0, 100, b - a).astype(np.float32),
            }))
    return schema


def test_zonemaps_written_with_correct_bounds():
    sink = MemorySink()
    _flat_file(sink)
    r = RNTJReader(sink)
    try:
        assert len(r.zonemaps) == len(r.clusters) > 1
        for i, zm in enumerate(r.zonemaps):
            assert zm is not None
            cols = r.read_cluster(i)
            cm = r.clusters[i]
            for ci in (0, 1):
                d = zm[ci]
                # page geometry: fe/le per page, monotone, covering
                assert len(d["fe"]) == len(d["le"]) == sum(
                    1 for p in cm.pages if p.column == ci)
                assert d["fe"][0] == 0 and d["le"][-1] == cm.n_entries - 1
                assert all(a <= b for a, b in zip(d["fe"], d["le"]))
            # id column is monotone: page bounds are exactly first/last
            assert zm[0]["lo"][0] == float(cols[0][0])
            assert zm[0]["hi"][-1] == float(cols[0][-1])
            assert all(n == 0 for n in zm[0]["nn"])
    finally:
        r.close()


def test_pruned_equals_fullscan_flat():
    sink = MemorySink()
    _flat_file(sink)
    got, stats = _assert_pruned_equals_full(
        sink, (F("id") >= 100) & (F("id") < 140), expect_prune=True)
    assert [e["id"] for e in got] == list(range(100, 140))
    assert stats.clusters_pruned > 0


def test_cluster_skip_accounting_and_iter_clusters():
    sink = MemorySink()
    _flat_file(sink)
    expr = F("id").between(0, 50)
    r = RNTJReader(sink, options=ReadOptions(filter=expr))
    try:
        seen = [i for i, _ in r.iter_clusters()]
        assert len(seen) < len(r.clusters)  # later clusters skipped outright
        assert r.stats.clusters_pruned == len(r.clusters) - len(seen)
    finally:
        r.close()


def test_pages_read_leq_unpruned():
    sink = MemorySink()
    _flat_file(sink)
    for expr in (F("id") == 1234, F("val") > 250.0, F("id") < 0):
        _, pp, _ = _filtered_scan(sink, expr, True)
        _, pf, _ = _filtered_scan(sink, expr, False)
        assert pp <= pf
    # the needle query must actually prune hard, not just tie
    _, pp, _ = _filtered_scan(sink, F("id") == 1234, True)
    _, pf, _ = _filtered_scan(sink, F("id") == 1234, False)
    assert pp < pf


NESTED = Schema([
    Leaf("id", "int64"),
    Collection("js", Record("_0", [Leaf("pt", "float32")])),
])


def _nested_file(sink, n=1500, empties=True, codec="none"):
    rng = np.random.default_rng(11)
    entries = []
    for i in range(n):
        k = int(rng.integers(0, 5))
        if not empties:
            k = max(k, 1)
        entries.append({
            "id": i,
            "js": [{"pt": float(rng.normal(50, 30))} for _ in range(k)],
        })
    write_entries(NESTED, sink, entries,
                  WriteOptions(**{**SMALL, "codec": codec}))
    return entries


def test_pruned_equals_fullscan_nested_existential():
    sink = MemorySink()
    _nested_file(sink)
    _assert_pruned_equals_full(sink, F("js._0.pt") > 120.0)
    _assert_pruned_equals_full(sink, (F("js._0.pt") > 60.0) & (F("id") < 400))


def test_gap_entries_with_negated_predicate():
    # entries with EMPTY collections have no elements in any page of the
    # nested column; ~(exists pt > x) must keep them
    sink = MemorySink()
    _nested_file(sink, empties=True)
    expr = ~(F("js._0.pt") > -1e9)  # matches exactly the empty-collection entries
    got, _ = _assert_pruned_equals_full(sink, expr)
    ref = [e for e in RNTJReader(sink).iter_entries() if len(e["js"]) == 0]
    assert [e["id"] for e in got] == [e["id"] for e in ref]
    assert len(got) > 0


def test_straddling_entries_conjunction():
    # huge collections so single entries span multiple pages: a
    # conjunction whose witnesses live in different pages must not prune
    # the straddling entry from per-page verdicts
    schema = Schema([Leaf("id", "int64"),
                     Collection("c", Leaf("_0", "float64"))])
    entries = []
    for i in range(40):
        vals = [float(i)] * 200          # 200 elems × 8B ≫ 256B pages
        vals[0] = -1000.0 - i            # low witness at the front
        vals[-1] = 1000.0 + i            # high witness at the back
        entries.append({"id": i, "c": vals})
    sink = MemorySink()
    write_entries(schema, sink, entries, WriteOptions(**SMALL))
    expr = (F("c._0") > 999.0) & (F("c._0") < -999.0)
    got, _ = _assert_pruned_equals_full(sink, expr)
    assert len(got) == 40  # every entry has both witnesses


def test_nan_inf_bounds():
    schema = Schema([Leaf("x", "float64")])
    rng = np.random.default_rng(3)
    x = rng.normal(0, 10, 3000)
    x[::7] = np.nan
    x[::11] = np.inf
    x[::13] = -np.inf
    sink = MemorySink()
    opts = WriteOptions(**SMALL)
    with SequentialWriter(schema, sink, opts) as w:
        for a in range(0, len(x), 300):
            b = min(a + 300, len(x))
            w.fill_batch(ColumnBatch(schema, b - a, {0: x[a:b]}))
    for expr in (F("x") > 25.0, F("x") < -25.0, F("x") == np.inf,
                 F("x").is_null(), F("x").not_null(),
                 F("x").between(-5.0, 5.0), ~(F("x") > 0.0)):
        _assert_pruned_equals_full(sink, expr)


def test_all_nan_pages():
    schema = Schema([Leaf("x", "float32")])
    sink = MemorySink()
    with SequentialWriter(schema, sink, WriteOptions(**SMALL)) as w:
        w.fill_batch(ColumnBatch(schema, 512,
                                 {0: np.full(512, np.nan, np.float32)}))
        w.fill_batch(ColumnBatch(schema, 512,
                                 {0: np.arange(512, dtype=np.float32)}))
    got, _ = _assert_pruned_equals_full(sink, F("x").is_null(),
                                        expect_prune=True)
    assert len(got) == 512
    got, _ = _assert_pruned_equals_full(sink, F("x") >= 0.0)
    assert len(got) == 512


def test_parallel_writer_zonemaps():
    schema = Schema([Leaf("id", "int64")])
    sink = MemorySink()
    w = ParallelWriter(schema, sink, WriteOptions(**SMALL))
    ctxs = [w.create_fill_context() for _ in range(2)]
    try:
        for t, ctx in enumerate(ctxs):
            ctx.fill_batch(ColumnBatch(schema, 1000, {
                0: np.arange(t * 1000, (t + 1) * 1000, dtype=np.int64)}))
    finally:
        for ctx in ctxs:
            ctx.close()
        w.close()
    r = RNTJReader(sink)
    try:
        assert all(zm is not None for zm in r.zonemaps)
    finally:
        r.close()
    got, _ = _assert_pruned_equals_full(sink, F("id") == 1500,
                                        expect_prune=True)
    assert got == [{"id": 1500}]


def test_unbuffered_mode_zonemaps():
    sink = MemorySink()
    _flat_file(sink, buffered=False)
    r = RNTJReader(sink)
    try:
        assert all(zm is not None for zm in r.zonemaps)
    finally:
        r.close()
    got, _ = _assert_pruned_equals_full(sink, F("id").between(77, 99),
                                        expect_prune=True)
    assert [e["id"] for e in got] == list(range(77, 100))


# ---------------------------------------------------------------------------
# randomized property: pruned ≡ full-scan-then-filter


@st.composite
def _random_case(draw):
    n = draw(st.integers(min_value=1, max_value=400))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    # predicate shape: pick 1-3 atoms over the three leaves, random glue
    atoms = draw(st.lists(st.tuples(
        st.sampled_from(["id", "val", "js._0.pt"]),
        st.sampled_from(["gt", "lt", "eq", "between", "is_null", "not_null"]),
        st.floats(min_value=-150.0, max_value=150.0),
    ), min_size=1, max_size=3))
    glue = draw(st.lists(st.sampled_from(["and", "or"]),
                         min_size=2, max_size=2))
    negate = draw(st.sampled_from([False, True]))
    return n, seed, atoms, glue, negate


def _build_expr(atoms, glue, negate):
    parts = []
    for path, op, c in atoms:
        f = F(path)
        if op == "gt":
            parts.append(f > c)
        elif op == "lt":
            parts.append(f < c)
        elif op == "eq":
            parts.append(f == (int(c) if path == "id" else c))
        elif op == "between":
            parts.append(f.between(c - 25.0, c + 25.0))
        elif op == "is_null":
            parts.append(f.is_null())
        else:
            parts.append(f.not_null())
    e = parts[0]
    for i, p in enumerate(parts[1:]):
        e = (e & p) if glue[i] == "and" else (e | p)
    return ~e if negate else e


RANDOM_SCHEMA = Schema([
    Leaf("id", "int64"),
    Leaf("val", "float64"),
    Collection("js", Record("_0", [Leaf("pt", "float32")])),
])


@given(_random_case())
@settings(max_examples=40, deadline=None)
def test_property_pruned_equals_fullscan(case):
    n, seed, atoms, glue, negate = case
    rng = np.random.default_rng(seed)
    entries = []
    for i in range(n):
        val = float(rng.normal(0, 60))
        r = rng.random()
        if r < 0.08:
            val = float("nan")
        elif r < 0.12:
            val = float("inf") if r < 0.10 else float("-inf")
        k = int(rng.integers(0, 4))
        entries.append({"id": i, "val": val,
                        "js": [{"pt": float(rng.normal(40, 40))}
                               for _ in range(k)]})
    sink = MemorySink()
    write_entries(RANDOM_SCHEMA, sink, entries, WriteOptions(**SMALL))
    expr = _build_expr(atoms, glue, negate)
    _assert_pruned_equals_full(sink, expr)


# ---------------------------------------------------------------------------
# compatibility: old files, old readers, merge, recovery


def test_backcompat_zone_maps_off_reads_unpruned_without_warnings():
    sink = MemorySink()
    _flat_file(sink, zone_maps=False)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        r = RNTJReader(sink, options=ReadOptions(filter=F("id") < 10))
        try:
            assert all(zm is None for zm in r.zonemaps)
            got = [_norm(e) for e in r.iter_filtered_entries()]
            # no zone plan: nothing is pruned at cluster level and every
            # cluster is scanned (late materialization of non-filter
            # columns still applies — that's not zone pruning)
            assert r.stats.clusters_pruned == 0
            assert r.stats.clusters == len(r.clusters)
        finally:
            r.close()
    assert [e["id"] for e in got] == list(range(10))


def test_forward_compat_seed_reader_reads_zonemapped_file(tmp_path):
    spec = importlib.util.spec_from_file_location(
        "_legacy_seed_reader",
        Path(__file__).resolve().parent.parent
        / "benchmarks" / "_legacy_seed_reader.py")
    legacy = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(legacy)
    path = str(tmp_path / "zm.rntj")
    _flat_file(path, codec="zlib")
    new, old = RNTJReader(path), legacy.SeedRNTJReader(path)
    try:
        assert old.n_clusters == len(new.clusters)
        for i in range(old.n_clusters):
            a, b = new.read_cluster(i), old.read_cluster(i)
            for ci in a:
                np.testing.assert_array_equal(a[ci], b[ci])
    finally:
        new.close()
        old.close()


def test_merge_raw_copy_preserves_zonemaps(tmp_path):
    p1, p2, out = (str(tmp_path / f) for f in ("a.rntj", "b.rntj", "m.rntj"))
    _flat_file(p1, n=1000)
    _flat_file(p2, n=1000)
    merge_files([p1, p2], out)  # same codec: raw byte-verbatim path
    r1, r2, rm = RNTJReader(p1), RNTJReader(p2), RNTJReader(out)
    try:
        assert rm.zonemaps == r1.zonemaps + r2.zonemaps
    finally:
        r1.close(); r2.close(); rm.close()
    _assert_pruned_equals_full(out, F("id") == 5, expect_prune=True)


def test_merge_reencode_recomputes_zonemaps(tmp_path):
    p1, out = str(tmp_path / "a.rntj"), str(tmp_path / "m.rntj")
    _flat_file(p1, n=1000, codec="none")
    merge_files([p1], out, WriteOptions(**{**SMALL, "codec": "zlib"}),
                recompress=True)
    r = RNTJReader(out)
    try:
        assert any(zm is not None for zm in r.zonemaps)
    finally:
        r.close()
    got, _ = _assert_pruned_equals_full(out, F("id").between(10, 20),
                                        expect_prune=True)
    assert [e["id"] for e in got] == list(range(10, 21))


def test_recover_drops_zonemaps_with_reason(tmp_path):
    path = str(tmp_path / "torn.rntj")
    _flat_file(path, n=1000)
    # tear off the footer chain: recovery must rebuild from the journal
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) - 100)
    report = recover_container(path)
    assert report.rebuilt
    assert report.zonemaps is not None
    assert report.zonemaps["preserved"] is False
    assert report.zonemaps["reason"]
    assert report.as_dict()["zonemaps"]["preserved"] is False
    r = RNTJReader(path)
    try:
        assert all(zm is None for zm in r.zonemaps)
    finally:
        r.close()
    got, _ = _assert_pruned_equals_full(path, F("id") < 5, expect_prune=False)
    assert [e["id"] for e in got] == list(range(5))


def test_poisoned_bounds_regression(tmp_path):
    """A footer claiming wrong bounds wrongly prunes; recovery must drop
    the unattested stats so reads are correct again."""
    path = str(tmp_path / "poison.rntj")
    _flat_file(path, n=1000)
    ref, _, _ = _filtered_scan(path, F("id") < 50, prune=False)
    assert len(ref) == 50
    # forge a footer whose zone maps exclude every real value
    with open(path, "rb") as f:
        raw = f.read()
    anchor = md.parse_anchor(raw[-md.ANCHOR_SIZE:])
    foff, fsize = anchor["footer"]
    footer = md.parse_footer(raw[foff:foff + fsize])
    zm = footer["extra"]["zonemaps"]
    for cl in zm["clusters"]:
        for d in (cl or {}).values():
            if "lo" in d:
                d["lo"] = [1e18] * len(d["lo"])
                d["hi"] = [1e18] * len(d["hi"])
    size = len(raw)
    new_footer = md.build_footer(footer["n_entries"], footer["n_clusters"],
                                 tuple(footer["pagelist"]), footer["extra"])
    new_anchor = md.build_anchor(anchor["header"], (size, len(new_footer)),
                                 anchor["n_entries"], anchor["n_clusters"])
    with open(path, "ab") as f:
        f.write(new_footer + new_anchor)
    # the poison bites: the pruned read now wrongly drops everything
    poisoned, _, _ = _filtered_scan(path, F("id") < 50, prune=True)
    assert poisoned == []
    # forced recovery rebuilds from the journal and drops the bounds
    report = recover_container(path, force=True)
    assert report.zonemaps is not None and not report.zonemaps["preserved"]
    healed, _ = _assert_pruned_equals_full(path, F("id") < 50)
    assert healed == ref


# ---------------------------------------------------------------------------
# skim strategies: pruned vs unpruned byte identity (partition-boundary pin)


def _digest(path):
    import hashlib
    with open(path, "rb") as f:
        return hashlib.sha256(f.read()).hexdigest()


@pytest.mark.parametrize("strategy", ["imt", "separate", "buffermerger",
                                      "parallel"])
def test_skim_pruned_output_byte_identical(tmp_path, strategy):
    from repro.skim.engine import Cuts, make_agc_dataset, skim_partitions

    parts = make_agc_dataset(str(tmp_path / "in"), n_partitions=2,
                             files_per_partition=2, events_per_file=1500)
    cuts = Cuts(pt_cut=35.0, min_jets=2)
    outs, kept = {}, {}
    for mode in ("pruned", "full"):
        d = str(tmp_path / mode)
        res = skim_partitions(parts, d, strategy, n_threads=1, cuts=cuts,
                              pushdown=(mode == "pruned"))
        kept[mode] = res["kept_events"]
        outs[mode] = sorted(Path(d).glob("skim_*.rntj"))
    assert kept["pruned"] == kept["full"]
    assert [p.name for p in outs["pruned"]] == [p.name for p in outs["full"]]
    for a, b in zip(outs["pruned"], outs["full"]):
        assert _digest(a) == _digest(b), f"{strategy}: {a.name} differs"


def test_skim_segments_match_unpruned_partitioning(tmp_path):
    # the shared entry-range helper must yield one (cluster, segments)
    # group per surviving cluster in BOTH modes, same cluster order
    from repro.skim.engine import Cuts, cuts_expr, make_agc_dataset

    parts = make_agc_dataset(str(tmp_path / "in"), n_partitions=1,
                             files_per_partition=1, events_per_file=2000)
    f = parts[0][0]
    expr = cuts_expr(Cuts(pt_cut=35.0))
    rp = RNTJReader(f, options=ReadOptions(filter=expr))
    rf = RNTJReader(f, options=ReadOptions(filter=expr, prune=False))
    try:
        gp = [(i, len(segs)) for i, segs in rp.iter_cluster_segments()]
        gf = [(i, len(segs)) for i, segs in rf.iter_cluster_segments()]
        # full mode reads whole clusters; pruned mode may split one into
        # ranges or skip it, but never reorders or invents clusters
        assert [i for i, _ in gp if _ > 0] == [
            i for i, n in gf if n > 0 and rp._prune_plan()[i] != []]
        assert rp.stats.pages <= rf.stats.pages
    finally:
        rp.close()
        rf.close()


# ---------------------------------------------------------------------------
# review regressions: zero-min cuts, full-scan accessors, run-shared pages


def test_cuts_expr_drops_zero_min_collections():
    # a collection with min_* == 0 imposes no existential requirement:
    # its atom must not appear in the pushdown predicate, and all-zero
    # mins imply no predicate at all
    from repro.skim.engine import EVENT_SCHEMA, Cuts, cuts_expr

    assert cuts_expr(Cuts(min_electrons=0, min_muons=0, min_jets=0)) is None
    expr = cuts_expr(Cuts(min_muons=0))
    assert expr is not None
    paths = {EVENT_SCHEMA.columns[ci].path
             for ci in required_columns(EVENT_SCHEMA, expr)}
    assert "muons_pt._0" not in paths
    assert {"electrons_pt._0", "jets_pt._0"} <= paths
    # defaults (every min >= 1): all three atoms present
    full = {EVENT_SCHEMA.columns[ci].path
            for ci in required_columns(EVENT_SCHEMA, cuts_expr(Cuts()))}
    assert {"electrons_pt._0", "muons_pt._0", "jets_pt._0"} <= full


def test_skim_pushdown_zero_min_channel_no_loss(tmp_path):
    # an electron+jet channel (min_muons=0) over a file whose muons are
    # ALL below the cut: an unconditional muon atom would zone-prune
    # every cluster (silent total loss); the cuts-implied predicate must
    # skip the muon atom so pruned ≡ unpruned
    from repro.skim.engine import Cuts, EVENT_SCHEMA, skim_file

    rng = np.random.default_rng(3)
    n = 3000
    ne = rng.poisson(1.5, n).astype(np.int64)
    nm = rng.poisson(1.0, n).astype(np.int64)
    nj = rng.poisson(6.0, n).astype(np.int64)
    hot = lambda k: (rng.exponential(18.0, int(k)) + 15.0).astype(np.float32)
    src = str(tmp_path / "mu_cold.rntj")
    with SequentialWriter(EVENT_SCHEMA, src,
                          WriteOptions(page_size=1024,
                                       cluster_bytes=32 * 1024,
                                       codec="none")) as w:
        w.fill_batch(ColumnBatch.from_arrays(EVENT_SCHEMA, n, {
            "event_id": np.arange(n, dtype=np.int64),
            "met": rng.exponential(30.0, n).astype(np.float32),
            "electrons_pt": ne, "electrons_pt._0": hot(ne.sum()),
            "muons_pt": nm,
            "muons_pt._0": rng.uniform(1.0, 10.0, int(nm.sum()))
                              .astype(np.float32),
            "jets_pt": nj, "jets_pt._0": hot(nj.sum()),
        }))
    cuts = Cuts(pt_cut=20.0, min_electrons=1, min_muons=0, min_jets=2)
    got = {}
    for mode in (True, False):
        ids = []

        def fill(b, ids=ids):
            ci = b.schema.column_of_path["event_id"]
            ids.extend(np.asarray(b.data[ci]).tolist())

        kept = skim_file(src, fill, cuts, pushdown=mode)
        assert kept == len(ids)
        got[mode] = ids
    assert got[True] == got[False]
    assert len(got[True]) > 0


def test_full_scan_accessors_ignore_filter():
    # iter_entries / read_column are whole-file APIs: with a filter set
    # they must not silently drop zone-pruned clusters
    sink = MemorySink()
    _flat_file(sink)
    expr = F("id").between(0, 50)
    ref = RNTJReader(sink)
    r = RNTJReader(sink, options=ReadOptions(filter=expr))
    try:
        n = len(list(ref.iter_entries()))
        assert len(list(r.iter_entries())) == n
        np.testing.assert_array_equal(r.read_column("id"),
                                      ref.read_column("id"))
        np.testing.assert_array_equal(r.read_column("val"),
                                      ref.read_column("val"))
        assert r.stats.clusters_pruned == 0
    finally:
        r.close()
        ref.close()


def test_iter_filtered_run_shared_pages_counted_once():
    # many short matching runs inside one cluster: late-materialization
    # pages shared by adjacent runs decode once, and skipped pages are
    # accounted once per cluster — so neither pages nor pages_pruned can
    # exceed the file's total page count (the old per-run accounting did)
    schema = Schema([Leaf("id", "int64"), Leaf("val", "float64")])
    n = 128
    sink = MemorySink()
    opts = WriteOptions(page_size=256, cluster_bytes=1 << 20, codec="none")
    with SequentialWriter(schema, sink, opts) as w:
        w.fill_batch(ColumnBatch.from_arrays(schema, n, {
            "id": np.arange(n, dtype=np.int64),
            "val": np.arange(n, dtype=np.float64) * 0.5,
        }))
    expr = F("id").between(0, 1)
    for a in range(4, n, 4):
        expr = expr | F("id").between(a, a + 1)
    r = RNTJReader(sink, options=ReadOptions(filter=expr))
    try:
        got = [e["id"] for e in r.iter_filtered_entries()]
        assert got == [i for i in range(n) if i % 4 in (0, 1)]
        total_pages = sum(len(cm.pages) for cm in r.clusters)
        assert r.stats.pages <= total_pages
        assert r.stats.pages_pruned <= total_pages
    finally:
        r.close()
