"""Smollm 360M — exact literature config (see base.ArchConfig)."""

from .base import ArchConfig, MLAConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, head_dim=64,
    d_ff=2560, vocab_size=49_152,
    source="hf:HuggingFaceTB/SmolLM-360M",
)

SMOLLM_360M = CONFIG
