"""Deepseek Moe 16B — exact literature config (see base.ArchConfig)."""

from .base import ArchConfig, MLAConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=102_400,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2),
    source="arXiv:2401.06066 (2 shared + 64 routed top-6, fine-grained; "
           "NOTE: paper's dense first layer folded into MoE stack for "
           "scan homogeneity, see DESIGN.md)",
)

DEEPSEEK_MOE_16B = CONFIG
