"""Mixtral 8X22B — exact literature config (see base.ArchConfig)."""

from .base import ArchConfig, MLAConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=32_768, window=4096,
    moe=MoEConfig(n_experts=8, top_k=2),
    source="arXiv:2401.04088 (8 experts top-2, SWA)",
)

MIXTRAL_8X22B = CONFIG
