"""Musicgen Large — exact literature config (see base.ArchConfig)."""

from .base import ArchConfig, MLAConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048, n_codebooks=4, mlp="gelu",
    source="arXiv:2306.05284 (decoder-only over EnCodec tokens, 4 codebooks)",
)

MUSICGEN_LARGE = CONFIG
