"""Zamba2 2 7B — exact literature config (see base.ArchConfig)."""

from .base import ArchConfig, MLAConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32_000, shared_attn_every=6,
    ssm=SSMConfig(kind="mamba2", d_state=64, head_dim=64, expand=2, chunk=64),
    source="arXiv:2411.15242 (Mamba2 backbone + shared attn blocks)",
)

ZAMBA2_2_7B = CONFIG
