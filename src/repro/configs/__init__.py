"""Architecture configs + input-shape cells."""

from .archs import ARCHS, get_arch, smoke_config
from .base import (
    ArchConfig, MLAConfig, MoEConfig, SSMConfig, ShapeCell, SHAPES,
    SHAPES_BY_NAME,
)

__all__ = [
    "ARCHS", "get_arch", "smoke_config", "ArchConfig", "MLAConfig",
    "MoEConfig", "SSMConfig", "ShapeCell", "SHAPES", "SHAPES_BY_NAME",
]
