"""The paper's own configuration: the RNT-J writer defaults.

These mirror the paper's evaluated setup (§6): 64 KiB uncompressed target
page size, cluster-granular buffered writing, zstd-class compression
(zlib/DEFLATE level 1 here — see DESIGN.md §3 hardware adaptation), and
the synthetic-benchmark event schema (id + Poisson(5) float vector).
"""

from repro.core import Collection, Leaf, Schema, WriteOptions

SYNTH_EVENT_SCHEMA = Schema([
    Leaf("id", "int64"),
    Collection("vals", Leaf("_0", "float32")),
])

PAPER_WRITE_OPTIONS = WriteOptions(
    page_size=64 * 1024,          # paper §6.1 default
    codec="zlib",                 # stands in for zstd (DESIGN.md §3)
    level=1,
    cluster_bytes=8 * 1024 * 1024,
    buffered=True,                # unit of writing = cluster (paper §5)
)

UNBUFFERED_OPTIONS = WriteOptions(
    page_size=64 * 1024, codec="zlib", level=1,
    cluster_bytes=8 * 1024 * 1024, buffered=False,
)
