"""Chameleon 34B — exact literature config (see base.ArchConfig)."""

from .base import ArchConfig, MLAConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=65_536, qk_norm=True,
    source="arXiv:2405.09818 (early-fusion, VQ image tokens in vocab)",
)

CHAMELEON_34B = CONFIG
