"""Rwkv6 7B — exact literature config (see base.ArchConfig)."""

from .base import ArchConfig, MLAConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,
    d_ff=14336, vocab_size=65_536, attention="none",
    ssm=SSMConfig(kind="rwkv6", head_dim=64, chunk=32),
    source="arXiv:2404.05892 (Finch, data-dependent decay)",
)

RWKV6_7B = CONFIG
