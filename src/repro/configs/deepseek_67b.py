"""Deepseek 67B — exact literature config (see base.ArchConfig)."""

from .base import ArchConfig, MLAConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=102_400,
    source="arXiv:2401.02954 (llama-arch GQA)",
)

DEEPSEEK_67B = CONFIG
