"""Minicpm3 4B — exact literature config (see base.ArchConfig)."""

from .base import ArchConfig, MLAConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab_size=73_448, attention="mla",
    mla=MLAConfig(q_rank=768, kv_rank=256, d_nope=64, d_rope=32, d_v=64),
    source="hf:openbmb/MiniCPM3-4B (MLA)",
)

MINICPM3_4B = CONFIG
