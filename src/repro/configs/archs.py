"""The ten assigned architectures — registry.

Exact literature configs live in one module per architecture
(``gemma_2b.py`` ... ``musicgen_large.py``) per the deliverable layout;
this module aggregates them and derives reduced smoke variants.
"""

from __future__ import annotations

from typing import Dict

from .base import ArchConfig, MLAConfig, MoEConfig, SSMConfig
from .gemma_2b import CONFIG as GEMMA_2B
from .minicpm3_4b import CONFIG as MINICPM3_4B
from .deepseek_67b import CONFIG as DEEPSEEK_67B
from .smollm_360m import CONFIG as SMOLLM_360M
from .rwkv6_7b import CONFIG as RWKV6_7B
from .chameleon_34b import CONFIG as CHAMELEON_34B
from .mixtral_8x22b import CONFIG as MIXTRAL_8X22B
from .deepseek_moe_16b import CONFIG as DEEPSEEK_MOE_16B
from .zamba2_2_7b import CONFIG as ZAMBA2_2_7B
from .musicgen_large import CONFIG as MUSICGEN_LARGE

ARCHS: Dict[str, ArchConfig] = {
    c.name: c
    for c in (
        GEMMA_2B, MINICPM3_4B, DEEPSEEK_67B, SMOLLM_360M, RWKV6_7B,
        CHAMELEON_34B, MIXTRAL_8X22B, DEEPSEEK_MOE_16B, ZAMBA2_2_7B,
        MUSICGEN_LARGE,
    )
}


def get_arch(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}") from None


def smoke_config(name: str) -> ArchConfig:
    """A reduced same-family config for CPU smoke tests.

    Shrinks layers/width/experts/vocab but keeps every structural feature
    (GQA ratios, MLA ranks, MoE topology, shared-attn period, codebooks).
    """
    c = get_arch(name)
    kw = dict(
        n_layers=min(c.n_layers, 4 if c.shared_attn_every == 0 else 4),
        d_model=128, d_ff=256, vocab_size=512,
        n_heads=4, n_kv_heads=max(1, 4 * c.n_kv_heads // c.n_heads),
        head_dim=32, remat=False,
    )
    if c.shared_attn_every:
        kw["n_layers"] = 4
        kw["shared_attn_every"] = 2
    if c.mla is not None:
        kw["mla"] = MLAConfig(q_rank=64, kv_rank=32, d_nope=16, d_rope=8, d_v=16)
        kw["n_kv_heads"] = kw["n_heads"]
    if c.moe is not None:
        kw["moe"] = MoEConfig(
            n_experts=min(c.moe.n_experts, 8),
            top_k=min(c.moe.top_k, 2),
            n_shared=min(c.moe.n_shared, 1),
        )
    if c.ssm is not None:
        kw["ssm"] = SSMConfig(
            kind=c.ssm.kind, d_state=16, head_dim=16,
            expand=c.ssm.expand, conv_kernel=c.ssm.conv_kernel, chunk=16,
        )
        if c.ssm.kind == "rwkv6":
            kw["n_heads"] = kw["n_kv_heads"] = 128 // 16  # d_model / head_dim
    return c.with_(**kw)
