"""Architecture configuration model.

One frozen dataclass describes every assigned architecture; the model
builders in ``repro.models`` consume it.  Exact literature values live in
the per-arch files in this package.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0                 # shared (always-on) experts
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)."""

    q_rank: int          # low-rank query compression
    kv_rank: int         # low-rank kv compression (this is what decode caches)
    d_nope: int          # per-head non-rotary q/k dim
    d_rope: int          # shared rotary dim
    d_v: int             # per-head value dim


@dataclass(frozen=True)
class SSMConfig:
    kind: str            # "rwkv6" | "mamba2"
    d_state: int = 64    # mamba2 N; rwkv6 uses head_dim
    head_dim: int = 64   # P (mamba2) / Dk=Dv (rwkv6)
    expand: int = 2      # d_inner = expand * d_model (mamba2)
    conv_kernel: int = 4
    chunk: int = 64


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | ssm | moe | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    mlp: str = "swiglu"               # swiglu | geglu
    attention: str = "gqa"            # gqa | mla | none
    window: Optional[int] = None      # sliding-window attention
    qk_norm: bool = False             # chameleon-style qk layernorm
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    shared_attn_every: int = 0        # zamba2: shared attn block period
    n_codebooks: int = 1              # musicgen: 4 EnCodec codebooks
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True                # activation checkpointing over layers
    scan_layers: bool = True          # False: python-unrolled (cost probes)
    attn_impl: str = "ref"            # "ref" | "chunked" (§Perf variant)
    # source provenance for the config values
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context with bounded memory?"""
        if self.family in ("ssm",):
            return True
        if self.family == "hybrid":
            return True  # shared-attn blocks run windowed at long context
        return self.window is not None

    def with_(self, **kw) -> "ArchConfig":
        return replace(self, **kw)

    # -- parameter counting (for 6·N·D roofline bookkeeping) -------------------

    def param_count(self) -> int:
        from repro.models.registry import build  # lazy, avoids cycle
        import jax

        bundle = build(self)
        shapes = jax.eval_shape(lambda: bundle.init(jax.random.PRNGKey(0)))
        return sum(
            int(x.size) for x in jax.tree_util.tree_leaves(shapes)
        )

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k routed)."""
        from repro.models.registry import build
        import jax

        bundle = build(self)
        shapes = jax.eval_shape(lambda: bundle.init(jax.random.PRNGKey(0)))
        total = sum(int(x.size) for x in jax.tree_util.tree_leaves(shapes))
        if self.moe is None:
            return total

        # subtract inactive routed-expert params
        def moe_leaf_size(path, x):
            p = "/".join(str(k) for k in path)
            return int(x.size) if "experts" in p else 0

        flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
        routed = sum(moe_leaf_size([getattr(k, "key", getattr(k, "idx", k)) for k in path], x)
                     for path, x in flat)
        active_frac = self.moe.top_k / self.moe.n_experts
        return total - int(routed * (1 - active_frac))


@dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""

    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", 4_096, 256),
    ShapeCell("prefill_32k", "prefill", 32_768, 32),
    ShapeCell("decode_32k", "decode", 32_768, 128),
    ShapeCell("long_500k", "decode", 524_288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}
