"""Gemma 2B — exact literature config (see base.ArchConfig)."""

from .base import ArchConfig, MLAConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=256_000, mlp="geglu", tie_embeddings=True,
    source="arXiv:2403.08295 (GeGLU, head_dim=256, MQA)",
)

GEMMA_2B = CONFIG
