"""repro — parallel writing of nested data in columnar formats, as a
production JAX training/inference framework.

Subpackages:
  core        the paper's contribution: the RNT-J columnar format + writers
  kernels     Pallas TPU kernels (columnar encoders + model hot spots)
  models      the 10 assigned architectures (decoder LMs, MoE, SSM, hybrid)
  configs     architecture configs + input-shape cells
  pipeline    nested-columnar training-data ingest + packing loader
  ckpt        parallel single-file distributed checkpointing
  skim        AGC-style dataset skimming application
  train       optimizer, train/serve steps, training loop
  distributed sharding rules, collectives, pipeline parallelism
  launch      production mesh, multi-pod dry-run, train/serve entry points
"""

__version__ = "1.0.0"
