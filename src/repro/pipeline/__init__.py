"""Training-data pipeline on the nested columnar store."""

from .tokens import DOC_SCHEMA, docs_to_batch
from .ingest import ingest_corpus, synth_corpus
from .loader import PackedLoader

__all__ = ["DOC_SCHEMA", "docs_to_batch", "ingest_corpus", "synth_corpus",
           "PackedLoader"]
