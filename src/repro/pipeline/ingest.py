"""Parallel corpus ingest: many tokenizer workers -> ONE dataset file.

Each worker owns a fill context of the shared ParallelWriter and streams
its documents as relocatable clusters; a run with N workers produces a
file readers cannot distinguish from a sequential ingest (paper §4.3).
"""

from __future__ import annotations

import threading
from typing import Callable, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import ParallelWriter, WriteOptions

from .tokens import DOC_SCHEMA, docs_to_batch


def synth_corpus(n_docs: int, seed: int = 0, mean_len: int = 512,
                 vocab: int = 50_000, n_phrases: int = 512,
                 ) -> Iterator[Tuple[int, np.ndarray]]:
    """Synthetic tokenized corpus with log-normal doc lengths.

    Documents are Zipf-weighted concatenations of a fixed phrase inventory,
    so the data is LEARNABLE (per-token entropy ~ H(phrase)/len(phrase)
    << ln(vocab)) — training loss curves show real progress, unlike
    uniform-random tokens whose floor is ln(vocab).
    """
    rng = np.random.default_rng(seed)
    phrases = [
        rng.integers(0, vocab, int(rng.integers(8, 32))).astype(np.int32)
        for _ in range(n_phrases)
    ]
    p = 1.0 / np.arange(1, n_phrases + 1)
    p /= p.sum()
    for i in range(n_docs):
        n = max(8, int(rng.lognormal(np.log(mean_len), 0.6)))
        parts, total = [], 0
        while total < n:
            ph = phrases[rng.choice(n_phrases, p=p)]
            parts.append(ph)
            total += len(ph)
        yield i, np.concatenate(parts)[:n]


def ingest_corpus(
    docs: Iterator[Tuple[int, np.ndarray]],
    path: str,
    n_workers: int = 4,
    batch_docs: int = 256,
    options: Optional[WriteOptions] = None,
) -> dict:
    """Pull-based parallel ingest; returns writer stats."""
    options = options or WriteOptions(codec="zlib", level=1,
                                      cluster_bytes=4 * 1024 * 1024)
    writer = ParallelWriter(DOC_SCHEMA, path, options)
    feed_lock = threading.Lock()

    def pull_batch():
        ids: List[int] = []
        toks: List[np.ndarray] = []
        with feed_lock:
            for _ in range(batch_docs):
                try:
                    i, t = next(docs)
                except StopIteration:
                    break
                ids.append(i)
                toks.append(t)
        return ids, toks

    def worker():
        ctx = writer.create_fill_context()
        while True:
            ids, toks = pull_batch()
            if not ids:
                break
            ctx.fill_batch(docs_to_batch(np.asarray(ids, np.int64), toks))
        ctx.close()

    threads = [threading.Thread(target=worker) for _ in range(n_workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    writer.close()
    return writer.stats.as_dict()
