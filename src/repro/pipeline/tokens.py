"""Tokenized-document schema: the ML instance of the paper's nested data.

A document is ``{doc_id, tokens[]}`` — a variable-length collection, i.e.
exactly the row shape (Fig. 1) that makes regular-grid parallel writing
impossible and the paper's protocol necessary.
"""

from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core import Collection, ColumnBatch, Leaf, Schema

DOC_SCHEMA = Schema([
    Leaf("doc_id", "int64"),
    Collection("tokens", Leaf("_0", "int32")),
])


def docs_to_batch(doc_ids: np.ndarray, token_lists: Sequence[np.ndarray]) -> ColumnBatch:
    sizes = np.array([len(t) for t in token_lists], np.int64)
    values = (np.concatenate(token_lists).astype(np.int32)
              if len(token_lists) else np.empty(0, np.int32))
    return ColumnBatch.from_arrays(
        DOC_SCHEMA, len(doc_ids),
        {"doc_id": np.asarray(doc_ids, np.int64), "tokens": sizes,
         "tokens._0": values},
    )
