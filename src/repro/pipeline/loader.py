"""Packing data loader: nested columnar docs -> fixed (B, S) token batches.

Deterministic and exactly resumable: the loader state is
``(entry_cursor, leftover_tokens)`` and is stored inside the training
checkpoint, so a restarted run continues mid-epoch on the same tokens.
Reads go cluster-at-a-time (the format's natural unit) with column
projection — no entry-by-entry Python loop on the hot path.

Two engines behind one contract (DESIGN.md §9):

* **host** — the original numpy path: ``read_cluster`` + a per-document
  Python loop feeding ``np.concatenate`` packing.
* **device** — built on :meth:`RNTJReader.iter_clusters_device`: stored
  page bytes upload once per cluster, columns materialize as JAX device
  arrays (offset columns as exact int32 ends), and the batch packing —
  document gather with EOS insertion, ``(B, S)`` reshape — runs as
  jitted device ops.  The training loop consumes the yielded batches
  with zero host-side copies, and cluster *N+1*'s I/O + decompression +
  H2D upload overlap cluster *N*'s decode and packing.

Both engines emit the byte-identical token stream (EOS-joined documents
in entry order, wrapped over epochs) and keep the same
``(entry_cursor, leftover)`` state: ``entry_cursor`` counts documents
pulled from the stream, ``leftover`` holds pulled-but-unemitted tokens.
A checkpoint written under either engine restores under either.
"""

from __future__ import annotations

import sys
from typing import Dict, Iterator, Optional

import numpy as np

from repro.core import RNTJReader
from repro.core.reader import ReadOptions


def _pack_cluster(vals, offs, ndocs: int, eos_id: int):
    """Jitted device pack: a cluster's value column + offset column ->
    the packed token stream ``doc0 .. EOS doc1 .. EOS ...``.

    Gather formulation (an order of magnitude faster than the naive
    token scatter on CPU XLA): document ``k``'s EOS lands at output
    position ``offs[k] + k``, so a tiny ``ndocs``-element scatter marks
    the EOS slots, a cumsum over the marks counts completed documents
    before each position, and every other slot gathers token
    ``j - docs_before(j)``.
    """
    import jax.numpy as jnp

    n = vals.shape[0]
    n_out = n + ndocs
    eos_pos = offs + jnp.arange(ndocs, dtype=offs.dtype)
    mark = jnp.zeros(n_out, jnp.int32).at[eos_pos].set(1)
    docs_before = jnp.cumsum(mark) - mark
    j = jnp.arange(n_out, dtype=jnp.int32)
    tok = jnp.clip(j - docs_before, 0, max(n - 1, 0))
    return jnp.where(mark == 1, jnp.int32(eos_id),
                     vals.astype(jnp.int32)[tok])


def _pack_cluster_with_carry(carry, vals, offs, ndocs: int, eos_id: int):
    """Fused refill: carry-prefix concat + cluster pack in ONE jitted
    call, so the packed stream is written exactly once (a separate
    pack-then-concatenate costs an extra full sweep over the cluster's
    tokens on every refill)."""
    import jax.numpy as jnp

    return jnp.concatenate(
        [carry, _pack_cluster(vals, offs, ndocs, eos_id)])


_jit_cache: Dict[str, object] = {}


def _jitted(name: str, fn, **kw):
    """Lazily ``jax.jit`` a module-level helper (jax imports on first use)."""
    if name not in _jit_cache:
        import jax

        _jit_cache[name] = jax.jit(fn, **kw)
    return _jit_cache[name]


def _batch_views(flat, pos, batch: int, seq_len: int):
    import jax

    grid = jax.lax.dynamic_slice(
        flat, (pos,), (batch * (seq_len + 1),)
    ).reshape(batch, seq_len + 1)
    return grid[:, :-1], grid[:, 1:]


class PackedLoader:
    """``device``: ``"auto"`` (device engine when jax is already imported
    by the application and the reader allows it), ``"device"`` (force),
    or ``"host"`` (the numpy path).  ``read_options`` tunes the
    underlying reader — in particular ``device_decode`` picks the fused
    decode backend and ``"off"`` pins the loader to the host engine.
    """

    def __init__(self, path: str, batch: int, seq_len: int,
                 eos_id: int = 0, state: Optional[Dict] = None,
                 device: str = "auto",
                 read_options: Optional[ReadOptions] = None):
        self.reader = RNTJReader(path, options=read_options)
        self.batch = batch
        self.seq_len = seq_len
        self.eos_id = eos_id
        self.device = device
        schema = self.reader.schema
        self._col_off = schema.column_of_path["tokens"]
        self._col_val = schema.column_of_path["tokens._0"]
        self.entry_cursor = 0
        self.leftover = np.empty(0, np.int32)
        # device-engine buffer: the packed stream lives on device as
        # (_flat, _pos) — flat tokens plus a consumed-prefix cursor — so
        # per-batch state updates are O(1) (no leftover re-slice copy)
        self._flat = None
        self._pos = 0
        if state:
            self.load_state(state)

    # -- resumable state ---------------------------------------------------

    def state(self) -> Dict:
        """The exact-resume state ``{entry_cursor, leftover}``.

        Under the device engine the leftover materializes to host here —
        checkpoint time is the one place the device stream syncs.
        """
        if self._flat is not None:
            left = np.asarray(self._flat)[self._pos:].copy()
        else:
            left = np.asarray(self.leftover, np.int32).copy()
        return {"entry_cursor": self.entry_cursor, "leftover": left}

    def load_state(self, state: Dict) -> None:
        """Restore ``(entry_cursor, leftover)``; applies to the next
        :meth:`batches` call (generators already running keep their own
        position, exactly like the host path)."""
        self.entry_cursor = int(state["entry_cursor"])
        self.leftover = np.asarray(state["leftover"], np.int32)
        self._flat = None
        self._pos = 0

    @property
    def n_docs(self) -> int:
        return self.reader.n_entries

    # -- engine selection --------------------------------------------------

    def _use_device(self) -> bool:
        if self.device == "host":
            return False
        if self.reader.read_options.device_decode == "off":
            return False
        if self.device == "device":
            return True
        # auto: never pay a cold jax import for data loading — the
        # training application has always already imported jax
        return "jax" in sys.modules

    # -- iteration ------------------------------------------------------------

    def batches(self) -> Iterator[Dict[str, np.ndarray]]:
        """Yields ``{tokens (B,S), labels (B,S)}`` forever (epoch-wrapped).

        Host engine yields numpy arrays; device engine yields JAX device
        arrays (``jnp.asarray`` in the train step is then a no-op).
        """
        if self._use_device():
            return self._device_batches()
        return self._host_batches()

    # -- host engine -------------------------------------------------------

    def _doc_stream(self) -> Iterator[np.ndarray]:
        """Docs starting at entry_cursor, wrapping around epochs."""
        while True:
            for ci in range(self.reader.n_clusters):
                first, last = self.reader.cluster_entry_range(ci)
                if last <= self.entry_cursor:
                    continue
                cols = self.reader.read_cluster(ci, [self._col_off, self._col_val])
                offs = cols[self._col_off]
                vals = cols[self._col_val]
                starts = np.concatenate([[0], offs[:-1]])
                lo = self.entry_cursor - first
                for j in range(lo, last - first):
                    self.entry_cursor += 1
                    yield vals[starts[j]:offs[j]].astype(np.int32)
            self.entry_cursor = 0  # next epoch

    def _host_batches(self) -> Iterator[Dict[str, np.ndarray]]:
        need = self.batch * (self.seq_len + 1)
        stream = self._doc_stream()
        buf = self.leftover
        while True:
            parts = [buf]
            total = len(buf)
            while total < need:
                doc = next(stream)
                parts.append(doc)
                parts.append(np.array([self.eos_id], np.int32))
                total += len(doc) + 1
            flat = np.concatenate(parts)
            chunk, self.leftover = flat[:need], flat[need:]
            buf = self.leftover
            grid = chunk.reshape(self.batch, self.seq_len + 1)
            yield {"tokens": grid[:, :-1].copy(), "labels": grid[:, 1:].copy()}

    # -- device engine -----------------------------------------------------

    def _device_stream(self):
        """Raw per-cluster device chunks ``(vals, offs, ndocs, drop)``
        starting at ``entry_cursor``, wrapping around epochs — the
        cluster-granular analog of :meth:`_doc_stream` (pulling a
        cluster advances ``entry_cursor`` to its end; the chunk's
        unemitted tail is the leftover).  ``drop`` is the count of
        already-consumed leading packed elements (mid-cluster resume
        only; 0 in steady state) — packing itself happens in
        :meth:`_device_batches` so the refill can fuse it with the
        carry concat."""
        import jax.numpy as jnp

        want = [self._col_off, self._col_val]
        while True:
            start_ci = None
            for ci in range(self.reader.n_clusters):
                _f, last = self.reader.cluster_entry_range(ci)
                if last > self.entry_cursor:
                    start_ci = ci
                    break
            if start_ci is None:
                self.entry_cursor = 0  # next epoch
                continue
            for i, cols in self.reader.iter_clusters_device(want, start=start_ci):
                first, last = self.reader.cluster_entry_range(i)
                o = cols[self._col_off]
                if isinstance(o, np.ndarray):  # host-fallback column
                    o = o.astype(np.int32)
                offs = jnp.asarray(o)
                vals = jnp.asarray(cols[self._col_val])
                lo = self.entry_cursor - first
                # mid-cluster resume: docs < lo and their EOS slots are
                # already consumed.  The one host sync of the stream
                # (restore only, never steady state).
                drop = (int(offs[lo - 1]) + lo) if lo > 0 else 0
                self.entry_cursor = last
                yield vals, offs, int(last - first), drop

    def _device_batches(self):
        import jax.numpy as jnp

        need = self.batch * (self.seq_len + 1)
        stream = self._device_stream()
        views = _jitted("batch_views", _batch_views,
                        static_argnames=("batch", "seq_len"))
        pack = _jitted("pack", _pack_cluster,
                       static_argnames=("ndocs", "eos_id"))
        pack_carry = _jitted("pack_carry", _pack_cluster_with_carry,
                             static_argnames=("ndocs", "eos_id"))
        if self._flat is None:
            left = np.asarray(self.leftover, np.int32)
            if left.shape[0] < need:
                # left-pad so _flat is always at least `need` long — the
                # refill below can then take a fixed (need,) carry slice
                pad = np.zeros(need - left.shape[0], np.int32)
                self._pos = pad.shape[0]
                self._flat = jnp.asarray(np.concatenate([pad, left]))
            else:
                self._flat = jnp.asarray(left)
                self._pos = 0
        while True:
            avail = int(self._flat.shape[0]) - self._pos
            if avail < need:
                # Right-align the remainder inside a fixed (need,) carry
                # window so the concatenated shape depends only on WHICH
                # clusters this refill pulls (per-cluster constants), not
                # on the drifting remainder length.  Shape drift here
                # recompiles concatenate + the views jit on every refill,
                # forever — the carry keeps steady state compile-free
                # after the first epoch.
                buf = self._flat[-need:]
                total = avail
                while total < need:
                    vals, offs, ndocs, drop = next(stream)
                    if drop:  # restore-only: pack, slice, plain concat
                        chunk = pack(vals, offs, ndocs=ndocs,
                                     eos_id=self.eos_id)[drop:]
                        buf = jnp.concatenate([buf, chunk])
                    else:
                        buf = pack_carry(buf, vals, offs, ndocs=ndocs,
                                         eos_id=self.eos_id)
                    total += int(vals.shape[0]) + ndocs - drop
                self._flat = buf
                self._pos = need - avail
            tokens, labels = views(self._flat, self._pos,
                                   batch=self.batch, seq_len=self.seq_len)
            self._pos += need
            yield {"tokens": tokens, "labels": labels}

    def close(self) -> None:
        self.reader.close()
