"""Packing data loader: nested columnar docs -> fixed (B, S) token batches.

Deterministic and exactly resumable: the loader state is
``(entry_cursor, leftover_tokens)`` and is stored inside the training
checkpoint, so a restarted run continues mid-epoch on the same tokens.
Reads go cluster-at-a-time (the format's natural unit) with column
projection — no entry-by-entry Python loop on the hot path.
"""

from __future__ import annotations

from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.core import RNTJReader
from repro.core.encoding import offsets_to_sizes


class PackedLoader:
    def __init__(self, path: str, batch: int, seq_len: int,
                 eos_id: int = 0, state: Optional[Dict] = None):
        self.reader = RNTJReader(path)
        self.batch = batch
        self.seq_len = seq_len
        self.eos_id = eos_id
        schema = self.reader.schema
        self._col_off = schema.column_of_path["tokens"]
        self._col_val = schema.column_of_path["tokens._0"]
        self.entry_cursor = 0
        self.leftover = np.empty(0, np.int32)
        if state:
            self.entry_cursor = int(state["entry_cursor"])
            self.leftover = np.asarray(state["leftover"], np.int32)

    # -- resumable state ---------------------------------------------------

    def state(self) -> Dict:
        return {"entry_cursor": self.entry_cursor,
                "leftover": self.leftover.copy()}

    @property
    def n_docs(self) -> int:
        return self.reader.n_entries

    # -- iteration ------------------------------------------------------------

    def _doc_stream(self) -> Iterator[np.ndarray]:
        """Docs starting at entry_cursor, wrapping around epochs."""
        while True:
            for ci in range(self.reader.n_clusters):
                first, last = self.reader.cluster_entry_range(ci)
                if last <= self.entry_cursor:
                    continue
                cols = self.reader.read_cluster(ci, [self._col_off, self._col_val])
                offs = cols[self._col_off]
                vals = cols[self._col_val]
                starts = np.concatenate([[0], offs[:-1]])
                lo = self.entry_cursor - first
                for j in range(lo, last - first):
                    self.entry_cursor += 1
                    yield vals[starts[j]:offs[j]].astype(np.int32)
            self.entry_cursor = 0  # next epoch

    def batches(self) -> Iterator[Dict[str, np.ndarray]]:
        """Yields {tokens (B,S), labels (B,S)} forever (epoch-wrapped)."""
        need = self.batch * (self.seq_len + 1)
        stream = self._doc_stream()
        buf = self.leftover
        while True:
            parts = [buf]
            total = len(buf)
            while total < need:
                doc = next(stream)
                parts.append(doc)
                parts.append(np.array([self.eos_id], np.int32))
                total += len(doc) + 1
            flat = np.concatenate(parts)
            chunk, self.leftover = flat[:need], flat[need:]
            buf = self.leftover
            grid = chunk.reshape(self.batch, self.seq_len + 1)
            yield {"tokens": grid[:, :-1].copy(), "labels": grid[:, 1:].copy()}

    def close(self) -> None:
        self.reader.close()
