"""Serving launcher: batched prefill+decode with columnar output logging.

Generations are variable-length nested data ({request_id, prompt_len,
tokens[]}) and are written through the ParallelWriter — the inference-side
instance of the paper's technique (concurrent decode workers, one output
file).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
      --requests 8 --max-new 32 --out /tmp/gen.rntj
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_arch, smoke_config
from repro.core import Collection, ColumnBatch, Leaf, ParallelWriter, Schema
from repro.launch.mesh import make_local_mesh
from repro.models.registry import build

GEN_SCHEMA = Schema([
    Leaf("request_id", "int64"),
    Leaf("prompt_len", "int32"),
    Collection("tokens", Leaf("_0", "int32")),
])


def generate(bundle, params, prompts: np.ndarray, max_new: int):
    """Greedy decode a batch of same-length prompts -> (B, max_new)."""
    b, s = prompts.shape[:2]
    max_len = s + max_new
    logits, cache = jax.jit(
        lambda p, t: bundle.prefill(p, t, max_len=max_len))(params, prompts)
    step = jax.jit(bundle.decode_step)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    for i in range(max_new - 1):
        pos = jnp.full((b,), s + i, jnp.int32)
        logits, cache = step(params, tok, cache, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    return np.concatenate([np.asarray(t) for t in out], axis=1)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--out", default="/tmp/generations.rntj")
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_arch(args.arch)
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    shape = ((args.requests, args.prompt_len)
             if cfg.n_codebooks == 1
             else (args.requests, args.prompt_len, cfg.n_codebooks))
    prompts = rng.integers(0, cfg.vocab_size, shape).astype(np.int32)
    gen = generate(bundle, params, jnp.asarray(prompts), args.max_new)
    if gen.ndim == 3:
        gen = gen[..., 0]  # log first codebook stream

    writer = ParallelWriter(GEN_SCHEMA, args.out)
    ctx = writer.create_fill_context()
    sizes = np.full(args.requests, gen.shape[1], np.int64)
    ctx.fill_batch(ColumnBatch.from_arrays(GEN_SCHEMA, args.requests, {
        "request_id": np.arange(args.requests, dtype=np.int64),
        "prompt_len": np.full(args.requests, args.prompt_len, np.int32),
        "tokens": sizes,
        "tokens._0": gen.reshape(-1).astype(np.int32),
    }))
    ctx.close()
    writer.close()
    print(f"wrote {args.requests} generations x {gen.shape[1]} tokens -> {args.out}")


if __name__ == "__main__":
    main()
