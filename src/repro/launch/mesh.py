"""Production mesh definitions.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16); the "pod"
axis carries inter-pod data parallelism (gradient all-reduce crosses the
pod boundary; everything bandwidth-heavy stays intra-pod).

Functions, not module constants: importing this module never touches jax
device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """A mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))


def describe(mesh) -> str:
    return " x ".join(f"{a}={mesh.shape[a]}" for a in mesh.axis_names)
