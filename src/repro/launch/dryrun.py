import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real step function (train_step for train_4k,
prefill_step for prefill_32k, serve_step for decode cells) against
ShapeDtypeStruct inputs — no allocation — compiles it for the production
mesh, and records:

  * memory_analysis()  — per-device bytes: proves the cell fits
  * cost_analysis()    — HLO FLOPs / bytes for the §Roofline terms
  * collective traffic — parsed from the optimized HLO (hlo_analysis)

Results are written incrementally to benchmarks/results/dryrun/ as JSON so
the full 40-cell x 2-mesh sweep can resume.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.configs import ARCHS, SHAPES_BY_NAME, get_arch
from repro.launch import hlo_analysis as H
from repro.launch.mesh import describe, make_production_mesh
from repro.models.registry import build
from repro.train.step import make_prefill_step, make_serve_step, make_train_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def model_flops(cfg, cell) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); D = tokens/step.

    For decode cells D = global_batch tokens (one step).
    """
    bundle = build(cfg)
    shapes = jax.eval_shape(lambda: bundle.init(jax.random.PRNGKey(0)))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = 0
    routed = 0
    for path, leaf in flat:
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        n = int(np.prod(leaf.shape))
        total += n
        if "experts" in keys:
            routed += n
    n_params = total
    if cfg.moe is not None:
        active = total - routed + routed * cfg.moe.top_k / cfg.moe.n_experts
    else:
        active = n_params
    d_tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    mult = 6 if cell.kind == "train" else 2
    return mult * active * d_tokens


VARIANTS = {
    # §Perf variants: config / step-builder deltas applied on top of the
    # paper-faithful baseline.  Results land in ...__<variant>.json.
    None: {},
    "chunked-attn": {"cfg": {"attn_impl": "chunked"}},
    "no-remat": {"cfg": {"remat": False}},
    "gradcomp": {"step": {"grad_compression": True}},
    "microbatch4": {"step": {"microbatches": 4}},
    "microbatch8": {"step": {"microbatches": 8}},
    "chunked+mb8": {"cfg": {"attn_impl": "chunked"},
                    "step": {"microbatches": 8}},
    # widen the batch axis over the model axis too (removes replicated
    # attention compute for archs whose heads don't divide model=16)
    "dp-wide": {"rules": {"dp": ("pod", "data", "model")}},
    "chunked+dpwide": {"cfg": {"attn_impl": "chunked"},
                       "rules": {"dp": ("pod", "data", "model")}},
    # serving: bf16 parameters halve the per-token weight traffic (decode
    # is weight/cache-bandwidth bound)
    "bf16-params": {"cfg": {"param_dtype": "bfloat16"}},
    "bf16+chunked": {"cfg": {"param_dtype": "bfloat16",
                             "attn_impl": "chunked"}},
    # serving: TP-only parameter sharding (no per-step FSDP weight gather;
    # costs replicated weight memory across the dp axis)
    "bf16+tponly": {"cfg": {"param_dtype": "bfloat16"}, "fsdp_axes": ()},
}


def _lower_step(cfg, cell, mesh, bundle=None, variant: str = None):
    """Lower the cell's step function; returns the Lowered object."""
    vspec = VARIANTS[variant]
    if vspec.get("cfg"):
        cfg = cfg.with_(**vspec["cfg"])
        bundle = None
    bundle = bundle or build(cfg)
    specs = bundle.input_specs(cell)
    rules_mapping = None
    if vspec.get("rules"):
        from repro.distributed.sharding import DEFAULT_RULES
        rules_mapping = {**DEFAULT_RULES, **vspec["rules"]}
    step_kw = dict(vspec.get("step", {}))
    if "fsdp_axes" in vspec:
        step_kw["fsdp_axes"] = tuple(vspec["fsdp_axes"])
    if cell.kind == "train":
        jitted_for, _ = make_train_step(bundle, mesh,
                                        rules_mapping=rules_mapping, **step_kw)
        from repro.train.optimizer import make_optimizer
        opt = make_optimizer()
        param_shapes = bundle.param_shapes()
        opt_shapes = jax.eval_shape(opt.init, param_shapes)
        err_shapes = (param_shapes if step_kw.get("grad_compression")
                      else jax.ShapeDtypeStruct((), np.float32))
        fn = jitted_for(specs)
        with mesh:
            return fn.lower(param_shapes, opt_shapes, err_shapes, specs)
    if cell.kind == "prefill":
        jitted_for, _ = make_prefill_step(bundle, mesh, max_len=cell.seq_len,
                                          rules_mapping=rules_mapping,
                                          **step_kw)
        param_shapes = bundle.param_shapes()
        fn = jitted_for(specs["tokens"])
        with mesh:
            return fn.lower(param_shapes, specs["tokens"])
    fn, _ = make_serve_step(bundle, mesh, cell, rules_mapping=rules_mapping,
                            **step_kw)
    param_shapes = bundle.param_shapes()
    with mesh:
        return fn.lower(param_shapes, specs["tokens"], specs["cache"],
                        specs["pos"])


def _measure(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = H.parse_collectives(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll.total_bytes),
        "_coll": coll,
    }


def _probe_corrected(cfg, cell, mesh, full, variant=None):
    """Correct body-once while-loop counting via unrolled layer probes.

    XLA's HloCostAnalysis counts a while body once regardless of trip
    count, so the scanned full model underreports per-layer costs by ~L.
    Two small python-unrolled compiles at L=u and L=2u (u = the hybrid
    group size or 1) give exact per-layer-unit deltas; costs extrapolate
    linearly: total(L) = base + (L/u)·per_unit.
    """
    unit = cfg.shared_attn_every or 1
    probes = {}
    for k in (1, 2):
        pcfg = cfg.with_(n_layers=k * unit, scan_layers=False)
        lowered = _lower_step(pcfg, cell, mesh, variant=variant)
        probes[k] = _measure(lowered.compile())
    out = {}
    for key in ("flops", "bytes", "coll_bytes"):
        per_unit = max(probes[2][key] - probes[1][key], 0.0)
        base = max(probes[1][key] - per_unit, 0.0)
        out[key] = base + (cfg.n_layers / unit) * per_unit
    out["probe_unit"] = unit
    out["probe_values"] = {
        k: {kk: v[kk] for kk in ("flops", "bytes", "coll_bytes")}
        for k, v in probes.items()
    }
    return out


def lower_cell(arch: str, shape: str, multi_pod: bool, variant: str = None):
    cfg = get_arch(arch)
    cell = SHAPES_BY_NAME[shape]
    bundle = build(cfg)
    ok, reason = bundle.runnable(cell)
    if not ok:
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    lowered = _lower_step(cfg, cell, mesh, variant=variant)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    raw = _measure(compiled)
    corrected = _probe_corrected(cfg, cell, mesh, raw, variant=variant)
    # the microbatch scan is another body-once while loop: scale by n
    mb = VARIANTS[variant].get("step", {}).get("microbatches", 1)
    if mb > 1:
        for key in ("flops", "bytes", "coll_bytes"):
            corrected[key] *= mb

    terms = H.roofline_terms(corrected["flops"], corrected["bytes"],
                             corrected["coll_bytes"], n_chips)
    mf = model_flops(cfg, cell)
    # decode: irreducible bytes = params + cache, each read once per step
    ideal_bytes = None
    if cell.kind == "decode":
        pb = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                 for l in jax.tree_util.tree_leaves(bundle.param_shapes()))
        cb = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                 for l in jax.tree_util.tree_leaves(
                     jax.eval_shape(lambda: bundle.init_cache(
                         cell.global_batch, cell.seq_len))))
        ideal_bytes = (pb + cb) / n_chips
    # cost_analysis is per-device under SPMD; model_flops is fleet-wide
    per_device_mf = mf / n_chips

    rec = {
        "arch": arch,
        "shape": shape,
        "multi_pod": multi_pod,
        "variant": variant,
        "mesh": describe(mesh),
        "n_chips": n_chips,
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "hlo_flops_raw": raw["flops"],
        "hlo_bytes_raw": raw["bytes"],
        "hlo_flops": corrected["flops"],
        "hlo_bytes": corrected["bytes"],
        "collective_bytes": corrected["coll_bytes"],
        "collectives": raw["_coll"].as_dict(),
        "probe": {k: v for k, v in corrected.items()
                  if k in ("probe_unit", "probe_values")},
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "roofline": terms,
        "model_flops": mf,
        "model_flops_per_device": per_device_mf,
        "ideal_bytes_per_device": ideal_bytes,
        "useful_fraction": per_device_mf / corrected["flops"]
        if corrected["flops"] else None,
    }
    return rec


def result_path(arch: str, shape: str, multi_pod: bool,
                variant: str = None) -> Path:
    mesh_tag = "multipod" if multi_pod else "singlepod"
    vtag = f"__{variant}" if variant else ""
    return RESULTS_DIR / f"{arch}__{shape}__{mesh_tag}{vtag}.json"


def run_one(arch: str, shape: str, multi_pod: bool, force: bool = False,
            variant: str = None) -> dict:
    out = result_path(arch, shape, multi_pod, variant)
    if out.exists() and not force:
        return json.loads(out.read_text())
    try:
        rec = lower_cell(arch, shape, multi_pod, variant=variant)
    except Exception as e:  # record failures: they are bugs to fix
        rec = {"arch": arch, "shape": shape, "multi_pod": multi_pod,
               "variant": variant,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), action="append")
    ap.add_argument("--shape", choices=sorted(SHAPES_BY_NAME), action="append")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", choices=[v for v in VARIANTS if v],
                    default=None)
    args = ap.parse_args()

    archs = args.arch or (sorted(ARCHS) if args.all else [])
    shapes = args.shape or (sorted(SHAPES_BY_NAME) if args.all or args.arch else [])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if not archs:
        ap.error("pass --arch/--shape or --all")

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_one(arch, shape, mp, force=args.force,
                              variant=args.variant)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f"compile={rec['compile_s']}s "
                             f"dominant={r['dominant']} "
                             f"flops={rec['hlo_flops']:.3g}")
                elif status == "error":
                    extra = rec["error"][:120]
                else:
                    extra = rec["reason"]
                print(f"[{status:7s}] {arch:18s} {shape:12s} "
                      f"{'multi' if mp else 'single'}  {extra}", flush=True)


if __name__ == "__main__":
    main()
