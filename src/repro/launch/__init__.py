"""Launchers: production mesh, multi-pod dry-run, train/serve entries."""

from .mesh import describe, make_local_mesh, make_production_mesh

__all__ = ["describe", "make_local_mesh", "make_production_mesh"]
