"""Post-SPMD HLO analysis: collective traffic + roofline terms.

``compiled.cost_analysis()`` gives FLOPs and bytes accessed but NOT
collective bytes; those are extracted from the optimized HLO text by
summing operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction.

Hardware model (TPU v5e target): 197 bf16 TFLOP/s, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# "%name = <shape-or-tuple> opcode(...operands...)"
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+([\w\-]+)"
)


def shape_bytes(shape_str: str) -> int:
    """bytes of 'bf16[8,128,2048]{...}' or tuple '(f32[2], bf16[4,4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def as_dict(self) -> dict:
        return {
            "bytes_by_kind": self.bytes_by_kind,
            "count_by_kind": self.count_by_kind,
            "total_bytes": self.total_bytes,
            "total_count": self.total_count,
        }


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective in optimized HLO text.

    Builds a name->shape symbol table from instruction definitions, then
    for each collective instruction sums its operands' shapes.  Counts are
    per-instruction (each executes once per step on every device).
    """
    shapes: Dict[str, str] = {}
    instrs: List[Tuple[str, str, str]] = []  # (opcode, shape, line)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape, opcode = m.groups()
        shapes[name] = shape
        base = opcode.rstrip("-start").rstrip(".")
        for c in _COLLECTIVES:
            if opcode == c or opcode == c + "-start" or opcode.startswith(c + "."):
                instrs.append((c, shape, line))
                break

    stats = CollectiveStats()
    for kind, shape, line in instrs:
        # operand sizes: names inside the call parens
        mcall = re.search(r"\(([^)]*)\)", line[line.index("=") :])
        nbytes = 0
        if mcall:
            for op in mcall.group(1).split(","):
                op = op.strip().lstrip("%")
                # strip 'f32[...] %name' style typed operands
                mname = re.search(r"([\w.\-]+)$", op)
                if mname and mname.group(1) in shapes:
                    nbytes += shape_bytes(shapes[mname.group(1)])
                elif _SHAPE_RE.search(op):
                    nbytes += shape_bytes(op)
        if nbytes == 0:
            nbytes = shape_bytes(shape)  # fallback: output size
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


def roofline_terms(
    hlo_flops: float,
    hlo_bytes: float,
    collective_bytes: float,
    n_chips: int,
) -> Dict[str, float]:
    """The three roofline terms in seconds (per step, fleet-wide work).

    cost_analysis flops/bytes are per-device HLO module costs under SPMD
    (the module is the per-device program), so divide-by-chips applies to
    the collective sum only when it was accumulated over one device's
    program — which it is (HLO text is the per-device module).
    """
    compute_s = hlo_flops / PEAK_FLOPS
    memory_s = hlo_bytes / HBM_BW
    collective_s = collective_bytes / ICI_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s), key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "n_chips": n_chips,
    }
