"""Training launcher.

CPU-scale end-to-end entry point (examples/train_lm.py wraps this) and the
production shape: on a real pod the same code runs under
``jax.distributed.initialize`` with the production mesh.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 50 --data /tmp/corpus.rntj --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
from pathlib import Path

import jax

from repro.configs import ARCHS, get_arch, smoke_config
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.registry import build
from repro.pipeline import PackedLoader, ingest_corpus, synth_corpus
from repro.train import LoopConfig, TrainLoop, make_optimizer


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data", default="/tmp/repro_corpus.rntj")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 production mesh (needs 256 devices)")
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_arch(args.arch)
    bundle = build(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh())

    if not Path(args.data).exists():
        print(f"ingesting synthetic corpus -> {args.data}")
        ingest_corpus(
            synth_corpus(2000, mean_len=256, vocab=cfg.vocab_size),
            args.data, n_workers=4,
        )
    loader = PackedLoader(args.data, batch=args.batch, seq_len=args.seq)

    loop = TrainLoop(
        bundle, mesh, loader, args.ckpt_dir,
        config=LoopConfig(
            steps=args.steps, ckpt_every=args.ckpt_every,
            grad_compression=args.grad_compression,
            microbatches=args.microbatches,
        ),
        optimizer=make_optimizer(peak_lr=args.lr, warmup=20, total=args.steps),
    )
    if loop.step:
        print(f"restored from checkpoint at step {loop.step}")
    history = loop.run()
    print(f"done: step {loop.step}, "
          f"loss {history[0].loss:.3f} -> {history[-1].loss:.3f}")


if __name__ == "__main__":
    main()
