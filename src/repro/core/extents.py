"""Shared extent allocation for multi-process writing (DESIGN.md §8.6).

N independent writer processes commit clusters into ONE container file.  The
commit path is already position-independent (reserve-then-pwritev), so the
only shared state is the allocation frontier plus enough bookkeeping to
survive any writer dying at any point.  That state lives in a **side-car
reservation log** (``<container>.mpwlog``): an append-only record stream,
every append made under an exclusive ``fcntl`` file lock and (by default)
fsynced, so the log is a write-ahead journal of every allocation decision.
State is never stored — it is **replayed** from the log, which makes the
protocol crash-consistent by construction: whatever prefix of the log
survived a crash IS the state.

Record types::

    CREATE   frontier initialised past the container header; carries the
             container's generation id, binding this log to that exact
             file instance
    JOIN     a writer registers; assigned (writer_id, epoch); takes a lease
    LEASE    heartbeat: extends the writer's lease deadline
    RESERVE  allocates [offset, offset+size) + the global commit seq
    COMMIT   the reservation's framed cluster extent is fully on disk
    RELEASE  the writer gives the (uncommitted) reservation back as a hole
    FENCE    the writer's epoch is dead: all its future transactions refuse
    DONE     the writer committed everything and fsynced its data
    SEAL     the coordinator froze the file; no further transaction succeeds

Safety invariants:

* **Extents are disjoint and never reused.**  An abandoned or expired
  reservation becomes a permanent hole — the frontier never rolls back.
  This is what makes fencing safe without kernel-level write fencing: a
  fenced writer's late ``pwrite`` can only land inside its *own* abandoned
  extent, never inside a committed cluster or the footer.
* **Fencing is checked inside the locked transaction.**  A fenced (or
  lease-expired-and-fenced) writer's ``reserve``/``commit`` raises
  :class:`FencedError` before any record is appended, so a stale-epoch
  writer cannot extend the file or mark garbage committed.
* **Replay is pure.**  Every record carries its concrete values (offsets,
  seqs, ids) — replay applies them verbatim and tolerates a torn record at
  the tail (a crash mid-append), which it drops.  The next locked
  transaction *truncates* that torn tail before appending, so a record
  appended after a tear is always visible to every later replay.
* **The log is bound to one container instance.**  CREATE carries the
  generation id the coordinator also stamped into the container header;
  a join or recovery that finds a mismatched (or missing) generation
  refuses the log (:class:`StaleLogError`) instead of replaying state
  that belongs to a previous file at the same path.

Clock note: lease timestamps are ``time.time()`` (wall clock) because they
are written by one process and compared in another — ``time.monotonic()``
deltas are only defined within a single process.  A wall-clock step skews
lease expiry by the step size; that can only fence a live writer early
(safe: fencing never corrupts, see above) or delay fencing a dead one.
"""

from __future__ import annotations

import fcntl
import json
import os
import struct
import threading
import time
import zlib
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

XLOG_SUFFIX = ".mpwlog"
XLOG_MAGIC = b"RJXL"

XREC_CREATE = 1
XREC_JOIN = 2
XREC_LEASE = 3
XREC_RESERVE = 4
XREC_COMMIT = 5
XREC_RELEASE = 6
XREC_FENCE = 7
XREC_DONE = 8
XREC_SEAL = 9

_XREC_HDR = struct.Struct("<4sHHI")  # magic, type, flags, payload_len


class FencedError(RuntimeError):
    """This writer's epoch has been fenced (lease lost, coordinator sealed,
    or an explicit fence): every further reservation/commit is refused."""


class StaleLogError(RuntimeError):
    """The side-car log does not belong to this container instance (its
    generation id disagrees with the container header's), or a CREATE found
    a non-empty log left behind by a previous run at the same path."""


# ---------------------------------------------------------------------------
# record framing


def _pack_record(rtype: int, payload: dict) -> bytes:
    body = json.dumps(payload, separators=(",", ":")).encode()
    crc = zlib.crc32(struct.pack("<HH", rtype, 0) + body)
    return (_XREC_HDR.pack(XLOG_MAGIC, rtype, 0, len(body)) + body
            + struct.pack("<I", crc))


def scan_records(raw: bytes) -> Tuple[List[Tuple[int, dict]], int]:
    """``(records, valid_end)``: every intact ``(rtype, payload)`` plus the
    offset where the intact prefix ends.  A torn or corrupt tail (crash
    mid-append) terminates the scan; ``valid_end < len(raw)`` marks it so
    the next transaction can truncate it before appending — otherwise
    records appended past the tear would be invisible to every replay."""
    records: List[Tuple[int, dict]] = []
    pos = 0
    while pos + _XREC_HDR.size <= len(raw):
        magic, rtype, flags, plen = _XREC_HDR.unpack_from(raw, pos)
        end = pos + _XREC_HDR.size + plen + 4
        if magic != XLOG_MAGIC or end > len(raw):
            break
        body = raw[pos + _XREC_HDR.size : end - 4]
        (crc,) = struct.unpack_from("<I", raw, end - 4)
        if zlib.crc32(struct.pack("<HH", rtype, flags) + body) != crc:
            break
        records.append((rtype, json.loads(body)))
        pos = end
    return records, pos


def iter_records(raw: bytes):
    """Yield ``(rtype, payload_dict)`` for every intact record; a torn or
    corrupt tail terminates iteration silently (crash mid-append)."""
    yield from scan_records(raw)[0]


# ---------------------------------------------------------------------------
# replayed state


@dataclass
class Reservation:
    rid: int
    writer_id: int
    epoch: int
    offset: int
    size: int
    seq: int
    committed: bool = False
    released: bool = False


@dataclass
class WriterInfo:
    writer_id: int
    epoch: int
    pid: int = 0
    lease_interval: float = 5.0
    lease_deadline: float = 0.0
    fenced: bool = False
    done: bool = False

    def expired(self, now: float) -> bool:
        return not self.done and not self.fenced and now > self.lease_deadline


@dataclass
class LogState:
    """The full allocator state, rebuilt by replaying the side-car log."""

    data_start: int = 0
    generation: Optional[str] = None
    next_offset: int = 0
    next_seq: int = 0
    next_rid: int = 0
    next_writer: int = 1
    next_epoch: int = 1
    sealed: bool = False
    seal_info: dict = field(default_factory=dict)
    writers: Dict[int, WriterInfo] = field(default_factory=dict)
    reservations: Dict[int, Reservation] = field(default_factory=dict)

    def live_writers(self, now: float) -> List[WriterInfo]:
        return [w for w in self.writers.values()
                if not w.fenced and not w.done and not w.expired(now)]

    def check_writable(self, writer_id: int, epoch: int) -> None:
        if self.sealed:
            raise FencedError("container already sealed")
        w = self.writers.get(writer_id)
        if w is None or w.epoch != epoch or w.fenced:
            raise FencedError(
                f"writer {writer_id} epoch {epoch} is fenced")
        if w.done:
            # DONE is terminal: it is the participant's half of the footer
            # rendezvous, and the coordinator may seal the moment every
            # writer is done — a post-DONE reservation would race the seal
            raise FencedError(f"writer {writer_id} already reported done")


def replay_log(raw: bytes) -> LogState:
    st = LogState()
    for rtype, d in iter_records(raw):
        _apply_record(st, rtype, d)
    return st


def _apply_record(st: LogState, rtype: int, d: dict) -> None:
    if rtype == XREC_CREATE:
        st.data_start = st.next_offset = d["start"]
        st.next_seq = d.get("seq", 0)
        st.generation = d.get("gen")
    elif rtype == XREC_JOIN:
        w = WriterInfo(d["w"], d["e"], d.get("pid", 0),
                       d.get("li", 5.0), d["t"] + d.get("li", 5.0))
        st.writers[w.writer_id] = w
        st.next_writer = max(st.next_writer, w.writer_id + 1)
        st.next_epoch = max(st.next_epoch, w.epoch + 1)
    elif rtype == XREC_LEASE:
        w = st.writers.get(d["w"])
        if w is not None:
            w.lease_deadline = d["t"] + w.lease_interval
    elif rtype == XREC_RESERVE:
        r = Reservation(d["r"], d["w"], d["e"], d["o"], d["s"], d["q"])
        st.reservations[r.rid] = r
        st.next_offset = max(st.next_offset, r.offset + r.size)
        st.next_seq = max(st.next_seq, r.seq + 1)
        st.next_rid = max(st.next_rid, r.rid + 1)
    elif rtype == XREC_COMMIT:
        r = st.reservations.get(d["r"])
        if r is not None:
            r.committed = True
    elif rtype == XREC_RELEASE:
        r = st.reservations.get(d["r"])
        if r is not None:
            r.released = True
    elif rtype == XREC_FENCE:
        w = st.writers.get(d["w"])
        if w is not None:
            w.fenced = True
    elif rtype == XREC_DONE:
        w = st.writers.get(d["w"])
        if w is not None:
            w.done = True
    elif rtype == XREC_SEAL:
        st.sealed = True
        st.seal_info = d


# ---------------------------------------------------------------------------
# the log itself

# fcntl record locks are per (process, inode): two fds in one process do not
# exclude each other, so in-process concurrency (heartbeat thread vs commit,
# or many writers in one test process) is serialized by a shared per-inode
# threading lock on top of the cross-process file lock.
_PROC_LOCKS: Dict[Tuple[int, int], threading.Lock] = {}
_PROC_LOCKS_GUARD = threading.Lock()


def _proc_lock(st: os.stat_result) -> threading.Lock:
    key = (st.st_dev, st.st_ino)
    with _PROC_LOCKS_GUARD:
        return _PROC_LOCKS.setdefault(key, threading.Lock())


class ExtentLog:
    """Append-only reservation log; every mutation is one locked transaction
    (lock → replay → decide → append → fsync → unlock)."""

    def __init__(self, path: str, fsync: bool = True):
        self.path = path
        self._fsync = fsync
        self._fd = os.open(path, os.O_RDWR | os.O_CREAT, 0o666)
        self._tlock = _proc_lock(os.fstat(self._fd))
        self._closed = False

    @classmethod
    def sidecar_path(cls, container_path: str) -> str:
        return container_path + XLOG_SUFFIX

    @classmethod
    def create(cls, container_path: str, data_start: int, *,
               fsync: bool = True, start_seq: int = 0,
               generation: Optional[str] = None) -> "ExtentLog":
        log = cls(cls.sidecar_path(container_path), fsync=fsync)

        def txn(state: LogState, append):
            if state.data_start != 0 or state.writers or state.sealed:
                # a leftover log from a previous run at the same path must
                # never be adopted: its sealed flag would fence every new
                # join, and its reservations describe a different file
                raise StaleLogError(
                    f"refusing to create over a non-empty side-car log "
                    f"({log.path}): remove the stale log first")
            append(XREC_CREATE, {"start": data_start, "seq": start_seq,
                                 "gen": generation})
        try:
            log.transact(txn)
        except StaleLogError:
            log.close()
            raise
        return log

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            os.close(self._fd)

    def unlink(self) -> None:
        try:
            os.unlink(self.path)
        except FileNotFoundError:
            pass

    # -- locked transaction core ------------------------------------------

    @contextmanager
    def _locked(self):
        with self._tlock:
            fcntl.lockf(self._fd, fcntl.LOCK_EX)
            try:
                yield
            finally:
                fcntl.lockf(self._fd, fcntl.LOCK_UN)

    def _read_all(self) -> bytes:
        size = os.fstat(self._fd).st_size
        return os.pread(self._fd, size, 0) if size else b""

    def transact(self, fn: Callable[[LogState, Callable[[int, dict], None]], object]):
        """Run ``fn(state, append)`` under the cross-process lock.  Records
        queued via ``append`` are written (and fsynced) atomically-enough at
        the end; if ``fn`` raises, nothing is appended."""
        with self._locked():
            raw = self._read_all()
            records, valid_end = scan_records(raw)
            state = LogState()
            for rtype, d in records:
                _apply_record(state, rtype, d)
            queued: List[bytes] = []

            def append(rtype: int, payload: dict) -> None:
                queued.append(_pack_record(rtype, payload))

            out = fn(state, append)
            if queued:
                if valid_end < len(raw):
                    # discard the torn tail (crash mid-append) so the new
                    # records land inside — not after — the replayable
                    # prefix; appending at len(raw) would make them
                    # permanently invisible to iter_records/replay_log
                    os.ftruncate(self._fd, valid_end)
                os.pwrite(self._fd, b"".join(queued), valid_end)
                if self._fsync:
                    os.fsync(self._fd)
            return out

    def snapshot(self) -> LogState:
        """Replay the current log under the lock (read-only)."""
        with self._locked():
            return replay_log(self._read_all())

    # -- protocol operations ----------------------------------------------

    def join(self, lease_interval: float = 5.0, *,
             expect_generation: Optional[str] = None) -> "WriterSession":
        def txn(state: LogState, append):
            if (expect_generation is not None
                    and state.generation != expect_generation):
                # the log next to the container belongs to a different
                # file instance (prior run at the same path): joining it
                # would reserve extents into the wrong file's layout
                raise StaleLogError(
                    f"side-car log generation {state.generation!r} does "
                    f"not match container generation {expect_generation!r}")
            if state.sealed:
                raise FencedError("container already sealed")
            wid, epoch = state.next_writer, state.next_epoch
            append(XREC_JOIN, {"w": wid, "e": epoch, "pid": os.getpid(),
                               "li": lease_interval, "t": time.time()})
            return wid, epoch
        wid, epoch = self.transact(txn)
        return WriterSession(self, wid, epoch, lease_interval)

    def reserve(self, writer_id: int, epoch: int, size: int) -> Reservation:
        def txn(state: LogState, append):
            state.check_writable(writer_id, epoch)
            r = Reservation(state.next_rid, writer_id, epoch,
                            state.next_offset, size, state.next_seq)
            append(XREC_RESERVE, {"r": r.rid, "w": writer_id, "e": epoch,
                                  "o": r.offset, "s": r.size, "q": r.seq})
            return r
        return self.transact(txn)

    def commit(self, writer_id: int, epoch: int, rid: int) -> None:
        def txn(state: LogState, append):
            state.check_writable(writer_id, epoch)
            r = state.reservations.get(rid)
            if r is None or r.writer_id != writer_id:
                raise FencedError(f"reservation {rid} is not writer {writer_id}'s")
            append(XREC_COMMIT, {"r": rid, "w": writer_id})
        self.transact(txn)

    def release(self, writer_id: int, epoch: int, rid: int) -> None:
        def txn(state: LogState, append):
            state.check_writable(writer_id, epoch)
            append(XREC_RELEASE, {"r": rid, "w": writer_id})
        self.transact(txn)

    def heartbeat(self, writer_id: int, epoch: int) -> None:
        def txn(state: LogState, append):
            state.check_writable(writer_id, epoch)
            # wall clock, not monotonic: deadlines cross process boundaries
            append(XREC_LEASE, {"w": writer_id, "t": time.time()})
        self.transact(txn)

    def done(self, writer_id: int, epoch: int) -> None:
        def txn(state: LogState, append):
            state.check_writable(writer_id, epoch)
            append(XREC_DONE, {"w": writer_id})
        self.transact(txn)

    def fence(self, writer_id: int, reason: str = "") -> None:
        def txn(state: LogState, append):
            w = state.writers.get(writer_id)
            if w is not None and not w.fenced:
                append(XREC_FENCE, {"w": writer_id, "reason": reason})
        self.transact(txn)

    def seal(self, info: Optional[dict] = None) -> None:
        def txn(state: LogState, append):
            if not state.sealed:
                append(XREC_SEAL, dict(info or {}))
        self.transact(txn)


@dataclass
class WriterSession:
    """One writer's identity in the shared log: ``(writer_id, epoch)`` plus
    the lease it must keep alive.  All operations raise :class:`FencedError`
    once the writer has been fenced or the log sealed."""

    log: ExtentLog
    writer_id: int
    epoch: int
    lease_interval: float = 5.0

    def reserve(self, size: int) -> Reservation:
        return self.log.reserve(self.writer_id, self.epoch, size)

    def commit(self, rid: int) -> None:
        self.log.commit(self.writer_id, self.epoch, rid)

    def release(self, rid: int) -> None:
        self.log.release(self.writer_id, self.epoch, rid)

    def heartbeat(self) -> None:
        self.log.heartbeat(self.writer_id, self.epoch)

    def done(self) -> None:
        self.log.done(self.writer_id, self.epoch)
