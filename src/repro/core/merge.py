"""The paper's comparison baselines: file merging (hadd) and TBufferMerger.

Both exploit cluster relocatability: the **raw fast path** never
recompresses — sealed cluster bytes are copied verbatim and only the
metadata (entry ranges, page locators) is rebuilt, exactly like ROOT's
fast hadd path.  When the caller asks for a *different* codec than an
input file carries, that input takes the **re-encode slow path** instead:
it streams through the read engine's prefetching cluster iterator and is
refilled through the normal write path (hadd's slow mode).
"""

from __future__ import annotations

import threading
from typing import List, Optional, Sequence

from .bufpool import Recyclable
from .container import MemorySink, close_all
from .encoding import offsets_to_sizes
from .metadata import ClusterMeta
from .reader import RNTJReader
from .schema import KIND_OFFSET, ColumnBatch, Schema
from .writer import ParallelWriter, SequentialWriter, WriteOptions, _WriterBase


def _copy_clusters(reader: RNTJReader, writer: _WriterBase) -> None:
    """Raw fast path: copy committed clusters byte-verbatim.

    The critical section per cluster is the same reserve+metadata protocol
    as parallel writing — relocatability makes this a pure byte copy, no
    decompression and no re-encoding.  The bytes go out through the
    writer's I/O engine, so merges inherit striping and write-behind from
    the output's ``WriteOptions`` for free (framed-member side-car records
    ride along on the rebased descriptors).  With a writer buffer pool
    the copy buffer is pooled too — ``pread_into`` a recycled buffer,
    returned by the engine when the cluster's write lands — so the merge
    path performs no per-cluster allocation in steady state.
    """
    pool = writer._bufpool
    for idx, cm in enumerate(reader.clusters):
        owner = None
        if cm.byte_size:
            if pool is not None:
                blob = pool.take_view(cm.byte_size)
                reader.sink.pread_into(cm.byte_offset, blob)
                owner = Recyclable([blob.obj])
            else:
                blob = reader.sink.pread(cm.byte_offset, cm.byte_size)
            base = cm.byte_offset
        else:
            # unbuffered-mode source: pages are scattered; gather them.
            pages = sorted(cm.pages, key=lambda p: p.offset)
            descs = []
            pos = 0
            for p in pages:
                q = p.rebase(-p.offset)  # zero-base
                q.offset = pos
                pos += p.size
                descs.append(q)
            if pool is not None:
                blob = pool.take_view(pos)
                for p, q in zip(pages, descs):
                    reader.sink.pread_into(
                        p.offset, blob[q.offset : q.offset + p.size]
                    )
                owner = Recyclable([blob.obj])
            else:
                blob = b"".join(
                    reader.sink.pread(p.offset, p.size) for p in pages
                )
            cm = ClusterMeta(cm.first_entry, cm.n_entries, cm.n_elements, descs, 0, len(blob))
            base = 0
        # reserve + metadata + envelope/journal framing + submit: the same
        # critical section every direct commit uses, so merged outputs are
        # crash-recoverable exactly like directly written ones.  Zone maps
        # travel verbatim: their entry indices are cluster-relative, so a
        # byte-verbatim cluster copy keeps them valid without a rebase.
        writer._commit_raw_cluster(blob, cm.n_entries, cm.n_elements,
                                   cm.pages, base, owner=owner,
                                   zonemaps=reader.zonemaps[idx])


def _reencode_clusters(reader: RNTJReader, writer: ParallelWriter) -> None:
    """Slow path: decode through the read engine, refill through the
    write path — used when the output codec differs from the input's.

    Streams via the prefetching cluster iterator, so the next cluster's
    I/O + decode overlaps this cluster's re-compression.
    """
    ctx = writer.create_fill_context()
    try:
        for ci, cols in reader.iter_clusters():
            cm = reader.clusters[ci]
            data = {}
            for c in reader.schema.columns:
                arr = cols[c.index]
                # on-disk offsets are cluster-relative ends; the fill
                # path wants per-collection sizes back
                data[c.index] = (
                    offsets_to_sizes(arr) if c.kind == KIND_OFFSET else arr
                )
            ctx.fill_batch(ColumnBatch(reader.schema, cm.n_entries, data))
    finally:
        ctx.close()


def _needs_reencode(
    reader: RNTJReader,
    out: ParallelWriter,
    options: Optional[WriteOptions],
    recompress: Optional[bool],
) -> bool:
    if recompress is not None:
        return recompress
    # encodings are file-level state (e.g. a precondition=False source):
    # raw-copying clusters whose per-column encodings differ from what the
    # output header records would silently mis-decode, so such inputs
    # always re-encode
    if [c.encoding for c in reader.schema.columns] != out.column_encodings():
        return True
    if options is None:
        return False  # no target codec named: raw copy, never recompress
    src = reader.options.get("codec")
    return src is not None and int(src) != options.codec_id


def merge_files(
    inputs: Sequence[str],
    output,
    options: Optional[WriteOptions] = None,
    schema: Optional[Schema] = None,
    recompress: Optional[bool] = None,
) -> None:
    """``hadd`` analog: sequential post-processing merge of many files.

    The paper's Fig. 5 "separate files + merge" baseline: scalable writing
    but pays a read-back + rewrite and transiently doubles storage.

    Inputs whose on-disk codec matches the requested ``options.codec``
    (or all inputs, when ``options`` is None) take the raw byte-verbatim
    fast path; mismatching inputs are decoded and re-encoded with
    ``options``.  ``recompress`` overrides the auto choice: ``True``
    forces the re-encode path, ``False`` forces raw copy.
    """
    readers: List[RNTJReader] = []
    try:
        for p in inputs:  # opened one at a time: a failed open leaks nothing
            readers.append(RNTJReader(p))
        schema = schema or readers[0].schema
        for r in readers:
            if r.schema != schema:
                raise ValueError("cannot merge files with differing schemas")
        out = ParallelWriter(schema, output, options)
        try:
            for r in readers:
                if _needs_reencode(r, out, options, recompress):
                    _reencode_clusters(r, out)
                else:
                    _copy_clusters(r, out)
        finally:
            # surfaces a poisoned close on the success path; suppresses
            # it while another exception is already unwinding
            close_all([out])
    finally:
        close_all(readers)


class BufferMerger:
    """TBufferMerger analog (paper §2): per-producer in-memory files merged
    into one output from the worker threads themselves.

    Each producer gets a :class:`BufferMergerFile` — a complete sequential
    writer into a :class:`MemorySink`.  On ``commit()`` the worker takes the
    merger lock and copies its clusters into the shared output.  Matching
    the refined TBufferMerger design, there is no queue: workers block until
    they may merge.
    """

    def __init__(self, schema: Schema, output, options: Optional[WriteOptions] = None):
        self.schema = schema
        self.options = options or WriteOptions()
        self.out = ParallelWriter(schema, output, self.options)
        self._merge_lock = threading.Lock()

    def get_file(self) -> "BufferMergerFile":
        return BufferMergerFile(self)

    def close(self) -> None:
        self.out.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class BufferMergerFile:
    def __init__(self, merger: BufferMerger):
        self.merger = merger
        self._new_writer()

    def _new_writer(self) -> None:
        self.sink = MemorySink()
        self.writer = SequentialWriter(
            self.merger.schema, self.sink, self.merger.options
        )

    def fill(self, entry) -> None:
        self.writer.fill(entry)

    def fill_batch(self, batch) -> None:
        self.writer.fill_batch(batch)

    def commit(self) -> None:
        """Close the in-memory file and merge it into the shared output."""
        self.writer.close()
        reader = RNTJReader(self.sink)
        with self.merger._merge_lock:
            _copy_clusters(reader, self.merger.out)
        self._new_writer()

    def close(self) -> None:
        has_data = self.writer.n_entries > 0 or not self.writer._builder.is_empty
        if has_data:
            self.commit()
        self.writer.close()
