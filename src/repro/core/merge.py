"""The paper's comparison baselines: file merging (hadd) and TBufferMerger.

Both exploit cluster relocatability: merging never recompresses — sealed
cluster bytes are copied verbatim and only the metadata (entry ranges,
page locators) is rebuilt, exactly like ROOT's fast hadd path.
"""

from __future__ import annotations

import threading
from typing import List, Optional, Sequence

import numpy as np

from .container import MemorySink, Sink, open_sink
from .metadata import ClusterMeta
from .reader import RNTJReader
from .schema import Schema
from .writer import ParallelWriter, SequentialWriter, WriteOptions, _WriterBase


def _copy_clusters(reader: RNTJReader, writer: _WriterBase) -> None:
    """Copy committed clusters from ``reader`` into ``writer`` byte-verbatim.

    The critical section per cluster is the same reserve+metadata protocol
    as parallel writing — relocatability makes this a pure byte copy.
    """
    for idx, cm in enumerate(reader.clusters):
        if cm.byte_size:
            blob = reader.sink.pread(cm.byte_offset, cm.byte_size)
            base = cm.byte_offset
        else:
            # unbuffered-mode source: pages are scattered; gather them.
            parts, descs = [], []
            pos = 0
            for p in sorted(cm.pages, key=lambda p: p.offset):
                parts.append(reader.sink.pread(p.offset, p.size))
                q = p.rebase(-p.offset)  # zero-base
                q.offset = pos
                pos += p.size
                descs.append(q)
            blob = b"".join(parts)
            cm = ClusterMeta(cm.first_entry, cm.n_entries, cm.n_elements, descs, 0, len(blob))
            base = 0
        with writer.lock:
            off = writer.sink.reserve(len(blob))
            first_entry = writer._n_entries
            writer._n_entries += cm.n_entries
            writer._clusters.append(
                ClusterMeta(
                    first_entry=first_entry,
                    n_entries=cm.n_entries,
                    n_elements=list(cm.n_elements),
                    pages=[p.rebase(off - base) for p in cm.pages],
                    byte_offset=off,
                    byte_size=len(blob),
                )
            )
            writer.sink.pwrite(off, blob)
        writer.stats.clusters += 1
        writer.stats.entries += cm.n_entries
        writer.stats.pages += len(cm.pages)
        writer.stats.compressed_bytes += len(blob)


def merge_files(inputs: Sequence[str], output, options: Optional[WriteOptions] = None,
                schema: Optional[Schema] = None) -> None:
    """``hadd`` analog: sequential post-processing merge of many files.

    The paper's Fig. 5 "separate files + merge" baseline: scalable writing
    but pays a read-back + rewrite and transiently doubles storage.
    """
    readers = [RNTJReader(p) for p in inputs]
    schema = schema or readers[0].schema
    for r in readers:
        if r.schema != schema:
            raise ValueError("cannot merge files with differing schemas")
    out = ParallelWriter(schema, output, options)
    for r in readers:
        _copy_clusters(r, out)
        r.close()
    out.close()


class BufferMerger:
    """TBufferMerger analog (paper §2): per-producer in-memory files merged
    into one output from the worker threads themselves.

    Each producer gets a :class:`BufferMergerFile` — a complete sequential
    writer into a :class:`MemorySink`.  On ``commit()`` the worker takes the
    merger lock and copies its clusters into the shared output.  Matching
    the refined TBufferMerger design, there is no queue: workers block until
    they may merge.
    """

    def __init__(self, schema: Schema, output, options: Optional[WriteOptions] = None):
        self.schema = schema
        self.options = options or WriteOptions()
        self.out = ParallelWriter(schema, output, self.options)
        self._merge_lock = threading.Lock()

    def get_file(self) -> "BufferMergerFile":
        return BufferMergerFile(self)

    def close(self) -> None:
        self.out.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class BufferMergerFile:
    def __init__(self, merger: BufferMerger):
        self.merger = merger
        self._new_writer()

    def _new_writer(self) -> None:
        self.sink = MemorySink()
        self.writer = SequentialWriter(
            self.merger.schema, self.sink, self.merger.options
        )

    def fill(self, entry) -> None:
        self.writer.fill(entry)

    def fill_batch(self, batch) -> None:
        self.writer.fill_batch(batch)

    def commit(self) -> None:
        """Close the in-memory file and merge it into the shared output."""
        self.writer.close()
        reader = RNTJReader(self.sink)
        with self.merger._merge_lock:
            _copy_clusters(reader, self.merger.out)
        self._new_writer()

    def close(self) -> None:
        has_data = self.writer.n_entries > 0 or not self.writer._builder.is_empty
        if has_data:
            self.commit()
        self.writer.close()
