"""The sink-side I/O engine: scatter-gather, striped, write-behind commits.

The paper's evaluation (§5) scales the CPU side of parallel writing until
it is "only limited by storage bandwidth" — this module makes our commit
path actually behave that way (DESIGN.md §6).  Three cooperating levers,
each individually optional:

* **scatter-gather** — a sealed cluster's iovec plan goes to
  ``Sink.pwritev`` with no assembly memcpy (the plan comes from
  ``ClusterBuilder._gather``; this engine only chooses *how* to submit);
* **striping** — an extent larger than ``stripe_bytes`` splits into
  independent sub-extent jobs at computed offsets inside the reserved
  extent, executed concurrently on the engine pool, so ONE producer can
  keep a deep device queue busy the way chunked compression keeps the
  codec pool busy;
* **write-behind** — with ``inflight_bytes > 0`` a commit only *enqueues*
  its extent; producers seal cluster N+1..N+k while earlier extents
  drain.  ``admit()`` is the backpressure gate (called before the
  writer's critical section, so a stalled producer never holds the
  commit lock), errors poison the writer through ``on_error`` exactly
  like a synchronous failed ``pwrite``, and ``drain()`` is the
  drain-before-footer barrier ``close()`` runs.

The fsync policy rides here too: ``"on_close"`` (default; the writer's
close() fsyncs, as always), ``"every_cluster"`` (fsync when an extent's
last stripe lands), or an ``int`` byte interval (fsync each time that
many bytes have landed since the previous fsync).

With every lever off the engine degenerates to exactly the seed's
behavior: one synchronous ``pwrite``/``pwritev`` on the committing
thread.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, List, Optional, Tuple

_ns = time.perf_counter_ns

# pool size when striping / write-behind is enabled without an explicit
# WriteOptions.io_workers: enough concurrent submissions to keep an NVMe
# queue (or a sleeping ThrottledSink window) busy without thread bloat
DEFAULT_IO_WORKERS = 4

FSYNC_ON_CLOSE = "on_close"
FSYNC_EVERY_CLUSTER = "every_cluster"


class _ExtentGroup:
    """One logical extent (a cluster or page) split into 1..n stripe jobs."""

    __slots__ = ("remaining", "nbytes", "owner")

    def __init__(self, remaining: int, nbytes: int, owner):
        self.remaining = remaining
        self.nbytes = nbytes
        # the SealedCluster (or any object) whose buffers back the iovecs:
        # referenced until the last stripe lands, then released
        self.owner = owner


class IOEngine:
    """Positioned-write executor for one writer's sink.

    ``write_extent(off, parts, nbytes)`` is the single entry point used by
    every commit path (buffered clusters, unbuffered pages, merge's raw
    cluster copies).  Synchronous mode writes on the calling thread
    (striped over the pool when configured) and returns the measured
    io_ns; write-behind mode enqueues and returns 0 — the workers add
    their io time to ``stats`` directly and report drained bytes through
    ``on_drain`` (the rate-aware codec policy's bandwidth signal).
    """

    def __init__(
        self,
        sink,
        workers: int = 0,
        inflight_bytes: int = 0,
        stripe_bytes: int = 0,
        fsync_policy=FSYNC_ON_CLOSE,
        stats=None,
        on_error: Optional[Callable] = None,
        on_drain: Optional[Callable] = None,
    ):
        self.sink = sink
        self.stripe_bytes = int(stripe_bytes)
        self.inflight_bytes = int(inflight_bytes)
        self.stats = stats
        self._on_error = on_error
        self._on_drain = on_drain
        if not workers and (self.stripe_bytes > 0 or self.inflight_bytes > 0):
            workers = DEFAULT_IO_WORKERS
        self._pool = (
            ThreadPoolExecutor(max_workers=workers, thread_name_prefix="rntj-io")
            if workers
            else None
        )
        self._cv = threading.Condition()
        self._inflight = 0      # admitted write-behind bytes not yet drained
        self._pending = 0       # queued/running async jobs
        self._error: Optional[BaseException] = None
        # busy-window drain accounting for on_drain: concurrent jobs must
        # not each report their own wall time (that would under-report the
        # sink's bandwidth by the concurrency factor) — instead bytes
        # accumulate and are reported over the union busy window whenever
        # the last running job finishes
        self._running = 0
        self._busy_start = 0
        self._drained_bytes = 0
        # fsync policy state
        self._fsync_every = fsync_policy == FSYNC_EVERY_CLUSTER
        self._fsync_interval = (
            int(fsync_policy) if isinstance(fsync_policy, int) else 0
        )
        self._since_fsync = 0
        self._fsync_lock = threading.Lock()

    # -- mode ----------------------------------------------------------------

    @property
    def async_mode(self) -> bool:
        """True when commits are queued (write-behind) instead of written
        on the committing thread."""
        return self.inflight_bytes > 0 and self._pool is not None

    # -- backpressure ---------------------------------------------------------

    def admit(self, nbytes: int) -> None:
        """Block until ``nbytes`` fits in the in-flight budget.

        Called by producers BEFORE the writer's critical section: a
        producer stalled on storage must never stall the other producers'
        commits.  An extent larger than the whole budget is admitted alone
        (the engine never deadlocks on one oversized cluster).  No-op in
        synchronous mode.
        """
        if not self.async_mode:
            return
        t0 = _ns()
        with self._cv:
            while self._inflight and self._inflight + nbytes > self.inflight_bytes:
                self._cv.wait()
            self._inflight += nbytes
        stall = _ns() - t0
        if self.stats is not None and stall:
            self.stats.add_io_stall_ns(stall)

    def _release(self, nbytes: int) -> None:
        with self._cv:
            self._inflight -= nbytes
            self._cv.notify_all()

    # -- submission -----------------------------------------------------------

    def write_extent(self, off: int, parts: List, nbytes: int,
                     owner=None) -> int:
        """Write ``parts`` contiguously at ``off`` — inline, striped, or
        queued.  Returns the io_ns spent on THIS thread (0 when queued).

        The caller has already ``admit()``ed ``nbytes`` in write-behind
        mode and already reserved the extent; stripes never overlap, so
        no ordering between jobs is needed.  A failed write calls
        ``on_error`` (the writer's commit-poison hook) — synchronous
        failures also raise, exactly like the direct ``pwrite`` they
        replace.
        """
        stripes = self._stripes(off, parts, nbytes)
        if not self.async_mode:
            t0 = _ns()
            try:
                if len(stripes) == 1 or self._pool is None:
                    for s_off, s_parts, _n in stripes:
                        self._pwritev(s_off, s_parts)
                else:
                    futs = [
                        self._pool.submit(self._pwritev, s_off, s_parts)
                        for s_off, s_parts, _n in stripes
                    ]
                    for f in futs:
                        f.result()
            except BaseException as e:
                self._fail(e)
                raise
            io_ns = _ns() - t0
            self._extent_done(nbytes)
            if self._on_drain is not None:
                self._on_drain(nbytes, io_ns)
            return io_ns
        # write-behind: enqueue one job per stripe
        if self._error is not None:
            # the writer is poisoned: drop the bytes (finalization will
            # refuse anyway) but keep the budget accounting balanced
            self._release(nbytes)
            return 0
        group = _ExtentGroup(len(stripes), nbytes, owner)
        with self._cv:
            self._pending += len(stripes)
            depth = self._pending
        if self.stats is not None:
            for _ in stripes:
                self.stats.note_io_job(depth, self._inflight)
        for s_off, s_parts, s_n in stripes:
            self._pool.submit(self._run_job, group, s_off, s_parts, s_n)
        return 0

    def _stripes(self, off: int, parts: List, nbytes: int
                 ) -> List[Tuple[int, List, int]]:
        """Split an extent's iovec plan into ``[(offset, parts, nbytes)]``
        stripe sub-extents of at most ``stripe_bytes`` each."""
        if (
            self.stripe_bytes <= 0
            or nbytes <= self.stripe_bytes
            or self._pool is None
        ):
            return [(off, list(parts), nbytes)]
        out: List[Tuple[int, List, int]] = []
        cur: List = []
        cur_n = 0
        cur_off = off
        for part in parts:
            mv = memoryview(part)
            pos = 0
            while pos < len(mv):
                take = min(len(mv) - pos, self.stripe_bytes - cur_n)
                cur.append(mv[pos : pos + take])
                cur_n += take
                pos += take
                if cur_n == self.stripe_bytes:
                    out.append((cur_off, cur, cur_n))
                    cur_off += cur_n
                    cur, cur_n = [], 0
        if cur:
            out.append((cur_off, cur, cur_n))
        return out

    def _pwritev(self, off: int, parts: List) -> None:
        if len(parts) == 1:
            self.sink.pwrite(off, parts[0])
        else:
            self.sink.pwritev(off, parts)

    def _run_job(self, group: _ExtentGroup, off: int, parts: List,
                 nbytes: int) -> None:
        t0 = _ns()
        with self._cv:
            if self._running == 0:
                self._busy_start = t0
            self._running += 1
        try:
            if self._error is None:
                self._pwritev(off, parts)
        except BaseException as e:
            self._fail(e)
        finally:
            io_ns = _ns() - t0
            if self.stats is not None:
                self.stats.add_io_ns(io_ns)
            last = False
            drained = None
            with self._cv:
                self._running -= 1
                self._drained_bytes += nbytes
                if self._running == 0:
                    # window closed: report accumulated bytes over the
                    # union busy time — the sink's actual drain bandwidth
                    drained = (self._drained_bytes, _ns() - self._busy_start)
                    self._drained_bytes = 0
                self._pending -= 1
                self._inflight -= nbytes
                group.remaining -= 1
                last = group.remaining == 0
                self._cv.notify_all()
            if drained is not None and self._on_drain is not None:
                self._on_drain(*drained)
            if last:
                group.owner = None  # release the sealed cluster's buffers
                if self._error is None:
                    try:
                        self._extent_done(group.nbytes)
                    except BaseException as e:
                        self._fail(e)

    def _fail(self, e: BaseException) -> None:
        with self._cv:
            if self._error is None:
                self._error = e
            self._cv.notify_all()
        if self._on_error is not None:
            self._on_error(e)

    # -- fsync policy ---------------------------------------------------------

    def _extent_done(self, nbytes: int) -> None:
        """Apply the every-cluster / byte-interval fsync policy after an
        extent's bytes have fully landed."""
        if self._fsync_every:
            self.sink.fsync()
        elif self._fsync_interval:
            due = False
            with self._fsync_lock:
                self._since_fsync += nbytes
                if self._since_fsync >= self._fsync_interval:
                    self._since_fsync = 0
                    due = True
            if due:
                self.sink.fsync()

    # -- drain / shutdown ------------------------------------------------------

    @property
    def error(self) -> Optional[BaseException]:
        return self._error

    def drain(self) -> None:
        """Block until every queued write job has finished (successfully
        or not).  The drain-before-footer barrier: any failure is already
        latched in the writer via ``on_error``; this never raises."""
        with self._cv:
            while self._pending:
                self._cv.wait()

    def close(self) -> None:
        self.drain()
        if self._pool is not None:
            self._pool.shutdown(wait=True)
