"""The sink-side I/O engine: scatter-gather, striped, async-ring commits.

The paper's evaluation (§5) scales the CPU side of parallel writing until
it is "only limited by storage bandwidth" — this module makes our commit
path actually behave that way (DESIGN.md §6).  Four cooperating levers,
each individually optional:

* **scatter-gather** — a sealed cluster's iovec plan goes to
  ``Sink.pwritev`` with no assembly memcpy (the plan comes from
  ``ClusterBuilder._gather``; this engine only chooses *how* to submit);
* **striping** — an extent larger than ``stripe_bytes`` splits into
  independent sub-extent jobs at computed offsets inside the reserved
  extent, executed concurrently, so ONE producer can keep a deep device
  queue busy the way chunked compression keeps the codec pool busy;
* **write-behind** — with ``inflight_bytes > 0`` a commit only *enqueues*
  its extent; producers seal cluster N+1..N+k while earlier extents
  drain.  ``admit()`` is the backpressure gate (called before the
  writer's critical section, so a stalled producer never holds the
  commit lock), errors poison the writer through ``on_error`` exactly
  like a synchronous failed ``pwrite``, and ``drain()`` is the
  drain-before-footer barrier ``close()`` runs;
* **ring submission** (DESIGN.md §6.7) — queued extents go onto a
  **submission ring** instead of one executor future per stripe: an
  io_uring ring through a thin ctypes/liburing binding when the library
  loads and the sink is a real fd (``AsyncFileSink``), otherwise a
  completion-thread + ``pwritev`` emulation whose observable behavior —
  ``io_inflight_bytes`` accounting, poisoning, drain-before-footer
  ordering, byte output — is identical on every platform.  A producer's
  submit cost drops to one deque append + notify (``io_submit_ns``
  counts it), and completions fold back through the same accounting as
  the executor path.

The engine also closes the commit path's last allocation: with a
:class:`~repro.core.bufpool.BufferPool` attached, an extent owner's
detached scatter buffers are **returned to the pool when the extent's
last write lands** — never earlier, because a queued commit's iovecs
alias them until then (DESIGN.md §6.8).

The fsync policy rides here too: ``"on_close"`` (default; the writer's
close() fsyncs, as always), ``"every_cluster"`` (fsync when an extent's
last stripe lands), or an ``int`` byte interval (fsync each time that
many bytes have landed since the previous fsync).

With every lever off the engine degenerates to exactly the seed's
behavior: one synchronous ``pwrite``/``pwritev`` on the committing
thread.
"""

from __future__ import annotations

import ctypes
import ctypes.util
import errno
import os
import random
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

import numpy as _np

_ns = time.perf_counter_ns

# pool size when striping / write-behind is enabled without an explicit
# WriteOptions.io_workers: enough concurrent submissions to keep an NVMe
# queue (or a sleeping ThrottledSink window) busy without thread bloat
DEFAULT_IO_WORKERS = 4

FSYNC_ON_CLOSE = "on_close"
FSYNC_EVERY_CLUSTER = "every_cluster"

RING_AUTO = "auto"
RING_EMULATED = "emulated"
RING_URING = "uring"
RING_OFF = "off"


# ---------------------------------------------------------------------------
# retry policy (DESIGN.md §8.2)

#: errnos worth retrying: transient device/medium hiccups and interruptions.
#: ENOSPC is included deliberately — on shared/quota'd storage it is often
#: transient (another writer freeing space, quota refresh); a genuinely full
#: disk just exhausts the attempts and poisons like any permanent error.
DEFAULT_RETRYABLE_ERRNOS = (
    errno.EIO, errno.EAGAIN, errno.ENOSPC, errno.EINTR, errno.ETIMEDOUT,
    errno.EBUSY,
)


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded-retry policy applied by the I/O engine to every write and
    fsync (DESIGN.md §8.2).

    A failed operation whose errno is in ``retryable_errnos`` is retried
    up to ``max_attempts`` total attempts with exponential backoff
    (``backoff_base * 2**k`` seconds, capped at ``backoff_cap``, with
    ±50% deterministic jitter when ``jitter``).  ``deadline`` bounds one
    logical operation's total retry time in seconds (0 = unbounded).
    Positioned writes are idempotent — a retry rewrites the same extent
    bytes at the same offsets — so retrying after a *partial* (torn)
    write is always safe.  Non-``OSError`` failures (including the fault
    harness's :class:`~repro.core.faults.ProcessKilled`) are never
    retried.  Only an exhausted retry budget poisons the writer.
    """

    max_attempts: int = 4
    backoff_base: float = 0.002
    backoff_cap: float = 0.25
    jitter: bool = True
    retryable_errnos: Tuple[int, ...] = DEFAULT_RETRYABLE_ERRNOS
    deadline: float = 0.0

    def retryable(self, exc: BaseException) -> bool:
        return isinstance(exc, OSError) and exc.errno in self.retryable_errnos

    def backoff(self, attempt: int, rng=None) -> float:
        """Sleep before retry number ``attempt`` (1-based)."""
        delay = min(self.backoff_cap, self.backoff_base * (2 ** (attempt - 1)))
        if self.jitter and rng is not None:
            delay *= 0.5 + rng.random()
        return delay


class Retrier:
    """The retry chokepoint as a standalone object: run any callable under
    a :class:`RetryPolicy`.

    Originally the loop lived inside :meth:`IOEngine._retrying` and covered
    only engine-issued writes and fsyncs; it is factored out here so the
    *read* paths — :class:`~repro.core.reader.RNTJReader` preads and the
    remote :class:`~repro.core.remote.ObjectStoreSink` transport ops —
    apply the identical semantics: retry ``retryable_errnos`` up to
    ``max_attempts`` with exponential backoff + deterministic jitter,
    honor the policy's per-logical-op ``deadline``, re-raise everything
    else (non-``OSError`` failures such as
    :class:`~repro.core.faults.ProcessKilled` are never retried).

    Thread-safe: the jitter RNG is seeded (same backoff schedule every
    run) and guarded by a lock; ``on_retry``/``on_giveup`` fire once per
    retried / abandoned operation so callers can wire their own counters
    (sink IOStats, ReaderStats, engine mirrors).
    """

    def __init__(self, policy: Optional[RetryPolicy],
                 seed: int = 0x52455452,
                 on_retry: Optional[Callable] = None,
                 on_giveup: Optional[Callable] = None) -> None:
        self.policy = policy
        self._rng = random.Random(seed)
        self._mu = threading.Lock()
        self._on_retry = on_retry
        self._on_giveup = on_giveup

    def call(self, fn, *args):
        """``fn(*args)`` under the policy; a plain call when policy is
        ``None``."""
        policy = self.policy
        if policy is None:
            return fn(*args)
        deadline = (
            time.monotonic() + policy.deadline if policy.deadline else None
        )
        attempt = 0
        while True:
            try:
                return fn(*args)
            except OSError as e:
                attempt += 1
                if not policy.retryable(e):
                    raise
                if attempt >= policy.max_attempts or (
                        deadline is not None
                        and time.monotonic() >= deadline):
                    if self._on_giveup is not None:
                        self._on_giveup()
                    raise
                if self._on_retry is not None:
                    self._on_retry()
                with self._mu:
                    delay = policy.backoff(attempt, self._rng)
                if deadline is not None:
                    delay = min(delay, max(0.0, deadline - time.monotonic()))
                if delay > 0:
                    time.sleep(delay)


class _ExtentGroup:
    """One logical extent (a cluster or page) split into 1..n stripe jobs."""

    __slots__ = ("remaining", "nbytes", "owner", "striped")

    def __init__(self, remaining: int, nbytes: int, owner,
                 striped: bool = False):
        self.remaining = remaining
        self.nbytes = nbytes
        # the SealedCluster (or any object) whose buffers back the iovecs:
        # referenced until the last stripe lands, then recycled + released
        self.owner = owner
        self.striped = striped


# ---------------------------------------------------------------------------
# submission rings


class _RingOp:
    __slots__ = ("group", "off", "parts", "nbytes")

    def __init__(self, group, off, parts, nbytes):
        self.group = group
        self.off = off
        self.parts = parts
        self.nbytes = nbytes


class EmulatedRing:
    """Completion-thread + ``pwritev`` emulation of the submission ring.

    Producers append ops under one condition variable (a deque append —
    no future allocation, no executor work-queue churn); ``workers``
    completion threads pop small batches and execute them through the
    engine's normal job body, so accounting, poisoning and drain
    semantics are *identical* to the io_uring backend and to the
    executor path it replaces.
    """

    # ops claimed per lock acquisition: amortizes wakeups without letting
    # one thread hoard the queue
    BATCH = 8

    def __init__(self, engine: "IOEngine", workers: int):
        self._engine = engine
        self._cv = threading.Condition()
        self._ops: deque = deque()
        self._stop = False
        self._workers = max(1, workers)
        # completion threads start lazily at the first submit, so a
        # writer that never enters write-behind (or a skim spawning many
        # writers) pays no idle threads — matching the executor path
        self._threads: List[threading.Thread] = []

    def _ensure_threads(self) -> None:
        if self._threads:
            return
        self._threads = [
            threading.Thread(
                target=self._loop, daemon=True, name=f"rntj-ring-{i}"
            )
            for i in range(self._workers)
        ]
        for t in self._threads:
            t.start()

    def submit(self, group, off, parts, nbytes) -> None:
        with self._cv:
            self._ensure_threads()
            self._ops.append(_RingOp(group, off, parts, nbytes))
            self._cv.notify()

    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._ops and not self._stop:
                    self._cv.wait()
                if not self._ops:
                    return  # stopping and drained
                # claim a share of the queue, not the whole head: with
                # fewer ops than workers each claims one (a high-latency
                # sink keeps every worker busy); only a queue deeper than
                # the worker pool amortizes wakeups with bigger batches
                share = max(1, len(self._ops) // self._workers)
                batch = [
                    self._ops.popleft()
                    for _ in range(min(len(self._ops), self.BATCH, share))
                ]
            for op in batch:
                self._engine._run_job(op.group, op.off, op.parts, op.nbytes)

    def close(self) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        for t in self._threads:
            t.join()


# -- io_uring (thin ctypes/liburing binding) --------------------------------
#
# Only engaged when (a) liburing.so loads, (b) the sink advertises a raw
# fd with no pwrite override (AsyncFileSink), and (c) REPRO_IO_URING is
# not "0".  The emulated ring above is the behavioral reference; this
# backend must be observationally identical (same bytes, same poisoning,
# same drain ordering) — it only changes *how* queued writes reach the
# kernel: batched SQE submission, no thread per write.

IORING_OP_WRITEV = 2
_URING_DEPTH = 256


class _IoUringSqe(ctypes.Structure):  # kernel UAPI, 64 bytes, stable
    _fields_ = [
        ("opcode", ctypes.c_uint8), ("flags", ctypes.c_uint8),
        ("ioprio", ctypes.c_uint16), ("fd", ctypes.c_int32),
        ("off", ctypes.c_uint64), ("addr", ctypes.c_uint64),
        ("len", ctypes.c_uint32), ("rw_flags", ctypes.c_uint32),
        ("user_data", ctypes.c_uint64), ("buf_index", ctypes.c_uint16),
        ("personality", ctypes.c_uint16), ("splice_fd_in", ctypes.c_int32),
        ("pad2", ctypes.c_uint64 * 2),
    ]


class _IoUringCqe(ctypes.Structure):  # kernel UAPI, stable
    _fields_ = [
        ("user_data", ctypes.c_uint64), ("res", ctypes.c_int32),
        ("flags", ctypes.c_uint32),
    ]


class _IoUringSq(ctypes.Structure):  # liburing 2.x ABI
    _fields_ = [
        ("khead", ctypes.POINTER(ctypes.c_uint)),
        ("ktail", ctypes.POINTER(ctypes.c_uint)),
        ("kring_mask", ctypes.POINTER(ctypes.c_uint)),
        ("kring_entries", ctypes.POINTER(ctypes.c_uint)),
        ("kflags", ctypes.POINTER(ctypes.c_uint)),
        ("kdropped", ctypes.POINTER(ctypes.c_uint)),
        ("array", ctypes.POINTER(ctypes.c_uint)),
        ("sqes", ctypes.POINTER(_IoUringSqe)),
        ("sqe_head", ctypes.c_uint), ("sqe_tail", ctypes.c_uint),
        ("ring_sz", ctypes.c_size_t), ("ring_ptr", ctypes.c_void_p),
        ("pad", ctypes.c_uint * 4),
    ]


class _IoUringCq(ctypes.Structure):  # liburing 2.x ABI
    _fields_ = [
        ("khead", ctypes.POINTER(ctypes.c_uint)),
        ("ktail", ctypes.POINTER(ctypes.c_uint)),
        ("kring_mask", ctypes.POINTER(ctypes.c_uint)),
        ("kring_entries", ctypes.POINTER(ctypes.c_uint)),
        ("kflags", ctypes.POINTER(ctypes.c_uint)),
        ("koverflow", ctypes.POINTER(ctypes.c_uint)),
        ("cqes", ctypes.POINTER(_IoUringCqe)),
        ("ring_sz", ctypes.c_size_t), ("ring_ptr", ctypes.c_void_p),
        ("pad", ctypes.c_uint * 4),
    ]


class _IoUring(ctypes.Structure):
    _fields_ = [
        ("sq", _IoUringSq), ("cq", _IoUringCq),
        ("flags", ctypes.c_uint), ("ring_fd", ctypes.c_int),
        ("features", ctypes.c_uint), ("enter_ring_fd", ctypes.c_int),
        ("int_flags", ctypes.c_uint8), ("pad", ctypes.c_uint8 * 3),
        ("pad2", ctypes.c_uint),
    ]


class _Iovec(ctypes.Structure):
    _fields_ = [("iov_base", ctypes.c_void_p), ("iov_len", ctypes.c_size_t)]


_liburing = None          # loaded library handle; False once ruled out
_liburing_lock = threading.Lock()


def load_liburing():
    """The liburing handle, or ``None`` when unavailable.

    ``REPRO_IO_URING=0`` disables loading outright (the emulated ring is
    then the only async backend); any load/symbol failure also resolves
    to ``None`` — callers fall back, they never crash.
    """
    global _liburing
    with _liburing_lock:
        if _liburing is None:
            if os.environ.get("REPRO_IO_URING", "").strip() == "0":
                _liburing = False
            else:
                _liburing = _try_load_liburing() or False
    return _liburing or None


def _try_load_liburing():
    name = ctypes.util.find_library("uring")
    candidates = [name] if name else []
    candidates += ["liburing.so.2", "liburing.so.1", "liburing.so"]
    for cand in candidates:
        if not cand:
            continue
        try:
            lib = ctypes.CDLL(cand, use_errno=True)
            for sym in ("io_uring_queue_init", "io_uring_get_sqe",
                        "io_uring_submit", "__io_uring_get_cqe",
                        "io_uring_queue_exit"):
                getattr(lib, sym)
            lib.io_uring_queue_init.restype = ctypes.c_int
            lib.io_uring_submit.restype = ctypes.c_int
            lib.io_uring_get_sqe.restype = ctypes.POINTER(_IoUringSqe)
            lib.__io_uring_get_cqe.restype = ctypes.c_int
            return lib
        except (OSError, AttributeError):
            continue
    return None


class UringRing:
    """io_uring submission ring over a raw file descriptor.

    One event-loop thread both flushes queued ops as batched SQEs (one
    ``io_uring_submit`` syscall for a whole burst — the submission cost
    the executor path paid per stripe) and reaps CQEs, folding each
    completion back through the engine's normal accounting.  Buffers and
    iovec arrays are pinned in ``_live`` from submit to completion.
    """

    def __init__(self, engine: "IOEngine", fd: int, lib=None,
                 depth: int = _URING_DEPTH):
        self._engine = engine
        self._fd = fd
        self._lib = lib or load_liburing()
        if self._lib is None:
            raise ValueError(
                "io_uring requested but liburing is not loadable on this "
                "platform (and REPRO_IO_URING may disable it); use the "
                "emulated ring instead"
            )
        self._ring = _IoUring()
        rc = self._lib.io_uring_queue_init(
            ctypes.c_uint(depth), ctypes.byref(self._ring), ctypes.c_uint(0)
        )
        if rc < 0:
            raise OSError(-rc, "io_uring_queue_init failed")
        self._cv = threading.Condition()
        self._ops: deque = deque()
        self._stop = False
        self._live = {}  # user_data -> (op, iovec array, pinned parts, t0)
        self._next_id = 1
        self._degraded = False  # submission broke: run ops synchronously
        self._seen_fence = threading.Lock()  # memory fence for CQ-head store
        self._thread = threading.Thread(
            target=self._loop, daemon=True, name="rntj-uring"
        )
        self._thread.start()

    def submit(self, group, off, parts, nbytes) -> None:
        with self._cv:
            self._ops.append(_RingOp(group, off, parts, nbytes))
            self._cv.notify()

    # -- event loop ----------------------------------------------------------

    def _prep(self, op: _RingOp) -> bool:
        sqe = self._lib.io_uring_get_sqe(ctypes.byref(self._ring))
        if not sqe:
            return False  # SQ full: flush + reap first
        parts = [memoryview(p) for p in op.parts if len(p)]
        iov = (_Iovec * max(1, len(parts)))()
        # read-only views reject ctypes.from_buffer; a zero-copy numpy
        # wrap exposes the address either way, and pinning the wrapper
        # (plus the view it holds) keeps the bytes alive until the CQE
        pinned = []
        for i, mv in enumerate(parts):
            arr = _np.frombuffer(mv, dtype=_np.uint8)
            iov[i].iov_base = arr.ctypes.data
            iov[i].iov_len = arr.nbytes
            pinned.append(arr)
        uid = self._next_id
        self._next_id += 1
        s = sqe.contents
        s.opcode = IORING_OP_WRITEV
        s.flags = 0
        s.ioprio = 0
        s.fd = self._fd
        s.off = op.off
        s.addr = ctypes.cast(iov, ctypes.c_void_p).value or 0
        s.len = len(parts)
        s.rw_flags = 0
        s.user_data = uid
        s.buf_index = 0
        s.personality = 0
        s.splice_fd_in = 0
        self._live[uid] = (op, iov, pinned, self._engine._job_begin())
        return True

    def _reap(self, wait: bool) -> int:
        cqe_pp = ctypes.POINTER(_IoUringCqe)()
        rc = self._lib.__io_uring_get_cqe(
            ctypes.byref(self._ring), ctypes.byref(cqe_pp),
            ctypes.c_uint(0), ctypes.c_uint(1 if wait else 0), None,
        )
        if rc < 0 or not cqe_pp:
            return 0
        cqe = cqe_pp.contents
        uid, res = cqe.user_data, cqe.res
        # mark seen: advance the CQ head.  The store must not become
        # visible before the field loads above (liburing uses a release
        # store); pure ctypes has no atomics, so acquire/release a lock —
        # a full fence on CPython — between the loads and the store.
        with self._seen_fence:
            self._ring.cq.khead.contents.value = (
                self._ring.cq.khead.contents.value + 1
            )
        entry = self._live.pop(uid, None)
        if entry is None:
            return 1
        op, _iov, _pinned, t0 = entry
        err = None
        if res > 0:
            # the kernel wrote past the Sink API: account what landed so
            # IOStats stays truthful on the native ring path too (a
            # partial write's resumed tail is counted by sink.pwrite)
            self._engine.sink._count_writev(1, res)
        if res < 0:
            err = OSError(-res, os.strerror(-res))
            if self._engine.retry is not None and self._engine.retry.retryable(err):
                # a retryable CQE error re-enters the engine's retrying
                # write path synchronously (positioned rewrite: idempotent)
                self._engine._count_retry()
                try:
                    self._engine._pwritev(op.off, op.parts)
                    err = None
                except BaseException as e:  # noqa: BLE001
                    err = e
        elif res != op.nbytes:
            # a partial vectored write: finish it synchronously through
            # the engine (correctness first; partials are rare here)
            try:
                self._engine._pwritev_resume(op.off, op.parts, res)
            except BaseException as e:  # noqa: BLE001
                err = e
        self._engine._job_end(op.group, op.nbytes, t0, err)
        return 1

    def _submit_prepared(self) -> None:
        """Flush prepared SQEs to the kernel.  On failure (the SQEs never
        reached — or will never leave — the kernel, so no CQE will ever
        arrive; silently dropping them would hang ``drain()`` forever)
        the ring *degrades* instead of failing every in-flight extent:
        :meth:`_fallback_execute` writes them out synchronously."""
        rc = self._lib.io_uring_submit(ctypes.byref(self._ring))
        if rc < 0:
            self._fallback_execute(OSError(-rc, os.strerror(-rc)))

    def _fallback_execute(self, err: OSError) -> None:
        """Ring submission broke (DESIGN.md §8.2): execute every op still
        in ``_live`` synchronously through the engine's retrying
        ``_pwritev`` and fold the completions through ``_job_end``, then
        stay degraded — future ops run the same way on this thread, like
        a one-worker emulated ring.  Ops already submitted in an earlier
        successful batch may still complete via CQE; a rewrite of the
        same extent bytes is idempotent, and ``_reap`` ignores CQEs whose
        op has already been folded."""
        self._degraded = True
        self._engine._note_ring_fallback(err)
        for uid in list(self._live):
            op, _iov, _pinned, t0 = self._live.pop(uid)
            op_err = None
            try:
                self._engine._pwritev(op.off, op.parts)
            except BaseException as e:  # noqa: BLE001
                op_err = e
            self._engine._job_end(op.group, op.nbytes, t0, op_err)

    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._ops and not self._stop and not self._live:
                    self._cv.wait()
                if self._stop and not self._ops and not self._live:
                    return
                batch = list(self._ops)
                self._ops.clear()
            for op in batch:
                if self._degraded:
                    self._engine._run_job(op.group, op.off, op.parts,
                                          op.nbytes)
                    continue
                prepped = self._prep(op)
                while not prepped and not self._degraded:
                    # SQ full: flush prepared SQEs, then reap for room
                    self._submit_prepared()
                    if self._degraded:
                        break
                    self._reap(wait=True)
                    prepped = self._prep(op)
                if not prepped and self._degraded:
                    # never made it into _live: run it directly
                    self._engine._run_job(op.group, op.off, op.parts,
                                          op.nbytes)
            if batch and not self._degraded:
                self._submit_prepared()
            # reap whatever is ready; block only when nothing new can be
            # submitted and completions are still owed
            while self._reap(wait=False):
                pass
            if self._live:
                with self._cv:
                    if self._ops or self._stop:
                        continue
                self._reap(wait=True)

    def close(self) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        self._thread.join()
        self._lib.io_uring_queue_exit(ctypes.byref(self._ring))


def make_ring(engine: "IOEngine", mode, workers: int):
    """Resolve a ring ``mode`` to a backend (or ``None`` for the
    executor path): ``"uring"`` requires the liburing binding + a native
    async sink and raises otherwise; ``"auto"`` prefers io_uring when
    both are available and falls back to the emulation; ``"emulated"``
    forces the emulation; ``"off"``/falsy keeps the PR-4 executor
    submission."""
    if not mode or mode == RING_OFF:
        return None
    if mode is True:
        mode = RING_AUTO
    if mode not in (RING_AUTO, RING_EMULATED, RING_URING):
        raise ValueError(f"unknown io_ring mode {mode!r}")
    sink = engine.sink
    fd = getattr(sink, "fd", None)
    # only a sink that advertises native ring capability (AsyncFileSink:
    # a real fd AND no pwrite override — instrumentation/fault-injection
    # subclasses must keep seeing every byte) may bypass Sink.pwritev
    native = bool(getattr(sink, "native_ring", False)) and isinstance(fd, int)
    if mode == RING_URING:
        if not native:
            raise ValueError(
                "io_ring='uring' needs an AsyncFileSink (a real fd with no "
                "pwrite override)"
            )
        return UringRing(engine, fd)
    if mode == RING_AUTO and native and load_liburing() is not None:
        try:
            return UringRing(engine, fd)
        except (OSError, ValueError):
            pass  # kernel without io_uring etc.: fall through to emulation
    return EmulatedRing(engine, workers)


# ---------------------------------------------------------------------------
# the engine


class IOEngine:
    """Positioned-write executor for one writer's sink.

    ``write_extent(off, parts, nbytes)`` is the single entry point used by
    every commit path (buffered clusters, unbuffered pages, merge's raw
    cluster copies).  Synchronous mode writes on the calling thread
    (striped over the pool when configured) and returns the measured
    io_ns; write-behind mode enqueues — onto the submission ring when one
    is configured (``ring=``), else as executor jobs — and returns 0: the
    workers add their io time to ``stats`` directly and report drained
    bytes through ``on_drain`` (the rate-aware codec policy's bandwidth
    signal).  ``buffer_pool`` receives an extent owner's recyclable
    buffers when its last write lands.
    """

    def __init__(
        self,
        sink,
        workers: int = 0,
        inflight_bytes: int = 0,
        stripe_bytes: int = 0,
        fsync_policy=FSYNC_ON_CLOSE,
        stats=None,
        on_error: Optional[Callable] = None,
        on_drain: Optional[Callable] = None,
        ring=RING_OFF,
        buffer_pool=None,
        retry: Optional[RetryPolicy] = None,
    ):
        self.sink = sink
        self.stripe_bytes = int(stripe_bytes)
        self.inflight_bytes = int(inflight_bytes)
        self.stats = stats
        self.buffer_pool = buffer_pool
        self._on_error = on_error
        self._on_drain = on_drain
        # -- retry + degradation state (DESIGN.md §8.2) ---------------------
        self.retry = retry
        # seeded Retrier: fault-injection runs replay the same backoff
        # schedule; the counters mirror into the engine AND the sink
        self._retrier = Retrier(retry, on_retry=self._count_retry,
                                on_giveup=self._count_giveup)
        self._retry_mu = threading.Lock()
        self.retries = 0             # retried operations (mirror of IOStats)
        self.giveups = 0             # operations that exhausted the budget
        self.stripe_fallbacks = 0    # striping disabled after stripe failure
        self.ring_fallbacks = 0      # native ring degraded to synchronous
        self._stripe_disabled = False
        self._closed = False
        if not workers and (self.stripe_bytes > 0 or self.inflight_bytes > 0):
            workers = DEFAULT_IO_WORKERS
        self._workers = workers
        # the submission ring exists only in write-behind mode; when it
        # does, it owns all queued submissions and the executor would be
        # dead weight — create one or the other, never both
        self._ring = (
            make_ring(self, ring, workers) if self.inflight_bytes > 0 else None
        )
        self._pool = (
            ThreadPoolExecutor(max_workers=workers, thread_name_prefix="rntj-io")
            if workers and self._ring is None
            else None
        )
        self._cv = threading.Condition()
        self._inflight = 0      # admitted write-behind bytes not yet drained
        self._pending = 0       # queued/running async jobs
        self._error: Optional[BaseException] = None
        # busy-window drain accounting for on_drain: concurrent jobs must
        # not each report their own wall time (that would under-report the
        # sink's bandwidth by the concurrency factor) — instead bytes
        # accumulate and are reported over the union busy window whenever
        # the last running job finishes
        self._running = 0
        self._busy_start = 0
        self._drained_bytes = 0
        # fsync policy state
        self._fsync_every = fsync_policy == FSYNC_EVERY_CLUSTER
        self._fsync_interval = (
            int(fsync_policy) if isinstance(fsync_policy, int) else 0
        )
        self._since_fsync = 0
        self._fsync_lock = threading.Lock()

    # -- mode ----------------------------------------------------------------

    @property
    def async_mode(self) -> bool:
        """True when commits are queued (write-behind) instead of written
        on the committing thread."""
        return self.inflight_bytes > 0 and (
            self._pool is not None or self._ring is not None
        )

    @property
    def ring(self):
        """The active submission ring backend, or ``None`` (executor
        submission / synchronous mode)."""
        return self._ring

    # -- backpressure ---------------------------------------------------------

    def admit(self, nbytes: int) -> None:
        """Block until ``nbytes`` fits in the in-flight budget.

        Called by producers BEFORE the writer's critical section: a
        producer stalled on storage must never stall the other producers'
        commits.  An extent larger than the whole budget is admitted alone
        (the engine never deadlocks on one oversized cluster).  No-op in
        synchronous mode.
        """
        if not self.async_mode:
            return
        t0 = _ns()
        with self._cv:
            while self._inflight and self._inflight + nbytes > self.inflight_bytes:
                self._cv.wait()
            self._inflight += nbytes
        stall = _ns() - t0
        if self.stats is not None and stall:
            self.stats.add_io_stall_ns(stall)

    def _release(self, nbytes: int) -> None:
        with self._cv:
            self._inflight -= nbytes
            self._cv.notify_all()

    # -- retrying (DESIGN.md §8.2) --------------------------------------------

    def _count_retry(self) -> None:
        with self._retry_mu:
            self.retries += 1
        counter = getattr(self.sink, "_count_retry", None)
        if counter is not None:
            counter()

    def _count_giveup(self) -> None:
        with self._retry_mu:
            self.giveups += 1
        counter = getattr(self.sink, "_count_giveup", None)
        if counter is not None:
            counter()

    def _retrying(self, fn, *args):
        """Run ``fn(*args)`` under the engine's retry policy.  The single
        choke point every engine-issued write and fsync goes through:
        sync, striped, emulated-ring, and uring-resume paths all call it
        via :meth:`_pwritev`; CQE errors re-enter it via
        :meth:`_pwritev`.  The loop itself lives in :class:`Retrier` —
        shared with the reader's retrying preads and the remote sink's
        transport ops.  Without a policy it is a plain call."""
        return self._retrier.call(fn, *args)

    def _note_stripe_fallback(self) -> None:
        """A striped sub-extent failed even with retries: stop striping
        for the rest of this engine's life (the device is telling us it
        dislikes concurrent sub-extent writes) and count the event."""
        with self._retry_mu:
            self.stripe_fallbacks += 1
            self._stripe_disabled = True
        if self.stats is not None:
            self.stats.note_stripe_fallback()

    def _note_ring_fallback(self, err: BaseException) -> None:
        """The native submission ring can no longer submit: it degrades to
        executing ops synchronously on its own thread (same bytes, same
        accounting) rather than failing in-flight extents."""
        with self._retry_mu:
            self.ring_fallbacks += 1
        if self.stats is not None:
            self.stats.note_ring_fallback()

    # -- submission -----------------------------------------------------------

    def write_extent(self, off: int, parts: List, nbytes: int,
                     owner=None) -> int:
        """Write ``parts`` contiguously at ``off`` — inline, striped, or
        queued.  Returns the io_ns spent on THIS thread (0 when queued).

        The caller has already ``admit()``ed ``nbytes`` in write-behind
        mode and already reserved the extent; stripes never overlap, so
        no ordering between jobs is needed.  A failed write calls
        ``on_error`` (the writer's commit-poison hook) — synchronous
        failures also raise, exactly like the direct ``pwrite`` they
        replace.
        """
        stripes = self._stripes(off, parts, nbytes)
        if not self.async_mode:
            t0 = _ns()
            try:
                try:
                    if len(stripes) == 1 or self._pool is None:
                        for s_off, s_parts, _n in stripes:
                            self._pwritev(s_off, s_parts)
                    else:
                        futs = [
                            self._pool.submit(self._pwritev, s_off, s_parts)
                            for s_off, s_parts, _n in stripes
                        ]
                        for f in futs:
                            f.result()
                except OSError:
                    if len(stripes) <= 1 or self.retry is None:
                        raise
                    # stripe degradation: the reserved extent is untouched
                    # by readers until the footer lands, so rewriting it
                    # monolithically (with a fresh retry budget) is
                    # idempotent; striping stays off from here on
                    self._note_stripe_fallback()
                    self._pwritev(off, list(parts))
            except BaseException as e:
                self._fail(e)
                raise
            io_ns = _ns() - t0
            try:
                # fsync policy failures poison exactly like write failures
                # (they used to be able to slip through mid-run)
                self._extent_done(nbytes)
            except BaseException as e:
                self._fail(e)
                raise
            self._recycle(owner)
            if self._on_drain is not None:
                self._on_drain(nbytes, io_ns)
            return io_ns
        # write-behind: enqueue one job per stripe
        if self._error is not None:
            # the writer is poisoned: drop the bytes (finalization will
            # refuse anyway) but keep the budget accounting balanced
            self._release(nbytes)
            return 0
        t0 = _ns()
        group = _ExtentGroup(len(stripes), nbytes, owner, len(stripes) > 1)
        with self._cv:
            self._pending += len(stripes)
            depth = self._pending
        if self.stats is not None:
            for _ in stripes:
                self.stats.note_io_job(depth, self._inflight)
        if self._ring is not None:
            for s_off, s_parts, s_n in stripes:
                self._ring.submit(group, s_off, s_parts, s_n)
        else:
            for s_off, s_parts, s_n in stripes:
                self._pool.submit(self._run_job, group, s_off, s_parts, s_n)
        if self.stats is not None:
            self.stats.add_io_submit_ns(_ns() - t0)
        return 0

    def _stripes(self, off: int, parts: List, nbytes: int
                 ) -> List[Tuple[int, List, int]]:
        """Split an extent's iovec plan into ``[(offset, parts, nbytes)]``
        stripe sub-extents of at most ``stripe_bytes`` each."""
        if (
            self.stripe_bytes <= 0
            or self._stripe_disabled
            or nbytes <= self.stripe_bytes
            or (self._pool is None and self._ring is None)
        ):
            return [(off, list(parts), nbytes)]
        out: List[Tuple[int, List, int]] = []
        cur: List = []
        cur_n = 0
        cur_off = off
        for part in parts:
            mv = memoryview(part)
            pos = 0
            while pos < len(mv):
                take = min(len(mv) - pos, self.stripe_bytes - cur_n)
                cur.append(mv[pos : pos + take])
                cur_n += take
                pos += take
                if cur_n == self.stripe_bytes:
                    out.append((cur_off, cur, cur_n))
                    cur_off += cur_n
                    cur, cur_n = [], 0
        if cur:
            out.append((cur_off, cur, cur_n))
        return out

    def _pwritev(self, off: int, parts: List) -> None:
        self._retrying(self._pwritev_once, off, parts)

    def _pwritev_once(self, off: int, parts: List) -> None:
        if len(parts) == 1:
            self.sink.pwrite(off, parts[0])
        else:
            self.sink.pwritev(off, parts)

    def _pwritev_resume(self, off: int, parts: List, written: int) -> None:
        """Finish a partially completed vectored write from byte
        ``written`` onward (io_uring short-write recovery).  Retried as a
        whole — re-running the resume loop rewrites the same tail bytes."""
        self._retrying(self._pwritev_resume_once, off, parts, written)

    def _pwritev_resume_once(self, off: int, parts: List, written: int) -> None:
        pos = 0
        for p in parts:
            mv = memoryview(p)
            n = len(mv)
            if written >= pos + n:
                pos += n
                continue
            skip = max(0, written - pos)
            self.sink.pwrite(off + pos + skip, mv[skip:])
            pos += n

    # -- job body (executor and ring workers share it) ------------------------

    def _job_begin(self) -> int:
        t0 = _ns()
        with self._cv:
            if self._running == 0:
                self._busy_start = t0
            self._running += 1
        return t0

    def _job_end(self, group: _ExtentGroup, nbytes: int, t0: int,
                 err: Optional[BaseException]) -> None:
        """Completion fold shared by every async backend: stats, budget
        release, busy-window drain reporting, last-stripe recycling +
        fsync, poisoning."""
        io_ns = _ns() - t0
        if err is not None:
            self._fail(err)
        if self.stats is not None:
            self.stats.add_io_ns(io_ns)
        last = False
        drained = None
        with self._cv:
            self._running -= 1
            self._drained_bytes += nbytes
            if self._running == 0:
                # window closed: report accumulated bytes over the
                # union busy time — the sink's actual drain bandwidth
                drained = (self._drained_bytes, _ns() - self._busy_start)
                self._drained_bytes = 0
            self._pending -= 1
            self._inflight -= nbytes
            group.remaining -= 1
            last = group.remaining == 0
            self._cv.notify_all()
        if drained is not None and self._on_drain is not None:
            self._on_drain(*drained)
        if last:
            # the extent's final byte has landed (or failed): only now is
            # it safe to hand its buffers back to the pool — a queued
            # write referenced them until this moment
            self._recycle(group.owner)
            group.owner = None  # release the sealed cluster's buffers
            if self._error is None:
                try:
                    self._extent_done(group.nbytes)
                except BaseException as e:
                    self._fail(e)

    def _run_job(self, group: _ExtentGroup, off: int, parts: List,
                 nbytes: int) -> None:
        t0 = self._job_begin()
        err = None
        try:
            if self._error is None:
                self._pwritev(off, parts)
        except BaseException as e:
            err = e
            if isinstance(e, OSError) and group.striped:
                # the group's other stripes may already be in flight, so
                # this extent cannot be rewritten monolithically; poison,
                # but stop striping future extents
                self._note_stripe_fallback()
        self._job_end(group, nbytes, t0, err)

    def _recycle(self, owner) -> None:
        """Return an extent owner's pooled buffers (``owner.recycle``)."""
        if owner is None or self.buffer_pool is None:
            return
        bufs = getattr(owner, "recycle", None)
        if bufs:
            self.buffer_pool.put_all(bufs)
            try:
                owner.recycle = None
            except AttributeError:
                pass

    def _fail(self, e: BaseException) -> None:
        with self._cv:
            if self._error is None:
                self._error = e
            self._cv.notify_all()
        if self._on_error is not None:
            self._on_error(e)

    # -- fsync policy ---------------------------------------------------------

    def _do_fsync(self) -> None:
        """One retried fsync; a final failure is accounted in IOStats
        before it propagates (callers decide how it poisons)."""
        try:
            self._retrying(self.sink.fsync)
        except BaseException:
            counter = getattr(self.sink, "_count_fsync_failure", None)
            if counter is not None:
                counter()
            raise

    def fsync(self) -> None:
        """Retrying fsync that poisons the writer on failure — the entry
        point the writer's journal barrier and close() use, so a failed
        final sync surfaces exactly like a failed write."""
        try:
            self._do_fsync()
        except BaseException as e:
            self._fail(e)
            raise

    def _extent_done(self, nbytes: int) -> None:
        """Apply the every-cluster / byte-interval fsync policy after an
        extent's bytes have fully landed.  Raises on (retry-exhausted)
        fsync failure: both the sync path and ``_job_end`` route that
        into ``_fail`` — the mid-run fsync error is never swallowed."""
        if self._fsync_every:
            self._do_fsync()
        elif self._fsync_interval:
            due = False
            with self._fsync_lock:
                self._since_fsync += nbytes
                if self._since_fsync >= self._fsync_interval:
                    self._since_fsync = 0
                    due = True
            if due:
                self._do_fsync()

    # -- drain / shutdown ------------------------------------------------------

    @property
    def error(self) -> Optional[BaseException]:
        return self._error

    def drain(self) -> None:
        """Block until every queued write job has finished (successfully
        or not).  The drain-before-footer barrier: any failure is already
        latched in the writer via ``on_error``; this never raises."""
        with self._cv:
            while self._pending:
                self._cv.wait()

    def close(self) -> None:
        """Drain and release workers.  Idempotent: a poisoned writer's
        second close (``__exit__`` after the first raised) must not touch
        an already-shut-down ring or pool."""
        if self._closed:
            return
        self._closed = True
        self.drain()
        if self._ring is not None:
            self._ring.close()
            self._ring = None
        if self._pool is not None:
            self._pool.shutdown(wait=True)
