"""Multi-process parallel writing into ONE RNT-J container (DESIGN.md §8.6).

The paper removes the single-thread writer bottleneck; this module removes
the single-process one.  The commit protocol is unchanged — seal without
synchronization, reserve an extent in a short critical section, positioned
``pwritev`` — but the critical section's shared state (the allocation
frontier + commit sequence) moves out of the writer lock into the
crash-consistent side-car reservation log (:mod:`repro.core.extents`).

Roles:

* :class:`MultiWriterCoordinator` — creates the container + header + log,
  and owns finalization: the **footer-assembly rendezvous** at
  :meth:`~MultiWriterCoordinator.seal` waits for every joined writer's
  DONE with a straggler timeout, fences the dead and the late, then seals
  a valid footer over every fully-journaled cluster — from live and dead
  writers alike — recording salvaged/abandoned extents in ``footer.extra``.
* :class:`ParticipantWriter` — a :class:`~repro.core.writer.ParallelWriter`
  whose extents come from the shared log: it writes no header, stamps each
  journal record with its ``(writer_id, epoch)``, keeps its lease alive
  from a heartbeat thread, and at close fsyncs its clusters and reports
  DONE instead of writing a footer.  Join from another process with
  :func:`join_container`.

Crash-safety recap (the invariants live in :mod:`repro.core.extents`):
abandoned extents are holes that are never reused, so a fenced writer's
late ``pwrite`` can only land inside its own abandoned extent — never
inside a committed cluster or the footer; the SEAL record is appended
*before* the first footer byte exists, so no reservation can overlap the
footer region.
"""

from __future__ import annotations

import os
import threading
import time
import uuid
from typing import Optional, Tuple

from .container import Sink, open_sink
from .extents import (
    ExtentLog,
    FencedError,
    LogState,
    Reservation,
    StaleLogError,
    WriterSession,
)
from .metadata import (
    ANCHOR_SIZE,
    CLUSTER_ENV_SIZE,
    ClusterMeta,
    _ENV_HDR,
    build_anchor,
    build_footer,
    build_header,
    build_pagelist,
    parse_cluster_envelope,
    parse_header,
    parse_journal_record,
)
from .recover import _read_exact, _verify_cluster_pages
from .schema import Schema
from .writer import ParallelWriter, WriteOptions

_POLL_S = 0.02  # rendezvous poll period


class SharedExtentSink:
    """Sink wrapper routing extent reservation through the shared log.

    Every ``reserve`` appends a RESERVE record (raising
    :class:`FencedError` once this writer is fenced) and remembers the
    :class:`Reservation` so the commit path can read the global seq and
    append the matching COMMIT.  Everything else delegates to the wrapped
    sink — positioned writes need no coordination at all.
    """

    def __init__(self, inner: Sink, session: WriterSession):
        self.inner = inner
        self.session = session
        self.pending = {}           # offset -> Reservation (COMMIT not yet sent)
        self.last: Optional[Reservation] = None

    def reserve(self, size: int) -> int:
        r = self.session.reserve(size)
        self.pending[r.offset] = r
        self.last = r
        return r.offset

    def take(self, offset: int) -> Optional[Reservation]:
        return self.pending.pop(offset, None)

    @property
    def io(self):
        return self.inner.io

    @property
    def size(self) -> int:
        return self.inner.size

    @property
    def native_ring(self) -> bool:
        return getattr(self.inner, "native_ring", False)

    @property
    def fd(self):  # pragma: no cover - only consulted by the native ring
        return getattr(self.inner, "fd", -1)

    def pwrite(self, offset: int, data) -> None:
        self.inner.pwrite(offset, data)

    def pwritev(self, offset: int, parts) -> None:
        self.inner.pwritev(offset, parts)

    def pread(self, offset: int, size: int) -> bytes:
        return self.inner.pread(offset, size)

    def pread_into(self, offset: int, buf) -> int:
        return self.inner.pread_into(offset, buf)

    def fallocate(self, offset: int, size: int) -> None:
        self.inner.fallocate(offset, size)

    def fsync(self) -> None:
        self.inner.fsync()

    def close(self) -> None:
        self.inner.close()

    def readable(self) -> bool:
        return self.inner.readable()


class ParticipantWriter(ParallelWriter):
    """A parallel writer whose extents come from the shared reservation log.

    Identical fill/seal/commit machinery to :class:`ParallelWriter`; the
    differences are exactly the multi-writer protocol: no header, v3
    journal records stamped ``(writer_id, epoch)``, a lease heartbeat
    thread, COMMIT after every extent write, and a close that makes this
    writer's clusters durable and reports DONE instead of finalizing.
    """

    _writes_header = False

    def __init__(self, schema: Schema, sink, session: WriterSession,
                 options: Optional[WriteOptions] = None,
                 owns_log: bool = False):
        options = options or WriteOptions()
        if not options.buffered or not options.journal:
            raise ValueError(
                "multi-process writing requires buffered=True and "
                "journal=True (the journal records ARE the shared file's "
                "recoverable metadata)")
        self._mp_session = session
        self._owns_log = owns_log
        self._jrec_writer_id = session.writer_id
        self._jrec_epoch = session.epoch
        inner = (open_sink(sink, create=False)
                 if isinstance(sink, (str, os.PathLike)) else sink)
        super().__init__(schema, SharedExtentSink(inner, session), options)
        self._hb_stop = threading.Event()
        self._hb = threading.Thread(
            target=self._heartbeat_loop, daemon=True,
            name=f"rntj-lease-w{session.writer_id}")
        self._hb.start()

    @property
    def writer_id(self) -> int:
        return self._mp_session.writer_id

    @property
    def epoch(self) -> int:
        return self._mp_session.epoch

    def _heartbeat_loop(self) -> None:
        # renew at half the lease period so one missed beat is survivable
        period = max(0.01, self._mp_session.lease_interval / 2)
        while not self._hb_stop.wait(period):
            try:
                self._mp_session.heartbeat()
            except FencedError as e:
                # a beat racing the shutdown may observe this writer's own
                # terminal DONE — that is a clean close, not a fencing
                if not self._hb_stop.is_set():
                    self._poison(e)
                return
            except OSError:
                pass  # transient side-car hiccup: the next beat retries

    def _commit_seq(self) -> int:
        # caller holds the writer lock, right after sink.reserve: `last`
        # is this commit's reservation and its seq is the global one
        return self.sink.last.seq

    def _post_commit(self, ext: int) -> None:
        r = self.sink.take(ext)
        if r is not None:
            try:
                self._mp_session.commit(r.rid)
            except FencedError as e:
                self._poison(e)
                raise

    def _stop_heartbeat(self) -> None:
        self._hb_stop.set()
        if self._hb.is_alive():
            self._hb.join(timeout=10)

    def _finalize(self) -> None:
        # the participant's half of the rendezvous: data durable FIRST,
        # DONE second — the coordinator may seal the moment every writer
        # is done, so DONE must never precede the bytes it vouches for.
        # The heartbeat keeps running through the drain + fsync above and
        # here: a close whose final fsync of large buffered clusters
        # outlasts the fencing grace (~2x lease_interval) must stay
        # leased, or the coordinator fences a healthy writer mid-close
        # and spuriously degrades the seal.
        self._io.fsync()
        self._stop_heartbeat()
        if self._commit_error is None:
            self._mp_session.done()

    def close(self) -> None:
        try:
            super().close()
        finally:
            # poisoned closes skip _finalize: make sure the beat dies
            self._stop_heartbeat()
            if self._owns_log:
                self._mp_session.log.close()


def join_container(path, schema: Optional[Schema] = None,
                   options: Optional[WriteOptions] = None,
                   sink: Optional[Sink] = None) -> ParticipantWriter:
    """Join an open multi-writer container from any process.

    Reads the schema from the container header when not given; ``sink``
    lets tests interpose a fault-injection wrapper over the data file.
    The header is always read for the container's generation id, so a
    stale side-car log left next to the path by a previous run raises
    :class:`~repro.core.extents.StaleLogError` instead of joining it.
    """
    path = os.fspath(path)
    options = options or WriteOptions()
    inner = sink if sink is not None else open_sink(path, create=False)
    hdr16 = inner.pread(0, _ENV_HDR.size)
    _m, _t, plen = _ENV_HDR.unpack(hdr16)
    hdr_schema, hdr_opts = parse_header(
        inner.pread(0, _ENV_HDR.size + plen + 4))
    if schema is None:
        schema = hdr_schema
    log = ExtentLog(ExtentLog.sidecar_path(path), fsync=options.mpw_log_fsync)
    try:
        session = log.join(options.lease_interval,
                           expect_generation=hdr_opts.get("mpw_gen"))
    except BaseException:
        log.close()
        raise
    return ParticipantWriter(schema, inner, session, options, owns_log=True)


class MultiWriterCoordinator:
    """Owns one shared container: header first, footer rendezvous last.

    Usage::

        coord = MultiWriterCoordinator(schema, path, options)
        # spawn N processes, each: join_container(path).fill(...).close()
        # (or in-process: coord.participant())
        report = coord.seal(expect_writers=N)
        coord.close()
    """

    def __init__(self, schema: Schema, path, options: Optional[WriteOptions] = None):
        self.schema = schema
        self.path = os.fspath(path)
        self.options = options or WriteOptions()
        if not self.options.buffered or not self.options.journal:
            raise ValueError(
                "multi-process writing requires buffered=True and journal=True")
        # the generation id binds header, side-car log, and every join to
        # THIS file instance: a stale log (or a stale writer) from a prior
        # run at the same path can never be mistaken for ours
        self.generation = uuid.uuid4().hex
        self.sink = open_sink(self.path, create=True)
        hdr = self._header_bytes()
        self.sink.pwrite(self.sink.reserve(len(hdr)), hdr)
        self.sink.fsync()  # participants + recovery read it right away
        self._header_loc = (0, len(hdr))
        # a leftover side-car from a crashed or degraded-sealed previous
        # run (only a CLEAN seal unlinks it) must not be adopted: if
        # sealed it would fence every join, and its reservations point
        # into the file we just truncated
        try:
            os.unlink(ExtentLog.sidecar_path(self.path))
        except FileNotFoundError:
            pass
        self.log = ExtentLog.create(self.path, len(hdr),
                                    fsync=self.options.mpw_log_fsync,
                                    generation=self.generation)
        self._sealed = False
        self.report: Optional[dict] = None

    def _header_bytes(self) -> bytes:
        hdr_opts = self.options.as_dict()
        hdr_opts["mpw_gen"] = self.generation
        if self.options.precondition:
            hdr_opts["encodings"] = [c.encoding for c in self.schema.columns]
        else:
            hdr_opts["encodings"] = ["none"] * self.schema.n_columns
        return build_header(self.schema, hdr_opts)

    def participant(self, options: Optional[WriteOptions] = None) -> ParticipantWriter:
        """An in-process participant (shares this coordinator's log fd)."""
        opts = options or self.options
        session = self.log.join(opts.lease_interval,
                                expect_generation=self.generation)
        return ParticipantWriter(self.schema, open_sink(self.path, create=False),
                                 session, opts)

    # -- the footer-assembly rendezvous -----------------------------------

    def seal(self, expect_writers: Optional[int] = None,
             timeout: Optional[float] = None) -> dict:
        """Wait for every joined writer's DONE, fence stragglers at the
        timeout, then seal a footer over every fully-journaled cluster.

        ``expect_writers`` additionally waits (within the same timeout)
        for that many writers to have joined — use it when the workers
        are spawned but may not have registered yet.  Degrades
        gracefully: a dead or fenced writer's committed clusters are
        verified page-by-page and salvaged; torn extents become permanent
        holes recorded in ``footer.extra["mpw"]["abandoned"]``.
        """
        if self._sealed:
            return self.report
        timeout = (self.options.rendezvous_timeout if timeout is None
                   else timeout)
        deadline = time.monotonic() + timeout
        while True:
            st = self.log.snapshot()
            # lease deadlines are wall-clock (written by other processes);
            # the rendezvous timeout is local, so monotonic is fine for it
            now_wall = time.time()
            for w in st.writers.values():
                # 2x lease-interval grace: one missed heartbeat survives,
                # a silent writer is fenced without waiting for the full
                # rendezvous timeout
                if (not w.done and not w.fenced
                        and now_wall > w.lease_deadline + w.lease_interval):
                    self.log.fence(w.writer_id, "lease expired")
                    w.fenced = True
            undone = [w for w in st.writers.values()
                      if not w.done and not w.fenced]
            waiting_join = (expect_writers is not None
                            and len(st.writers) < expect_writers)
            if not undone and not waiting_join:
                break
            if time.monotonic() >= deadline:
                for w in undone:
                    self.log.fence(w.writer_id, "rendezvous timeout")
                break
            time.sleep(_POLL_S)
        # freeze allocation BEFORE any footer byte exists: after SEAL no
        # reservation can be appended, so nothing can overlap the footer
        self.log.seal({"coordinator_pid": os.getpid()})
        st = self.log.snapshot()
        metas, n_entries, mpw = self._assemble(st)
        self._write_footer(st, metas, n_entries, mpw)
        self._sealed = True
        self.report = mpw
        clean = not (mpw["fenced"] or mpw["salvaged"] or mpw["abandoned"])
        if clean:
            self.log.unlink()  # the sealed file is fully self-contained
        return mpw

    def _assemble(self, st: LogState):
        """Build the cluster list from the log + targeted extent reads.

        A reservation from a writer that finished cleanly (DONE, not
        fenced) is trusted on its framing (envelope + journal record CRCs
        — the writer fsynced before DONE); anything else gets full
        page-CRC verification, because the writer may have died mid-write.
        """
        keyed = []
        salvaged, abandoned = [], []
        for rid in sorted(st.reservations):
            r = st.reservations[rid]
            w = st.writers.get(r.writer_id)
            clean = w is not None and w.done and not w.fenced
            info = {"writer": r.writer_id, "epoch": r.epoch,
                    "offset": r.offset, "size": r.size}
            if r.released:
                abandoned.append(dict(info, reason="released"))
                continue
            cm, reason = self._load_cluster(st, r, verify_pages=not clean)
            if cm is None:
                abandoned.append(dict(info, reason=reason))
            elif clean and r.committed:
                keyed.append((r.seq, cm))
            else:
                # journaled bytes from a dead/fenced writer (or a COMMIT
                # record the crash swallowed): verified above, salvaged
                keyed.append((r.seq, cm))
                salvaged.append(dict(info, entries=cm.n_entries))
        keyed.sort(key=lambda kv: kv[0])
        metas, n = [], 0
        for _seq, cm in keyed:
            cm.first_entry = n
            n += cm.n_entries
            metas.append(cm)
        mpw = {
            "writers": len(st.writers),
            "done": sorted(w.writer_id for w in st.writers.values() if w.done),
            "fenced": sorted(w.writer_id for w in st.writers.values() if w.fenced),
            "clusters": len(metas),
            "entries": n,
            "salvaged": salvaged,
            "abandoned": abandoned,
        }
        return metas, n, mpw

    def _load_cluster(self, st: LogState, r: Reservation,
                      verify_pages: bool) -> Tuple[Optional[ClusterMeta], str]:
        """Read + validate one reserved extent; None + reason on failure."""
        sink = self.sink
        env_buf = _read_exact(sink, r.offset, CLUSTER_ENV_SIZE)
        if env_buf is None:
            return None, "extent unreadable"
        try:
            env = parse_cluster_envelope(env_buf)
        except IOError:
            return None, "cluster envelope torn"
        jr_off = r.offset + CLUSTER_ENV_SIZE + env["payload_len"]
        if env["seq"] != r.seq or jr_off >= r.offset + r.size:
            return None, "envelope/reservation disagree"
        jbuf = _read_exact(sink, jr_off, r.offset + r.size - jr_off)
        if jbuf is None:
            return None, "journal record unreadable"
        try:
            jr, _end = parse_journal_record(jbuf, 0)
        except IOError:
            return None, "journal record torn"
        if (jr.seq != r.seq or jr.crc != env["desc_crc"]
                or jr.cluster_off != r.offset + CLUSTER_ENV_SIZE
                or jr.cluster_size != env["payload_len"]):
            return None, "envelope/journal disagree"
        if jr.writer_id != r.writer_id or jr.epoch != r.epoch:
            # a stale-epoch writer wrote into space it does not own under
            # its current identity: fencing says this data is dead
            return None, "journal record from a fenced epoch"
        reason = _verify_cluster_pages(sink, jr, st.next_offset, verify_pages)
        if reason is not None:
            return None, reason
        return ClusterMeta(
            first_entry=0,  # renumbered by the caller
            n_entries=jr.n_entries,
            n_elements=list(jr.n_elements),
            pages=list(jr.pages),
            byte_offset=jr.cluster_off,
            byte_size=jr.cluster_size,
        ), ""

    def _write_footer(self, st: LogState, metas, n_entries: int,
                      mpw: dict) -> None:
        sink = self.sink
        # finalization begins exactly at the sealed allocation frontier;
        # abandoned extents before it stay as holes (never reused)
        sink._end = max(sink.size, st.next_offset)
        pl = build_pagelist(metas, self.schema.n_columns)
        pl_off = sink.reserve(len(pl))
        sink.pwrite(pl_off, pl)
        ftr = build_footer(n_entries, len(metas), (pl_off, len(pl)),
                           extra={"mpw": mpw})
        f_off = sink.reserve(len(ftr))
        sink.pwrite(f_off, ftr)
        anchor = build_anchor(self._header_loc, (f_off, len(ftr)),
                              n_entries, len(metas))
        sink.pwrite(sink.reserve(ANCHOR_SIZE), anchor)
        sink.fsync()

    def close(self) -> None:
        if not self._sealed:
            self.seal()
        self.sink.close()
        self.log.close()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.close()
        else:  # do not mask the in-flight error with a rendezvous
            self.sink.close()
            self.log.close()
