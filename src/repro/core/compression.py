"""Entropy-coder codecs for pages.

ROOT supports DEFLATE, LZMA, LZ4 and Zstandard (paper §3).  This container
has the Python stdlib only, so we provide DEFLATE (zlib), LZMA and BZ2 plus
an explicit ``none`` fast path; codec ids 4 (lz4) and 5 (zstd) are reserved
so files written elsewhere with those codecs keep stable ids.

``zlib``/``lzma``/``bz2`` all release the GIL while (de)compressing buffers,
which is what lets the paper's thread-parallel compression model work in
Python too: serialization+compression of a unit of writing runs with no
synchronization (paper §4.1).
"""

from __future__ import annotations

import bz2
import lzma
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, Optional, Tuple

CODEC_NONE = 0
CODEC_ZLIB = 1
CODEC_LZMA = 2
CODEC_BZ2 = 3
CODEC_LZ4 = 4  # reserved (not installed here)
CODEC_ZSTD = 5  # reserved (not installed here)

_NAMES: Dict[str, int] = {
    "none": CODEC_NONE,
    "zlib": CODEC_ZLIB,
    "deflate": CODEC_ZLIB,
    "lzma": CODEC_LZMA,
    "bz2": CODEC_BZ2,
}

DEFAULT_LEVEL = {CODEC_ZLIB: 1, CODEC_LZMA: 0, CODEC_BZ2: 1}


def codec_id(name_or_id) -> int:
    if isinstance(name_or_id, int):
        return name_or_id
    try:
        return _NAMES[name_or_id.lower()]
    except KeyError:
        raise ValueError(f"unknown codec {name_or_id!r}") from None


def make_pool(workers: int, prefix: str = "rntj-codec") -> Optional[ThreadPoolExecutor]:
    """Shared worker-pool plumbing for page codec work.

    One pool per writer (compression) or reader (decompression), sized
    independently of the producer/consumer count.  Because the codecs
    above release the GIL, page (de)compression submitted to the pool
    runs truly in parallel.  Returns ``None`` when ``workers`` is 0 so
    callers can keep a synchronous fast path.
    """
    if not workers:
        return None
    return ThreadPoolExecutor(max_workers=workers, thread_name_prefix=prefix)


def compress(data: bytes, codec: int, level: int = -1) -> bytes:
    if codec == CODEC_NONE:
        return data
    if level < 0:
        level = DEFAULT_LEVEL[codec]
    if codec == CODEC_ZLIB:
        # compressobj produces the identical byte stream but manages the
        # output buffer more cheaply than zlib.compress (~10% on 64 KiB
        # pages); this path runs once per page, so it matters
        c = zlib.compressobj(level)
        return c.compress(data) + c.flush()
    if codec == CODEC_LZMA:
        return lzma.compress(data, preset=level)
    if codec == CODEC_BZ2:
        return bz2.compress(data, max(1, level))
    raise ValueError(f"codec {codec} not available in this build")


def decompress(data: bytes, codec: int, uncompressed_size: int) -> bytes:
    if codec == CODEC_NONE:
        return data
    if codec == CODEC_ZLIB:
        out = zlib.decompress(data)
    elif codec == CODEC_LZMA:
        out = lzma.decompress(data)
    elif codec == CODEC_BZ2:
        out = bz2.decompress(data)
    else:
        raise ValueError(f"codec {codec} not available in this build")
    if len(out) != uncompressed_size:
        raise IOError(
            f"decompressed size mismatch: {len(out)} != {uncompressed_size}"
        )
    return out
