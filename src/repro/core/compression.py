"""The page codec engine: registry, framed chunk-parallel compression,
and adaptive per-column codec policy.

ROOT supports DEFLATE, LZMA, LZ4 and Zstandard (paper §3).  This container
has the Python stdlib only, so DEFLATE (zlib), LZMA and BZ2 are always
registered plus an explicit ``none`` fast path; codec ids 4 (lz4) and
5 (zstd) are **auto-registered when the ``lz4`` / ``zstandard`` packages
are importable** and otherwise stay reserved with a clear error naming
the missing package — files written elsewhere with those codecs keep
stable ids either way.

Three properties make compressed configurations scale like uncompressed
ones (the point of the codec engine, see DESIGN.md §5):

* every registered codec releases the GIL while (de)compressing, which is
  what lets the paper's thread-parallel compression model work in Python:
  serialization+compression of a unit of writing runs with no
  synchronization (paper §4.1);
* **framed chunking**: a page whose preconditioned payload exceeds
  ``chunk_bytes`` is compressed as independent, concatenated *members*
  (complete codec streams).  Members compress concurrently on a worker
  pool — a single producer sealing one big page saturates the pool — and
  the decoder transparently loops a decompressor over the members, so the
  on-disk codec id does not change and per-page checksums fold over the
  member payloads incrementally (``crc32(b, crc32(a)) == crc32(a+b)``);
* an adaptive :class:`CodecPolicy` samples each column's first sealed
  pages and falls back to raw storage (``CODEC_NONE``, as ROOT does) when
  the achieved ratio is not worth the CPU.
"""

from __future__ import annotations

import bz2
import lzma
import threading
import zlib
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

CODEC_NONE = 0
CODEC_ZLIB = 1
CODEC_LZMA = 2
CODEC_BZ2 = 3
CODEC_LZ4 = 4   # registered when the ``lz4`` package is importable
CODEC_ZSTD = 5  # registered when the ``zstandard`` package is importable

_NAMES: Dict[str, int] = {
    "none": CODEC_NONE,
    "zlib": CODEC_ZLIB,
    "deflate": CODEC_ZLIB,
    "lzma": CODEC_LZMA,
    "bz2": CODEC_BZ2,
    "lz4": CODEC_LZ4,
    "zstd": CODEC_ZSTD,
    "zstandard": CODEC_ZSTD,
}


@dataclass(frozen=True)
class Codec:
    """One registered entropy coder.

    ``compress`` emits a complete, self-terminating stream (a *member*);
    ``decompressor`` returns a fresh stdlib-style decompressor object with
    ``.decompress(buf)`` and ``.unused_data`` — the engine loops it over
    concatenated members, so chunk-framed pages need no extra metadata.
    """

    id: int
    name: str
    default_level: int
    compress: Callable[[bytes, int], bytes]
    decompressor: Callable[[], object]


def _zlib_compress(data, level: int) -> bytes:
    # compressobj produces the identical byte stream but manages the
    # output buffer more cheaply than zlib.compress (~10% on 64 KiB
    # pages); this path runs once per page member, so it matters
    c = zlib.compressobj(level)
    return c.compress(data) + c.flush()


_REGISTRY: Dict[int, Codec] = {}

# package that would provide each reserved codec id (for error messages)
_RESERVED_PACKAGES = {CODEC_LZ4: "lz4", CODEC_ZSTD: "zstandard"}


def register_codec(codec: Codec) -> None:
    _REGISTRY[codec.id] = codec
    _NAMES.setdefault(codec.name, codec.id)


register_codec(Codec(CODEC_ZLIB, "zlib", 1, _zlib_compress,
                     zlib.decompressobj))
register_codec(Codec(CODEC_LZMA, "lzma", 0,
                     lambda d, lvl: lzma.compress(d, preset=lvl),
                     lzma.LZMADecompressor))
register_codec(Codec(CODEC_BZ2, "bz2", 1,
                     lambda d, lvl: bz2.compress(d, max(1, lvl)),
                     bz2.BZ2Decompressor))


def _register_optional() -> None:
    """Detect importable lz4/zstandard and claim the reserved ids."""
    try:  # pragma: no cover - depends on installed packages
        import lz4.frame as _lz4f

        register_codec(Codec(
            CODEC_LZ4, "lz4", 0,
            lambda d, lvl: _lz4f.compress(bytes(d), compression_level=lvl),
            _lz4f.LZ4FrameDecompressor,
        ))
    except ImportError:
        pass
    try:  # pragma: no cover - depends on installed packages
        import io as _io

        import zstandard as _zstd

        class _ZstdMembers:
            """stdlib-decompressor facade over concatenated zstd frames."""

            unused_data = b""

            def decompress(self, buf):
                reader = _zstd.ZstdDecompressor().stream_reader(
                    _io.BytesIO(bytes(buf)), read_across_frames=True
                )
                return reader.read()

        register_codec(Codec(
            CODEC_ZSTD, "zstd", 3,
            lambda d, lvl: _zstd.ZstdCompressor(level=lvl).compress(bytes(d)),
            _ZstdMembers,
        ))
    except ImportError:
        pass


_register_optional()

# kept as a public alias: levels used when callers pass level < 0
DEFAULT_LEVEL = {cid: c.default_level for cid, c in _REGISTRY.items()}


def codec_id(name_or_id) -> int:
    if isinstance(name_or_id, int):
        return name_or_id
    try:
        return _NAMES[name_or_id.lower()]
    except KeyError:
        raise ValueError(f"unknown codec {name_or_id!r}") from None


def codec_name(cid: int) -> str:
    if cid == CODEC_NONE:
        return "none"
    c = _REGISTRY.get(cid)
    if c is not None:
        return c.name
    return _RESERVED_PACKAGES.get(cid, str(cid))


def is_available(cid: int) -> bool:
    return cid == CODEC_NONE or cid in _REGISTRY


def require(cid: int) -> Codec:
    """Availability check FIRST: unavailable ids raise ``ValueError``
    before any level lookup (reserved ids name the missing package)."""
    c = _REGISTRY.get(cid)
    if c is None:
        pkg = _RESERVED_PACKAGES.get(cid)
        if pkg is not None:
            raise ValueError(
                f"codec {cid} ({codec_name(cid)}) not available in this "
                f"build: requires the {pkg!r} package"
            )
        raise ValueError(f"codec {cid} not available in this build")
    return c


def make_pool(workers: int, prefix: str = "rntj-codec") -> Optional[ThreadPoolExecutor]:
    """Shared worker-pool plumbing for page codec work.

    One pool per writer (compression) or reader (decompression), sized
    independently of the producer/consumer count.  Because the codecs
    above release the GIL, page (de)compression submitted to the pool
    runs truly in parallel.  Returns ``None`` when ``workers`` is 0 so
    callers can keep a synchronous fast path.
    """
    if not workers:
        return None
    return ThreadPoolExecutor(max_workers=workers, thread_name_prefix=prefix)


# ---------------------------------------------------------------------------
# framed chunking


def chunk_ranges(n: int, chunk_bytes: int) -> List[Tuple[int, int]]:
    """Byte ranges of a payload's independent members.

    One member when chunking is disabled (``chunk_bytes <= 0``) or the
    payload fits in one chunk, else ``ceil(n / chunk_bytes)`` members.
    """
    if chunk_bytes <= 0 or n <= chunk_bytes:
        return [(0, n)]
    return [(i, min(i + chunk_bytes, n)) for i in range(0, n, chunk_bytes)]


def compress_parts(
    data, codec: int, level: int = -1, chunk_bytes: int = 0, pool=None
) -> List[bytes]:
    """Compress ``data`` into one or more independent members.

    Members are complete streams of the codec: concatenated, they form a
    payload :func:`decompress` (and any stdlib decompressor loop) accepts
    under the same codec id.  With ``pool`` the members compress
    concurrently — the chunk-parallel path a single producer uses to
    saturate the writer's pool on one large page.
    """
    c = require(codec)
    if level < 0:
        level = c.default_level
    mv = memoryview(data)
    ranges = chunk_ranges(len(mv), chunk_bytes)
    if len(ranges) == 1:
        return [c.compress(mv, level)]
    if pool is None:
        return [c.compress(mv[a:b], level) for a, b in ranges]
    return list(pool.map(lambda r: c.compress(mv[r[0]:r[1]], level), ranges))


def compress(data, codec: int, level: int = -1, chunk_bytes: int = 0,
             pool=None) -> bytes:
    if codec == CODEC_NONE:
        return data
    parts = compress_parts(data, codec, level, chunk_bytes, pool)
    return parts[0] if len(parts) == 1 else b"".join(parts)


def crc32_parts(parts: Sequence, crc: int = 0) -> int:
    """Fold member CRCs into one page checksum incrementally.

    ``crc32`` is streaming — ``crc32(a + b) == crc32(b, crc32(a))`` — so
    the fold over a chunked page's members equals the whole-payload CRC:
    chunk-framed files stay verifiable by any whole-page reader.
    """
    for p in parts:
        crc = zlib.crc32(p, crc)
    return crc


def decompress(data, codec: int, uncompressed_size: int) -> bytes:
    """Decompress a page payload, looping over concatenated members.

    A non-chunked page is simply a single member, so this is THE decode
    path for every codec id; the member loop adds no work to it.
    """
    if codec == CODEC_NONE:
        return data
    c = require(codec)
    d = c.decompressor()
    out = d.decompress(data)
    rest = d.unused_data
    if rest:
        parts = [out]
        total = len(out)
        while rest and total <= uncompressed_size:
            d = c.decompressor()
            part = d.decompress(rest)
            if not part and not d.unused_data:
                break  # no progress: corrupt trailing member
            parts.append(part)
            total += len(part)
            rest = d.unused_data
        out = b"".join(parts)
    if len(out) != uncompressed_size:
        raise IOError(
            f"decompressed size mismatch: {len(out)} != {uncompressed_size}"
        )
    return out


# ---------------------------------------------------------------------------
# adaptive per-column codec policy


class CodecPolicy:
    """Per-column adaptive codec decisions, shared by every builder of one
    writer (ROOT's "use no compression when it does not pay" heuristic,
    per column instead of per page).

    Each column starts in a *sampling* phase: its first ``sample_pages``
    compressed pages are trialed with the configured codec while the
    achieved in/out byte totals accumulate.  Once the sample is complete
    the column's codec is **locked**: kept if the sampled ratio
    (``out/in``) is at most ``threshold``, dropped to ``CODEC_NONE``
    otherwise.  Decisions are monotonic and thread-safe — concurrent
    producers share one policy, and pages already written under the trial
    codec stay valid because ``PageDesc.codec`` is per page.

    With ``rate_aware=True`` the decision also weighs measured
    **bandwidths**, not ratio alone: the commit path feeds the sink's
    observed drain rate through :meth:`observe_drain`, and a column whose
    ratio misses the threshold is still kept when its *savings rate* —
    bytes removed per second of compression CPU,
    ``(in - out) / compress_time`` — beats what the sink can drain.  A
    throttled disk (drain slower than the savings rate) keeps compression
    that a /dev/null-fast sink would drop, which is exactly the paper's
    storage-bandwidth-is-the-wall regime.  While no drain observation
    exists yet, a would-drop column keeps sampling (up to
    ``4 * sample_pages`` pages) instead of locking a decision the rate
    data could reverse.

    Constructor parameters are tabulated in DESIGN.md §7.3 (the writer
    builds one from the ``adaptive_*`` fields of DESIGN.md §7.1).
    """

    def __init__(self, n_columns: int, sample_pages: int = 8,
                 threshold: float = 0.9, rate_aware: bool = False):
        self.sample_pages = sample_pages
        self.threshold = threshold
        self.rate_aware = rate_aware
        self._lock = threading.Lock()
        self._pages = [0] * n_columns
        self._bytes_in = [0] * n_columns
        self._bytes_out = [0] * n_columns
        self._ns = [0] * n_columns       # compression CPU time of the sample
        self._drain_bytes = 0
        self._drain_ns = 0
        # None = sampling; True = keep the configured codec; False = raw
        self._keep: List[Optional[bool]] = [None] * n_columns

    def effective_codec(self, column: int, codec: int) -> int:
        """The codec to use for this column's next page."""
        if codec == CODEC_NONE or self._keep[column] is None or self._keep[column]:
            return codec
        return CODEC_NONE

    def observe_drain(self, nbytes: int, ns: int) -> None:
        """Account one drained write: the sink's observed bandwidth."""
        if not self.rate_aware:
            return
        with self._lock:
            self._drain_bytes += nbytes
            self._drain_ns += ns

    def _drain_rate(self) -> Optional[float]:
        """Observed sink bandwidth in bytes/s (None before any write)."""
        if not self._drain_ns:
            return None
        return self._drain_bytes / (self._drain_ns / 1e9)

    def record(self, column: int, raw_size: int, payload_size: int,
               ns: int = 0) -> None:
        """Account one compressed trial page; lock the decision once the
        sample is complete."""
        with self._lock:
            if self._keep[column] is not None:
                return
            self._pages[column] += 1
            self._bytes_in[column] += raw_size
            self._bytes_out[column] += payload_size
            self._ns[column] += ns
            if self._pages[column] < self.sample_pages:
                return
            ratio = self._bytes_out[column] / max(1, self._bytes_in[column])
            if ratio <= self.threshold:
                self._keep[column] = True
                return
            if not self.rate_aware:
                self._keep[column] = False
                return
            # ratio alone says drop — but if the sink drains slower than
            # this codec removes bytes, compression still buys wall time
            drain = self._drain_rate()
            if drain is None:
                # no bandwidth signal yet: keep sampling (bounded)
                if self._pages[column] >= 4 * self.sample_pages:
                    self._keep[column] = False
                return
            saved = self._bytes_in[column] - self._bytes_out[column]
            cpu_s = self._ns[column] / 1e9
            savings_rate = saved / cpu_s if cpu_s > 0 else 0.0
            self._keep[column] = savings_rate >= drain

    def decision(self, column: int) -> Optional[bool]:
        """None while sampling, else whether the codec was kept."""
        return self._keep[column]

    def remaining_sample(self, column: int) -> int:
        """Trial pages still wanted before this column's decision locks."""
        with self._lock:
            if self._keep[column] is not None:
                return 0
            return max(0, self.sample_pages - self._pages[column])

    def as_dict(self) -> dict:
        with self._lock:
            drain = self._drain_rate()
            return {
                "sample_pages": self.sample_pages,
                "threshold": self.threshold,
                "rate_aware": self.rate_aware,
                "drain_mb_s": round(drain / 1e6, 2) if drain else None,
                "columns": [
                    {
                        "pages": p,
                        "bytes_in": bi,
                        "bytes_out": bo,
                        "keep": k,
                    }
                    for p, bi, bo, k in zip(
                        self._pages, self._bytes_in, self._bytes_out, self._keep
                    )
                ],
            }
