"""Clusters: the (default) unit of writing (paper §4, §5).

A cluster holds all pages of a consecutive range of entries.  Offset
columns are accumulated as *sizes* and integrated to **cluster-relative**
offsets at seal time, which makes the sealed byte blob relocatable: it can
be committed at any file offset without content changes — the property
that lets serialization and compression run with no synchronization.

Hot-path layout: each column accumulates into a contiguous, amortized-
doubling :class:`~repro.core.colbuf.ColumnBuffer` — appends are vectorized
copies, offset integration happens in place on the reserved tail, and page
extraction at seal time is a zero-copy view slice (no ``np.concatenate``).

``seal()`` is the ONE compression code path shared by the sequential
writer (IMT mode) and the parallel writer, structured as two passes:

1. **column-batched preconditioning** on the sealing thread — every
   column's pages split/delta-encoded in a handful of vectorized calls
   (with the Pallas ``byteshuffle`` dispatch on accelerator backends);
2. **chunk-granular compression** — each page becomes one or more framed
   compression jobs (pages above ``chunk_bytes`` split into independent
   concatenated members), distributed over the writer-owned pool when one
   is given, so a *single producer* sealing one cluster saturates the
   pool.  zlib/lzma/bz2 (and lz4/zstd when installed) release the GIL, so
   members compress truly in parallel; per-page CRCs fold over the
   members incrementally.

Per-column codecs resolve once per builder (``column_codecs``), and an
optional shared :class:`~repro.core.compression.CodecPolicy` downgrades
columns whose sampled compression ratio is not worth the CPU to raw
storage.  The pooled and serial paths are byte-identical.
"""

from __future__ import annotations

import time
import zlib
from dataclasses import dataclass, replace as dc_replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import compression as comp
from .colbuf import ColumnBuffer
from .encoding import EncodeScratch, integrate_sizes, precondition_column_pages
from .pages import PageDesc, build_page, elements_per_page
from .schema import (
    ENC_NONE,
    KIND_OFFSET,
    OFFSET_DTYPE,
    ColumnBatch,
    Schema,
    decompose_entry,
)

_ns = time.perf_counter_ns

# plan-slot sentinel: this page's codec resolves mid-seal, after the
# column's adaptive-policy trial pages have been compressed and recorded
_PENDING = -2


@dataclass
class SealedCluster:
    """A serialized+compressed cluster, ready to commit anywhere.

    Two carrier forms, byte-identical on disk (DESIGN.md §6):

    * **assembled** — ``blob`` is a bytes-like single allocation holding
      every page payload back to back (the reference path);
    * **scatter-gather** — ``blob`` is ``None`` and ``iovecs`` is the
      ordered list of page-payload buffers (bytes for compressed pages,
      zero-copy views of the builder's detached buffers for raw pages),
      handed to ``Sink.pwritev`` with no assembly memcpy.  The views keep
      their backing arrays alive; the builder detached those buffers at
      seal time, so queued write-behind commits stay valid while the
      builder refills.

    ``pages[i]`` descriptors carry cluster-relative offsets into the
    payload stream either way.  ``codec_stats`` maps codec id ->
    ``[pages, bytes_in, bytes_out, ns]`` so writer stats can attribute
    bytes and time to each codec.
    """

    blob: Optional[bytes]          # bytes-like (bytearray from seal())
    n_entries: int
    n_elements: List[int]          # per column
    pages: List[PageDesc]          # cluster-relative offsets
    uncompressed_bytes: int
    seal_ns: int = 0               # wall time of the whole seal
    compress_ns: int = 0           # summed per-page build time (CPU view)
    codec_stats: Optional[Dict[int, List[int]]] = None
    iovecs: Optional[List] = None  # scatter-gather payload buffers
    nbytes: int = -1               # total payload bytes (-1: use len(blob))
    # detached buffers backing raw-stored iovecs, returned to the
    # writer's BufferPool by the I/O engine once this cluster's last
    # write lands (this object and its iovecs must not be read after
    # that point — DESIGN.md §6.8)
    recycle: Optional[List] = None
    # per-page zone maps (DESIGN.md §11): column index -> parallel page
    # lists {"fe","le"[,"lo","hi","nn"]}, cluster-relative entry indices
    zonemaps: Optional[Dict[int, Dict[str, list]]] = None

    @property
    def size(self) -> int:
        if self.nbytes >= 0:
            return self.nbytes
        return len(self.blob)

    def iov_plan(self) -> List:
        """The write plan: payload buffers in offset order (an assembled
        cluster is simply a one-buffer plan)."""
        if self.iovecs is not None:
            return self.iovecs
        return [self.blob]

    def tobytes(self) -> bytes:
        """Materialize the full payload (tests / reference comparisons)."""
        if self.blob is not None:
            return bytes(self.blob)
        return b"".join(bytes(memoryview(p)) for p in self.iovecs)

    def rebase(self, base: int) -> List[PageDesc]:
        return [p.rebase(base) for p in self.pages]


class ClusterBuilder:
    """Accumulates decomposed entries and seals them into a cluster.

    Also supports *page draining* for the unbuffered (page-granular) writer
    mode: whenever a column holds a full page of elements it can be built
    and handed out immediately.

    Builders are reusable: after :meth:`seal` / :meth:`finish_unbuffered`
    the column buffers keep their storage, so refilling performs no
    allocations in steady state (this is what double-buffered pipelined
    sealing relies on).

    ``column_codecs`` is an optional per-column ``[(codec_id, level)]``
    resolution (writers compute it once from ``WriteOptions`` +
    ``ColumnSpec`` overrides); ``policy`` is the writer-shared adaptive
    :class:`~repro.core.compression.CodecPolicy`; ``chunk_bytes`` frames
    pages larger than it into independently compressed members; and
    ``precondition=False`` disables split/delta encodings (every column
    stored with ``ENC_NONE``, matching the header's ``precondition`` flag).
    """

    def __init__(self, schema: Schema, page_size: int, codec: int, level: int = -1,
                 checksum: bool = True,
                 column_codecs: Optional[Sequence[Tuple[int, int]]] = None,
                 chunk_bytes: int = 0,
                 policy: Optional[comp.CodecPolicy] = None,
                 precondition: bool = True,
                 scatter: bool = False,
                 buffer_pool=None,
                 zone_maps: bool = True):
        self.schema = schema
        self.page_size = page_size
        self.codec = codec
        self.level = level
        self.checksum = checksum
        self.chunk_bytes = chunk_bytes
        self.scatter = scatter
        self.zone_maps = zone_maps
        # writer-shared BufferPool (DESIGN.md §6.8): column storage and
        # preconditioning scratch draw from it, and seal() hands detached
        # buffers to the sealed cluster for completion-time recycling
        self._bufpool = buffer_pool
        self._policy = policy
        # effective per-column specs: encodings drop to ENC_NONE when
        # preconditioning is disabled (the reader honors the header flag)
        self._specs = [
            c if precondition else dc_replace(c, encoding=ENC_NONE)
            for c in schema.columns
        ]
        self._page_elems = [
            elements_per_page(c, page_size) for c in schema.columns
        ]
        self._cols = [
            ColumnBuffer(
                OFFSET_DTYPE if c.kind == KIND_OFFSET else c.dtype,
                capacity=self._page_elems[c.index],
                pool=buffer_pool,
            )
            for c in schema.columns
        ]
        # cluster-relative running end-offset per offset column
        self._acc_offset = [0] * schema.n_columns
        self.n_entries = 0
        self.uncompressed_bytes = 0
        # unbuffered mode: elements already drained into standalone pages
        self._drained: List[int] = [0] * schema.n_columns
        # unbuffered mode: zone-map rows accumulated as pages drain
        self._zm_acc: Dict[int, Dict[str, list]] = {}
        # seal() runs on one thread at a time; the scratch amortizes the
        # column-wide preconditioning temporaries across clusters (and
        # recycles them through the pool when one is attached)
        self._scratch = EncodeScratch(pool=buffer_pool)
        # None = no explicit table: every page uses the live
        # ``self.codec``/``self.level`` (kept mutable for tests and
        # ad-hoc callers)
        self._column_codecs = (
            list(column_codecs) if column_codecs is not None else None
        )

    # -- filling -----------------------------------------------------------

    def fill(self, entry: Dict) -> None:
        arrays = decompose_entry(self.schema, entry)
        self._append_arrays(arrays, 1)

    def fill_batch(self, batch: ColumnBatch) -> None:
        if batch.schema.n_columns != self.schema.n_columns:
            raise ValueError("batch schema does not match writer schema")
        arrays = [batch.data[c.index] for c in self.schema.columns]
        self._append_arrays(arrays, batch.n_entries)

    def _append_arrays(self, arrays: Sequence[np.ndarray], n_entries: int) -> None:
        for col in self.schema.columns:
            a = arrays[col.index]
            n = len(a)
            if not n:
                continue
            buf = self._cols[col.index]
            if col.kind == KIND_OFFSET:
                # sizes -> cluster-relative end offsets, integrated in
                # place on the reserved buffer tail (no temporary)
                dst = buf.reserve(n)
                integrate_sizes(a, base=self._acc_offset[col.index], out=dst)
                self._acc_offset[col.index] = int(dst[-1])
            else:
                buf.extend(a)
            self.uncompressed_bytes += n * buf.dtype.itemsize
        self.n_entries += n_entries

    @property
    def is_empty(self) -> bool:
        return self.n_entries == 0

    def n_elements(self, idx: int) -> int:
        return len(self._cols[idx])

    # -- sealing (buffered mode) --------------------------------------------

    def _column_elements(self, idx: int) -> np.ndarray:
        """Zero-copy view of all elements accumulated for column ``idx``."""
        return self._cols[idx].view()

    def _page_codec(self, column: int) -> Tuple[int, int]:
        """(codec, level) for the column's next page, after the adaptive
        policy's say."""
        if self._column_codecs is not None:
            codec, level = self._column_codecs[column]
        else:
            codec, level = self.codec, self.level
        if self._policy is not None:
            codec = self._policy.effective_codec(column, codec)
        return codec, level

    # -- zone maps (DESIGN.md §11) ------------------------------------------

    def _entries_of(self, ci: int, pos: np.ndarray) -> np.ndarray:
        """Cluster-relative entry index of each element position of
        column ``ci``, walking positions up the offset-column chain
        (offset columns hold cluster-relative *end* offsets, so the
        parent element of child position j is the first i with
        ends[i] > j)."""
        p = self.schema.parent[ci]
        while p != -1:
            pos = np.searchsorted(self._cols[p].view(), pos, side="right")
            p = self.schema.parent[p]
        return pos

    def _zone_page_row(self, col, elems: np.ndarray, start: int,
                       stop: int) -> None:
        """Accumulate one page's zone-map row (unbuffered drain path)."""
        d = self._zm_acc.setdefault(
            col.index,
            {"fe": [], "le": []} if col.kind == KIND_OFFSET
            else {"fe": [], "le": [], "lo": [], "hi": [], "nn": []},
        )
        fe, le = self._entries_of(
            col.index, np.array([start, stop - 1], dtype=np.int64)
        ).tolist()
        d["fe"].append(fe)
        d["le"].append(le)
        if col.kind == KIND_OFFSET:
            return
        if elems.dtype.kind == "f":
            d["lo"].append(float(np.fmin.reduce(elems)))
            d["hi"].append(float(np.fmax.reduce(elems)))
            d["nn"].append(int(np.count_nonzero(np.isnan(elems))))
        else:
            d["lo"].append(elems.min().item())
            d["hi"].append(elems.max().item())
            d["nn"].append(0)

    def _zone_maps(self) -> Optional[Dict[int, Dict[str, list]]]:
        """Per-page zone maps for every non-empty column.

        For each page: first/last cluster-relative entry index (element
        positions walked up the offset chain), plus — for leaf columns —
        min/max over non-NaN elements and the NaN ("null") count.  One
        vectorized ``reduceat`` pass per column over the still-contiguous
        column buffers; an all-NaN page records NaN bounds.
        """
        if not self.zone_maps:
            return None
        zm: Dict[int, Dict[str, list]] = {}
        for col in self.schema.columns:
            n = len(self._cols[col.index])
            if n == 0:
                continue
            per = self._page_elems[col.index]
            starts = np.arange(0, n, per, dtype=np.int64)
            fe = self._entries_of(col.index, starts)
            le = self._entries_of(
                col.index, np.minimum(starts + per, n) - 1
            )
            entry = {"fe": fe.tolist(), "le": le.tolist()}
            if col.kind != KIND_OFFSET:
                elems = self._cols[col.index].view()
                if elems.dtype.kind == "f":
                    lo = np.fmin.reduceat(elems, starts)
                    hi = np.fmax.reduceat(elems, starts)
                    nn = np.add.reduceat(
                        np.isnan(elems).astype(np.int64), starts
                    )
                else:
                    lo = np.minimum.reduceat(elems, starts)
                    hi = np.maximum.reduceat(elems, starts)
                    nn = np.zeros(len(starts), dtype=np.int64)
                entry["lo"] = lo.tolist()
                entry["hi"] = hi.tolist()
                entry["nn"] = nn.tolist()
            zm[col.index] = entry
        return zm

    def seal(self, pool=None) -> SealedCluster:
        """Serialize + compress all pages.  No lock required (paper §4.1).

        The single compression code path behind both ROOT-style IMT in the
        sequential writer and the shared writer-owned pool of the parallel
        writer.  Pass 1 preconditions whole columns in O(1) vectorized
        calls on this thread; pass 2 compresses chunk-granular jobs — over
        ``pool`` (any Executor with ``map``) when given, serially
        otherwise, with byte-identical output either way.

        While the adaptive policy is still *sampling* a column, only its
        next ``sample_pages`` pages are compressed up front (the trial);
        the column's remaining pages are marked ``_PENDING`` and resolve
        — mid-seal, once the trial results are recorded — to either the
        codec or raw storage, so a doomed codec never burns more than the
        sample on its first cluster.
        """
        t0 = _ns()
        # zone maps come first: the column views are still contiguous and
        # untouched (gathering may detach raw-aliased buffers later)
        zonemaps = self._zone_maps()
        # pass 1: column-batched preconditioning -> per-page plan
        # [column, n_elements, raw_u8_view, codec, level] (mutable: the
        # codec slot of _PENDING pages is resolved in pass 2).  Each
        # column gets its own scratch key so every page's payload stays
        # alive until assembly.
        plan: List[List] = []
        for col in self._specs:
            elems = self._cols[col.index].view()
            n = len(elems)
            if n == 0:
                continue
            per = self._page_elems[col.index]
            itemb = elems.dtype.itemsize
            codec, level = self._page_codec(col.index)
            budget = None
            if (
                self._policy is not None
                and codec != comp.CODEC_NONE
                and self._policy.decision(col.index) is None
            ):
                budget = self._policy.remaining_sample(col.index)
            raw_all = precondition_column_pages(
                elems, col.encoding, per, self._scratch,
                out_key=f"u8:{col.index}",
            )
            for pi, start in enumerate(range(0, n, per)):
                count = min(per, n - start)
                page_codec = (
                    _PENDING if budget is not None and pi >= budget else codec
                )
                plan.append([
                    col.index, count,
                    raw_all[start * itemb : (start + count) * itemb],
                    page_codec, level,
                ])
        # pass 2: chunk-granular compression
        if pool is None:
            payloads, build_ns = self._compress_serial(plan)
        else:
            payloads, build_ns = self._compress_pooled(plan, pool)
        final, total = self._finalize(plan, payloads)
        # element counts BEFORE gathering: _detach_aliased hands raw-page
        # columns' storage to the sealed cluster, emptying the buffers
        n_elements = [len(c) for c in self._cols]
        recycle = None
        if self.scatter:
            blob = None
            iovecs, descs, compress_ns, codec_stats, recycle = self._gather(
                plan, final, build_ns
            )
        else:
            iovecs = None
            blob, descs, compress_ns, codec_stats = self._assemble(
                plan, final, build_ns, total
            )
        sealed = SealedCluster(
            blob=blob,
            n_entries=self.n_entries,
            n_elements=n_elements,
            pages=descs,
            uncompressed_bytes=self.uncompressed_bytes,
            seal_ns=_ns() - t0,
            compress_ns=compress_ns,
            codec_stats=codec_stats,
            iovecs=iovecs,
            nbytes=total,
            recycle=recycle,
            zonemaps=zonemaps,
        )
        self._reset()
        return sealed

    def _record_trial(self, ci: int, raw_len: int, size: int,
                      ns: int = 0) -> None:
        if self._policy is not None:
            self._policy.record(ci, raw_len, size, ns)

    def _resolve_pending(self, ci: int) -> int:
        """A _PENDING page's codec, once its column's trial is recorded.

        Falls back to the configured codec when the sample is still short
        (the column simply had fewer pages than the sample wants)."""
        codec, _level = self._page_codec(ci)
        return codec

    def _compress_serial(self, plan):
        """Compress every planned page on this thread (member-framed)."""
        payloads: List[Optional[List[bytes]]] = []
        build_ns: List[int] = []
        for entry in plan:
            ci, _count, raw, codec, level = entry
            if codec == _PENDING:
                # the column's trial pages precede this page in the plan,
                # so their ratios are recorded by now
                codec = entry[3] = self._resolve_pending(ci)
            if codec == comp.CODEC_NONE:
                payloads.append(None)
                build_ns.append(0)
                continue
            tb = _ns()
            parts = comp.compress_parts(raw, codec, level, self.chunk_bytes)
            build_ns.append(_ns() - tb)
            payloads.append(parts)
            self._record_trial(ci, len(raw), sum(len(p) for p in parts),
                               build_ns[-1])
        return payloads, build_ns

    def _compress_pooled(self, plan, pool):
        """Distribute chunk-granular compression jobs over ``pool``.

        Jobs are (page, member) pairs: one small page is one job, a page
        above ``chunk_bytes`` fans out into one job per member — which is
        how a single producer's seal saturates the whole pool.  ``map``
        preserves order, so reassembly (and the resulting bytes) match
        the serial path exactly.  _PENDING pages wait for the first
        phase's trial results, then compress (or store) in a second
        phase — an extra barrier paid only while the policy samples.
        """
        payloads: List[Optional[List[bytes]]] = [None] * len(plan)
        build_ns: List[int] = [0] * len(plan)

        def run(job):
            i, a, b = job
            _ci, _count, raw, codec, level = plan[i]
            c = comp.require(codec)
            if level < 0:
                level = c.default_level
            tb = _ns()
            out = c.compress(memoryview(raw)[a:b], level)
            return i, out, _ns() - tb

        def submit(indices):
            jobs: List[Tuple[int, int, int]] = []
            for i in indices:
                raw = plan[i][2]
                for a, b in comp.chunk_ranges(len(raw), self.chunk_bytes):
                    jobs.append((i, a, b))
            for i, out, dt in pool.map(run, jobs):
                if payloads[i] is None:
                    payloads[i] = []
                payloads[i].append(out)
                build_ns[i] += dt
            for i in indices:
                self._record_trial(
                    plan[i][0], len(plan[i][2]),
                    sum(len(p) for p in payloads[i]), build_ns[i],
                )

        pending = [i for i, e in enumerate(plan) if e[3] == _PENDING]
        submit([
            i for i, e in enumerate(plan)
            if e[3] not in (comp.CODEC_NONE, _PENDING)
        ])
        if pending:
            for i in pending:
                plan[i][3] = self._resolve_pending(plan[i][0])
            submit([i for i in pending if plan[i][3] != comp.CODEC_NONE])
        return payloads, build_ns

    def _finalize(self, plan, payloads):
        """Per-page fallback decisions: ``[(parts|None, used_codec, size)]``.

        ``parts is None`` means the page stores its raw preconditioned
        bytes verbatim (``CODEC_NONE``) — either because no codec was
        configured or because compression did not shrink it (ROOT's
        store-uncompressed fallback).
        """
        final: List[Tuple[Optional[List[bytes]], int, int]] = []
        total = 0
        for (ci, _count, raw, codec, _level), parts in zip(plan, payloads):
            nbytes = len(raw)
            if parts is None:
                used, size = comp.CODEC_NONE, nbytes
                parts = None
            else:
                size = sum(len(p) for p in parts)
                if size >= nbytes:
                    # Like ROOT, store uncompressed when compression does
                    # not shrink the page.
                    used, size, parts = comp.CODEC_NONE, nbytes, None
                else:
                    used = codec
            final.append((parts, used, size))
            total += size
        return final, total

    def _page_desc(self, ci, count, raw, parts, used, size, pos):
        """Build one page descriptor (checksum folded over the parts)."""
        crc = 0
        if self.checksum:
            for p in parts:
                # per-chunk CRCs fold into the page checksum
                # incrementally: equals the whole-payload crc32
                crc = zlib.crc32(p, crc)
        members = None
        if used != comp.CODEC_NONE and len(parts) > 1:
            members = [len(p) for p in parts]
        return PageDesc(
            column=ci,
            n_elements=count,
            offset=pos,
            size=size,
            uncompressed_size=len(raw),
            checksum=crc,
            codec=used,
            members=members,
            member_chunk=self.chunk_bytes if members else 0,
        )

    def _assemble(self, plan, final, build_ns, total):
        """Checksums + single-allocation assembly (the reference path)."""
        blob = bytearray(total)
        mv = memoryview(blob)
        descs: List[PageDesc] = []
        codec_stats: Dict[int, List[int]] = {}
        compress_ns = 0
        pos = 0
        for (ci, count, raw, _codec, _level), (parts, used, size), ns in zip(
            plan, final, build_ns
        ):
            if parts is None:
                parts = (raw,)
            descs.append(self._page_desc(ci, count, raw, parts, used, size, pos))
            for p in parts:
                mv[pos : pos + len(p)] = p
                pos += len(p)
            compress_ns += ns
            st = codec_stats.setdefault(used, [0, 0, 0, 0])
            st[0] += 1
            st[1] += len(raw)
            st[2] += size
            st[3] += ns
        return blob, descs, compress_ns, codec_stats

    def _gather(self, plan, final, build_ns):
        """Zero-copy iovec plan: page payloads in offset order, no blob.

        Byte-identical to :meth:`_assemble`'s blob (the benchmarks and
        tests assert it), minus the full-cluster memcpy.  Raw-stored parts
        are views of the builder's preconditioning buffers; those buffers
        are detached (see :meth:`_detach_aliased`) so the plan stays valid
        while this builder refills and the write drains in the background.
        """
        iovecs: List = []
        descs: List[PageDesc] = []
        codec_stats: Dict[int, List[int]] = {}
        compress_ns = 0
        pos = 0
        alias_cols = set()
        for (ci, count, raw, _codec, _level), (parts, used, size), ns in zip(
            plan, final, build_ns
        ):
            if parts is None:
                parts = (raw,)
                alias_cols.add(ci)
            descs.append(self._page_desc(ci, count, raw, parts, used, size, pos))
            for p in parts:
                # normalize ndarray views to memoryviews: every sink's
                # pwritev (and bytearray slice assignment) accepts those
                iovecs.append(memoryview(p) if isinstance(p, np.ndarray) else p)
                pos += len(p)
            compress_ns += ns
            st = codec_stats.setdefault(used, [0, 0, 0, 0])
            st[0] += 1
            st[1] += len(raw)
            st[2] += size
            st[3] += ns
        recycle = self._detach_aliased(alias_cols)
        return iovecs, descs, compress_ns, codec_stats, recycle

    def _detach_aliased(self, alias_cols) -> Optional[List]:
        """Hand ownership of raw-aliased buffers to the sealed cluster.

        A raw-stored part is a view of either this builder's per-column
        preconditioning scratch (split/dzs encodings) or the live
        :class:`ColumnBuffer` storage (``none`` encoding).  numpy views
        keep their base arrays alive, so the only hazard is *reuse*: the
        next fill/seal of this builder would overwrite the bytes before a
        write-behind commit drains them.  Dropping the scratch slot /
        detaching the ColumnBuffer storage makes the next cluster allocate
        fresh buffers — recycled from the writer's :class:`BufferPool`
        when one is attached, a fresh O(1) allocation otherwise; either
        way no O(bytes) assembly memcpy.  Columns whose pages all
        compressed keep their buffers for steady-state reuse.

        Returns the detached arrays so the sealed cluster can carry them
        to the I/O engine, which returns them to the pool when the
        cluster's last write lands (``SealedCluster.recycle``).
        """
        if not alias_cols:
            return None
        detached: List = []
        for col in self._specs:
            if col.index not in alias_cols:
                continue
            if col.encoding == ENC_NONE:
                detached.append(self._cols[col.index].detach())
            else:
                buf = self._scratch._bufs.pop(f"u8:{col.index}", None)
                if buf is not None:
                    detached.append(buf)
        return detached if self._bufpool is not None else None

    # -- page draining (unbuffered mode) -------------------------------------

    def drain_full_pages(self, pool=None) -> List[Tuple[bytes, PageDesc, int]]:
        """Build pages for every column that holds >= one full page.

        Used by the page-granular ("unbuffered") writer: compressed pages
        are written out immediately, only their descriptors are retained
        until the cluster is finalized (paper §5).  ``pool`` parallelizes
        the members of chunk-framed pages.  Yields ``(payload, desc,
        build_ns)`` so writer stats can attribute the build time per codec.
        """
        out: List[Tuple[bytes, PageDesc, int]] = []
        for col in self._specs:
            per = self._page_elems[col.index]
            start = self._drained[col.index]
            pending = len(self._cols[col.index]) - start
            if pending < per:
                continue
            while pending >= per:
                out.append(self._drain_one(col, start, start + per, pool))
                start += per
                pending -= per
            self._drained[col.index] = start
        return out

    def drain_rest(self, pool=None) -> List[Tuple[bytes, PageDesc, int]]:
        """Build the final partial pages (cluster finalization)."""
        out: List[Tuple[bytes, PageDesc, int]] = []
        for col in self._specs:
            start = self._drained[col.index]
            per = self._page_elems[col.index]
            end = len(self._cols[col.index])
            while start < end:
                payload, desc, ns = self._drain_one(
                    col, start, start + per, pool
                )
                out.append((payload, desc, ns))
                start += desc.n_elements
            self._drained[col.index] = start
        return out

    def _drain_one(self, col, start, stop, pool):
        codec, level = self._page_codec(col.index)
        elems = self._cols[col.index].view(start, stop)
        if self.zone_maps:
            self._zone_page_row(col, elems, start, start + len(elems))
        t0 = _ns()
        payload, desc = build_page(
            col, elems, codec, level, self.checksum, self.chunk_bytes, pool,
            buffer_pool=self._bufpool,
        )
        build_ns = _ns() - t0
        if self._policy is not None and codec != comp.CODEC_NONE:
            # after an in-page raw fallback desc.size == uncompressed_size,
            # which records as ratio 1.0 — the right signal either way
            self._policy.record(col.index, desc.uncompressed_size, desc.size,
                                build_ns)
        return payload, desc, build_ns

    def finish_unbuffered(self) -> Tuple[int, List[int], int]:
        """Return (n_entries, per-column n_elements, uncompressed) and reset."""
        res = (self.n_entries, [len(c) for c in self._cols], self.uncompressed_bytes)
        self._reset()
        return res

    def take_zonemaps(self) -> Optional[Dict[int, Dict[str, list]]]:
        """Zone-map rows accumulated by page draining (unbuffered mode).
        Must be called before :meth:`finish_unbuffered`'s reset."""
        if not self.zone_maps or not self._zm_acc:
            return None
        zm = self._zm_acc
        self._zm_acc = {}
        return zm

    def _reset(self) -> None:
        # keep the ColumnBuffer storage: steady-state refills are
        # allocation-free (and pipelined sealing hands builders back
        # for exactly this reuse)
        for c in self._cols:
            c.reset()
        self._acc_offset = [0] * self.schema.n_columns
        self._drained = [0] * self.schema.n_columns
        self._zm_acc = {}
        self.n_entries = 0
        self.uncompressed_bytes = 0
