"""Clusters: the (default) unit of writing (paper §4, §5).

A cluster holds all pages of a consecutive range of entries.  Offset
columns are accumulated as *sizes* and integrated to **cluster-relative**
offsets at seal time, which makes the sealed byte blob relocatable: it can
be committed at any file offset without content changes — the property
that lets serialization and compression run with no synchronization.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import compression as comp
from .encoding import sizes_to_offsets
from .pages import PageDesc, build_page, elements_per_page
from .schema import KIND_OFFSET, OFFSET_DTYPE, ColumnBatch, Schema, decompose_entry


@dataclass
class SealedCluster:
    """A serialized+compressed cluster, ready to commit anywhere.

    ``pages[i]`` descriptors carry cluster-relative offsets into ``blob``.
    """

    blob: bytes
    n_entries: int
    n_elements: List[int]          # per column
    pages: List[PageDesc]          # cluster-relative offsets
    uncompressed_bytes: int
    seal_ns: int = 0

    @property
    def size(self) -> int:
        return len(self.blob)

    def rebase(self, base: int) -> List[PageDesc]:
        return [p.rebase(base) for p in self.pages]


class ClusterBuilder:
    """Accumulates decomposed entries and seals them into a cluster.

    Also supports *page draining* for the unbuffered (page-granular) writer
    mode: whenever a column holds a full page of elements it can be built
    and handed out immediately.
    """

    def __init__(self, schema: Schema, page_size: int, codec: int, level: int = -1,
                 checksum: bool = True):
        self.schema = schema
        self.page_size = page_size
        self.codec = codec
        self.level = level
        self.checksum = checksum
        self._chunks: List[List[np.ndarray]] = [[] for _ in schema.columns]
        # cluster-relative running end-offset per offset column
        self._acc_offset = [0] * schema.n_columns
        self._n_elements = [0] * schema.n_columns
        self.n_entries = 0
        self.uncompressed_bytes = 0
        self._page_elems = [
            elements_per_page(c, page_size) for c in schema.columns
        ]
        # unbuffered mode: elements already drained into standalone pages
        self._drained: List[int] = [0] * schema.n_columns

    # -- filling -----------------------------------------------------------

    def fill(self, entry: Dict) -> None:
        arrays = decompose_entry(self.schema, entry)
        self._append_arrays(arrays, 1)

    def fill_batch(self, batch: ColumnBatch) -> None:
        if batch.schema.n_columns != self.schema.n_columns:
            raise ValueError("batch schema does not match writer schema")
        arrays = [batch.data[c.index] for c in self.schema.columns]
        self._append_arrays(arrays, batch.n_entries)

    def _append_arrays(self, arrays: Sequence[np.ndarray], n_entries: int) -> None:
        for col in self.schema.columns:
            a = arrays[col.index]
            if col.kind == KIND_OFFSET:
                # sizes -> cluster-relative end offsets, continuing the
                # running sum of this cluster
                offs = sizes_to_offsets(a) + self._acc_offset[col.index]
                if len(offs):
                    self._acc_offset[col.index] = int(offs[-1])
                a = offs
            if len(a):
                self._chunks[col.index].append(a)
                self._n_elements[col.index] += len(a)
                self.uncompressed_bytes += a.nbytes
        self.n_entries += n_entries

    @property
    def is_empty(self) -> bool:
        return self.n_entries == 0

    # -- sealing (buffered mode) --------------------------------------------

    def _column_elements(self, idx: int) -> np.ndarray:
        chunks = self._chunks[idx]
        if not chunks:
            col = self.schema.columns[idx]
            dt = OFFSET_DTYPE if col.kind == KIND_OFFSET else col.dtype
            return np.empty(0, dtype=dt)
        if len(chunks) == 1:
            return chunks[0]
        return np.concatenate(chunks)

    def seal(self) -> SealedCluster:
        """Serialize + compress all pages.  No lock required (paper §4.1)."""
        t0 = time.perf_counter_ns()
        parts: List[bytes] = []
        descs: List[PageDesc] = []
        pos = 0
        for col in self.schema.columns:
            elems = self._column_elements(col.index)
            per = self._page_elems[col.index]
            for start in range(0, len(elems), per):
                payload, desc = build_page(
                    col, elems[start : start + per], self.codec, self.level,
                    self.checksum,
                )
                desc.offset = pos
                pos += desc.size
                parts.append(payload)
                descs.append(desc)
        sealed = SealedCluster(
            blob=b"".join(parts),
            n_entries=self.n_entries,
            n_elements=list(self._n_elements),
            pages=descs,
            uncompressed_bytes=self.uncompressed_bytes,
            seal_ns=time.perf_counter_ns() - t0,
        )
        self._reset()
        return sealed

    # -- page draining (unbuffered mode) -------------------------------------

    def drain_full_pages(self) -> List[Tuple[bytes, PageDesc]]:
        """Build pages for every column that holds >= one full page.

        Used by the page-granular ("unbuffered") writer: compressed pages
        are written out immediately, only their descriptors are retained
        until the cluster is finalized (paper §5).
        """
        out: List[Tuple[bytes, PageDesc]] = []
        for col in self.schema.columns:
            per = self._page_elems[col.index]
            pending = self._n_elements[col.index] - self._drained[col.index]
            if pending < per:
                continue
            elems = self._column_elements(col.index)
            self._chunks[col.index] = [elems]  # canonicalize
            start = self._drained[col.index]
            while pending >= per:
                payload, desc = build_page(
                    col, elems[start : start + per], self.codec, self.level,
                    self.checksum,
                )
                out.append((payload, desc))
                start += per
                pending -= per
            self._drained[col.index] = start
        return out

    def drain_rest(self) -> List[Tuple[bytes, PageDesc]]:
        """Build the final partial pages (cluster finalization)."""
        out: List[Tuple[bytes, PageDesc]] = []
        for col in self.schema.columns:
            elems = self._column_elements(col.index)
            start = self._drained[col.index]
            per = self._page_elems[col.index]
            while start < len(elems):
                payload, desc = build_page(
                    col, elems[start : start + per], self.codec, self.level,
                    self.checksum,
                )
                out.append((payload, desc))
                start += desc.n_elements
            self._drained[col.index] = start
        return out

    def finish_unbuffered(self) -> Tuple[int, List[int], int]:
        """Return (n_entries, per-column n_elements, uncompressed) and reset."""
        res = (self.n_entries, list(self._n_elements), self.uncompressed_bytes)
        self._reset()
        return res

    def _reset(self) -> None:
        self._chunks = [[] for _ in self.schema.columns]
        self._acc_offset = [0] * self.schema.n_columns
        self._n_elements = [0] * self.schema.n_columns
        self._drained = [0] * self.schema.n_columns
        self.n_entries = 0
        self.uncompressed_bytes = 0
