"""Clusters: the (default) unit of writing (paper §4, §5).

A cluster holds all pages of a consecutive range of entries.  Offset
columns are accumulated as *sizes* and integrated to **cluster-relative**
offsets at seal time, which makes the sealed byte blob relocatable: it can
be committed at any file offset without content changes — the property
that lets serialization and compression run with no synchronization.

Hot-path layout: each column accumulates into a contiguous, amortized-
doubling :class:`~repro.core.colbuf.ColumnBuffer` — appends are vectorized
copies, offset integration happens in place on the reserved tail, and page
extraction at seal time is a zero-copy view slice (no ``np.concatenate``).
``seal()`` optionally distributes page compression over a writer-owned
thread pool; zlib/lzma/bz2 release the GIL, so pages of one cluster
compress truly in parallel.  This is the ONE compression code path shared
by the sequential writer (IMT mode) and the parallel writer.
"""

from __future__ import annotations

import time
import zlib
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from . import compression as comp
from .colbuf import ColumnBuffer
from .encoding import EncodeScratch, integrate_sizes, precondition_column_pages
from .pages import PageDesc, build_page, elements_per_page
from .schema import KIND_OFFSET, OFFSET_DTYPE, ColumnBatch, Schema, decompose_entry


@dataclass
class SealedCluster:
    """A serialized+compressed cluster, ready to commit anywhere.

    ``pages[i]`` descriptors carry cluster-relative offsets into ``blob``
    (a bytes-like single allocation).
    """

    blob: bytes                    # bytes-like (bytearray from seal())
    n_entries: int
    n_elements: List[int]          # per column
    pages: List[PageDesc]          # cluster-relative offsets
    uncompressed_bytes: int
    seal_ns: int = 0               # wall time of the whole seal
    compress_ns: int = 0           # summed per-page build time (CPU view)

    @property
    def size(self) -> int:
        return len(self.blob)

    def rebase(self, base: int) -> List[PageDesc]:
        return [p.rebase(base) for p in self.pages]


def _build_page_timed(job, codec: int, level: int, checksum: bool):
    col, elems = job
    t0 = time.perf_counter_ns()
    payload, desc = build_page(col, elems, codec, level, checksum)
    return payload, desc, time.perf_counter_ns() - t0


class ClusterBuilder:
    """Accumulates decomposed entries and seals them into a cluster.

    Also supports *page draining* for the unbuffered (page-granular) writer
    mode: whenever a column holds a full page of elements it can be built
    and handed out immediately.

    Builders are reusable: after :meth:`seal` / :meth:`finish_unbuffered`
    the column buffers keep their storage, so refilling performs no
    allocations in steady state (this is what double-buffered pipelined
    sealing relies on).
    """

    def __init__(self, schema: Schema, page_size: int, codec: int, level: int = -1,
                 checksum: bool = True):
        self.schema = schema
        self.page_size = page_size
        self.codec = codec
        self.level = level
        self.checksum = checksum
        self._page_elems = [
            elements_per_page(c, page_size) for c in schema.columns
        ]
        self._cols = [
            ColumnBuffer(
                OFFSET_DTYPE if c.kind == KIND_OFFSET else c.dtype,
                capacity=self._page_elems[c.index],
            )
            for c in schema.columns
        ]
        # cluster-relative running end-offset per offset column
        self._acc_offset = [0] * schema.n_columns
        self.n_entries = 0
        self.uncompressed_bytes = 0
        # unbuffered mode: elements already drained into standalone pages
        self._drained: List[int] = [0] * schema.n_columns
        # seal() runs on one thread at a time; the scratch amortizes the
        # column-wide preconditioning temporaries across clusters
        self._scratch = EncodeScratch()

    # -- filling -----------------------------------------------------------

    def fill(self, entry: Dict) -> None:
        arrays = decompose_entry(self.schema, entry)
        self._append_arrays(arrays, 1)

    def fill_batch(self, batch: ColumnBatch) -> None:
        if batch.schema.n_columns != self.schema.n_columns:
            raise ValueError("batch schema does not match writer schema")
        arrays = [batch.data[c.index] for c in self.schema.columns]
        self._append_arrays(arrays, batch.n_entries)

    def _append_arrays(self, arrays: Sequence[np.ndarray], n_entries: int) -> None:
        for col in self.schema.columns:
            a = arrays[col.index]
            n = len(a)
            if not n:
                continue
            buf = self._cols[col.index]
            if col.kind == KIND_OFFSET:
                # sizes -> cluster-relative end offsets, integrated in
                # place on the reserved buffer tail (no temporary)
                dst = buf.reserve(n)
                integrate_sizes(a, base=self._acc_offset[col.index], out=dst)
                self._acc_offset[col.index] = int(dst[-1])
            else:
                buf.extend(a)
            self.uncompressed_bytes += n * buf.dtype.itemsize
        self.n_entries += n_entries

    @property
    def is_empty(self) -> bool:
        return self.n_entries == 0

    def n_elements(self, idx: int) -> int:
        return len(self._cols[idx])

    # -- sealing (buffered mode) --------------------------------------------

    def _column_elements(self, idx: int) -> np.ndarray:
        """Zero-copy view of all elements accumulated for column ``idx``."""
        return self._cols[idx].view()

    def _page_jobs(self) -> List[Tuple]:
        jobs: List[Tuple] = []
        for col in self.schema.columns:
            elems = self._cols[col.index].view()
            per = self._page_elems[col.index]
            for start in range(0, len(elems), per):
                jobs.append((col, elems[start : start + per]))
        return jobs

    def seal(self, pool=None) -> SealedCluster:
        """Serialize + compress all pages.  No lock required (paper §4.1).

        The single compression code path behind both ROOT-style IMT in the
        sequential writer and the shared writer-owned pool of the parallel
        writer.  With ``pool`` (any Executor with ``map``) page builds are
        distributed over the pool's threads; serially, whole columns are
        preconditioned in O(1) vectorized calls and, for the ``none``
        codec, written straight into the blob.
        """
        t0 = time.perf_counter_ns()
        if pool is None:
            blob, descs, compress_ns = self._seal_serial()
        else:
            jobs = self._page_jobs()
            results = list(
                pool.map(
                    lambda j: _build_page_timed(
                        j, self.codec, self.level, self.checksum
                    ),
                    jobs,
                )
            )
            # single-allocation blob assembly
            total = sum(r[1].size for r in results)
            blob = bytearray(total)
            mv = memoryview(blob)
            descs = []
            pos = 0
            compress_ns = 0
            for payload, desc, build_ns in results:
                desc.offset = pos
                mv[pos : pos + desc.size] = payload
                pos += desc.size
                descs.append(desc)
                compress_ns += build_ns
        sealed = SealedCluster(
            blob=blob,
            n_entries=self.n_entries,
            n_elements=[len(c) for c in self._cols],
            pages=descs,
            uncompressed_bytes=self.uncompressed_bytes,
            seal_ns=time.perf_counter_ns() - t0,
            compress_ns=compress_ns,
        )
        self._reset()
        return sealed

    def _seal_serial(self):
        """Column-batched serial seal: one precondition pass per column.

        Bit-identical to the per-page path (``build_page``), minus its
        per-page Python dispatch, temporaries and copies.
        """
        store = self.codec == comp.CODEC_NONE
        if store:
            # page sizes are known up front: build the blob in place
            blob = bytearray(
                sum(len(c) * c.dtype.itemsize for c in self._cols)
            )
            target = np.frombuffer(memoryview(blob), dtype=np.uint8)
        else:
            blob = None
            target = None
            parts: List[bytes] = []
        descs: List[PageDesc] = []
        pos = 0
        compress_ns = 0
        for col in self.schema.columns:
            elems = self._cols[col.index].view()
            n = len(elems)
            if n == 0:
                continue
            per = self._page_elems[col.index]
            itemb = elems.dtype.itemsize
            raw_all = precondition_column_pages(
                elems, col.encoding, per, self._scratch
            )
            for start in range(0, n, per):
                count = min(per, n - start)
                raw = raw_all[start * itemb : (start + count) * itemb]
                nbytes = count * itemb
                if store:
                    payload_len = nbytes
                    target[pos : pos + nbytes] = raw
                    crc_src = target[pos : pos + nbytes]
                    used_codec = comp.CODEC_NONE
                else:
                    tb = time.perf_counter_ns()
                    payload = comp.compress(raw, self.codec, self.level)
                    compress_ns += time.perf_counter_ns() - tb
                    used_codec = self.codec
                    if len(payload) >= nbytes:
                        payload, used_codec = bytes(raw), comp.CODEC_NONE
                    payload_len = len(payload)
                    parts.append(payload)
                    crc_src = payload
                descs.append(PageDesc(
                    column=col.index,
                    n_elements=count,
                    offset=pos,
                    size=payload_len,
                    uncompressed_size=nbytes,
                    checksum=zlib.crc32(crc_src) if self.checksum else 0,
                    codec=used_codec,
                ))
                pos += payload_len
        if not store:
            blob = bytearray(pos)
            mv = memoryview(blob)
            at = 0
            for payload in parts:
                mv[at : at + len(payload)] = payload
                at += len(payload)
        return blob, descs, compress_ns

    # -- page draining (unbuffered mode) -------------------------------------

    def drain_full_pages(self) -> List[Tuple[bytes, PageDesc]]:
        """Build pages for every column that holds >= one full page.

        Used by the page-granular ("unbuffered") writer: compressed pages
        are written out immediately, only their descriptors are retained
        until the cluster is finalized (paper §5).
        """
        out: List[Tuple[bytes, PageDesc]] = []
        for col in self.schema.columns:
            per = self._page_elems[col.index]
            start = self._drained[col.index]
            pending = len(self._cols[col.index]) - start
            if pending < per:
                continue
            while pending >= per:
                elems = self._cols[col.index].view(start, start + per)
                payload, desc = build_page(
                    col, elems, self.codec, self.level, self.checksum,
                )
                out.append((payload, desc))
                start += per
                pending -= per
            self._drained[col.index] = start
        return out

    def drain_rest(self) -> List[Tuple[bytes, PageDesc]]:
        """Build the final partial pages (cluster finalization)."""
        out: List[Tuple[bytes, PageDesc]] = []
        for col in self.schema.columns:
            start = self._drained[col.index]
            per = self._page_elems[col.index]
            end = len(self._cols[col.index])
            while start < end:
                elems = self._cols[col.index].view(start, start + per)
                payload, desc = build_page(
                    col, elems, self.codec, self.level, self.checksum,
                )
                out.append((payload, desc))
                start += desc.n_elements
            self._drained[col.index] = start
        return out

    def finish_unbuffered(self) -> Tuple[int, List[int], int]:
        """Return (n_entries, per-column n_elements, uncompressed) and reset."""
        res = (self.n_entries, [len(c) for c in self._cols], self.uncompressed_bytes)
        self._reset()
        return res

    def _reset(self) -> None:
        # keep the ColumnBuffer storage: steady-state refills are
        # allocation-free (and pipelined sealing hands builders back
        # for exactly this reuse)
        for c in self._cols:
            c.reset()
        self._acc_offset = [0] * self.schema.n_columns
        self._drained = [0] * self.schema.n_columns
        self.n_entries = 0
        self.uncompressed_bytes = 0
