"""Deterministic fault injection for the I/O paths (DESIGN.md §8.1, §10).

:class:`FaultInjectingSink` wraps any :class:`~repro.core.container.Sink`
and injects storage faults on the way through: transient/permanent
``EIO``/``ENOSPC`` errors, short (torn) writes that persist a prefix and
then fail, torn reads, fsync failures, latency spikes, and *process-kill
points* that freeze the file at an exact byte count — the writer sees an
unrecoverable exception and everything written after the kill point is
lost, which is how tests and ``tools/chaos.py`` produce the torn files
that :mod:`repro.core.recover` must salvage.

Faults come from two sources, combinable:

* **scripted** — an ordered list of :class:`FaultSpec` rules, each firing
  on a chosen operation at a chosen call index / file-offset window /
  cumulative-byte threshold, a bounded or unbounded number of times;
* **seeded** — a ``random.Random(seed)`` schedule injecting transient
  errors at ``error_rate`` per matching call.  Same seed, same workload →
  same fault sequence, so chaos runs are reproducible.

The decision core lives in :class:`FaultSchedule`, keyed by free-form op
names — the sink keys it by ``"write"``/``"fsync"``/``"read"``; the
remote :class:`~repro.core.remote.FakeTransport` reuses the same engine
keyed by transport ops (``"put"``/``"part"``/``"get"``/``"create"``/
``"complete"``/``"abort"``), so one fault-plan vocabulary covers local
device chaos and simulated object-store chaos alike.

Because the base :class:`Sink.pwritev` decomposes vectored writes into one
``pwrite`` per part (and every concrete sink falls back to it when
``pwrite`` is overridden), this wrapper observes *every byte* of every
engine path — monolithic, striped, write-behind, and ring submission all
funnel through here.  The same holds on the read side: the base
``pread_into`` copies through ``pread``, and :class:`FaultInjectingSink`
additionally overrides ``pread_into`` itself so the reader's zero-copy
staging path sees the schedule first-hand (torn reads fill a prefix into
the caller's buffer before failing — exercising the stale-tail contract).
A wrapped sink never advertises ``native_ring``, so the engine cannot
bypass it through the kernel.

Byte-count determinism: ``at_byte`` thresholds count bytes *persisted to
the inner sink* (retried bytes count again).  With a single producer and
no write-behind the writer emits the file front to back, so the persisted
count equals the file offset — kill points map exactly onto the on-disk
layout.
"""

from __future__ import annotations

import errno
import os
import random
import threading
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from .container import MemorySink, Sink


class ProcessKilled(RuntimeError):
    """Raised when a kill-point fires: models the writing process dying
    mid-write.  Deliberately NOT an ``OSError`` — no retry policy applies;
    the failure is terminal and poisons the writer."""


@dataclass
class FaultSpec:
    """One scripted fault rule.

    op        -- "write", "fsync", or "read" on a sink; a transport op
                 name ("put", "part", "get", ...) on a FakeTransport
    kind      -- "error" | "short" | "latency" | "kill"
    err       -- errno for error/short kinds
    at_call   -- fire on the Nth matching call (0-based); None = any call
    at_offset -- fire when the op touches file offsets [lo, hi); None = any
    at_byte   -- fire when cumulative persisted write bytes cross this
                 threshold (write ops only); None = any
    count     -- times to fire (-1 = every matching call, i.e. permanent)
    fraction  -- portion of the write persisted (or of the read delivered)
                 before a short/kill fault when at_byte does not pin the
                 split point
    delay_s   -- sleep for latency faults
    """

    op: str = "write"
    kind: str = "error"
    err: int = errno.EIO
    at_call: Optional[int] = None
    at_offset: Optional[Tuple[int, int]] = None
    at_byte: Optional[int] = None
    count: int = 1
    fraction: float = 0.5
    delay_s: float = 0.0

    # -- common scenarios ---------------------------------------------------

    @staticmethod
    def transient_error(err: int = errno.EIO, count: int = 1, op: str = "write",
                        at_call: Optional[int] = None,
                        at_offset: Optional[Tuple[int, int]] = None) -> "FaultSpec":
        return FaultSpec(op=op, kind="error", err=err, count=count,
                         at_call=at_call, at_offset=at_offset)

    @staticmethod
    def permanent_error(err: int = errno.EIO, op: str = "write",
                        at_call: Optional[int] = None) -> "FaultSpec":
        return FaultSpec(op=op, kind="error", err=err, count=-1,
                         at_call=at_call)

    @staticmethod
    def short_write(err: int = errno.EIO, fraction: float = 0.5,
                    count: int = 1, at_call: Optional[int] = None,
                    at_byte: Optional[int] = None) -> "FaultSpec":
        return FaultSpec(op="write", kind="short", err=err, count=count,
                         fraction=fraction, at_call=at_call, at_byte=at_byte)

    @staticmethod
    def short_read(err: int = errno.EIO, fraction: float = 0.5,
                   count: int = 1, at_call: Optional[int] = None,
                   op: str = "read") -> "FaultSpec":
        """A torn response: a prefix of the requested bytes arrives, then
        the op fails with ``err`` (retryable — a fresh attempt may get the
        whole range)."""
        return FaultSpec(op=op, kind="short", err=err, count=count,
                         fraction=fraction, at_call=at_call)

    @staticmethod
    def fsync_error(err: int = errno.EIO, count: int = 1) -> "FaultSpec":
        return FaultSpec(op="fsync", kind="error", err=err, count=count)

    @staticmethod
    def latency(delay_s: float, op: str = "write", count: int = -1) -> "FaultSpec":
        return FaultSpec(op=op, kind="latency", delay_s=delay_s, count=count)

    @staticmethod
    def kill_at(byte: int, op: str = "write") -> "FaultSpec":
        """Kill the process once cumulative persisted bytes reach ``byte``:
        the crossing write persists exactly up to the threshold, then every
        subsequent operation raises :class:`ProcessKilled`."""
        return FaultSpec(op=op, kind="kill", at_byte=byte, count=1)


@dataclass
class FaultStats:
    errors: int = 0
    short_writes: int = 0
    short_reads: int = 0
    latencies: int = 0
    fsync_errors: int = 0
    kills: int = 0
    random_errors: int = 0

    @property
    def injected(self) -> int:
        return (self.errors + self.short_writes + self.short_reads
                + self.latencies + self.fsync_errors + self.kills)

    def as_dict(self) -> dict:
        return {
            "errors": self.errors, "short_writes": self.short_writes,
            "short_reads": self.short_reads,
            "latencies": self.latencies, "fsync_errors": self.fsync_errors,
            "kills": self.kills, "random_errors": self.random_errors,
            "injected": self.injected,
        }


def injected_os_error(err: int) -> OSError:
    return OSError(err, os.strerror(err) + " (injected)")


class FaultSchedule:
    """The scripted + seeded fault decision engine, keyed by op name.

    Holds the rule list, per-op call counters, the cumulative
    persisted-byte counter that ``at_byte`` rules trigger on, the
    dead-after-kill flag, and the :class:`FaultStats`.  Thread-safe.
    :class:`FaultInjectingSink` keys it by sink ops; the remote
    :class:`~repro.core.remote.FakeTransport` keys the identical engine
    by transport ops — one schedule, one vocabulary, two fault surfaces.
    """

    def __init__(
        self,
        faults: Sequence[FaultSpec] = (),
        seed: Optional[int] = None,
        error_rate: float = 0.0,
        errnos: Sequence[int] = (errno.EIO,),
        random_ops: Sequence[str] = ("write",),
    ) -> None:
        self._rules: List[FaultSpec] = list(faults)
        self._fired = [0] * len(self._rules)
        self._rng = random.Random(seed) if seed is not None else None
        self._error_rate = float(error_rate)
        self._errnos = tuple(errnos)
        self._random_ops = frozenset(random_ops)
        self._mu = threading.Lock()
        self._calls: dict = {}
        self.persisted_bytes = 0   # bytes actually persisted downstream
        self.dead = False          # a kill point fired
        self.killed_at: Optional[int] = None
        self.stats = FaultStats()

    def decide(self, op: str, offset: int = 0, nbytes: int = 0):
        """Pick the fault (if any) for this call.  Returns (rule, persisted)
        where ``persisted`` is the byte counter before this operation."""
        with self._mu:
            idx = self._calls.get(op, 0)
            self._calls[op] = idx + 1
            persisted = self.persisted_bytes
            for i, r in enumerate(self._rules):
                if r.op != op:
                    continue
                if r.count >= 0 and self._fired[i] >= r.count:
                    continue
                if r.at_call is not None and r.at_call != idx:
                    continue
                if r.at_offset is not None and not (
                        offset < r.at_offset[1] and offset + max(nbytes, 1) > r.at_offset[0]):
                    continue
                if r.at_byte is not None:
                    if not (persisted <= r.at_byte < persisted + nbytes or
                            (persisted >= r.at_byte and r.kind == "kill")):
                        continue
                self._fired[i] += 1
                return r, persisted
            if (self._rng is not None and op in self._random_ops
                    and self._error_rate > 0.0
                    and self._rng.random() < self._error_rate):
                self.stats.random_errors += 1
                err = self._rng.choice(self._errnos)
                return FaultSpec(op=op, kind="error", err=err), persisted
        return None, persisted

    def advance(self, n: int) -> None:
        """Account ``n`` bytes as persisted (the ``at_byte`` clock)."""
        with self._mu:
            self.persisted_bytes += n

    def note_kill(self, at_byte: int) -> None:
        self.stats.kills += 1
        self.dead = True
        self.killed_at = at_byte

    def check_dead(self) -> None:
        if self.dead:
            raise ProcessKilled(
                f"process killed at byte {self.killed_at}; sink is dead")


class FaultInjectingSink(Sink):
    """Wrap ``inner`` and inject the given faults (see module docstring).

    Covers every :class:`Sink` read/write entry point: ``pwrite`` and
    ``fsync`` directly, ``pwritev`` through the base one-``pwrite``-per-
    part decomposition (every concrete sink falls back to it when
    ``pwrite`` is overridden — the vectored fast paths check
    ``type(self).pwrite``), and both ``pread`` and ``pread_into`` — the
    latter explicitly, so the reader's zero-copy staging reads cannot
    bypass the schedule.
    """

    def __init__(
        self,
        inner: Sink,
        faults: Sequence[FaultSpec] = (),
        seed: Optional[int] = None,
        error_rate: float = 0.0,
        errnos: Sequence[int] = (errno.EIO,),
        random_ops: Sequence[str] = ("write",),
    ) -> None:
        super().__init__()
        self.inner = inner
        self.schedule = FaultSchedule(
            faults, seed=seed, error_rate=error_rate, errnos=errnos,
            random_ops=random_ops,
        )

    # -- back-compat views onto the schedule --------------------------------

    @property
    def faults(self) -> FaultStats:
        return self.schedule.stats

    @property
    def persisted_bytes(self) -> int:
        return self.schedule.persisted_bytes

    @property
    def dead(self) -> bool:
        return self.schedule.dead

    @property
    def killed_at(self) -> Optional[int]:
        return self.schedule.killed_at

    # -- layout delegation (the wrapper owns no bytes) ----------------------

    def reserve(self, size: int) -> int:
        off = self.inner.reserve(size)
        self._end = self.inner.size
        return off

    @property
    def size(self) -> int:
        return self.inner.size

    def fallocate(self, offset: int, size: int) -> None:
        super().fallocate(offset, size)
        self.inner.fallocate(offset, size)

    def readable(self) -> bool:
        return self.inner.readable()

    def close(self) -> None:
        # teardown always works, dead or alive: the writer's poisoned
        # close path must be able to release the sink
        self.inner.close()

    # -- fault scheduling ---------------------------------------------------

    def _decide(self, op: str, offset: int, nbytes: int):
        return self.schedule.decide(op, offset, nbytes)

    def _advance(self, n: int) -> None:
        self.schedule.advance(n)

    def _check_dead(self) -> None:
        self.schedule.check_dead()

    @staticmethod
    def _os_error(err: int) -> OSError:
        return injected_os_error(err)

    # -- faulted operations -------------------------------------------------

    def pwrite(self, offset: int, data) -> None:
        self._check_dead()
        n = len(data)
        rule, persisted = self._decide("write", offset, n)
        if rule is None:
            self.inner.pwrite(offset, data)
            self._advance(n)
            self._count_write(1, n)
            return
        if rule.kind == "latency":
            self.faults.latencies += 1
            time.sleep(rule.delay_s)
            self.inner.pwrite(offset, data)
            self._advance(n)
            self._count_write(1, n)
            return
        # split point for torn writes / kills
        if rule.at_byte is not None:
            keep = max(0, min(n, rule.at_byte - persisted))
        else:
            keep = int(n * rule.fraction)
        if rule.kind == "error":
            self.faults.errors += 1
            raise self._os_error(rule.err)
        if keep:
            self.inner.pwrite(offset, bytes(memoryview(data)[:keep]))
            self._advance(keep)
            self._count_write(1, keep)
        if rule.kind == "short":
            self.faults.short_writes += 1
            raise self._os_error(rule.err)
        # kill
        self.schedule.note_kill(persisted + keep)
        raise ProcessKilled(f"process killed at byte {self.killed_at}")

    def fsync(self) -> None:
        self._check_dead()
        rule, _ = self._decide("fsync", 0, 0)
        if rule is not None:
            if rule.kind == "latency":
                self.faults.latencies += 1
                time.sleep(rule.delay_s)
            elif rule.kind == "kill":
                self.schedule.note_kill(self.persisted_bytes)
                raise ProcessKilled(f"process killed at byte {self.killed_at}")
            else:
                self.faults.fsync_errors += 1
                raise self._os_error(rule.err)
        super().fsync()
        self.inner.fsync()

    def _read_fault(self, rule: FaultSpec) -> Optional[Tuple[int, float]]:
        """Handle a read-op rule: sleeps for latency (returns None), raises
        for plain errors, and returns ``(err, fraction)`` for torn reads so
        the caller can deliver the prefix its path supports."""
        if rule.kind == "latency":
            self.faults.latencies += 1
            time.sleep(rule.delay_s)
            return None
        if rule.kind == "short":
            self.faults.short_reads += 1
            return rule.err, rule.fraction
        self.faults.errors += 1
        raise self._os_error(rule.err)

    def pread(self, offset: int, size: int) -> bytes:
        self._check_dead()
        rule, _ = self._decide("read", offset, size)
        if rule is not None:
            torn = self._read_fault(rule)
            if torn is not None:
                # a bytes-returning pread has nowhere to leave a prefix:
                # the torn response is just the error
                raise self._os_error(torn[0])
        out = self.inner.pread(offset, size)
        self._count_read(1, len(out))
        return out

    def pread_into(self, offset: int, buf) -> int:
        """The zero-copy read path under the same schedule as ``pread``.

        Without this override the base class would still funnel through
        the faulted ``pread`` — but via an extra copy, and a torn read
        could never exercise the caller's stale-prefix handling.  Torn
        reads here fill ``fraction`` of the caller's buffer before
        raising, exactly like a device delivering a partial DMA.
        """
        self._check_dead()
        mv = memoryview(buf)
        n = len(mv)
        rule, _ = self._decide("read", offset, n)
        if rule is not None:
            torn = self._read_fault(rule)
            if torn is not None:
                err, fraction = torn
                keep = int(n * fraction)
                if keep:
                    self.inner.pread_into(offset, mv[:keep])
                raise self._os_error(err)
        got = self.inner.pread_into(offset, mv)
        self._count_read(1, got)
        return got


def crashed_file_bytes(fault_sink: FaultInjectingSink) -> bytes:
    """The inner file's bytes as a crash would leave them on disk.

    Reserved-but-never-written regions read back as zeros (a sparse file's
    holes); everything past the persisted region of a :class:`MemorySink`
    is whatever was reserved — exactly what ``recover_container`` has to
    cope with."""
    inner = fault_sink.inner
    if isinstance(inner, MemorySink):
        return bytes(inner.buf[: inner.size])
    raise TypeError("crashed_file_bytes needs a MemorySink inner")


def memory_sink_from_bytes(data: bytes, slack: int = 0) -> MemorySink:
    """A readable/appendable :class:`MemorySink` over existing file bytes
    (the in-memory analog of opening a torn file for recovery).  ``slack``
    preallocates append headroom — without it, appending even a small
    footer to a large file doubles the backing bytearray (a realloc a
    recovery *benchmark* must keep out of its timings; a real file sink
    has no such cost)."""
    ms = MemorySink(len(data) + slack)
    ms.buf[: len(data)] = data
    ms._end = len(data)
    return ms
