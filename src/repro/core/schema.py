"""Nested schema model and its decomposition into columns.

Mirrors RNTuple's field/column split (paper §3): acyclic nested data
structures are decomposed recursively into *fields*; variable-length
collections become an *offset column* pointing into the columns of the
element field.  Leaves map to columns of primitive fixed-size types.

Example (paper Fig. 1 / Table 1)::

    schema = Schema([
        Leaf("fId", "int32"),
        Collection("fTracks", Record("_0", [
            Leaf("fEnergy", "float32"),
            Collection("fIds", Leaf("_0", "int32")),
        ])),
    ])

producing columns::

    0 fId                    leaf  int32
    1 fTracks                offset int64
    2 fTracks._0.fEnergy     leaf  float32
    3 fTracks._0.fIds        offset int64
    4 fTracks._0.fIds._0     leaf  int32
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field as dc_field, replace as dc_replace
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

# ---------------------------------------------------------------------------
# Primitive types

_DTYPES: Dict[str, np.dtype] = {
    "bool": np.dtype(np.bool_),
    "int8": np.dtype(np.int8),
    "uint8": np.dtype(np.uint8),
    "int16": np.dtype(np.int16),
    "uint16": np.dtype(np.uint16),
    "int32": np.dtype(np.int32),
    "uint32": np.dtype(np.uint32),
    "int64": np.dtype(np.int64),
    "uint64": np.dtype(np.uint64),
    "float16": np.dtype(np.float16),
    "float32": np.dtype(np.float32),
    "float64": np.dtype(np.float64),
}

OFFSET_DTYPE = np.dtype(np.int64)


def dtype_of(name: str) -> np.dtype:
    try:
        return _DTYPES[name]
    except KeyError:
        raise ValueError(f"unsupported primitive type {name!r}") from None


# ---------------------------------------------------------------------------
# Field tree


class Field:
    """Base class of the field tree."""

    name: str

    def children(self) -> Sequence["Field"]:
        raise NotImplementedError

    def to_dict(self) -> Dict[str, Any]:
        raise NotImplementedError

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "Field":
        kind = d["kind"]
        if kind == "leaf":
            return Leaf(d["name"], d["type"])
        if kind == "collection":
            return Collection(d["name"], Field.from_dict(d["item"]))
        if kind == "record":
            return Record(d["name"], [Field.from_dict(c) for c in d["fields"]])
        raise ValueError(f"unknown field kind {kind!r}")


@dataclass(frozen=True)
class Leaf(Field):
    """A primitive field, mapped to exactly one column."""

    name: str
    type: str

    def __post_init__(self) -> None:
        dtype_of(self.type)  # validate

    @property
    def dtype(self) -> np.dtype:
        return dtype_of(self.type)

    def children(self) -> Sequence[Field]:
        return ()

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": "leaf", "name": self.name, "type": self.type}


@dataclass(frozen=True)
class Collection(Field):
    """Variable-length collection: offset column + item field columns."""

    name: str
    item: Field

    def children(self) -> Sequence[Field]:
        return (self.item,)

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": "collection", "name": self.name, "item": self.item.to_dict()}


@dataclass(frozen=True)
class Record(Field):
    """A struct of sub-fields; produces no column of its own."""

    name: str
    fields: Tuple[Field, ...]

    def __init__(self, name: str, fields: Sequence[Field]):
        object.__setattr__(self, "name", name)
        object.__setattr__(self, "fields", tuple(fields))

    def children(self) -> Sequence[Field]:
        return self.fields

    def to_dict(self) -> Dict[str, Any]:
        return {
            "kind": "record",
            "name": self.name,
            "fields": [f.to_dict() for f in self.fields],
        }


# ---------------------------------------------------------------------------
# Column model

KIND_LEAF = 0
KIND_OFFSET = 1

# Default preconditioning encodings (see encoding.py).
ENC_NONE = "none"
ENC_SPLIT = "split"
ENC_DELTA_ZIGZAG_SPLIT = "dzs"


@dataclass(frozen=True)
class ColumnSpec:
    """A physical column of primitive fixed-size elements.

    ``codec``/``level`` are optional per-column entropy-coder overrides
    (ROOT's per-column codec choice): ``None``/``-1`` defer to the
    writer's ``WriteOptions`` (which may itself carry per-path overrides
    — resolution order is ``WriteOptions.column_codecs`` >
    ``ColumnSpec.codec`` > ``WriteOptions.codec``).  They are write-side
    hints only: the codec actually used is recorded per page in
    ``PageDesc.codec``, so readers never depend on these fields.
    """

    index: int              # column id, dense 0..n-1
    path: str               # dotted field path, e.g. "fTracks._0.fIds"
    kind: int               # KIND_LEAF or KIND_OFFSET
    type: str               # primitive type name
    encoding: str           # preconditioning encoding id
    codec: Optional[Any] = None   # codec name/id override (None = writer default)
    level: int = -1               # codec level override (-1 = codec default)

    @property
    def dtype(self) -> np.dtype:
        return dtype_of(self.type)

    @property
    def itemsize(self) -> int:
        return self.dtype.itemsize

    def to_dict(self) -> Dict[str, Any]:
        d = {
            "index": self.index,
            "path": self.path,
            "kind": self.kind,
            "type": self.type,
            "encoding": self.encoding,
        }
        if self.codec is not None:
            d["codec"] = self.codec
        if self.level >= 0:
            d["level"] = self.level
        return d

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "ColumnSpec":
        return ColumnSpec(d["index"], d["path"], d["kind"], d["type"],
                          d["encoding"], d.get("codec"), d.get("level", -1))


def _default_encoding(kind: int, type_name: str) -> str:
    if kind == KIND_OFFSET:
        return ENC_DELTA_ZIGZAG_SPLIT
    itemsize = dtype_of(type_name).itemsize
    return ENC_SPLIT if itemsize > 1 else ENC_NONE


class Schema:
    """Top-level entry schema: an implicit record of named fields.

    Performs the recursive decomposition into columns once at construction.
    ``columns[i]`` is the i-th physical column; ``parent[i]`` is the column
    index of the enclosing offset column (or -1 at top level), which defines
    the nesting used by readers and by the repetition/packing logic.
    """

    def __init__(self, fields: Sequence[Field]):
        self.fields: Tuple[Field, ...] = tuple(fields)
        names = [f.name for f in self.fields]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate top-level field names: {names}")
        self.columns: List[ColumnSpec] = []
        self.parent: List[int] = []
        # field path -> column index (offset column for collections,
        # data column for leaves)
        self.column_of_path: Dict[str, int] = {}
        for f in self.fields:
            self._decompose(f, prefix="", parent=-1)

    # -- decomposition ----------------------------------------------------

    def _add_column(self, path: str, kind: int, type_name: str, parent: int) -> int:
        idx = len(self.columns)
        enc = _default_encoding(kind, type_name)
        self.columns.append(ColumnSpec(idx, path, kind, type_name, enc))
        self.parent.append(parent)
        self.column_of_path[path] = idx
        return idx

    def _decompose(self, f: Field, prefix: str, parent: int) -> None:
        path = f"{prefix}{f.name}" if prefix == "" else f"{prefix}.{f.name}"
        if isinstance(f, Leaf):
            self._add_column(path, KIND_LEAF, f.type, parent)
        elif isinstance(f, Collection):
            off = self._add_column(path, KIND_OFFSET, "int64", parent)
            self._decompose(f.item, path, off)
        elif isinstance(f, Record):
            for c in f.fields:
                self._decompose(c, path, parent)
        else:  # pragma: no cover - defensive
            raise TypeError(f"unknown field type {type(f)!r}")

    # -- (de)serialization -------------------------------------------------

    def to_json(self) -> str:
        return json.dumps(
            {"fields": [f.to_dict() for f in self.fields]},
            separators=(",", ":"),
        )

    @staticmethod
    def from_json(s: Union[str, bytes]) -> "Schema":
        d = json.loads(s)
        return Schema([Field.from_dict(f) for f in d["fields"]])

    # -- helpers -----------------------------------------------------------

    @property
    def n_columns(self) -> int:
        return len(self.columns)

    def top_level_columns(self) -> List[ColumnSpec]:
        return [c for c, p in zip(self.columns, self.parent) if p == -1]

    def children_of(self, column_index: int) -> List[ColumnSpec]:
        return [c for c, p in zip(self.columns, self.parent) if p == column_index]

    def project(self, keep_fields: Sequence[str]) -> "Schema":
        """Horizontal skim: a new Schema with only ``keep_fields``."""
        by_name = {f.name: f for f in self.fields}
        missing = [n for n in keep_fields if n not in by_name]
        if missing:
            raise KeyError(f"unknown fields: {missing}")
        return Schema([by_name[n] for n in keep_fields])

    def set_column_codec(self, path: str, codec, level: int = -1) -> "Schema":
        """Attach a per-column codec override (returns ``self`` for
        chaining).  Columns are write-side derived state — not part of
        the serialized field tree — so this does not affect equality or
        the on-disk header; the chosen codec lands per page in
        ``PageDesc.codec``."""
        idx = self.column_of_path[path]
        self.columns[idx] = dc_replace(self.columns[idx], codec=codec,
                                       level=level)
        return self

    def __eq__(self, other: object) -> bool:
        return isinstance(other, Schema) and self.to_json() == other.to_json()

    def __repr__(self) -> str:
        cols = ", ".join(f"{c.index}:{c.path}" for c in self.columns)
        return f"Schema({cols})"


# ---------------------------------------------------------------------------
# Entry <-> column decomposition at fill time
#
# A "decomposed entry" is the per-column contribution of a single entry:
# for each leaf column a 1-D array of elements, for each offset column the
# list of collection sizes observed (one size per *parent element*).


def decompose_entry(schema: Schema, entry: Dict[str, Any]) -> List[np.ndarray]:
    """Decompose one nested dict entry into per-column element arrays.

    Offset-column contributions are *sizes* (not absolute offsets); the
    cluster builder integrates them into cluster-relative offsets, which is
    what makes clusters relocatable (paper §5).
    """
    out: List[List[Any]] = [[] for _ in schema.columns]

    def walk(field: Field, value: Any, prefix: str) -> None:
        path = f"{prefix}{field.name}" if prefix == "" else f"{prefix}.{field.name}"
        if isinstance(field, Leaf):
            out[schema.column_of_path[path]].append(value)
        elif isinstance(field, Collection):
            seq = value if value is not None else ()
            out[schema.column_of_path[path]].append(len(seq))
            for item in seq:
                walk(field.item, item, path)
        elif isinstance(field, Record):
            for sub in field.fields:
                walk(sub, value[sub.name], path)

    for f in schema.fields:
        walk(f, entry[f.name], "")

    arrays: List[np.ndarray] = []
    for col, vals in zip(schema.columns, out):
        dt = OFFSET_DTYPE if col.kind == KIND_OFFSET else col.dtype
        arrays.append(np.asarray(vals, dtype=dt))
    return arrays


def recompose_entries(
    schema: Schema, columns: List[np.ndarray], n_entries: int
) -> List[Dict[str, Any]]:
    """Inverse of repeated :func:`decompose_entry` — used by the reader.

    ``columns[i]`` holds the full element array of column *i* for the entry
    range, with offset columns already converted back to sizes is NOT
    assumed: offset columns here contain *absolute offsets within the
    range* (standard on-disk form), i.e. offsets[j] = end of collection j.
    """
    cursors = [0] * len(columns)

    def read_one(field: Field, prefix: str) -> Any:
        path = f"{prefix}{field.name}" if prefix == "" else f"{prefix}.{field.name}"
        if isinstance(field, Leaf):
            ci = schema.column_of_path[path]
            v = columns[ci][cursors[ci]]
            cursors[ci] += 1
            return v.item() if isinstance(v, np.generic) else v
        if isinstance(field, Collection):
            ci = schema.column_of_path[path]
            end = int(columns[ci][cursors[ci]])
            start = int(columns[ci][cursors[ci] - 1]) if cursors[ci] > 0 else 0
            cursors[ci] += 1
            return [read_one(field.item, path) for _ in range(end - start)]
        if isinstance(field, Record):
            return {sub.name: read_one(sub, path) for sub in field.fields}
        raise TypeError(type(field))

    return [
        {f.name: read_one(f, "") for f in schema.fields} for _ in range(n_entries)
    ]


# ---------------------------------------------------------------------------
# Columnar batch form (the fast path used by the ML pipeline and benchmarks)


@dataclass
class ColumnBatch:
    """N entries in decomposed columnar form.

    ``sizes[path]`` for each collection (per parent element), ``values[path]``
    flat element arrays for each leaf.  This is the zero-python-loop fill
    path; :meth:`from_entries` exists for convenience/testing.
    """

    schema: Schema
    n_entries: int
    data: Dict[int, np.ndarray] = dc_field(default_factory=dict)  # column idx -> arr

    @staticmethod
    def from_arrays(schema: Schema, n_entries: int, by_path: Dict[str, np.ndarray]) -> "ColumnBatch":
        data: Dict[int, np.ndarray] = {}
        for col in schema.columns:
            arr = by_path.get(col.path)
            if arr is None:
                raise KeyError(f"missing array for column {col.path!r}")
            dt = OFFSET_DTYPE if col.kind == KIND_OFFSET else col.dtype
            data[col.index] = np.ascontiguousarray(arr, dtype=dt)
        b = ColumnBatch(schema, n_entries, data)
        b.validate()
        return b

    @staticmethod
    def from_entries(schema: Schema, entries: Sequence[Dict[str, Any]]) -> "ColumnBatch":
        per_col: List[List[np.ndarray]] = [[] for _ in schema.columns]
        for e in entries:
            for i, arr in enumerate(decompose_entry(schema, e)):
                per_col[i].append(arr)
        data = {}
        for col in schema.columns:
            dt = OFFSET_DTYPE if col.kind == KIND_OFFSET else col.dtype
            parts = per_col[col.index]
            data[col.index] = (
                np.concatenate(parts) if parts else np.empty(0, dtype=dt)
            ).astype(dt, copy=False)
        b = ColumnBatch(schema, len(entries), data)
        b.validate()
        return b

    def validate(self) -> None:
        """Check size consistency between offset columns and children."""
        for col in self.schema.columns:
            parent = self.schema.parent[col.index]
            expect = (
                self.n_entries
                if parent == -1
                else int(self.data[parent].sum())
            )
            got = len(self.data[col.index])
            if got != expect:
                raise ValueError(
                    f"column {col.path!r}: {got} elements, expected {expect}"
                )

    def sizes_to_entry_arrays(self) -> Dict[int, np.ndarray]:
        return self.data
