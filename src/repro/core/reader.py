"""RNT-J read engine.

Knows nothing about parallel writing: it reads the anchor, footer, page
list and header and iterates clusters in entry order — which, by the
commit protocol, is exactly the sequential-equivalent order (paper §4.3).

Rebuilt (ISSUE 2) from a one-``pread``-per-page serial decoder into a
three-layer engine mirroring the write path's architecture:

1. **I/O coalescing** — a cluster's page descriptors are sorted by byte
   offset and adjacent/near ranges (hole ≤ ``ReadOptions.coalesce_gap``)
   merge into a few large ``pread``s; each page decodes from a zero-copy
   ``memoryview`` slice of its coalesced buffer.
2. **Parallel decode** — page decompression + decoding runs on a
   reader-owned worker pool (``decode_workers``; the same pool plumbing
   the writers use for compression, ``compression.make_pool``).  Every
   page decodes straight into its slice of ONE preallocated array per
   column (no ``np.concatenate``), and offset pages integrate their
   deltas through ``integrate_sizes`` — the Pallas ``offsets_scan``
   dispatch shared with the write path.
3. **Cluster prefetch** — ``iter_clusters`` keeps ``prefetch_clusters``
   clusters in flight on a background pool, so cluster *i+1* is being
   read and decoded while the caller consumes cluster *i* (double
   buffering at depth 1, the read-side analog of ``pipelined_seal``).

``ReaderStats`` breaks reader time into io / decompress / decode / wait
phases, mirroring ``WriterStats`` on the write side.
"""

from __future__ import annotations

import os
import struct
import threading
import time
import zlib
from collections import deque
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from . import compression as comp
from .bufpool import make_pool as make_buffer_pool
from .container import FileSink, Sink
from .encoding import unprecondition_pages_into
from .encoding import unprecondition_into
from .metadata import (
    ANCHOR_SIZE,
    ClusterMeta,
    parse_anchor,
    parse_footer,
    parse_header,
    parse_member_sidecar,
    parse_pagelist,
)
from .pages import PageDesc, _thread_scratch, decode_page_into
from .schema import KIND_OFFSET, ColumnSpec, Schema, recompose_entries
from .stats import ReaderStats, _merge_codec_stats

_ns = time.perf_counter_ns


def _member_plan(d: PageDesc) -> Optional[List[Tuple[int, int, int, int]]]:
    """``[(compressed_off, csize, raw_off, usize)]`` member layout of a
    side-car'd chunk-framed page, or ``None`` when the record does not
    exactly tile the payload (then the page decodes serially)."""
    chunk = d.member_chunk
    if not d.members or chunk <= 0 or sum(d.members) != d.size:
        return None
    n = len(d.members)
    usize = d.uncompressed_size
    if not ((n - 1) * chunk < usize <= n * chunk):
        return None
    plan = []
    coff = 0
    for k, csz in enumerate(d.members):
        uoff = k * chunk
        plan.append((coff, csz, uoff, min(chunk, usize - uoff)))
        coff += csz
    return plan


@dataclass
class ReadOptions:
    """Read-engine tuning knobs (the read-side mirror of WriteOptions).

    * ``coalesce_gap`` — merge two page reads into one ``pread`` when the
      hole between them is at most this many bytes (reading and
      discarding a small hole is cheaper than a second syscall/seek).
      A negative value disables coalescing: one ``pread`` per page, the
      seed's behavior.
    * ``max_coalesced_bytes`` — cap on a single merged read, bounding
      buffer size.
    * ``decode_workers`` — size of the reader-owned page-decode pool
      (0 = decode on the calling thread).
    * ``prefetch_clusters`` — clusters kept in flight ahead of the
      consumer by the streaming iterators (``iter_clusters``,
      ``iter_entries``, ``read_column``); 0 = fully synchronous.
    * ``parallel_members`` — when the file carries the framed-member
      side-car, decompress a chunked page's members as independent
      pool jobs (needs ``decode_workers``); files without the side-car
      (or with it disabled) decode members serially inside one job.
    * ``buffer_pool_bytes`` — residency bound of the reader-owned
      :class:`~repro.core.bufpool.BufferPool` (member-decompress scratch
      always recycles through it; 0 disables pooling).
    * ``recycle_buffers`` — draw the per-column decode output arrays from
      the pool and let :meth:`RNTJReader.iter_clusters` return the
      previous cluster's arrays once the consumer advances.  The yielded
      arrays are then only valid until the next iteration — strictly a
      streaming contract (``iter_entries``/``read_column`` never recycle,
      they may hold views across clusters).
    * ``tolerant`` — when the anchor/footer chain is missing or corrupt
      (a crashed writer), fall back to the journal scan of
      :mod:`repro.core.recover` and serve whatever clusters it salvages;
      :attr:`RNTJReader.salvage` then carries the
      :class:`~repro.core.recover.RecoveryReport` (``None`` on a normal
      open).  DESIGN.md §8.5.

    The full option table lives in DESIGN.md §7.
    """

    coalesce_gap: int = 256 * 1024
    max_coalesced_bytes: int = 32 * 1024 * 1024
    decode_workers: int = 0
    prefetch_clusters: int = 1
    parallel_members: bool = True
    buffer_pool_bytes: int = 32 * 1024 * 1024
    recycle_buffers: bool = False
    tolerant: bool = False


class RNTJReader:
    def __init__(
        self,
        sink_or_path,
        verify_checksums: bool = True,
        options: Optional[ReadOptions] = None,
    ):
        owns_sink = isinstance(sink_or_path, (str, os.PathLike))
        if owns_sink:
            self.sink: Sink = FileSink(os.fspath(sink_or_path), create=False)
        else:
            self.sink = sink_or_path
        self.verify = verify_checksums
        self.read_options = options or ReadOptions()
        self.stats = ReaderStats()
        self._decode_pool = None
        self._prefetch_pool = None
        self._pool_lock = threading.Lock()
        # reader-owned buffer pool: member-decompress scratch always
        # recycles through it; decode output arrays do too when
        # recycle_buffers is on (DESIGN.md §6.8)
        self._bufpool = make_buffer_pool(self.read_options.buffer_pool_bytes)
        self._closed = False
        self.salvage = None  # RecoveryReport when a tolerant open salvaged
        try:
            if not self.sink.readable():
                raise IOError("sink is not readable")
            try:
                self._load_footer_metadata()
            except (IOError, ValueError, KeyError, struct.error):
                if not self.read_options.tolerant:
                    raise
                # torn or corrupt finalization metadata: fall back to the
                # journal scan and serve whatever it salvages (§8.5)
                from .recover import scan_container
                self.schema, self.options, self.clusters, self.salvage = (
                    scan_container(self.sink)
                )
                self.n_entries = self.salvage.entries_salvaged
            # column ranges: first element index of each column per cluster
            # (paper §3) — the running sums of per-cluster element counts.
            self.column_ranges = np.zeros(
                (len(self.clusters), self.schema.n_columns), dtype=np.int64
            )
            acc = np.zeros(self.schema.n_columns, dtype=np.int64)
            for i, cm in enumerate(self.clusters):
                self.column_ranges[i] = acc
                acc += np.asarray(cm.n_elements, dtype=np.int64)
            self.total_elements = acc
        except BaseException:
            # never leak a file we opened ourselves when the metadata is
            # corrupt — the exact failure mode skim workers retry on
            if owns_sink:
                self.sink.close()
            raise

    def _load_footer_metadata(self) -> None:
        """The normal open path: anchor → header → footer → page list."""
        size = self.sink.size
        anchor = parse_anchor(self.sink.pread(size - ANCHOR_SIZE, ANCHOR_SIZE))
        hoff, hsize = anchor["header"]
        foff, fsize = anchor["footer"]
        self.schema, self.options = parse_header(self.sink.pread(hoff, hsize))
        footer = parse_footer(self.sink.pread(foff, fsize))
        pl_off, pl_size = footer["pagelist"]
        self.clusters: List[ClusterMeta] = parse_pagelist(
            self.sink.pread(pl_off, pl_size)
        )
        # optional framed-member side-car: attach member layouts so
        # chunked pages can decompress as parallel pool jobs.  Old
        # files simply have no locator and decode serially as before.
        mc_loc = (footer.get("extra") or {}).get("members")
        if mc_loc:
            parse_member_sidecar(
                self.sink.pread(mc_loc[0], mc_loc[1]), self.clusters
            )
        self.n_entries = int(footer["n_entries"])

    # -- worker pools --------------------------------------------------------

    def _get_decode_pool(self):
        if self.read_options.decode_workers and self._decode_pool is None:
            with self._pool_lock:
                if self._decode_pool is None:
                    self._decode_pool = comp.make_pool(
                        self.read_options.decode_workers, "rntj-decode"
                    )
        return self._decode_pool

    def _get_prefetch_pool(self):
        if self.read_options.prefetch_clusters and self._prefetch_pool is None:
            with self._pool_lock:
                if self._prefetch_pool is None:
                    self._prefetch_pool = comp.make_pool(
                        self.read_options.prefetch_clusters, "rntj-prefetch"
                    )
        return self._prefetch_pool

    # -- cluster-level access ------------------------------------------------

    @property
    def n_clusters(self) -> int:
        return len(self.clusters)

    def _alloc_column(self, ci: int, count: int) -> np.ndarray:
        """One decode output array — drawn from the reader's buffer pool
        when ``recycle_buffers`` is on (returned via :meth:`recycle`)."""
        dtype = self.schema.columns[ci].dtype
        if self._bufpool is not None and self.read_options.recycle_buffers:
            raw = self._bufpool.take(count * dtype.itemsize)
            return raw.view(dtype)[:count]
        return np.empty(count, dtype=dtype)

    def recycle(self, cols: Dict[int, np.ndarray]) -> None:
        """Return a cluster's decoded arrays to the reader's pool.

        Only call this when nothing references the arrays (or views of
        them) anymore; ``iter_clusters`` does it automatically for the
        previous cluster when ``ReadOptions.recycle_buffers`` is set.
        """
        if self._bufpool is None:
            return
        for arr in cols.values():
            self._bufpool.put(arr)

    def _coalesce(self, descs: List[PageDesc]) -> List[Tuple[int, int, List[PageDesc]]]:
        """Plan the cluster's reads: ``[(offset, end, pages)]`` ranges.

        Pages sort by byte offset; a page joins the previous range when
        the hole between them is ≤ ``coalesce_gap`` and the merged range
        stays under ``max_coalesced_bytes``.
        """
        o = self.read_options
        if o.coalesce_gap < 0:
            return [(d.offset, d.offset + d.size, [d]) for d in descs]
        ranges: List[List] = []
        for d in sorted(descs, key=lambda p: p.offset):
            if ranges:
                start, end, group = ranges[-1]
                if (
                    d.offset - end <= o.coalesce_gap
                    and d.offset + d.size - start <= o.max_coalesced_bytes
                ):
                    ranges[-1][1] = max(end, d.offset + d.size)
                    group.append(d)
                    continue
            ranges.append([d.offset, d.offset + d.size, [d]])
        return [(s, e, g) for s, e, g in ranges]

    def read_cluster(
        self, cluster_index: int, columns: Optional[Sequence[int]] = None
    ) -> Dict[int, np.ndarray]:
        """Read the element arrays of a cluster.

        Offset columns keep their on-disk cluster-relative form (ends of
        each collection within the cluster).  I/O is coalesced; pages
        decode — on the decode pool when one is configured — directly
        into one preallocated array per column, in page-list order.
        Consecutive stored-uncompressed pages of a column decode as ONE
        column-batched run (``unprecondition_pages_into``); the remaining
        pages decode per page, chunked to amortize pool dispatch.
        """
        cm = self.clusters[cluster_index]
        want = set(columns) if columns is not None else None
        targets = list(want) if want is not None else list(range(self.schema.n_columns))
        descs = [d for d in cm.pages if want is None or d.column in want]

        # one output array per column; pages fill slices in page-list order
        counts = {ci: 0 for ci in targets}
        for d in descs:
            counts[d.column] += d.n_elements
        out: Dict[int, np.ndarray] = {
            ci: self._alloc_column(ci, counts[ci]) for ci in targets
        }
        if not descs:
            return out
        pos = {}         # id(desc) -> first element index in its column array
        by_col: Dict[int, List[PageDesc]] = {}
        cursor = {ci: 0 for ci in targets}
        for d in descs:
            pos[id(d)] = cursor[d.column]
            cursor[d.column] += d.n_elements
            by_col.setdefault(d.column, []).append(d)

        # coalesced I/O
        ranges = self._coalesce(descs)
        t0 = _ns()
        bufs = [self.sink.pread(start, end - start) for start, end, _ in ranges]
        io_ns = _ns() - t0
        loc = {}         # id(desc) -> (range index, zero-copy payload view)
        for ri, ((start, _end, group), buf) in enumerate(zip(ranges, bufs)):
            mv = memoryview(buf)
            for d in group:
                rel = d.offset - start
                loc[id(d)] = (ri, mv[rel : rel + d.size])

        # plan: column-batched runs of byte-contiguous stored pages vs
        # per-page decode (compressed pages, or broken adjacency) vs
        # member-parallel decompression (side-car'd chunk-framed pages)
        pool = self._get_decode_pool()
        run_jobs: List[Tuple] = []
        page_jobs: List[PageDesc] = []
        member_pages: List[PageDesc] = []
        use_members = pool is not None and self.read_options.parallel_members
        for ci, ds in by_col.items():
            i = 0
            while i < len(ds):
                d = ds[i]
                if d.codec != comp.CODEC_NONE:
                    if use_members and d.members and len(d.members) > 1:
                        member_pages.append(d)
                    else:
                        page_jobs.append(d)
                    i += 1
                    continue
                run = [d]
                per = d.n_elements
                j = i + 1
                while j < len(ds):
                    p, q = ds[j - 1], ds[j]
                    if (
                        q.codec == comp.CODEC_NONE
                        and loc[id(q)][0] == loc[id(p)][0]
                        and q.offset == p.offset + p.size
                        and p.n_elements == per
                        and q.n_elements <= per
                    ):
                        run.append(q)
                        j += 1
                    else:
                        break
                if len(run) == 1:
                    page_jobs.append(d)
                else:
                    run_jobs.append((ci, run, per))
                i = j

        def _decode_run(job):
            ci, run, per = job
            col = self.schema.columns[ci]
            if self.verify:
                for d in run:
                    if d.checksum and zlib.crc32(loc[id(d)][1]) != d.checksum:
                        raise IOError(
                            f"page checksum mismatch (column {col.path!r})"
                        )
            first, last = run[0], run[-1]
            ri = loc[id(first)][0]
            base = memoryview(bufs[ri])
            rel = first.offset - ranges[ri][0]
            raw = base[rel : rel + (last.offset + last.size - first.offset)]
            n = pos[id(last)] + last.n_elements - pos[id(first)]
            dst = out[ci][pos[id(first)] : pos[id(first)] + n]
            t0 = _ns()
            unprecondition_pages_into(raw, col.encoding, per, dst,
                                      _thread_scratch())
            nbytes = sum(d.size for d in run)
            return 0, _ns() - t0, {
                comp.CODEC_NONE: [len(run), nbytes, nbytes, 0]
            }

        def _decode_pages(chunk):
            dec = deco = 0
            per_codec = {}
            for d in chunk:
                s = pos[id(d)]
                a, b = decode_page_into(
                    loc[id(d)][1], d, self.schema.columns[d.column],
                    out[d.column][s : s + d.n_elements], self.verify,
                )
                dec += a
                deco += b
                st = per_codec.setdefault(d.codec, [0, 0, 0, 0])
                st[0] += 1
                st[1] += d.size
                st[2] += d.uncompressed_size
                st[3] += a
            return dec, deco, per_codec

        # wave 1 — member-parallel entropy decode (ISSUE 4 satellite):
        # each side-car'd page's members decompress as independent pool
        # jobs into one preallocated raw buffer per page; the page then
        # unpreconditions like any raw page in the main task wave.  A page
        # whose side-car record does not cover its payload falls back to
        # the serial whole-page path.
        member_state: Dict[int, Tuple[bytearray, List[int]]] = {}
        if member_pages:
            mjobs: List[Tuple] = []
            ok_pages: List[PageDesc] = []
            for d in member_pages:
                plan = _member_plan(d)
                if plan is None:
                    page_jobs.append(d)
                    continue
                payload = loc[id(d)][1]
                if self.verify and d.checksum and zlib.crc32(payload) != d.checksum:
                    raise IOError(
                        "page checksum mismatch (column "
                        f"{self.schema.columns[d.column].path!r})"
                    )
                # member scratch recycles through the reader pool: it is
                # internal (dropped right after the unprecondition copies
                # into the output array), so pooling it is always safe
                if self._bufpool is not None:
                    raw = self._bufpool.take_view(d.uncompressed_size)
                else:
                    raw = bytearray(d.uncompressed_size)
                member_state[id(d)] = (raw, [0])
                for coff, csz, uoff, ulen in plan:
                    mjobs.append((d, payload[coff : coff + csz], raw, uoff, ulen))
                ok_pages.append(d)
            member_pages = ok_pages

            def _run_member(job):
                d, part, raw, uoff, ulen = job
                t0 = _ns()
                raw[uoff : uoff + ulen] = comp.decompress(part, d.codec, ulen)
                return id(d), _ns() - t0

            for did, ns in pool.map(_run_member, mjobs):
                member_state[did][1][0] += ns

        def _decode_member_page(d):
            raw, acc = member_state[id(d)]
            col = self.schema.columns[d.column]
            s = pos[id(d)]
            t0 = _ns()
            unprecondition_into(
                raw, col.encoding, out[d.column][s : s + d.n_elements],
                _thread_scratch(),
            )
            if self._bufpool is not None:
                self._bufpool.put(raw)  # scratch fully copied out: recycle
            return acc[0], _ns() - t0, {
                d.codec: [1, d.size, d.uncompressed_size, acc[0]]
            }

        tasks = [(_decode_run, j) for j in run_jobs]
        tasks += [(_decode_member_page, d) for d in member_pages]
        if page_jobs:
            if pool is None:
                chunks = [page_jobs]
            else:
                # ~2 chunks per worker: parallelism without per-page futures
                k = max(1, len(page_jobs)
                        // (2 * self.read_options.decode_workers))
                chunks = [page_jobs[i : i + k]
                          for i in range(0, len(page_jobs), k)]
            tasks += [(_decode_pages, c) for c in chunks]
        if pool is None:
            times = [fn(arg) for fn, arg in tasks]
        else:
            times = list(pool.map(lambda t: t[0](t[1]), tasks))
        per_codec: Dict[int, List[int]] = {}
        for _dec, _deco, pc in times:
            _merge_codec_stats(per_codec, pc)
        self.stats.add_cluster_read(
            pages=len(descs),
            reads=len(ranges),
            compressed_bytes=sum(d.size for d in descs),
            uncompressed_bytes=sum(d.uncompressed_size for d in descs),
            io_ns=io_ns,
            decompress_ns=sum(t[0] for t in times),
            decode_ns=sum(t[1] for t in times),
            per_codec=per_codec,
        )
        return out

    def cluster_entry_range(self, cluster_index: int) -> Tuple[int, int]:
        cm = self.clusters[cluster_index]
        return cm.first_entry, cm.first_entry + cm.n_entries

    # -- the prefetch pipeline -----------------------------------------------

    def iter_clusters(
        self,
        columns: Optional[Sequence[int]] = None,
        start: int = 0,
        stop: Optional[int] = None,
        recycle: Optional[bool] = None,
    ) -> Iterator[Tuple[int, Dict[int, np.ndarray]]]:
        """Yield ``(cluster_index, {column: elements})`` in entry order.

        With ``prefetch_clusters > 0`` up to that many clusters are read
        and decoded on a background pool while the caller consumes the
        current one; the ``wait`` phase of :class:`ReaderStats` records
        how long the consumer actually blocked.

        ``recycle`` (default: ``ReadOptions.recycle_buffers``) returns
        each cluster's arrays to the reader's buffer pool once the
        consumer advances past it — the yielded arrays are then only
        valid until the next iteration.  ``iter_entries`` and
        ``read_column`` always pass ``False``: they may hold views of a
        cluster's arrays beyond the iteration that produced them.
        """
        n = self.n_clusters
        if stop is None or stop > n:
            stop = n
        if recycle is None:
            recycle = self.read_options.recycle_buffers
        recycle = recycle and self._bufpool is not None
        depth = self.read_options.prefetch_clusters
        pool = self._get_prefetch_pool() if depth > 0 else None
        if pool is None:
            for i in range(start, stop):
                cols = self.read_cluster(i, columns)
                yield i, cols
                if recycle:
                    self.recycle(cols)
            return
        pending: deque = deque()
        nxt = start
        try:
            while pending or nxt < stop:
                while nxt < stop and len(pending) < depth:
                    pending.append((nxt, pool.submit(self.read_cluster, nxt, columns)))
                    nxt += 1
                i, fut = pending.popleft()
                t0 = _ns()
                cols = fut.result()
                self.stats.add_wait_ns(_ns() - t0)
                # top up BEFORE yielding: the next clusters make progress
                # while the consumer processes this one
                while nxt < stop and len(pending) < depth:
                    pending.append((nxt, pool.submit(self.read_cluster, nxt, columns)))
                    nxt += 1
                yield i, cols
                if recycle:
                    # the consumer advanced: this cluster's arrays feed
                    # the allocations of the clusters still to come
                    self.recycle(cols)
        finally:
            for _, fut in pending:
                fut.cancel()

    # -- entry-level access ----------------------------------------------------

    def iter_cluster_entries(
        self, cluster_index: int, fields: Optional[Sequence[str]] = None
    ) -> List[Dict]:
        cm = self.clusters[cluster_index]
        schema = self.schema if fields is None else self.schema.project(fields)
        if fields is None:
            cols = self.read_cluster(cluster_index)
            arrays = [cols[i] for i in range(self.schema.n_columns)]
        else:
            # map projected columns back to file columns (horizontal skim)
            file_idx = [self.schema.column_of_path[c.path] for c in schema.columns]
            cols = self.read_cluster(cluster_index, file_idx)
            arrays = [cols[i] for i in file_idx]
        return recompose_entries(schema, arrays, cm.n_entries)

    def iter_entries(self, fields: Optional[Sequence[str]] = None) -> Iterator[Dict]:
        schema = self.schema if fields is None else self.schema.project(fields)
        file_idx = (
            None
            if fields is None
            else [self.schema.column_of_path[c.path] for c in schema.columns]
        )
        # recycle=False: recomposed entries may hold views of the arrays
        for i, cols in self.iter_clusters(columns=file_idx, recycle=False):
            idx = file_idx if file_idx is not None else range(self.schema.n_columns)
            arrays = [cols[j] for j in idx]
            yield from recompose_entries(schema, arrays, self.clusters[i].n_entries)

    # -- whole-column access (analysis-style reads) ------------------------------

    def read_column(self, path: str) -> np.ndarray:
        """Concatenate a column across clusters (prefetched).

        Offset columns are globalized: cluster-relative offsets are shifted
        by the running element count of their *child* column — giving the
        usual global offsets array.
        """
        ci = self.schema.column_of_path[path]
        col = self.schema.columns[ci]
        chunks = []
        if col.kind == KIND_OFFSET:
            children = [
                k for k, p in enumerate(self.schema.parent) if p == ci
            ]
            child = children[0] if children else None
            base = 0
            # recycle=False on both paths: chunks holds every cluster's
            # array until the final concatenate
            for i, cols in self.iter_clusters(columns=[ci], recycle=False):
                arr = cols[ci].astype(np.int64)
                chunks.append(arr + base)
                if child is not None:
                    base += self.clusters[i].n_elements[child]
                elif len(arr):
                    base += int(arr[-1])
        else:
            for _i, cols in self.iter_clusters(columns=[ci], recycle=False):
                chunks.append(cols[ci])
        return (
            np.concatenate(chunks)
            if chunks
            else np.empty(0, dtype=col.dtype)
        )

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._prefetch_pool is not None:
            self._prefetch_pool.shutdown(wait=True)
        if self._decode_pool is not None:
            self._decode_pool.shutdown(wait=True)
        self.stats.merge_io(self.sink.io.snapshot())
        if self._bufpool is not None:
            self.stats.merge_pool(self._bufpool.snapshot())
        self.sink.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        self.close()
