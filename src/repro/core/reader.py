"""RNT-J read engine.

Knows nothing about parallel writing: it reads the anchor, footer, page
list and header and iterates clusters in entry order — which, by the
commit protocol, is exactly the sequential-equivalent order (paper §4.3).

Rebuilt (ISSUE 2) from a one-``pread``-per-page serial decoder into a
three-layer engine mirroring the write path's architecture:

1. **I/O coalescing** — a cluster's page descriptors are sorted by byte
   offset and adjacent/near ranges (hole ≤ ``ReadOptions.coalesce_gap``)
   merge into a few large ``pread``s; each page decodes from a zero-copy
   ``memoryview`` slice of its coalesced buffer.
2. **Parallel decode** — page decompression + decoding runs on a
   reader-owned worker pool (``decode_workers``; the same pool plumbing
   the writers use for compression, ``compression.make_pool``).  Every
   page decodes straight into its slice of ONE preallocated array per
   column (no ``np.concatenate``), and offset pages integrate their
   deltas through ``integrate_sizes`` — the Pallas ``offsets_scan``
   dispatch shared with the write path.
3. **Cluster prefetch** — ``iter_clusters`` keeps ``prefetch_clusters``
   clusters in flight on a background pool, so cluster *i+1* is being
   read and decoded while the caller consumes cluster *i* (double
   buffering at depth 1, the read-side analog of ``pipelined_seal``).

``ReaderStats`` breaks reader time into io / decompress / decode / wait
phases, mirroring ``WriterStats`` on the write side.
"""

from __future__ import annotations

import math
import os
import struct
import threading
import time
import zlib
from collections import deque
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from . import compression as comp
from .bufpool import make_pool as make_buffer_pool
from .container import FileSink, Sink, open_sink
from .encoding import unprecondition_pages_into
from .filter import (
    EvalContext,
    Expr,
    T_FALSE,
    Zone,
    filter_paths,
    required_columns,
)
from .ioengine import Retrier, RetryPolicy
from .encoding import unprecondition_into
from .metadata import (
    ANCHOR_SIZE,
    ClusterMeta,
    decode_zonemaps,
    parse_anchor,
    parse_footer,
    parse_header,
    parse_member_sidecar,
    parse_pagelist,
)
from .pages import PageDesc, _thread_scratch, decode_page_into
from .schema import KIND_OFFSET, ColumnSpec, Schema, recompose_entries
from .stats import ReaderStats, _merge_codec_stats

_ns = time.perf_counter_ns


def _member_plan(d: PageDesc) -> Optional[List[Tuple[int, int, int, int]]]:
    """``[(compressed_off, csize, raw_off, usize)]`` member layout of a
    side-car'd chunk-framed page, or ``None`` when the record does not
    exactly tile the payload (then the page decodes serially)."""
    chunk = d.member_chunk
    if not d.members or chunk <= 0 or sum(d.members) != d.size:
        return None
    n = len(d.members)
    usize = d.uncompressed_size
    if not ((n - 1) * chunk < usize <= n * chunk):
        return None
    plan = []
    coff = 0
    for k, csz in enumerate(d.members):
        uoff = k * chunk
        plan.append((coff, csz, uoff, min(chunk, usize - uoff)))
        coff += csz
    return plan


@dataclass
class ReadOptions:
    """Read-engine tuning knobs (the read-side mirror of WriteOptions).

    * ``coalesce_gap`` — merge two page reads into one ``pread`` when the
      hole between them is at most this many bytes (reading and
      discarding a small hole is cheaper than a second syscall/seek).
      A negative value disables coalescing: one ``pread`` per page, the
      seed's behavior.
    * ``max_coalesced_bytes`` — cap on a single merged read, bounding
      buffer size.
    * ``decode_workers`` — size of the reader-owned page-decode pool
      (0 = decode on the calling thread).
    * ``prefetch_clusters`` — clusters kept in flight ahead of the
      consumer by the streaming iterators (``iter_clusters``,
      ``iter_entries``, ``read_column``); 0 = fully synchronous.
    * ``parallel_members`` — when the file carries the framed-member
      side-car, decompress a chunked page's members as independent
      pool jobs (needs ``decode_workers``); files without the side-car
      (or with it disabled) decode members serially inside one job.
    * ``buffer_pool_bytes`` — residency bound of the reader-owned
      :class:`~repro.core.bufpool.BufferPool` (member-decompress scratch
      always recycles through it; 0 disables pooling).
    * ``recycle_buffers`` — draw the per-column decode output arrays from
      the pool and let :meth:`RNTJReader.iter_clusters` return the
      previous cluster's arrays once the consumer advances.  The yielded
      arrays are then only valid until the next iteration — strictly a
      streaming contract (``iter_entries``/``read_column`` never recycle,
      they may hold views across clusters).
    * ``device_decode`` — backend of the fused device decode chain used
      by :meth:`RNTJReader.read_cluster_device` /
      :meth:`RNTJReader.iter_clusters_device` (DESIGN.md §9):
      ``"auto"`` compiles the jnp oracle ops through XLA (Pallas kernels
      engage on TPU); ``"pallas"`` forces the Pallas kernels (interpret
      mode off-TPU — the bit-identity test configuration); ``"off"``
      disables the device path entirely (the device entry points raise).
      The host-path methods (``read_cluster``, ``iter_clusters``) never
      consult this knob.
    * ``retry_policy`` — retry transient pread failures (retryable
      ``OSError``: ``EIO``, ``ETIMEDOUT``, …) with exponential backoff
      before giving up, through the same
      :class:`~repro.core.ioengine.Retrier` chokepoint the write engine
      uses.  Reader-level retries land in ``ReaderStats.retries`` /
      ``giveups``, distinct from any retrying the sink does internally
      (the remote sink's transport retries show up in ``io_retries``).
      ``None`` (default) preserves the fail-fast behavior: the first
      error raises.  Non-``OSError`` failures always raise.
    * ``filter`` — a :mod:`repro.core.filter` predicate (built from
      ``F("path")`` comparisons).  ``iter_filtered`` evaluates it
      exactly; with ``prune`` on, the footer's zone maps compile into a
      per-cluster/per-page prune plan first, so clusters and pages that
      cannot satisfy the predicate are skipped before a single pread
      (DESIGN.md §11).  ``iter_clusters`` only *skips whole clusters*
      the plan proves empty — it still yields full clusters otherwise.
    * ``prune`` — consult zone maps for ``filter`` (on by default).
      Off, or on a file without the ``zonemaps`` footer extra, every
      path degrades to the exact unpruned scan — pruning is an
      optimization, never a correctness dependency.
    * ``tolerant`` — when the anchor/footer chain is missing or corrupt
      (a crashed writer), fall back to the journal scan of
      :mod:`repro.core.recover` and serve whatever clusters it salvages;
      :attr:`RNTJReader.salvage` then carries the
      :class:`~repro.core.recover.RecoveryReport` (``None`` on a normal
      open).  DESIGN.md §8.5.

    The full option table lives in DESIGN.md §7.
    """

    coalesce_gap: int = 256 * 1024
    max_coalesced_bytes: int = 32 * 1024 * 1024
    decode_workers: int = 0
    prefetch_clusters: int = 1
    parallel_members: bool = True
    buffer_pool_bytes: int = 32 * 1024 * 1024
    recycle_buffers: bool = False
    device_decode: str = "auto"
    retry_policy: Optional["RetryPolicy"] = None
    tolerant: bool = False
    filter: Optional["Expr"] = None
    prune: bool = True


def slice_entry_range(
    schema: Schema, cols: Dict[int, np.ndarray], e0: int, e1: int
) -> Dict[int, np.ndarray]:
    """Subset a range-local column dict to entries ``[e0, e1)``.

    Pure array math (no I/O): offset columns are walked parent-first to
    locate each column's element range and rebased so the result is
    again range-local.  ``cols`` must contain every ancestor offset
    column of every column it contains (readers always decode them)."""
    out: Dict[int, np.ndarray] = {}
    crng: Dict[int, Tuple[int, int]] = {}
    for ci in sorted(cols):
        p = schema.parent[ci]
        a, b = (e0, e1) if p == -1 else crng[p]
        arr = cols[ci]
        if schema.columns[ci].kind == KIND_OFFSET:
            base = int(arr[a - 1]) if a > 0 else 0
            end = int(arr[b - 1]) if b > a else base
            crng[ci] = (base, end)
            out[ci] = arr[a:b] - base
        else:
            out[ci] = arr[a:b]
    return out


class RNTJReader:
    def __init__(
        self,
        sink_or_path,
        verify_checksums: bool = True,
        options: Optional[ReadOptions] = None,
    ):
        owns_sink = isinstance(sink_or_path, (str, os.PathLike))
        if owns_sink:
            path = os.fspath(sink_or_path)
            if "://" in path:
                # remote URL: route through the scheme registry
                # (ObjectStoreSink in read mode — DESIGN.md §10)
                self.sink: Sink = open_sink(path, create=False)
            else:
                self.sink = FileSink(path, create=False)
        else:
            self.sink = sink_or_path
        self.verify = verify_checksums
        self.read_options = options or ReadOptions()
        self.stats = ReaderStats()
        # reader-level pread retry chokepoint (ReadOptions.retry_policy;
        # None = fail fast).  Counts land in ReaderStats.retries/giveups.
        self._retrier = Retrier(
            self.read_options.retry_policy,
            on_retry=self.stats.add_retry,
            on_giveup=self.stats.add_giveup,
        )
        self._decode_pool = None
        self._prefetch_pool = None
        self._pool_lock = threading.Lock()
        # reader-owned buffer pool: member-decompress scratch always
        # recycles through it; decode output arrays do too when
        # recycle_buffers is on (DESIGN.md §6.8)
        self._bufpool = make_buffer_pool(self.read_options.buffer_pool_bytes)
        self._closed = False
        self._plan_cache = None  # compiled prune plan (ReadOptions.filter)
        self.salvage = None  # RecoveryReport when a tolerant open salvaged
        try:
            if not self.sink.readable():
                raise IOError("sink is not readable")
            try:
                self._load_footer_metadata()
            except (IOError, ValueError, KeyError, struct.error):
                if not self.read_options.tolerant:
                    raise
                # torn or corrupt finalization metadata: fall back to the
                # journal scan and serve whatever it salvages (§8.5)
                from .recover import scan_container
                self.schema, self.options, self.clusters, self.salvage = (
                    scan_container(self.sink)
                )
                self.n_entries = self.salvage.entries_salvaged
                # the journal never carries zone maps (finalization
                # metadata): a salvaged open serves no stale bounds
                self.zonemaps = [None] * len(self.clusters)
            # column ranges: first element index of each column per cluster
            # (paper §3) — the running sums of per-cluster element counts.
            self.column_ranges = np.zeros(
                (len(self.clusters), self.schema.n_columns), dtype=np.int64
            )
            acc = np.zeros(self.schema.n_columns, dtype=np.int64)
            for i, cm in enumerate(self.clusters):
                self.column_ranges[i] = acc
                acc += np.asarray(cm.n_elements, dtype=np.int64)
            self.total_elements = acc
        except BaseException:
            # never leak a file we opened ourselves when the metadata is
            # corrupt — the exact failure mode skim workers retry on
            if owns_sink:
                self.sink.close()
            raise

    def _pread(self, offset: int, size: int) -> bytes:
        """Every reader pread funnels through here: the retry chokepoint
        (ReadOptions.retry_policy; pass-through when None)."""
        return self._retrier.call(self.sink.pread, offset, size)

    def _pread_into(self, offset: int, buf) -> int:
        return self._retrier.call(self.sink.pread_into, offset, buf)

    def _load_footer_metadata(self) -> None:
        """The normal open path: anchor → header → footer → page list."""
        size = self.sink.size
        anchor = parse_anchor(self._pread(size - ANCHOR_SIZE, ANCHOR_SIZE))
        hoff, hsize = anchor["header"]
        foff, fsize = anchor["footer"]
        self.schema, self.options = parse_header(self._pread(hoff, hsize))
        footer = parse_footer(self._pread(foff, fsize))
        pl_off, pl_size = footer["pagelist"]
        self.clusters: List[ClusterMeta] = parse_pagelist(
            self._pread(pl_off, pl_size)
        )
        # optional framed-member side-car: attach member layouts so
        # chunked pages can decompress as parallel pool jobs.  Old
        # files simply have no locator and decode serially as before.
        mc_loc = (footer.get("extra") or {}).get("members")
        if mc_loc:
            parse_member_sidecar(
                self._pread(mc_loc[0], mc_loc[1]), self.clusters
            )
        self.n_entries = int(footer["n_entries"])
        # optional per-page zone maps (DESIGN.md §11).  Old files have
        # no "zonemaps" extra and simply never prune; malformed stats
        # decode to None per cluster (decode_zonemaps is defensive).
        self.zonemaps = decode_zonemaps(
            (footer.get("extra") or {}).get("zonemaps"), len(self.clusters)
        ) or [None] * len(self.clusters)

    # -- worker pools --------------------------------------------------------

    def _get_decode_pool(self):
        if self.read_options.decode_workers and self._decode_pool is None:
            with self._pool_lock:
                if self._decode_pool is None:
                    self._decode_pool = comp.make_pool(
                        self.read_options.decode_workers, "rntj-decode"
                    )
        return self._decode_pool

    def _get_prefetch_pool(self):
        if self.read_options.prefetch_clusters and self._prefetch_pool is None:
            with self._pool_lock:
                if self._prefetch_pool is None:
                    self._prefetch_pool = comp.make_pool(
                        self.read_options.prefetch_clusters, "rntj-prefetch"
                    )
        return self._prefetch_pool

    # -- cluster-level access ------------------------------------------------

    @property
    def n_clusters(self) -> int:
        return len(self.clusters)

    def _alloc_column(self, ci: int, count: int) -> np.ndarray:
        """One decode output array — drawn from the reader's buffer pool
        when ``recycle_buffers`` is on (returned via :meth:`recycle`)."""
        dtype = self.schema.columns[ci].dtype
        if self._bufpool is not None and self.read_options.recycle_buffers:
            raw = self._bufpool.take(count * dtype.itemsize)
            return raw.view(dtype)[:count]
        return np.empty(count, dtype=dtype)

    def recycle(self, cols: Dict[int, np.ndarray]) -> None:
        """Return a cluster's decoded arrays to the reader's pool.

        Only call this when nothing references the arrays (or views of
        them) anymore; ``iter_clusters`` does it automatically for the
        previous cluster when ``ReadOptions.recycle_buffers`` is set.
        """
        if self._bufpool is None:
            return
        for arr in cols.values():
            self._bufpool.put(arr)

    def _coalesce(self, descs: List[PageDesc]) -> List[Tuple[int, int, List[PageDesc]]]:
        """Plan the cluster's reads: ``[(offset, end, pages)]`` ranges.

        Pages sort by byte offset; a page joins the previous range when
        the hole between them is ≤ ``coalesce_gap`` and the merged range
        stays under ``max_coalesced_bytes``.
        """
        o = self.read_options
        if o.coalesce_gap < 0:
            return [(d.offset, d.offset + d.size, [d]) for d in descs]
        ranges: List[List] = []
        for d in sorted(descs, key=lambda p: p.offset):
            if ranges:
                start, end, group = ranges[-1]
                if (
                    d.offset - end <= o.coalesce_gap
                    and d.offset + d.size - start <= o.max_coalesced_bytes
                ):
                    ranges[-1][1] = max(end, d.offset + d.size)
                    group.append(d)
                    continue
            ranges.append([d.offset, d.offset + d.size, [d]])
        return [(s, e, g) for s, e, g in ranges]

    def read_cluster(
        self, cluster_index: int, columns: Optional[Sequence[int]] = None
    ) -> Dict[int, np.ndarray]:
        """Read the element arrays of a cluster.

        Offset columns keep their on-disk cluster-relative form (ends of
        each collection within the cluster).  I/O is coalesced; pages
        decode — on the decode pool when one is configured — directly
        into one preallocated array per column, in page-list order.
        Consecutive stored-uncompressed pages of a column decode as ONE
        column-batched run (``unprecondition_pages_into``); the remaining
        pages decode per page, chunked to amortize pool dispatch.
        """
        cm = self.clusters[cluster_index]
        want = set(columns) if columns is not None else None
        targets = list(want) if want is not None else list(range(self.schema.n_columns))
        descs = [d for d in cm.pages if want is None or d.column in want]

        # one output array per column; pages fill slices in page-list order
        counts = {ci: 0 for ci in targets}
        for d in descs:
            counts[d.column] += d.n_elements
        out: Dict[int, np.ndarray] = {
            ci: self._alloc_column(ci, counts[ci]) for ci in targets
        }
        if not descs:
            return out
        pos = {}         # id(desc) -> first element index in its column array
        by_col: Dict[int, List[PageDesc]] = {}
        cursor = {ci: 0 for ci in targets}
        for d in descs:
            pos[id(d)] = cursor[d.column]
            cursor[d.column] += d.n_elements
            by_col.setdefault(d.column, []).append(d)

        # coalesced I/O
        ranges = self._coalesce(descs)
        t0 = _ns()
        bufs = [self._pread(start, end - start) for start, end, _ in ranges]
        io_ns = _ns() - t0
        loc = {}         # id(desc) -> (range index, zero-copy payload view)
        for ri, ((start, _end, group), buf) in enumerate(zip(ranges, bufs)):
            mv = memoryview(buf)
            for d in group:
                rel = d.offset - start
                loc[id(d)] = (ri, mv[rel : rel + d.size])

        # plan: column-batched runs of byte-contiguous stored pages vs
        # per-page decode (compressed pages, or broken adjacency) vs
        # member-parallel decompression (side-car'd chunk-framed pages)
        pool = self._get_decode_pool()
        run_jobs: List[Tuple] = []
        page_jobs: List[PageDesc] = []
        member_pages: List[PageDesc] = []
        use_members = pool is not None and self.read_options.parallel_members
        for ci, ds in by_col.items():
            i = 0
            while i < len(ds):
                d = ds[i]
                if d.codec != comp.CODEC_NONE:
                    if use_members and d.members and len(d.members) > 1:
                        member_pages.append(d)
                    else:
                        page_jobs.append(d)
                    i += 1
                    continue
                run = [d]
                per = d.n_elements
                j = i + 1
                while j < len(ds):
                    p, q = ds[j - 1], ds[j]
                    if (
                        q.codec == comp.CODEC_NONE
                        and loc[id(q)][0] == loc[id(p)][0]
                        and q.offset == p.offset + p.size
                        and p.n_elements == per
                        and q.n_elements <= per
                    ):
                        run.append(q)
                        j += 1
                    else:
                        break
                if len(run) == 1:
                    page_jobs.append(d)
                else:
                    run_jobs.append((ci, run, per))
                i = j

        def _decode_run(job):
            ci, run, per = job
            col = self.schema.columns[ci]
            if self.verify:
                for d in run:
                    if d.checksum and zlib.crc32(loc[id(d)][1]) != d.checksum:
                        raise IOError(
                            f"page checksum mismatch (column {col.path!r})"
                        )
            first, last = run[0], run[-1]
            ri = loc[id(first)][0]
            base = memoryview(bufs[ri])
            rel = first.offset - ranges[ri][0]
            raw = base[rel : rel + (last.offset + last.size - first.offset)]
            n = pos[id(last)] + last.n_elements - pos[id(first)]
            dst = out[ci][pos[id(first)] : pos[id(first)] + n]
            t0 = _ns()
            unprecondition_pages_into(raw, col.encoding, per, dst,
                                      _thread_scratch())
            nbytes = sum(d.size for d in run)
            return 0, _ns() - t0, {
                comp.CODEC_NONE: [len(run), nbytes, nbytes, 0]
            }

        def _decode_pages(chunk):
            dec = deco = 0
            per_codec = {}
            for d in chunk:
                s = pos[id(d)]
                a, b = decode_page_into(
                    loc[id(d)][1], d, self.schema.columns[d.column],
                    out[d.column][s : s + d.n_elements], self.verify,
                )
                dec += a
                deco += b
                st = per_codec.setdefault(d.codec, [0, 0, 0, 0])
                st[0] += 1
                st[1] += d.size
                st[2] += d.uncompressed_size
                st[3] += a
            return dec, deco, per_codec

        # wave 1 — member-parallel entropy decode (ISSUE 4 satellite):
        # each side-car'd page's members decompress as independent pool
        # jobs into one preallocated raw buffer per page; the page then
        # unpreconditions like any raw page in the main task wave.  A page
        # whose side-car record does not cover its payload falls back to
        # the serial whole-page path.
        member_state: Dict[int, Tuple[bytearray, List[int]]] = {}
        if member_pages:
            mjobs: List[Tuple] = []
            ok_pages: List[PageDesc] = []
            for d in member_pages:
                plan = _member_plan(d)
                if plan is None:
                    page_jobs.append(d)
                    continue
                payload = loc[id(d)][1]
                if self.verify and d.checksum and zlib.crc32(payload) != d.checksum:
                    raise IOError(
                        "page checksum mismatch (column "
                        f"{self.schema.columns[d.column].path!r})"
                    )
                # member scratch recycles through the reader pool: it is
                # internal (dropped right after the unprecondition copies
                # into the output array), so pooling it is always safe
                if self._bufpool is not None:
                    raw = self._bufpool.take_view(d.uncompressed_size)
                else:
                    raw = bytearray(d.uncompressed_size)
                member_state[id(d)] = (raw, [0])
                for coff, csz, uoff, ulen in plan:
                    mjobs.append((d, payload[coff : coff + csz], raw, uoff, ulen))
                ok_pages.append(d)
            member_pages = ok_pages

            def _run_member(job):
                d, part, raw, uoff, ulen = job
                t0 = _ns()
                raw[uoff : uoff + ulen] = comp.decompress(part, d.codec, ulen)
                return id(d), _ns() - t0

            for did, ns in pool.map(_run_member, mjobs):
                member_state[did][1][0] += ns

        def _decode_member_page(d):
            raw, acc = member_state[id(d)]
            col = self.schema.columns[d.column]
            s = pos[id(d)]
            t0 = _ns()
            unprecondition_into(
                raw, col.encoding, out[d.column][s : s + d.n_elements],
                _thread_scratch(),
            )
            if self._bufpool is not None:
                self._bufpool.put(raw)  # scratch fully copied out: recycle
            return acc[0], _ns() - t0, {
                d.codec: [1, d.size, d.uncompressed_size, acc[0]]
            }

        tasks = [(_decode_run, j) for j in run_jobs]
        tasks += [(_decode_member_page, d) for d in member_pages]
        if page_jobs:
            if pool is None:
                chunks = [page_jobs]
            else:
                # ~2 chunks per worker: parallelism without per-page futures
                k = max(1, len(page_jobs)
                        // (2 * self.read_options.decode_workers))
                chunks = [page_jobs[i : i + k]
                          for i in range(0, len(page_jobs), k)]
            tasks += [(_decode_pages, c) for c in chunks]
        if pool is None:
            times = [fn(arg) for fn, arg in tasks]
        else:
            times = list(pool.map(lambda t: t[0](t[1]), tasks))
        per_codec: Dict[int, List[int]] = {}
        for _dec, _deco, pc in times:
            _merge_codec_stats(per_codec, pc)
        self.stats.add_cluster_read(
            pages=len(descs),
            reads=len(ranges),
            compressed_bytes=sum(d.size for d in descs),
            uncompressed_bytes=sum(d.uncompressed_size for d in descs),
            io_ns=io_ns,
            decompress_ns=sum(t[0] for t in times),
            decode_ns=sum(t[1] for t in times),
            per_codec=per_codec,
        )
        return out

    def cluster_entry_range(self, cluster_index: int) -> Tuple[int, int]:
        cm = self.clusters[cluster_index]
        return cm.first_entry, cm.first_entry + cm.n_entries

    # -- zone-map pruning (DESIGN.md §11) ------------------------------------

    def _fold_zone(self, i: int, ci: int) -> Optional[Zone]:
        """Cluster-level :class:`Zone` of leaf column ``ci`` in cluster
        ``i`` — the fold of its page rows — or ``None`` when the cluster
        carries no stats for it."""
        cm = self.clusters[i]
        nested = self.schema.parent[ci] != -1
        count = int(cm.n_elements[ci])
        if count == 0:
            return Zone.empty(nested)
        zm = self.zonemaps[i]
        d = None if zm is None else zm.get(ci)
        if d is None or "lo" not in d:
            return None
        lo = hi = None
        for v, w in zip(d["lo"], d["hi"]):
            if isinstance(v, float) and math.isnan(v):
                continue  # all-NaN page: contributes no bounds
            if lo is None or v < lo:
                lo = v
            if hi is None or w > hi:
                hi = w
        return Zone(lo, hi, int(sum(d.get("nn", ()))), count, nested)

    def _page_counts(self, cm: ClusterMeta) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        for d in cm.pages:
            out.setdefault(d.column, []).append(d.n_elements)
        return out

    def _prune_plan(self):
        """Compile ``ReadOptions.filter`` against the footer zone maps.

        Returns one slot per cluster: ``None`` — no pruning possible,
        read the whole cluster; ``[]`` — the zone maps prove no entry
        can match, skip the cluster entirely; else the surviving
        half-open entry ranges ``[(e0, e1), ...]`` (cluster-relative).
        Returns ``None`` overall when no filter is set or pruning is
        disabled.  The plan is conservative: ranges are a *superset* of
        the matching entries; exactness comes from re-evaluating the
        predicate on what they decode.
        """
        o = self.read_options
        expr = o.filter
        if expr is None or not o.prune:
            return None
        if self._plan_cache is not None:
            return self._plan_cache
        expr.validate(self.schema)
        paths = filter_paths(self.schema, expr)
        plan: List[Optional[List[Tuple[int, int]]]] = []
        for i, cm in enumerate(self.clusters):
            plan.append(self._plan_cluster(i, cm, expr, paths))
        self._plan_cache = plan
        return plan

    def _plan_cluster(self, i, cm, expr, paths):
        n = cm.n_entries
        zm = self.zonemaps[i]
        if zm is None or n == 0:
            return None
        # cluster-scope zones: one fold per filter column.  A column
        # without stats stays out of the dict (= unconstrained).
        zones: Dict[str, Zone] = {}
        for path, ci in paths.items():
            z = self._fold_zone(i, ci)
            if z is not None:
                zones[path] = z
        if expr.zone_eval(zones) == T_FALSE:
            return []
        # per-page refinement: restrict one filter column at a time to a
        # single page's zone (the others stay at cluster scope) and keep
        # the page's entry range unless the verdict is T_FALSE.
        page_counts = self._page_counts(cm)
        cand = np.ones(n, dtype=bool)
        for path, ci in paths.items():
            d = zm.get(ci)
            if d is None or "lo" not in d:
                continue
            fe, le = d["fe"], d["le"]
            counts = page_counts.get(ci)
            if counts is None or len(counts) != len(fe):
                continue  # inconsistent stats: no refinement from ci
            nested = self.schema.parent[ci] != -1
            nn = d.get("nn") or [0] * len(fe)
            keep = np.zeros(n, dtype=bool)
            covered = np.zeros(n, dtype=bool)
            ok = True
            for k in range(len(fe)):
                a, b = int(fe[k]), int(le[k])
                if a < 0 or b >= n or b < a or (k and a < int(fe[k - 1])):
                    ok = False  # corrupt row: refine nothing from ci
                    break
                covered[a : b + 1] = True
                pz = Zone(d["lo"][k], d["hi"][k], int(nn[k]),
                          int(counts[k]), nested)
                if expr.zone_eval({**zones, path: pz}) != T_FALSE:
                    keep[a : b + 1] = True
            if not ok:
                continue
            # an entry whose elements STRADDLE pages is only soundly
            # judged by a zone covering all of them: re-judge every
            # boundary-shared entry against the fold of its pages
            for k in range(1, len(fe)):
                e = int(fe[k])
                if e > int(le[k - 1]) or keep[e]:
                    continue
                span = [j for j in range(len(fe))
                        if int(fe[j]) <= e <= int(le[j])]
                lo = hi = None
                nnn = cnt = 0
                for j in span:
                    v, w = d["lo"][j], d["hi"][j]
                    if not (isinstance(v, float) and math.isnan(v)):
                        lo = v if lo is None or v < lo else lo
                        hi = w if hi is None or w > hi else hi
                    nnn += int(nn[j])
                    cnt += int(counts[j])
                fz = Zone(lo, hi, nnn, cnt, nested)
                if expr.zone_eval({**zones, path: fz}) != T_FALSE:
                    keep[e] = True
            if not covered.all():
                # entries with no element in this column (empty
                # collections in page-boundary gaps): judge them
                # against an empty zone
                if expr.zone_eval(
                    {**zones, path: Zone.empty(nested)}
                ) != T_FALSE:
                    keep |= ~covered
            cand &= keep
        if cand.all():
            return None
        if not cand.any():
            return []
        d8 = np.diff(cand.astype(np.int8), prepend=0, append=0)
        starts = np.nonzero(d8 == 1)[0]
        ends = np.nonzero(d8 == -1)[0]
        return list(zip(starts.tolist(), ends.tolist()))

    def _pages_of(self, cm: ClusterMeta,
                  columns: Optional[Sequence[int]]) -> int:
        if columns is None:
            return len(cm.pages)
        want = set(columns)
        return sum(1 for d in cm.pages if d.column in want)

    def _expand_ancestors(
        self, columns: Optional[Sequence[int]]
    ) -> Optional[set]:
        """Requested columns plus every ancestor offset column (which
        locate the element ranges), or ``None`` for "all columns"."""
        if columns is None:
            return None
        want = set(columns)
        for ci in list(want):
            p = self.schema.parent[ci]
            while p != -1:
                want.add(p)
                p = self.schema.parent[p]
        return want

    def read_entry_range(
        self,
        cluster_index: int,
        e0: int,
        e1: int,
        columns: Optional[Sequence[int]] = None,
        _page_cache: Optional[Dict[int, np.ndarray]] = None,
    ) -> Dict[int, np.ndarray]:
        """Decode one entry range ``[e0, e1)`` of a cluster (entries are
        cluster-relative), reading only the pages that overlap it.

        Returns ``{column: array}`` where offset columns hold
        **range-local** end offsets (rebased so the range recomposes
        like a miniature cluster).  Ancestor offset columns of every
        requested column ride along — they locate the element ranges.

        ``_page_cache`` (one dict per cluster, shared across the ranges
        of a prune plan) memoizes decoded pages so adjacent ranges that
        straddle a page boundary never pread or decode that page twice —
        the pruned path can only ever read *fewer* pages than a full
        cluster scan, never more.

        ``ReaderStats.pages_pruned`` accounting is owned by the CALLER:
        a plan-driven iterator counts each cluster exactly once, as its
        page total minus the distinct pages decoded (``len(cache)``).
        Per-call accounting here would re-count the same unread pages
        for every range issued against one cluster, so a standalone
        range read contributes nothing to ``pages_pruned`` (``clusters``
        is likewise not bumped: range reads are sub-cluster).
        """
        cm = self.clusters[cluster_index]
        want = self._expand_ancestors(columns)
        if want is None:
            want = set(range(self.schema.n_columns))
        targets = sorted(want)  # schema order: parents precede children
        by_col: Dict[int, List[PageDesc]] = {ci: [] for ci in targets}
        for dsc in cm.pages:
            if dsc.column in want:
                by_col[dsc.column].append(dsc)

        out: Dict[int, np.ndarray] = {}
        child_range: Dict[int, Tuple[int, int]] = {}
        pages_read = reads = cbytes = ubytes = 0
        io_ns = deco_ns = dec_ns = 0
        for ci in targets:
            col = self.schema.columns[ci]
            is_off = col.kind == KIND_OFFSET
            p = self.schema.parent[ci]
            a, b = (e0, e1) if p == -1 else child_range[p]
            # offset columns fetch one extra leading element: the end of
            # the previous collection is the range's rebase base
            fa = max(a - 1, 0) if is_off else a
            ds = by_col[ci]
            if b <= fa or not ds:
                out[ci] = np.empty(0, dtype=col.dtype)
                if is_off:
                    child_range[ci] = (0, 0)
                continue
            starts = [0]
            for dsc in ds:
                starts.append(starts[-1] + dsc.n_elements)
            k0 = np.searchsorted(starts, fa, side="right") - 1
            kl = np.searchsorted(starts, b - 1, side="right") - 1
            picked = ds[k0 : kl + 1]
            fetch = (picked if _page_cache is None else
                     [dsc for dsc in picked if id(dsc) not in _page_cache])
            if fetch:
                ranges = self._coalesce(fetch)
                t0 = _ns()
                bufs = [self._pread(s, e - s) for s, e, _ in ranges]
                io_ns += _ns() - t0
                loc = {}
                for (s, _e, group), raw in zip(ranges, bufs):
                    mv = memoryview(raw)
                    for dsc in group:
                        rel = dsc.offset - s
                        loc[id(dsc)] = mv[rel : rel + dsc.size]
                pages_read += len(fetch)
                reads += len(ranges)
                cbytes += sum(dsc.size for dsc in fetch)
                ubytes += sum(dsc.uncompressed_size for dsc in fetch)
            if _page_cache is None:
                buf = np.empty(starts[kl + 1] - starts[k0], dtype=col.dtype)
                off = 0
                for dsc in picked:
                    da, db = decode_page_into(
                        loc[id(dsc)], dsc, col,
                        buf[off : off + dsc.n_elements], self.verify,
                    )
                    dec_ns += da
                    deco_ns += db
                    off += dsc.n_elements
            else:
                for dsc in fetch:
                    pb = np.empty(dsc.n_elements, dtype=col.dtype)
                    da, db = decode_page_into(
                        loc[id(dsc)], dsc, col, pb, self.verify,
                    )
                    dec_ns += da
                    deco_ns += db
                    _page_cache[id(dsc)] = pb
                arrs = [_page_cache[id(dsc)] for dsc in picked]
                buf = arrs[0] if len(arrs) == 1 else np.concatenate(arrs)
            sl = buf[fa - starts[k0] : b - starts[k0]]
            if is_off:
                base = int(sl[0]) if a > 0 else 0
                vals = sl[1:] if a > 0 else sl
                child_range[ci] = (
                    base, int(vals[-1]) if len(vals) else base
                )
                out[ci] = vals - base
            else:
                out[ci] = sl
        self.stats.add_cluster_read(
            pages=pages_read, reads=reads, compressed_bytes=cbytes,
            uncompressed_bytes=ubytes, io_ns=io_ns, decompress_ns=dec_ns,
            decode_ns=deco_ns, clusters=0,
        )
        return out

    def iter_cluster_segments(
        self,
        columns: Optional[Sequence[int]] = None,
        start: int = 0,
        stop: Optional[int] = None,
    ) -> Iterator[Tuple[int, List[Tuple[int, Dict[int, np.ndarray], int]]]]:
        """The shared entry-range-selection helper (skim engine +
        :meth:`iter_filtered`).

        Yields ``(cluster_index, segments)`` for EVERY cluster in entry
        order, ``segments`` being ``[(first_entry, cols, n_entries),
        ...]`` with cluster-relative ``first_entry`` and range-local
        arrays.  Without an applicable filter each cluster yields one
        full-cluster segment (the arrays of :meth:`iter_clusters`); with
        one, only zone-surviving entry ranges are decoded and fully
        pruned clusters yield ``(i, [])`` — so pruned and unpruned
        consumers see identical per-cluster grouping (the skim engine's
        byte-identity contract).
        """
        plan = self._prune_plan()
        n = self.n_clusters
        if stop is None or stop > n:
            stop = n
        if plan is None:
            for i, cols in self.iter_clusters(columns, start, stop,
                                              recycle=False):
                yield i, [(0, cols, self.clusters[i].n_entries)]
            return

        def read_segments(i):
            p = plan[i]
            if p is None:
                return [(0, self.read_cluster(i, columns),
                         self.clusters[i].n_entries)]
            # one decoded-page cache per cluster: ranges that straddle a
            # page boundary share the decode, so the pruned path never
            # reads more pages than the full-cluster scan would
            cache: Dict[int, np.ndarray] = {}
            segs = [(a, self.read_entry_range(i, a, b, columns,
                                              _page_cache=cache), b - a)
                    for a, b in p]
            want = self._expand_ancestors(columns)
            total = self._pages_of(
                self.clusters[i], None if want is None else sorted(want))
            self.stats.add_pruned(pages=max(total - len(cache), 0))
            return segs

        depth = self.read_options.prefetch_clusters
        pool = self._get_prefetch_pool() if depth > 0 else None
        live = [i for i in range(start, stop) if plan[i] != []]
        skipped = [i for i in range(start, stop) if plan[i] == []]
        for i in skipped:
            self.stats.add_pruned(
                clusters=1, pages=self._pages_of(self.clusters[i], columns)
            )
        if pool is None:
            for i in range(start, stop):
                yield i, ([] if plan[i] == [] else read_segments(i))
            return
        # double-buffered like iter_clusters: only live clusters occupy
        # prefetch slots; skipped ones yield [] inline (no I/O at all)
        pending: deque = deque()
        live_iter = iter(live)

        def top_up():
            while len(pending) < depth:
                j = next(live_iter, None)
                if j is None:
                    return
                pending.append((j, pool.submit(read_segments, j)))

        top_up()
        try:
            for i in range(start, stop):
                if plan[i] == []:
                    yield i, []
                    continue
                _j, fut = pending.popleft()
                t0 = _ns()
                got = fut.result()
                self.stats.add_wait_ns(_ns() - t0)
                top_up()
                yield i, got
        finally:
            for _, fut in pending:
                fut.cancel()

    def _live_clusters(
        self, start: int, stop: Optional[int],
        columns: Optional[Sequence[int]]
    ) -> List[int]:
        """Cluster indices to iterate after the cluster-level prune skip
        (``ReadOptions.filter``): clusters whose zone maps prove no
        entry can match drop out before any pread is issued for them
        (counted in ``ReaderStats.clusters_pruned``)."""
        n = self.n_clusters
        if stop is None or stop > n:
            stop = n
        plan = self._prune_plan()
        if plan is None:
            return list(range(start, stop))
        out = []
        for i in range(start, stop):
            if plan[i] == []:
                self.stats.add_pruned(
                    clusters=1,
                    pages=self._pages_of(self.clusters[i], columns),
                )
            else:
                out.append(i)
        return out

    def iter_filtered(
        self,
        columns: Optional[Sequence[int]] = None,
        start: int = 0,
        stop: Optional[int] = None,
    ) -> Iterator[Tuple[int, int, Dict[int, np.ndarray], int]]:
        """Exact filtered iteration over ``ReadOptions.filter``.

        Yields ``(cluster_index, absolute_first_entry, cols, n_entries)``
        for every maximal run of entries matching the predicate.  Two
        phases per zone-surviving segment: the filter's columns decode
        first and the predicate is evaluated exactly; the remaining
        requested columns are then **late-materialized** only for the
        matching runs.  ``cols`` carries the requested columns plus the
        filter's columns and any ancestor offsets, all range-local.

        The matching runs of one cluster late-materialize through ONE
        shared decoded-page cache (mirroring the phase-1 reads inside
        :meth:`iter_cluster_segments`), so adjacent runs never pread or
        decode a shared page twice — the pruned read touches no more
        pages than the unpruned scan here too.  Skipped pages of the
        late-materialized columns are counted in
        ``ReaderStats.pages_pruned`` once per cluster.
        """
        expr = self.read_options.filter
        if expr is None:
            raise ValueError("iter_filtered requires ReadOptions.filter")
        expr.validate(self.schema)
        freq = required_columns(self.schema, expr)
        want = (set(columns) if columns is not None
                else set(range(self.schema.n_columns)))
        phase1 = sorted(set(freq))
        rest = sorted(want - set(phase1))
        rest_want = (sorted(self._expand_ancestors(rest))
                     if rest else None)
        for i, segs in self.iter_cluster_segments(columns=phase1,
                                                  start=start, stop=stop):
            abs0 = self.clusters[i].first_entry
            cache: Dict[int, np.ndarray] = {}
            for e0, cols, n in segs:
                if n == 0:
                    continue
                mask = expr.evaluate(EvalContext(self.schema, cols, n))
                if not mask.any():
                    continue
                d8 = np.diff(mask.astype(np.int8), prepend=0, append=0)
                rs = np.nonzero(d8 == 1)[0].tolist()
                re_ = np.nonzero(d8 == -1)[0].tolist()
                for r0, r1 in zip(rs, re_):
                    out: Dict[int, np.ndarray] = {}
                    if rest:
                        out.update(self.read_entry_range(
                            i, e0 + r0, e0 + r1, rest, _page_cache=cache
                        ))
                    # the filter columns slice straight out of phase 1
                    out.update(
                        slice_entry_range(self.schema, cols, r0, r1)
                    )
                    yield i, abs0 + e0 + r0, out, r1 - r0
            if rest and segs:
                # zone-skipped clusters (segs == []) are accounted inside
                # iter_cluster_segments; surviving ones account their
                # late-materialization columns here, once per cluster
                total = self._pages_of(self.clusters[i], rest_want)
                self.stats.add_pruned(pages=max(total - len(cache), 0))

    # -- the device decode path (DESIGN.md §9) -------------------------------

    def _device_backend(self) -> Tuple[bool, bool]:
        """-> ``(use_pallas, interpret)`` for the fused decode drivers.

        ``auto`` compiles the jnp oracle ops through XLA (and engages the
        Pallas kernels on TPU); ``pallas`` forces the kernels — interpret
        mode off-TPU, the bit-identity test configuration.  ``auto``
        defers to ``REPRO_KERNEL_BACKEND`` (the one knob shared by every
        dispatched kernel, §7.4) so the CI pallas-interpret job drives
        this chain too.
        """
        import jax

        mode = self.read_options.device_decode
        if mode == "auto":
            from repro.kernels.ops import GLOBAL_BACKEND_ENV

            mode = os.environ.get(GLOBAL_BACKEND_ENV, "auto").lower()
        if mode == "pallas":
            return True, jax.default_backend() != "tpu"
        return jax.default_backend() == "tpu", False

    def _plan_device_cluster(self, cluster_index: int, targets: Sequence[int]):
        """Split a cluster's columns into device plans and host fallbacks,
        and lay out the staging buffer.

        A column decodes on device when its pages are *uniform* (every
        page but the last carries the same element count — the sealed
        layout), its element width survives 32-bit lanes (8-byte leaf
        columns fall back: jax runs with x64 disabled), and — for offset
        columns — the cluster's child element total fits int32, which
        makes the fused int32 offsets EXACT (§9).  Everything else
        decodes through the host path unchanged.
        """
        cm = self.clusters[cluster_index]
        by_col: Dict[int, List[PageDesc]] = {}
        want = set(targets)
        for d in cm.pages:
            if d.column in want:
                by_col.setdefault(d.column, []).append(d)
        plans: List[Dict] = []
        fallback: List[int] = []
        base = 0
        for ci in targets:
            ds = by_col.get(ci, [])
            col = self.schema.columns[ci]
            n = sum(d.n_elements for d in ds)
            nb = col.itemsize
            per = ds[0].n_elements if ds else 0
            uniform = bool(ds) and all(
                d.n_elements == per for d in ds[:-1]
            ) and ds[-1].n_elements <= per
            ok_bytes = all(
                d.uncompressed_size == d.n_elements * nb for d in ds
            )
            route = None
            if n and uniform and ok_bytes:
                enc = col.encoding
                if enc == "none" and nb < 8:
                    route = "none"
                elif enc == "split" and nb < 8:
                    route = "split"
                elif enc == "dzs" and col.kind == KIND_OFFSET:
                    kids = [
                        k for k, p in enumerate(self.schema.parent) if p == ci
                    ]
                    if kids and int(cm.n_elements[kids[0]]) < 2**31:
                        route = "offsets"
            if route is None:
                fallback.append(ci)
                continue
            plans.append({"ci": ci, "descs": ds, "per": per, "n": n,
                          "nb": nb, "base": base, "route": route})
            base += n * nb
        return plans, fallback, base

    def _stage_cluster_device(self, cluster_index: int,
                              columns: Optional[Sequence[int]]):
        """The HOST half of the device decode: pread + entropy-decode the
        cluster's device-eligible pages into ONE pooled staging buffer
        (page ``p`` of a column at byte range ``[p*per*nb, p*per*nb +
        k*nb)``), then run the single H2D upload.  Returns ``(plans,
        device_bytes, fallback_columns, staging)``.
        ``iter_clusters_device`` runs this on the prefetch pool so
        cluster *N+1*'s I/O, decompression and upload overlap cluster
        *N*'s device decode.

        The staging buffer rides along in the return value because the
        caller must recycle it only AFTER the device half: on CPU
        backends ``jax.device_put`` zero-copies a 64-byte-aligned host
        buffer, so ``dev`` may alias ``staging`` — recycling it here
        would let the next cluster's fill clobber this cluster's device
        bytes mid-decode.
        """
        import jax

        targets = (list(columns) if columns is not None
                   else list(range(self.schema.n_columns)))
        plans, fallback, total = self._plan_device_cluster(
            cluster_index, targets
        )
        if not plans:
            return [], None, fallback, None
        descs = [d for p in plans for d in p["descs"]]
        slot = {}  # id(desc) -> staging byte offset of the page's payload
        for p in plans:
            stride = p["per"] * p["nb"]
            for k, d in enumerate(p["descs"]):
                slot[id(d)] = p["base"] + k * stride

        if self._bufpool is not None:
            staging = self._bufpool.take(total)
        else:
            staging = np.empty(total, dtype=np.uint8)

        # Fast path: a codec-none column whose pages sit contiguously in
        # the file already IS in sealed staging layout — pread straight
        # into its staging slot, skipping the bounce buffer and the
        # memcpy pass entirely.
        direct, rest = [], []
        for p in plans:
            ds = p["descs"]
            stride = p["per"] * p["nb"]
            if ds and all(d.codec == comp.CODEC_NONE
                          and d.offset == ds[0].offset + k * stride
                          for k, d in enumerate(ds)):
                direct.append(p)
            else:
                rest.extend(p["descs"])

        smv = memoryview(staging)
        t0 = _ns()
        for p in direct:
            nbytes = sum(d.size for d in p["descs"])
            self._pread_into(
                p["descs"][0].offset, smv[p["base"] : p["base"] + nbytes]
            )
        ranges = self._coalesce(rest)
        bufs = [self._pread(start, end - start) for start, end, _ in ranges]
        io_ns = _ns() - t0
        if self.verify:
            for p in direct:
                for d in p["descs"]:
                    s = slot[id(d)]
                    if d.checksum and zlib.crc32(smv[s : s + d.size]) != d.checksum:
                        raise IOError(
                            "page checksum mismatch (column "
                            f"{self.schema.columns[d.column].path!r})"
                        )
        jobs = []
        for (start, _end, group), buf in zip(ranges, bufs):
            mv = memoryview(buf)
            for d in group:
                rel = d.offset - start
                jobs.append((d, mv[rel : rel + d.size]))

        def _fill(chunk):
            ns = 0
            per_codec: Dict[int, List[int]] = {}
            for d, payload in chunk:
                if self.verify and d.checksum and zlib.crc32(payload) != d.checksum:
                    raise IOError(
                        "page checksum mismatch (column "
                        f"{self.schema.columns[d.column].path!r})"
                    )
                s = slot[id(d)]
                t0 = _ns()
                if d.codec == comp.CODEC_NONE:
                    staging[s : s + d.size] = payload
                else:
                    staging[s : s + d.uncompressed_size] = np.frombuffer(
                        comp.decompress(payload, d.codec, d.uncompressed_size),
                        dtype=np.uint8,
                    )
                dt = _ns() - t0
                ns += dt
                st = per_codec.setdefault(d.codec, [0, 0, 0, 0])
                st[0] += 1
                st[1] += d.size
                st[2] += d.uncompressed_size
                st[3] += dt
            return ns, per_codec

        pool = self._get_decode_pool()
        if not jobs:
            results = []
        elif pool is None:
            results = [_fill(jobs)]
        else:
            k = max(1, len(jobs) // (2 * self.read_options.decode_workers))
            chunks = [jobs[i : i + k] for i in range(0, len(jobs), k)]
            results = list(pool.map(_fill, chunks))
        per_codec: Dict[int, List[int]] = {}
        deco_ns = 0
        for ns, pc in results:
            deco_ns += ns
            _merge_codec_stats(per_codec, pc)
        if direct:  # direct preads bypass _fill; account their pages
            st = per_codec.setdefault(comp.CODEC_NONE, [0, 0, 0, 0])
            for p in direct:
                for d in p["descs"]:
                    st[0] += 1
                    st[1] += d.size
                    st[2] += d.uncompressed_size

        t0 = _ns()
        dev = jax.device_put(staging[:total])
        dev.block_until_ready()
        h2d_ns = _ns() - t0
        self.stats.add_cluster_read(
            pages=len(descs),
            reads=len(ranges) + len(direct),
            compressed_bytes=sum(d.size for d in descs),
            uncompressed_bytes=sum(d.uncompressed_size for d in descs),
            io_ns=io_ns,
            decompress_ns=deco_ns,
            decode_ns=0,
            per_codec=per_codec,
        )
        self.stats.add_device_cluster(h2d_ns)
        return plans, dev, fallback, staging

    def _decode_staged(self, plans: List[Dict], dev) -> Dict[int, object]:
        """The DEVICE half: run the fused per-column decode drivers over
        the uploaded staging bytes -> ``{column: jax device array}``."""
        from repro.kernels import decode_pages as dk

        use_pallas, interpret = self._device_backend()
        out: Dict[int, object] = {}
        t0 = _ns()
        for p in plans:
            raw = dev[p["base"] : p["base"] + p["n"] * p["nb"]]
            if p["route"] == "offsets":
                out[p["ci"]] = dk.device_decode_offsets(
                    raw, p["n"], p["per"],
                    use_pallas=use_pallas, interpret=interpret,
                )
            elif p["route"] == "split":
                out[p["ci"]] = dk.device_decode_split(
                    raw, p["n"], p["per"],
                    self.schema.columns[p["ci"]].dtype.name,
                    use_pallas=use_pallas, interpret=interpret,
                )
            else:
                out[p["ci"]] = dk.device_decode_none(
                    raw, p["n"], p["per"],
                    self.schema.columns[p["ci"]].dtype.name,
                    use_pallas=use_pallas, interpret=interpret,
                )
        for arr in out.values():
            arr.block_until_ready()
        self.stats.add_decode_ns(_ns() - t0)
        return out

    def read_cluster_device(
        self, cluster_index: int, columns: Optional[Sequence[int]] = None
    ) -> Dict[int, object]:
        """Read a cluster through the fused device decode chain (§9).

        Device-eligible columns come back as JAX device arrays — offset
        columns as EXACT int32 cluster-relative ends (the dispatch guard
        proves every offset fits) — after ONE H2D upload of the stored
        page bytes.  Columns the plan gates out (8-byte leaves, oversize
        clusters, non-uniform pages) decode through the host path
        unchanged and come back as numpy arrays.
        """
        if self.read_options.device_decode == "off":
            raise RuntimeError(
                "device decode disabled (ReadOptions.device_decode='off')"
            )
        return self._finish_staged(
            self._stage_cluster_device(cluster_index, columns), cluster_index
        )

    def _finish_staged(self, staged, cluster_index: int) -> Dict[int, object]:
        """Device half + host fallbacks for one staged cluster, then
        recycle the staging buffer (safe only now — ``dev`` may alias
        it, see :meth:`_stage_cluster_device`)."""
        plans, dev, fallback, staging = staged
        out = self._decode_staged(plans, dev) if plans else {}
        if staging is not None and self._bufpool is not None:
            # the decode outputs are materialized (block_until_ready in
            # _decode_staged), so nothing references the staged bytes
            self._bufpool.put(staging)
        if fallback:
            out.update(self.read_cluster(cluster_index, fallback))
        return out

    def iter_clusters_device(
        self,
        columns: Optional[Sequence[int]] = None,
        start: int = 0,
        stop: Optional[int] = None,
    ) -> Iterator[Tuple[int, Dict[int, object]]]:
        """Device-path analog of :meth:`iter_clusters` (DESIGN.md §9).

        With ``prefetch_clusters > 0`` the prefetch pool runs the HOST
        half of cluster *N+1* (pread, entropy decode into pooled staging,
        H2D upload) while the consumer's thread runs the DEVICE half of
        cluster *N* — double-buffered read/decode overlap with the device
        in the loop.  Yields ``(cluster_index, {column: array})`` with
        the same array types as :meth:`read_cluster_device`.
        """
        if self.read_options.device_decode == "off":
            raise RuntimeError(
                "device decode disabled (ReadOptions.device_decode='off')"
            )
        order = self._live_clusters(start, stop, columns)
        depth = self.read_options.prefetch_clusters
        pool = self._get_prefetch_pool() if depth > 0 else None
        if pool is None:
            for i in order:
                yield i, self._finish_staged(
                    self._stage_cluster_device(i, columns), i
                )
            return
        pending: deque = deque()
        nxt = 0
        try:
            while pending or nxt < len(order):
                while nxt < len(order) and len(pending) < depth:
                    j = order[nxt]
                    pending.append(
                        (j, pool.submit(self._stage_cluster_device, j, columns))
                    )
                    nxt += 1
                i, fut = pending.popleft()
                t0 = _ns()
                staged = fut.result()
                self.stats.add_wait_ns(_ns() - t0)
                # top up BEFORE the device half + yield: the next
                # cluster's host half makes progress while this one
                # decodes on device and the consumer packs it
                while nxt < len(order) and len(pending) < depth:
                    j = order[nxt]
                    pending.append(
                        (j, pool.submit(self._stage_cluster_device, j, columns))
                    )
                    nxt += 1
                yield i, self._finish_staged(staged, i)
        finally:
            for _, fut in pending:
                fut.cancel()

    # -- the prefetch pipeline -----------------------------------------------

    def iter_clusters(
        self,
        columns: Optional[Sequence[int]] = None,
        start: int = 0,
        stop: Optional[int] = None,
        recycle: Optional[bool] = None,
        prune: bool = True,
    ) -> Iterator[Tuple[int, Dict[int, np.ndarray]]]:
        """Yield ``(cluster_index, {column: elements})`` in entry order.

        With ``prefetch_clusters > 0`` up to that many clusters are read
        and decoded on a background pool while the caller consumes the
        current one; the ``wait`` phase of :class:`ReaderStats` records
        how long the consumer actually blocked.

        ``recycle`` (default: ``ReadOptions.recycle_buffers``) returns
        each cluster's arrays to the reader's buffer pool once the
        consumer advances past it — the yielded arrays are then only
        valid until the next iteration.  ``iter_entries`` and
        ``read_column`` always pass ``False``: they may hold views of a
        cluster's arrays beyond the iteration that produced them.

        With ``ReadOptions.filter`` set (and ``prune`` on), clusters the
        zone maps prove empty are skipped before any pread; surviving
        clusters still yield in full — re-evaluate the predicate (or use
        :meth:`iter_filtered`) for exact per-entry selection.
        ``prune=False`` ignores the filter entirely (every cluster
        yields, no pruned-stats recorded) — the full-scan mode the
        whole-file accessors :meth:`iter_entries` / :meth:`read_column`
        use so their results never depend on ``ReadOptions.filter``.
        """
        if prune:
            order = self._live_clusters(start, stop, columns)
        else:
            n = self.n_clusters
            order = list(range(start, n if stop is None or stop > n
                               else stop))
        if recycle is None:
            recycle = self.read_options.recycle_buffers
        recycle = recycle and self._bufpool is not None
        depth = self.read_options.prefetch_clusters
        pool = self._get_prefetch_pool() if depth > 0 else None
        if pool is None:
            for i in order:
                cols = self.read_cluster(i, columns)
                yield i, cols
                if recycle:
                    self.recycle(cols)
            return
        pending: deque = deque()
        nxt = 0
        try:
            while pending or nxt < len(order):
                while nxt < len(order) and len(pending) < depth:
                    j = order[nxt]
                    pending.append((j, pool.submit(self.read_cluster, j, columns)))
                    nxt += 1
                i, fut = pending.popleft()
                t0 = _ns()
                cols = fut.result()
                self.stats.add_wait_ns(_ns() - t0)
                # top up BEFORE yielding: the next clusters make progress
                # while the consumer processes this one
                while nxt < len(order) and len(pending) < depth:
                    j = order[nxt]
                    pending.append((j, pool.submit(self.read_cluster, j, columns)))
                    nxt += 1
                yield i, cols
                if recycle:
                    # the consumer advanced: this cluster's arrays feed
                    # the allocations of the clusters still to come
                    self.recycle(cols)
        finally:
            for _, fut in pending:
                fut.cancel()

    # -- entry-level access ----------------------------------------------------

    def iter_cluster_entries(
        self, cluster_index: int, fields: Optional[Sequence[str]] = None
    ) -> List[Dict]:
        cm = self.clusters[cluster_index]
        schema = self.schema if fields is None else self.schema.project(fields)
        if fields is None:
            cols = self.read_cluster(cluster_index)
            arrays = [cols[i] for i in range(self.schema.n_columns)]
        else:
            # map projected columns back to file columns (horizontal skim)
            file_idx = [self.schema.column_of_path[c.path] for c in schema.columns]
            cols = self.read_cluster(cluster_index, file_idx)
            arrays = [cols[i] for i in file_idx]
        return recompose_entries(schema, arrays, cm.n_entries)

    def iter_entries(self, fields: Optional[Sequence[str]] = None) -> Iterator[Dict]:
        """EVERY entry of the file, recomposed in entry order.

        A full scan regardless of ``ReadOptions.filter`` (``prune=False``
        below bypasses the plan): the filter belongs to
        :meth:`iter_filtered` / :meth:`iter_filtered_entries`, and a
        whole-file accessor silently dropping zone-pruned-but-unfiltered
        clusters would be a trap.
        """
        schema = self.schema if fields is None else self.schema.project(fields)
        file_idx = (
            None
            if fields is None
            else [self.schema.column_of_path[c.path] for c in schema.columns]
        )
        # recycle=False: recomposed entries may hold views of the arrays
        for i, cols in self.iter_clusters(columns=file_idx, recycle=False,
                                          prune=False):
            idx = file_idx if file_idx is not None else range(self.schema.n_columns)
            arrays = [cols[j] for j in idx]
            yield from recompose_entries(schema, arrays, self.clusters[i].n_entries)

    def iter_filtered_entries(
        self, fields: Optional[Sequence[str]] = None
    ) -> Iterator[Dict]:
        """Entries matching ``ReadOptions.filter``, recomposed like
        :meth:`iter_entries` — the pruned equivalent of a full scan
        followed by an exact predicate filter (DESIGN.md §11)."""
        schema = self.schema if fields is None else self.schema.project(fields)
        file_idx = (
            None
            if fields is None
            else [self.schema.column_of_path[c.path] for c in schema.columns]
        )
        for _i, _e0, cols, n in self.iter_filtered(columns=file_idx):
            idx = file_idx if file_idx is not None else range(self.schema.n_columns)
            arrays = [cols[j] for j in idx]
            yield from recompose_entries(schema, arrays, n)

    # -- whole-column access (analysis-style reads) ------------------------------

    def read_column(self, path: str) -> np.ndarray:
        """Concatenate a column across ALL clusters (prefetched).

        Offset columns are globalized: cluster-relative offsets are shifted
        by the running element count of their *child* column — giving the
        usual global offsets array.

        Like :meth:`iter_entries`, a full scan regardless of
        ``ReadOptions.filter``: the result always has exactly
        ``n_entries`` top-level elements, zone maps or not.
        """
        ci = self.schema.column_of_path[path]
        col = self.schema.columns[ci]
        chunks = []
        if col.kind == KIND_OFFSET:
            children = [
                k for k, p in enumerate(self.schema.parent) if p == ci
            ]
            child = children[0] if children else None
            base = 0
            # recycle=False on both paths: chunks holds every cluster's
            # array until the final concatenate
            for i, cols in self.iter_clusters(columns=[ci], recycle=False,
                                              prune=False):
                arr = cols[ci].astype(np.int64)
                chunks.append(arr + base)
                if child is not None:
                    base += self.clusters[i].n_elements[child]
                elif len(arr):
                    base += int(arr[-1])
        else:
            for _i, cols in self.iter_clusters(columns=[ci], recycle=False,
                                               prune=False):
                chunks.append(cols[ci])
        return (
            np.concatenate(chunks)
            if chunks
            else np.empty(0, dtype=col.dtype)
        )

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._prefetch_pool is not None:
            self._prefetch_pool.shutdown(wait=True)
        if self._decode_pool is not None:
            self._decode_pool.shutdown(wait=True)
        self.stats.merge_io(self.sink.io.snapshot())
        if self._bufpool is not None:
            self.stats.merge_pool(self._bufpool.snapshot())
        self.sink.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        self.close()
