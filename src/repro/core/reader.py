"""RNT-J reader.

Knows nothing about parallel writing: it reads the anchor, footer, page
list and header and iterates clusters in entry order — which, by the
commit protocol, is exactly the sequential-equivalent order (paper §4.3).
"""

from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .container import FileSink, Sink
from .metadata import (
    ANCHOR_SIZE,
    ClusterMeta,
    parse_anchor,
    parse_footer,
    parse_header,
    parse_pagelist,
)
from .pages import read_page
from .schema import KIND_OFFSET, ColumnSpec, Schema, recompose_entries


class RNTJReader:
    def __init__(self, sink_or_path, verify_checksums: bool = True):
        if isinstance(sink_or_path, str):
            self.sink: Sink = FileSink(sink_or_path, create=False)
        else:
            self.sink = sink_or_path
        if not self.sink.readable():
            raise IOError("sink is not readable")
        self.verify = verify_checksums
        size = self.sink.size
        anchor = parse_anchor(self.sink.pread(size - ANCHOR_SIZE, ANCHOR_SIZE))
        hoff, hsize = anchor["header"]
        foff, fsize = anchor["footer"]
        self.schema, self.options = parse_header(self.sink.pread(hoff, hsize))
        footer = parse_footer(self.sink.pread(foff, fsize))
        pl_off, pl_size = footer["pagelist"]
        self.clusters: List[ClusterMeta] = parse_pagelist(
            self.sink.pread(pl_off, pl_size)
        )
        self.n_entries = int(footer["n_entries"])
        # column ranges: first element index of each column per cluster
        # (paper §3) — the running sums of per-cluster element counts.
        self.column_ranges = np.zeros(
            (len(self.clusters), self.schema.n_columns), dtype=np.int64
        )
        acc = np.zeros(self.schema.n_columns, dtype=np.int64)
        for i, cm in enumerate(self.clusters):
            self.column_ranges[i] = acc
            acc += np.asarray(cm.n_elements, dtype=np.int64)
        self.total_elements = acc

    # -- cluster-level access ------------------------------------------------

    @property
    def n_clusters(self) -> int:
        return len(self.clusters)

    def read_cluster(
        self, cluster_index: int, columns: Optional[Sequence[int]] = None
    ) -> Dict[int, np.ndarray]:
        """Read the element arrays of a cluster.

        Offset columns keep their on-disk cluster-relative form (ends of
        each collection within the cluster).
        """
        cm = self.clusters[cluster_index]
        want = set(columns) if columns is not None else None
        parts: Dict[int, List[np.ndarray]] = {}
        for desc in cm.pages:
            if want is not None and desc.column not in want:
                continue
            col = self.schema.columns[desc.column]
            buf = self.sink.pread(desc.offset, desc.size)
            parts.setdefault(desc.column, []).append(
                read_page(buf, desc, col, self.verify)
            )
        out: Dict[int, np.ndarray] = {}
        targets = want if want is not None else range(self.schema.n_columns)
        for ci in targets:
            col = self.schema.columns[ci]
            chunks = parts.get(ci, [])
            if chunks:
                out[ci] = np.concatenate(chunks) if len(chunks) > 1 else chunks[0]
            else:
                out[ci] = np.empty(0, dtype=col.dtype)
        return out

    def cluster_entry_range(self, cluster_index: int) -> Tuple[int, int]:
        cm = self.clusters[cluster_index]
        return cm.first_entry, cm.first_entry + cm.n_entries

    # -- entry-level access ----------------------------------------------------

    def iter_cluster_entries(
        self, cluster_index: int, fields: Optional[Sequence[str]] = None
    ) -> List[Dict]:
        cm = self.clusters[cluster_index]
        schema = self.schema if fields is None else self.schema.project(fields)
        if fields is None:
            cols = self.read_cluster(cluster_index)
            arrays = [cols[i] for i in range(self.schema.n_columns)]
        else:
            # map projected columns back to file columns (horizontal skim)
            file_idx = [self.schema.column_of_path[c.path] for c in schema.columns]
            cols = self.read_cluster(cluster_index, file_idx)
            arrays = [cols[i] for i in file_idx]
        return recompose_entries(schema, arrays, cm.n_entries)

    def iter_entries(self, fields: Optional[Sequence[str]] = None) -> Iterator[Dict]:
        for i in range(self.n_clusters):
            yield from self.iter_cluster_entries(i, fields)

    # -- whole-column access (analysis-style reads) ------------------------------

    def read_column(self, path: str) -> np.ndarray:
        """Concatenate a column across clusters.

        Offset columns are globalized: cluster-relative offsets are shifted
        by the running element count of their *child* column — giving the
        usual global offsets array.
        """
        ci = self.schema.column_of_path[path]
        col = self.schema.columns[ci]
        chunks = []
        if col.kind == KIND_OFFSET:
            children = [
                k for k, p in enumerate(self.schema.parent) if p == ci
            ]
            child = children[0] if children else None
            base = 0
            for i in range(self.n_clusters):
                arr = self.read_cluster(i, [ci])[ci].astype(np.int64)
                chunks.append(arr + base)
                if child is not None:
                    base += self.clusters[i].n_elements[child]
                elif len(arr):
                    base += int(arr[-1])
        else:
            for i in range(self.n_clusters):
                chunks.append(self.read_cluster(i, [ci])[ci])
        return (
            np.concatenate(chunks)
            if chunks
            else np.empty(0, dtype=col.dtype)
        )

    def close(self) -> None:
        self.sink.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        self.close()
