"""Typed filter expressions + zone-map pruning logic (DESIGN.md §11).

A small predicate language over *leaf fields* — comparisons, inclusive
ranges, null checks, and ``&``/``|``/``~`` combinators (hepconduit's
filtering shape)::

    from repro.core.filter import F

    expr = (F("event_id").between(1000, 2000)
            & ((F("met") > 40.0) | ~F("jets_pt._0").is_null()))

Entry semantics
    A predicate evaluates to one boolean per *entry*.  A comparison on a
    top-level leaf (one element per entry) is elementwise; a comparison
    on a **nested** leaf (inside one or more collections) is
    *existential*: the entry matches iff **any** of its elements
    matches (an empty collection matches nothing).  ``~`` is plain
    logical negation of the entry value, so ``~(F("jets_pt._0") > x)``
    means "no jet above x" (vacuously true for zero jets).

Null model
    The container has no explicit nulls; for float columns ``NaN`` plays
    that role.  ``is_null`` tests NaN-ness (always false on integer
    columns); comparisons and ranges never match NaN (IEEE semantics).

Exactness rules (float bounds)
    Zone bounds are min/max over the *non-NaN* elements of a page
    (±inf participate; an all-NaN page has undefined bounds and a full
    null count).  To keep the zone decision and the exact mask
    consistent, both sides compare in ONE numeric domain: float64
    whenever the column or the constant is floating (float32 ⊂ float64,
    so this is exact), arbitrary-precision ints otherwise — constants
    that do not fit the column's integer range are rejected at
    :meth:`Expr.validate` time rather than silently rounded.

Three-valued zone evaluation (:meth:`Expr.zone_eval`) returns
``T_TRUE`` (every entry in the zone's range matches), ``T_FALSE`` (no
entry can match — the zone is prunable), or ``T_MAYBE``.  Nested-leaf
atoms never return ``T_TRUE`` (emptiness is unknowable from bounds),
which keeps Kleene negation sound.  The reader compiles these verdicts
into per-cluster/per-page prune plans (``reader.PrunePlan``).
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .schema import KIND_LEAF, Schema

__all__ = [
    "F",
    "Field",
    "Expr",
    "Zone",
    "T_TRUE",
    "T_FALSE",
    "T_MAYBE",
    "required_columns",
]

# three-valued logic verdicts
T_FALSE = 0
T_TRUE = 1
T_MAYBE = 2


def _not3(t: int) -> int:
    if t == T_MAYBE:
        return T_MAYBE
    return T_FALSE if t == T_TRUE else T_TRUE


# ---------------------------------------------------------------------------
# Zones: the reader-side summary a predicate is tested against


class Zone:
    """Value summary of one page (or a fold of pages) of a leaf column.

    ``lo``/``hi`` are min/max over non-NaN elements (``None`` when the
    zone holds no non-NaN element); ``nulls`` counts NaN elements;
    ``count`` is the total element count; ``nested`` marks leaves inside
    a collection (existential entry semantics).
    """

    __slots__ = ("lo", "hi", "nulls", "count", "nested")

    def __init__(self, lo, hi, nulls: int, count: int, nested: bool):
        # an all-NaN (or empty) zone has no usable bounds
        if lo is not None and isinstance(lo, float) and math.isnan(lo):
            lo = hi = None
        self.lo = lo
        self.hi = hi
        self.nulls = nulls
        self.count = count
        self.nested = nested

    @staticmethod
    def empty(nested: bool = True) -> "Zone":
        """A zone covering zero elements (entries whose collections are
        all empty for this column)."""
        return Zone(None, None, 0, 0, nested)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Zone(lo={self.lo}, hi={self.hi}, nulls={self.nulls}, "
                f"count={self.count}, nested={self.nested})")


# ---------------------------------------------------------------------------
# Evaluation context: exact per-entry masks over decoded column arrays


class EvalContext:
    """Decoded columns of one entry range, ready for exact evaluation.

    ``cols[i]`` holds column *i*'s elements for the range; offset
    columns hold **range-local** end offsets (the on-disk cluster form
    rebased to the range).  Entry attribution for nested leaves is
    derived on demand and cached.
    """

    def __init__(self, schema: Schema, cols: Dict[int, np.ndarray],
                 n_entries: int):
        self.schema = schema
        self.cols = cols
        self.n_entries = n_entries
        self._entry_ids: Dict[int, np.ndarray] = {}

    def entry_ids(self, ci: int) -> np.ndarray:
        """Entry index of each element of column ``ci`` (nested leaves)."""
        got = self._entry_ids.get(ci)
        if got is not None:
            return got
        chain: List[int] = []
        c = ci
        while self.schema.parent[c] != -1:
            chain.append(self.schema.parent[c])
            c = self.schema.parent[c]
        chain.reverse()  # outermost offset column first
        ids = np.arange(self.n_entries, dtype=np.int64)
        for off in chain:
            ends = self.cols[off]
            sizes = np.diff(ends, prepend=0)
            ids = np.repeat(ids, sizes)
        self._entry_ids[ci] = ids
        return ids

    def reduce_any(self, ci: int, elem_mask: np.ndarray) -> np.ndarray:
        """Existential fold: entry mask from an element mask."""
        out = np.zeros(self.n_entries, dtype=bool)
        hits = np.nonzero(elem_mask)[0]
        if len(hits):
            out[self.entry_ids(ci)[hits]] = True
        return out


# ---------------------------------------------------------------------------
# Expression nodes


class Expr:
    """Base predicate node.  Combine with ``&``, ``|``, ``~``."""

    def __and__(self, other: "Expr") -> "Expr":
        return And((self, _expr(other)))

    def __or__(self, other: "Expr") -> "Expr":
        return Or((self, _expr(other)))

    def __invert__(self) -> "Expr":
        return Not(self)

    # -- interface --------------------------------------------------------

    def fields(self) -> Set[str]:
        """Dotted paths of every leaf field the predicate references."""
        raise NotImplementedError

    def validate(self, schema: Schema) -> None:
        """Check every referenced path is a known leaf column and every
        constant is representable in its column's domain."""
        raise NotImplementedError

    def evaluate(self, ctx: EvalContext) -> np.ndarray:
        """Exact per-entry boolean mask (length ``ctx.n_entries``)."""
        raise NotImplementedError

    def zone_eval(self, zones: Dict[int, Zone]) -> int:
        """Three-valued verdict against per-column zones; a column
        missing from ``zones`` is unconstrained (``T_MAYBE`` atoms)."""
        raise NotImplementedError


def _expr(x) -> "Expr":
    if isinstance(x, Expr):
        return x
    raise TypeError(
        f"expected a filter expression, got {type(x).__name__} "
        "(did you compare a Field with `and`/`or` instead of `&`/`|`?)"
    )


class _Atom(Expr):
    """Shared plumbing for single-field atoms."""

    def __init__(self, path: str):
        self.path = path

    def fields(self) -> Set[str]:
        return {self.path}

    def _col(self, schema: Schema):
        try:
            ci = schema.column_of_path[self.path]
        except KeyError:
            known = ", ".join(
                c.path for c in schema.columns if c.kind == KIND_LEAF
            )
            raise ValueError(
                f"filter references unknown field {self.path!r} "
                f"(leaf fields: {known})"
            ) from None
        col = schema.columns[ci]
        if col.kind != KIND_LEAF:
            raise ValueError(
                f"filter field {self.path!r} is a collection; predicates "
                "apply to leaf fields (e.g. {self.path!r} + '._0')"
            )
        return col

    def _check_value(self, schema: Schema, v) -> None:
        col = self._col(schema)
        if isinstance(v, bool):
            return
        if isinstance(v, int) and col.dtype.kind in "iub":
            info = np.iinfo(col.dtype) if col.dtype.kind != "b" else None
            if info is not None and not (info.min <= v <= info.max):
                raise ValueError(
                    f"constant {v} does not fit column {self.path!r} "
                    f"({col.type}); compare with a float instead"
                )
        elif not isinstance(v, (int, float)):
            raise TypeError(
                f"filter constant for {self.path!r} must be int or float, "
                f"got {type(v).__name__}"
            )


_OPS = {
    "eq": "__eq__",
    "ne": "__ne__",
    "lt": "__lt__",
    "le": "__le__",
    "gt": "__gt__",
    "ge": "__ge__",
}


def _cmp(arr: np.ndarray, op: str, value) -> np.ndarray:
    """Elementwise comparison in the unified numeric domain (float64
    whenever either side is floating — see module docstring)."""
    if isinstance(value, (float, np.floating)) or arr.dtype.kind == "f":
        arr = arr.astype(np.float64, copy=False)
        value = np.float64(value)
    return getattr(arr, _OPS[op])(value)


def _scmp(bound, op: str, value) -> bool:
    """Scalar comparison mirroring :func:`_cmp`'s domain."""
    if isinstance(value, (float, np.floating)) or isinstance(bound, float):
        bound = float(bound)
        value = float(value)
    if op == "eq":
        return bound == value
    if op == "ne":
        return bound != value
    if op == "lt":
        return bound < value
    if op == "le":
        return bound <= value
    if op == "gt":
        return bound > value
    return bound >= value


class Cmp(_Atom):
    """``field <op> constant``."""

    def __init__(self, path: str, op: str, value):
        super().__init__(path)
        if op not in _OPS:
            raise ValueError(f"unknown comparison op {op!r}")
        self.op = op
        self.value = value

    def validate(self, schema: Schema) -> None:
        self._check_value(schema, self.value)

    def evaluate(self, ctx: EvalContext) -> np.ndarray:
        ci = ctx.schema.column_of_path[self.path]
        m = _cmp(ctx.cols[ci], self.op, self.value)
        if ctx.schema.parent[ci] == -1:
            return m
        return ctx.reduce_any(ci, m)

    def zone_eval(self, zones: Dict[int, Zone]) -> int:
        z = zones.get(self.path)
        if z is None:
            return T_MAYBE
        v, op = self.value, self.op
        v_nan = isinstance(v, float) and math.isnan(v)
        if z.count == 0:
            # no elements: a nested atom has no witness; NaN never
            # compares true except via `ne`
            if z.nested:
                return T_FALSE
            return T_MAYBE  # unreachable for top-level zones in practice
        if v_nan:
            # IEEE: only `ne` matches NaN constants (for every value)
            if op == "ne":
                return T_MAYBE if z.nested else T_TRUE
            return T_FALSE
        if z.lo is None:
            # every element is NaN: nothing compares true except `ne`
            if op == "ne":
                return T_MAYBE if z.nested else T_TRUE
            return T_FALSE
        lo, hi, nn = z.lo, z.hi, z.nulls
        if op == "eq":
            if _scmp(v, "lt", lo) or _scmp(v, "gt", hi):
                return T_FALSE
            all_match = (
                nn == 0 and _scmp(lo, "eq", v) and _scmp(hi, "eq", v)
            )
        elif op == "ne":
            # NaN != v is true, so nulls count as matches
            if nn == 0 and _scmp(lo, "eq", v) and _scmp(hi, "eq", v):
                return T_FALSE
            all_match = _scmp(v, "lt", lo) or _scmp(v, "gt", hi)
        elif op in ("gt", "ge"):
            if not _scmp(hi, op, v):
                return T_FALSE
            all_match = nn == 0 and _scmp(lo, op, v)
        else:  # lt, le
            if not _scmp(lo, op, v):
                return T_FALSE
            all_match = nn == 0 and _scmp(hi, op, v)
        if z.nested:
            return T_MAYBE
        return T_TRUE if all_match else T_MAYBE

    def __repr__(self) -> str:
        sym = {"eq": "==", "ne": "!=", "lt": "<", "le": "<=",
               "gt": ">", "ge": ">="}[self.op]
        return f"(F({self.path!r}) {sym} {self.value!r})"


class Between(_Atom):
    """Inclusive range ``low <= field <= high`` (NaN never matches)."""

    def __init__(self, path: str, low, high):
        super().__init__(path)
        self.low = low
        self.high = high

    def validate(self, schema: Schema) -> None:
        self._check_value(schema, self.low)
        self._check_value(schema, self.high)

    def evaluate(self, ctx: EvalContext) -> np.ndarray:
        ci = ctx.schema.column_of_path[self.path]
        arr = ctx.cols[ci]
        m = _cmp(arr, "ge", self.low) & _cmp(arr, "le", self.high)
        if ctx.schema.parent[ci] == -1:
            return m
        return ctx.reduce_any(ci, m)

    def zone_eval(self, zones: Dict[int, Zone]) -> int:
        z = zones.get(self.path)
        if z is None:
            return T_MAYBE
        a, b = self.low, self.high
        if (isinstance(a, float) and math.isnan(a)) or (
            isinstance(b, float) and math.isnan(b)
        ):
            return T_FALSE
        if z.count == 0:
            return T_FALSE if z.nested else T_MAYBE
        if z.lo is None:  # all NaN
            return T_FALSE
        if _scmp(z.hi, "lt", a) or _scmp(z.lo, "gt", b):
            return T_FALSE
        if z.nested:
            return T_MAYBE
        all_match = (
            z.nulls == 0 and _scmp(z.lo, "ge", a) and _scmp(z.hi, "le", b)
        )
        return T_TRUE if all_match else T_MAYBE

    def __repr__(self) -> str:
        return f"F({self.path!r}).between({self.low!r}, {self.high!r})"


class IsNull(_Atom):
    """``field`` is NaN (never true on integer columns)."""

    def validate(self, schema: Schema) -> None:
        self._col(schema)

    def evaluate(self, ctx: EvalContext) -> np.ndarray:
        ci = ctx.schema.column_of_path[self.path]
        arr = ctx.cols[ci]
        if arr.dtype.kind == "f":
            m = np.isnan(arr)
        else:
            m = np.zeros(len(arr), dtype=bool)
        if ctx.schema.parent[ci] == -1:
            return m
        return ctx.reduce_any(ci, m)

    def zone_eval(self, zones: Dict[int, Zone]) -> int:
        z = zones.get(self.path)
        if z is None:
            return T_MAYBE
        if z.nulls == 0:
            return T_FALSE
        if z.nested:
            return T_MAYBE
        return T_TRUE if z.nulls == z.count else T_MAYBE

    def __repr__(self) -> str:
        return f"F({self.path!r}).is_null()"


class NotNull(_Atom):
    """``field`` is a non-NaN value (existential on nested leaves)."""

    def validate(self, schema: Schema) -> None:
        self._col(schema)

    def evaluate(self, ctx: EvalContext) -> np.ndarray:
        ci = ctx.schema.column_of_path[self.path]
        arr = ctx.cols[ci]
        if arr.dtype.kind == "f":
            m = ~np.isnan(arr)
        else:
            m = np.ones(len(arr), dtype=bool)
        if ctx.schema.parent[ci] == -1:
            return m
        return ctx.reduce_any(ci, m)

    def zone_eval(self, zones: Dict[int, Zone]) -> int:
        z = zones.get(self.path)
        if z is None:
            return T_MAYBE
        if z.nulls == z.count:  # all NaN — or no elements at all
            return T_FALSE
        if z.nested:
            return T_MAYBE
        return T_TRUE if z.nulls == 0 else T_MAYBE

    def __repr__(self) -> str:
        return f"F({self.path!r}).not_null()"


class And(Expr):
    def __init__(self, parts: Sequence[Expr]):
        self.parts = tuple(_expr(p) for p in parts)

    def fields(self) -> Set[str]:
        return set().union(*(p.fields() for p in self.parts))

    def validate(self, schema: Schema) -> None:
        for p in self.parts:
            p.validate(schema)

    def evaluate(self, ctx: EvalContext) -> np.ndarray:
        m = self.parts[0].evaluate(ctx)
        for p in self.parts[1:]:
            m = m & p.evaluate(ctx)
        return m

    def zone_eval(self, zones: Dict[int, Zone]) -> int:
        out = T_TRUE
        for p in self.parts:
            t = p.zone_eval(zones)
            if t == T_FALSE:
                return T_FALSE
            if t == T_MAYBE:
                out = T_MAYBE
        return out

    def __repr__(self) -> str:
        return "(" + " & ".join(map(repr, self.parts)) + ")"


class Or(Expr):
    def __init__(self, parts: Sequence[Expr]):
        self.parts = tuple(_expr(p) for p in parts)

    def fields(self) -> Set[str]:
        return set().union(*(p.fields() for p in self.parts))

    def validate(self, schema: Schema) -> None:
        for p in self.parts:
            p.validate(schema)

    def evaluate(self, ctx: EvalContext) -> np.ndarray:
        m = self.parts[0].evaluate(ctx)
        for p in self.parts[1:]:
            m = m | p.evaluate(ctx)
        return m

    def zone_eval(self, zones: Dict[int, Zone]) -> int:
        out = T_FALSE
        for p in self.parts:
            t = p.zone_eval(zones)
            if t == T_TRUE:
                return T_TRUE
            if t == T_MAYBE:
                out = T_MAYBE
        return out

    def __repr__(self) -> str:
        return "(" + " | ".join(map(repr, self.parts)) + ")"


class Not(Expr):
    def __init__(self, child: Expr):
        self.child = _expr(child)

    def fields(self) -> Set[str]:
        return self.child.fields()

    def validate(self, schema: Schema) -> None:
        self.child.validate(schema)

    def evaluate(self, ctx: EvalContext) -> np.ndarray:
        return ~self.child.evaluate(ctx)

    def zone_eval(self, zones: Dict[int, Zone]) -> int:
        return _not3(self.child.zone_eval(zones))

    def __repr__(self) -> str:
        return f"~{self.child!r}"


# ---------------------------------------------------------------------------
# Field handle: the user-facing entry point


class Field:
    """Handle for building predicates over one leaf field path.

    Comparison operators produce :class:`Expr` nodes (so ``==`` does NOT
    test Field identity); combine the results with ``&``/``|``/``~``.
    """

    __slots__ = ("path",)

    def __init__(self, path: str):
        self.path = path

    def __eq__(self, other):  # type: ignore[override]
        return Cmp(self.path, "eq", other)

    def __ne__(self, other):  # type: ignore[override]
        return Cmp(self.path, "ne", other)

    def __lt__(self, other):
        return Cmp(self.path, "lt", other)

    def __le__(self, other):
        return Cmp(self.path, "le", other)

    def __gt__(self, other):
        return Cmp(self.path, "gt", other)

    def __ge__(self, other):
        return Cmp(self.path, "ge", other)

    __hash__ = None  # type: ignore[assignment]

    def between(self, low, high) -> Between:
        return Between(self.path, low, high)

    def is_null(self) -> IsNull:
        return IsNull(self.path)

    def not_null(self) -> NotNull:
        return NotNull(self.path)

    def __repr__(self) -> str:
        return f"F({self.path!r})"


F = Field


# ---------------------------------------------------------------------------
# Helpers shared by the reader's prune planner


def required_columns(schema: Schema, expr: Expr) -> List[int]:
    """Column indices a predicate needs decoded: every referenced leaf
    plus the offset-column chain above each nested leaf (entry
    attribution), in schema order (parents before children)."""
    need: Set[int] = set()
    for path in expr.fields():
        ci = schema.column_of_path.get(path)
        if ci is None:
            raise ValueError(f"filter references unknown field {path!r}")
        need.add(ci)
        p = schema.parent[ci]
        while p != -1:
            need.add(p)
            p = schema.parent[p]
    return sorted(need)


def filter_paths(schema: Schema, expr: Expr) -> Dict[str, int]:
    """path -> leaf column index for every field the predicate tests."""
    return {p: schema.column_of_path[p] for p in expr.fields()}
