"""Contiguous per-column element buffers for the write hot path.

The seed accumulated a Python list of chunk arrays per column and paid an
``np.concatenate`` per column at seal time plus one allocation per append.
A :class:`ColumnBuffer` is a single preallocated contiguous array with
amortized-doubling growth:

* appends are vectorized copies into the tail (no per-append allocation),
* page extraction at seal time is a zero-copy view slice,
* :meth:`reset` keeps the storage, so in steady state a builder that is
  reused across clusters performs **no** allocations at all.

Offset columns additionally use :meth:`reserve`: the builder reserves the
tail slice and integrates collection sizes into cluster-relative end
offsets directly in place (``np.cumsum(..., out=tail)``), avoiding the
temporary the seed allocated per batch.

With a :class:`~repro.core.bufpool.BufferPool` attached, storage is drawn
from the pool's power-of-two size classes instead of ``np.empty`` — so
:meth:`detach` (the scatter-gather seal handing storage to a queued
commit) recycles instead of allocating once the I/O engine returns the
previous cluster's buffers on write completion (DESIGN.md §6.8).
"""

from __future__ import annotations

from typing import Optional

import numpy as np

DEFAULT_CAPACITY = 1024


class ColumnBuffer:
    """Amortized-doubling contiguous buffer of primitive elements."""

    __slots__ = ("dtype", "pool", "_data", "_len")

    def __init__(self, dtype, capacity: int = DEFAULT_CAPACITY, pool=None):
        self.dtype = np.dtype(dtype)
        self.pool = pool
        self._data = self._alloc(max(int(capacity), 1))
        self._len = 0

    def _alloc(self, n_elems: int) -> np.ndarray:
        """Storage for ``n_elems`` elements — pooled when a pool is set
        (the returned view keeps the pooled base array alive)."""
        if self.pool is not None:
            raw = self.pool.take(n_elems * self.dtype.itemsize)
            return raw.view(self.dtype)
        return np.empty(n_elems, dtype=self.dtype)

    # -- introspection -----------------------------------------------------

    def __len__(self) -> int:
        return self._len

    @property
    def nbytes(self) -> int:
        return self._len * self.dtype.itemsize

    @property
    def capacity(self) -> int:
        return len(self._data)

    # -- growth ------------------------------------------------------------

    def _grow(self, need: int) -> None:
        cap = len(self._data)
        new_cap = max(need, 2 * cap)
        data = self._alloc(new_cap)
        data[: self._len] = self._data[: self._len]
        old, self._data = self._data, data
        if self.pool is not None:
            # the outgrown storage is aliased by nothing durable (views
            # are documented invalid after extend/reserve): recycle it
            self.pool.put(old)

    # -- filling -----------------------------------------------------------

    def extend(self, arr: np.ndarray) -> None:
        """Append ``arr`` with one vectorized copy."""
        n = len(arr)
        if n == 0:
            return
        need = self._len + n
        if need > len(self._data):
            self._grow(need)
        self._data[self._len : need] = arr
        self._len = need

    def reserve(self, n: int) -> np.ndarray:
        """Grow by ``n`` elements and return the writable tail view.

        The caller fills the returned slice in place (used for in-place
        offset integration).
        """
        need = self._len + n
        if need > len(self._data):
            self._grow(need)
        view = self._data[self._len : need]
        self._len = need
        return view

    # -- extraction ----------------------------------------------------------

    def view(self, start: int = 0, stop: Optional[int] = None) -> np.ndarray:
        """Zero-copy view of elements ``[start, stop)`` (default: all).

        The view aliases the buffer storage: it is valid until the next
        :meth:`extend`/:meth:`reserve` (which may reallocate) or
        :meth:`reset` followed by refilling.
        """
        if stop is None or stop > self._len:
            stop = self._len
        return self._data[start:stop]

    def reset(self) -> None:
        """Forget the contents but keep the allocated storage for reuse."""
        self._len = 0

    def detach(self) -> np.ndarray:
        """Give up the current storage and start over with a fresh array.

        Used by the scatter-gather seal: zero-copy views of the old
        storage stay valid (numpy views keep their base alive) while this
        buffer refills into new storage.  With a pool, the replacement is
        recycled from the pool's size classes and the detached array is
        returned to the pool by the I/O engine when the queued write that
        references it lands — steady-state detaching is then
        allocation-free.  Returns the detached array.
        """
        old = self._data
        self._data = self._alloc(max(len(old), 1))
        self._len = 0
        return old
