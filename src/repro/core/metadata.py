"""On-disk metadata: envelopes, page list, footer, anchor.

Layout (RNT-J, a faithful simplification of RNTuple-in-TFile):

    file := header_env { cluster blobs / pages } pagelist_env footer_env anchor

* header envelope   — schema + write options (self-describing)
* page list envelope— per committed cluster, in entry order: entry range,
  per-column element counts, and every page descriptor (paper §3's "page
  list" + "column ranges": the element offset of each column in a cluster
  is the running sum of the per-cluster element counts, in cluster order)
* footer envelope   — cluster summaries + locator of the page list
* anchor            — fixed 64-byte trailer at EOF locating header+footer

Metadata is appended **in commit order** under the writer's critical
section, so the resulting file is indistinguishable from one written
sequentially (paper §4.3).
"""

from __future__ import annotations

import json
import struct
import zlib
from dataclasses import dataclass, field, replace as dc_replace
from typing import List, Optional, Tuple

import numpy as np

from .pages import PageDesc
from .schema import ENC_NONE, Schema

MAGIC = b"RNTJ"
# v2 adds the per-cluster recovery envelope + commit journal (DESIGN.md §8).
# v1 files (no journal) remain fully readable; v2 readers accept both.
# v3 exists only inside journal records: multi-writer commits stamp each
# record with (writer_id, epoch) for fencing (DESIGN.md §8.6).  The anchor
# and envelopes stay at v2 — a sealed multi-writer file is indistinguishable
# from a single-writer one except for the wider journal records.
VERSION = 2
JREC_VERSION_MP = 3
SUPPORTED_VERSIONS = (1, 2, 3)

ENV_HEADER = 1
ENV_PAGELIST = 2
ENV_FOOTER = 3
ENV_MEMBERS = 4   # optional framed-member side-car (DESIGN.md §6.4)

_ENV_HDR = struct.Struct("<4sHxxQ")  # magic, type, pad, payload_len
_ENV_MAGIC = b"RJEV"

_ANCHOR = struct.Struct("<4sIQQQQQQI4x")  # magic, ver, hdr(off,size), ftr(off,size), n_entries, n_clusters, crc
ANCHOR_SIZE = _ANCHOR.size  # 64 bytes

# page descriptor record on disk
_PAGE_REC = np.dtype(
    [
        ("column", "<u4"),
        ("codec", "<u1"),
        ("_pad", "V3"),
        ("n_elements", "<u8"),
        ("offset", "<u8"),
        ("size", "<u8"),
        ("uncompressed_size", "<u8"),
        ("checksum", "<u4"),
        ("_pad2", "V4"),
    ]
)


def wrap_envelope(env_type: int, payload: bytes) -> bytes:
    hdr = _ENV_HDR.pack(_ENV_MAGIC, env_type, len(payload))
    crc = struct.pack("<I", zlib.crc32(payload))
    return hdr + payload + crc


def unwrap_envelope(buf: bytes, expect_type: int) -> bytes:
    magic, etype, plen = _ENV_HDR.unpack_from(buf, 0)
    if magic != _ENV_MAGIC:
        raise IOError("bad envelope magic")
    if etype != expect_type:
        raise IOError(f"envelope type {etype}, expected {expect_type}")
    payload = buf[_ENV_HDR.size : _ENV_HDR.size + plen]
    (crc,) = struct.unpack_from("<I", buf, _ENV_HDR.size + plen)
    if zlib.crc32(payload) != crc:
        raise IOError("envelope checksum mismatch")
    return payload


# ---------------------------------------------------------------------------
# header


def build_header(schema: Schema, options: dict) -> bytes:
    payload = json.dumps(
        {"version": VERSION, "schema": json.loads(schema.to_json()), "options": options},
        separators=(",", ":"),
    ).encode()
    return wrap_envelope(ENV_HEADER, payload)


def parse_header(buf: bytes) -> Tuple[Schema, dict]:
    d = json.loads(unwrap_envelope(buf, ENV_HEADER))
    schema = Schema.from_json(json.dumps(d["schema"]))
    options = d.get("options", {})
    encodings = options.get("encodings")
    if encodings is not None:
        # restore the writer's EFFECTIVE per-column encodings over the
        # derived defaults, so ALL readers — engine and legacy
        # page-at-a-time alike — decode exactly what was written
        schema.columns = [
            c if c.encoding == e else dc_replace(c, encoding=e)
            for c, e in zip(schema.columns, encodings)
        ]
    elif options.get("precondition") is False:
        # older header without the encodings list: the writer stored
        # every column verbatim
        schema.columns = [
            dc_replace(c, encoding=ENC_NONE) for c in schema.columns
        ]
    return schema, options


# ---------------------------------------------------------------------------
# cluster metadata (in-memory while writing; page list envelope on close)


@dataclass
class ClusterMeta:
    """Metadata of one committed cluster (absolute page offsets)."""

    first_entry: int
    n_entries: int
    n_elements: List[int]            # per column
    pages: List[PageDesc]
    byte_offset: int = 0             # cluster blob start (buffered mode)
    byte_size: int = 0


def build_pagelist(clusters: List[ClusterMeta], n_columns: int) -> bytes:
    chunks: List[bytes] = [struct.pack("<IQ", len(clusters), n_columns)]
    for cm in clusters:
        chunks.append(
            struct.pack(
                "<QQQQI", cm.first_entry, cm.n_entries, cm.byte_offset,
                cm.byte_size, len(cm.pages),
            )
        )
        chunks.append(np.asarray(cm.n_elements, dtype="<u8").tobytes())
        chunks.append(_pack_page_recs(cm.pages))
    return wrap_envelope(ENV_PAGELIST, b"".join(chunks))


def parse_pagelist(buf: bytes) -> List[ClusterMeta]:
    payload = unwrap_envelope(buf, ENV_PAGELIST)
    pos = 0
    n_clusters, n_columns = struct.unpack_from("<IQ", payload, pos)
    pos += 12
    out: List[ClusterMeta] = []
    for _ in range(n_clusters):
        first_entry, n_entries, boff, bsize, n_pages = struct.unpack_from(
            "<QQQQI", payload, pos
        )
        pos += 36
        n_elements = np.frombuffer(payload, dtype="<u8", count=n_columns, offset=pos)
        pos += 8 * n_columns
        rec = np.frombuffer(payload, dtype=_PAGE_REC, count=n_pages, offset=pos)
        pos += _PAGE_REC.itemsize * n_pages
        pages = [
            PageDesc(
                column=int(r["column"]),
                n_elements=int(r["n_elements"]),
                offset=int(r["offset"]),
                size=int(r["size"]),
                uncompressed_size=int(r["uncompressed_size"]),
                checksum=int(r["checksum"]),
                codec=int(r["codec"]),
            )
            for r in rec
        ]
        out.append(
            ClusterMeta(first_entry, n_entries, [int(x) for x in n_elements],
                        pages, boff, bsize)
        )
    return out


# ---------------------------------------------------------------------------
# recovery envelope + commit journal (v2, DESIGN.md §8)
#
# With ``WriteOptions.journal`` (default on), every buffered cluster extent is
# written as
#
#     [32-byte cluster envelope][cluster payload][journal record]
#
# in ONE vectored engine write, and every unbuffered cluster commit appends a
# journal record alone.  The envelope makes the payload self-describing
# (magic, commit sequence, length, CRC of its descriptor); the journal record
# is a self-contained copy of the cluster's page-list entry.  Together they
# let :mod:`repro.core.recover` rebuild the footer of a torn file from the
# data region alone.  Footer-based readers never look at either — cluster
# byte offsets in the page list point at the payload, so the framing is
# invisible padding to them (v1 readers read v2 data regions unchanged; only
# the anchor version gates compatibility).

CLUSTER_ENV_MAGIC = b"RJCE"
JOURNAL_MAGIC = b"RJJR"

# magic, version, flags, seq, payload_len, desc_crc, env_crc, pad
_CLUSTER_ENV = struct.Struct("<4sHHIQII4x")
CLUSTER_ENV_SIZE = _CLUSTER_ENV.size  # 32 bytes

JREC_BUFFERED = 1  # flags bit: page offsets are cluster-relative

_JREC_HDR = struct.Struct("<4sI")  # magic, payload_len (crc32 trails payload)
# seq, version, flags, cluster_off, cluster_size, first_entry, n_entries,
# n_columns, n_pages
_JREC_FIX = struct.Struct("<IHHQQQQII")
# v3 (multi-writer): the v2 fields followed by writer_id, epoch
_JREC_FIX3 = struct.Struct("<IHHQQQQIIII")


def journal_record_size(n_columns: int, n_pages: int, multi: bool = False) -> int:
    """On-disk size of one journal record — known before it is built, so
    the writer can reserve the whole framed extent in one call."""
    fix = _JREC_FIX3.size if multi else _JREC_FIX.size
    return (_JREC_HDR.size + fix + 8 * n_columns
            + _PAGE_REC.itemsize * n_pages + 4)


def build_cluster_envelope(seq: int, payload_len: int, desc_crc: int) -> bytes:
    body = _CLUSTER_ENV.pack(CLUSTER_ENV_MAGIC, VERSION, 0, seq, payload_len,
                             desc_crc, 0)
    env_crc = zlib.crc32(body[:24])
    return _CLUSTER_ENV.pack(CLUSTER_ENV_MAGIC, VERSION, 0, seq, payload_len,
                             desc_crc, env_crc)


def parse_cluster_envelope(buf: bytes, pos: int = 0) -> dict:
    magic, ver, flags, seq, plen, desc_crc, env_crc = _CLUSTER_ENV.unpack_from(
        buf, pos)
    if magic != CLUSTER_ENV_MAGIC:
        raise IOError("bad cluster envelope magic")
    if zlib.crc32(bytes(buf[pos:pos + 24])) != env_crc:
        raise IOError("cluster envelope checksum mismatch")
    return {"version": ver, "flags": flags, "seq": seq, "payload_len": plen,
            "desc_crc": desc_crc}


def _pack_page_recs(pages: List[PageDesc]) -> bytes:
    rec = np.zeros(len(pages), dtype=_PAGE_REC)
    for i, p in enumerate(pages):
        rec[i] = (p.column, p.codec, b"", p.n_elements, p.offset, p.size,
                  p.uncompressed_size, p.checksum, b"")
    return rec.tobytes()


def build_journal_body(n_elements: List[int], pages: List[PageDesc]) -> bytes:
    """Variable part of a journal record (per-column element counts + page
    records).  Page offsets are stored exactly as given — cluster-relative
    for buffered commits, absolute for unbuffered ones — so the body can be
    serialized *outside* the writer's critical section, before the extent
    offset is known."""
    return (np.asarray(n_elements, dtype="<u8").tobytes()
            + _pack_page_recs(pages))


def finish_journal_record(
    seq: int,
    flags: int,
    cluster_off: int,
    cluster_size: int,
    first_entry: int,
    n_entries: int,
    n_columns: int,
    body: bytes,
    writer_id: Optional[int] = None,
    epoch: Optional[int] = None,
) -> Tuple[bytes, int]:
    """Complete a journal record around a prebuilt body.  Returns the record
    bytes and the payload CRC (= the envelope's ``desc_crc``).

    Passing ``writer_id``/``epoch`` emits a v3 (multi-writer) record that
    carries the committing writer's fencing identity; recovery uses it to
    attribute clusters to writers and drop records from fenced epochs."""
    n_pages = (len(body) - 8 * n_columns) // _PAGE_REC.itemsize
    if writer_id is not None:
        fix = _JREC_FIX3.pack(seq, JREC_VERSION_MP, flags, cluster_off,
                              cluster_size, first_entry, n_entries, n_columns,
                              n_pages, writer_id, epoch or 0)
    else:
        fix = _JREC_FIX.pack(seq, VERSION, flags, cluster_off, cluster_size,
                             first_entry, n_entries, n_columns, n_pages)
    crc = zlib.crc32(body, zlib.crc32(fix))
    rec = b"".join((
        _JREC_HDR.pack(JOURNAL_MAGIC, len(fix) + len(body)),
        fix, body, struct.pack("<I", crc),
    ))
    return rec, crc


@dataclass
class JournalRecord:
    """One parsed commit-journal record (page offsets resolved to absolute)."""

    seq: int
    flags: int
    cluster_off: int
    cluster_size: int
    first_entry: int
    n_entries: int
    n_elements: List[int]
    pages: List[PageDesc]
    crc: int
    end: int = 0          # file offset just past this record (scan bookkeeping)
    writer_id: int = 0    # v3 only; 0 for single-writer records
    epoch: int = 0        # v3 only; fencing epoch the commit ran under

    @property
    def buffered(self) -> bool:
        return bool(self.flags & JREC_BUFFERED)


def parse_journal_record(buf, pos: int = 0) -> Tuple[JournalRecord, int]:
    """Parse one journal record at ``pos``; raises ``IOError`` on any
    corruption (bad magic, truncation, CRC mismatch).  Returns the record
    and the position just past it."""
    if len(buf) - pos < _JREC_HDR.size:
        raise IOError("truncated journal record")
    magic, plen = _JREC_HDR.unpack_from(buf, pos)
    if magic != JOURNAL_MAGIC:
        raise IOError("bad journal record magic")
    end = pos + _JREC_HDR.size + plen + 4
    if plen < _JREC_FIX.size or end > len(buf):
        raise IOError("truncated journal record")
    payload = bytes(buf[pos + _JREC_HDR.size : pos + _JREC_HDR.size + plen])
    (crc,) = struct.unpack_from("<I", buf, pos + _JREC_HDR.size + plen)
    if zlib.crc32(payload) != crc:
        raise IOError("journal record checksum mismatch")
    (seq, ver, flags, c_off, c_size, first_entry, n_entries, n_cols,
     n_pages) = _JREC_FIX.unpack_from(payload, 0)
    if ver not in SUPPORTED_VERSIONS:
        raise IOError(f"unsupported journal record version {ver}")
    writer_id = epoch = 0
    if ver >= JREC_VERSION_MP:
        if len(payload) < _JREC_FIX3.size:
            raise IOError("truncated journal record")
        (seq, ver, flags, c_off, c_size, first_entry, n_entries, n_cols,
         n_pages, writer_id, epoch) = _JREC_FIX3.unpack_from(payload, 0)
        body_pos = _JREC_FIX3.size
    else:
        body_pos = _JREC_FIX.size
    if len(payload) != body_pos + 8 * n_cols + _PAGE_REC.itemsize * n_pages:
        raise IOError("journal record length mismatch")
    n_elements = np.frombuffer(payload, dtype="<u8", count=n_cols,
                               offset=body_pos)
    rec = np.frombuffer(payload, dtype=_PAGE_REC, count=n_pages,
                        offset=body_pos + 8 * n_cols)
    base = c_off if (flags & JREC_BUFFERED) else 0
    pages = [
        PageDesc(
            column=int(r["column"]),
            n_elements=int(r["n_elements"]),
            offset=int(r["offset"]) + base,
            size=int(r["size"]),
            uncompressed_size=int(r["uncompressed_size"]),
            checksum=int(r["checksum"]),
            codec=int(r["codec"]),
        )
        for r in rec
    ]
    jr = JournalRecord(seq, flags, c_off, c_size, first_entry, n_entries,
                       [int(x) for x in n_elements], pages, crc, end,
                       writer_id, epoch)
    return jr, end


# ---------------------------------------------------------------------------
# framed-member side-car (optional)


def build_member_sidecar(clusters: List[ClusterMeta]) -> Optional[bytes]:
    """Optional side-car recording chunk-framed pages' member layout.

    For every page compressed as multiple independent members (DESIGN.md
    §5.2) it records the compressed byte size of each member plus the
    uncompressed bytes a full member decodes to — which is exactly what
    the read engine needs to decompress one page's members as parallel
    pool jobs instead of looping a decompressor serially.  Returns
    ``None`` when no page is framed (the envelope is then omitted and the
    footer carries no locator: old files and unframed files are
    indistinguishable and decode exactly as before).
    """
    recs: List[bytes] = []
    n = 0
    for ci, cm in enumerate(clusters):
        for pi, p in enumerate(cm.pages):
            if p.members and len(p.members) > 1:
                recs.append(struct.pack("<IIII", ci, pi, p.member_chunk,
                                        len(p.members)))
                recs.append(np.asarray(p.members, dtype="<u4").tobytes())
                n += 1
    if not n:
        return None
    payload = struct.pack("<I", n) + b"".join(recs)
    return wrap_envelope(ENV_MEMBERS, payload)


def parse_member_sidecar(buf: bytes, clusters: List[ClusterMeta]) -> None:
    """Attach the side-car's member layouts to the parsed page descriptors."""
    payload = unwrap_envelope(buf, ENV_MEMBERS)
    (n,) = struct.unpack_from("<I", payload, 0)
    pos = 4
    for _ in range(n):
        ci, pi, chunk, k = struct.unpack_from("<IIII", payload, pos)
        pos += 16
        sizes = np.frombuffer(payload, dtype="<u4", count=k, offset=pos)
        pos += 4 * k
        page = clusters[ci].pages[pi]
        page.members = [int(s) for s in sizes]
        page.member_chunk = int(chunk)


# ---------------------------------------------------------------------------
# zone maps (footer.extra["zonemaps"], DESIGN.md §11)
#
# Per cluster, per column: parallel per-page lists in page-list order —
# first/last entry index (cluster-relative, so raw cluster copies stay
# valid across merge/rebase) plus, for leaf columns, min/max over non-NaN
# elements and the NaN count.  Stored as plain JSON inside the footer:
# readers that predate the key (including the vendored seed reader)
# ignore unknown ``extra`` entries, and Python's json round-trips the
# NaN/±Infinity bounds of float pages.


def encode_zonemaps(per_cluster) -> Optional[dict]:
    """``footer.extra["zonemaps"]`` value from per-cluster zone-map dicts
    (``None`` per cluster = no stats, e.g. a raw-copied cluster from an
    old file).  Returns ``None`` when no cluster carries stats."""
    if not any(per_cluster):
        return None
    clusters = []
    for zm in per_cluster:
        if not zm:
            clusters.append(None)
        else:
            clusters.append({str(ci): d for ci, d in zm.items()})
    return {"v": 1, "clusters": clusters}


def decode_zonemaps(value, n_clusters: int):
    """Parse ``footer.extra["zonemaps"]`` back to per-cluster dicts keyed
    by column index.  Defensive: an unknown version, a cluster-count
    mismatch, or inconsistent per-column page lists degrade to "no
    stats" (``None``) — pruning is an optimization, never a correctness
    dependency."""
    if not isinstance(value, dict) or value.get("v") != 1:
        return None
    clusters = value.get("clusters")
    if not isinstance(clusters, list) or len(clusters) != n_clusters:
        return None
    out = []
    for zm in clusters:
        if not isinstance(zm, dict):
            out.append(None)
            continue
        cols = {}
        for key, d in zm.items():
            try:
                ci = int(key)
            except (TypeError, ValueError):
                continue
            if not isinstance(d, dict) or "fe" not in d or "le" not in d:
                continue
            n = len(d["fe"])
            if len(d["le"]) != n:
                continue
            if "lo" in d and not (
                len(d.get("lo", ())) == len(d.get("hi", ()))
                == len(d.get("nn", ())) == n
            ):
                continue
            cols[ci] = d
        out.append(cols or None)
    return out


# ---------------------------------------------------------------------------
# footer + anchor


def build_footer(
    n_entries: int,
    n_clusters: int,
    pagelist_loc: Tuple[int, int],
    extra: Optional[dict] = None,
) -> bytes:
    payload = json.dumps(
        {
            "n_entries": n_entries,
            "n_clusters": n_clusters,
            "pagelist": list(pagelist_loc),
            "extra": extra or {},
        },
        separators=(",", ":"),
    ).encode()
    return wrap_envelope(ENV_FOOTER, payload)


def parse_footer(buf: bytes) -> dict:
    return json.loads(unwrap_envelope(buf, ENV_FOOTER))


def build_anchor(
    header_loc: Tuple[int, int],
    footer_loc: Tuple[int, int],
    n_entries: int,
    n_clusters: int,
) -> bytes:
    body = _ANCHOR.pack(
        MAGIC, VERSION, header_loc[0], header_loc[1], footer_loc[0],
        footer_loc[1], n_entries, n_clusters, 0,
    )
    crc = zlib.crc32(body[:-8])
    return _ANCHOR.pack(
        MAGIC, VERSION, header_loc[0], header_loc[1], footer_loc[0],
        footer_loc[1], n_entries, n_clusters, crc,
    )


def parse_anchor(buf: bytes) -> dict:
    magic, ver, hoff, hsize, foff, fsize, n_entries, n_clusters, crc = _ANCHOR.unpack(buf)
    if magic != MAGIC:
        raise IOError("not an RNT-J file (bad anchor magic)")
    if ver not in SUPPORTED_VERSIONS:
        raise IOError(f"unsupported RNT-J version {ver}")
    body = _ANCHOR.pack(magic, ver, hoff, hsize, foff, fsize, n_entries, n_clusters, 0)
    if zlib.crc32(body[:-8]) != crc:
        raise IOError("anchor checksum mismatch")
    return {
        "header": (hoff, hsize),
        "footer": (foff, fsize),
        "n_entries": n_entries,
        "n_clusters": n_clusters,
    }
