"""Container sinks: where reserved extents of the file live.

The container format provides exactly the operation the paper needs
(§4.2): *reserve a byte extent of known size* (requires synchronization —
done by the writer's critical section) and *write bytes at an offset*
(no synchronization needed; ``pwrite`` is positioned and thread-safe).

Sinks:
  * :class:`FileSink`      — a real file, ``os.pwrite`` + optional fallocate.
  * :class:`AsyncFileSink` — a FileSink advertising native ring submission
    (io_uring via the thin liburing binding, DESIGN.md §6.7).
  * :class:`DevNullSink`   — infinitely fast storage (paper Fig. 2).
  * :class:`ThrottledSink` — bandwidth-limited wrapper to emulate the SSD /
    HDD of Figs. 3–4 on this container (token-bucket on write completion).
  * :class:`MemorySink`    — in-memory file for the TBufferMerger analog.

Every sink additionally speaks **scatter-gather**: ``pwritev(offset,
parts)`` writes a list of buffers contiguously at an offset.  The
:class:`FileSink` maps it onto ``os.pwritev`` (deep vectored submission,
one syscall per ``IOV_MAX`` buffers); the in-memory sinks copy part by
part but account the whole call as ONE ``writev`` — which is what lets
the I/O engine's zero-copy commit skip cluster assembly entirely (see
DESIGN.md §6).
"""

from __future__ import annotations

import errno
import os
import sys
import threading
import time
from typing import Optional

from .stats import IOStats

try:  # the vectored-write batch limit (Linux: usually 1024)
    IOV_MAX = os.sysconf("SC_IOV_MAX")
except (AttributeError, ValueError, OSError):  # pragma: no cover
    IOV_MAX = 1024
if IOV_MAX <= 0:  # pragma: no cover - sysconf may return -1 for "no limit"
    IOV_MAX = 1024


def close_all(closeables) -> None:
    """Close everything; surface the first close error only when not
    already unwinding another exception (never mask the original).

    The ``exc_info`` check runs OUTSIDE any except block — callers use
    this from ``finally``, where it sees the in-flight exception, if any.
    """
    first = None
    for item in closeables:
        try:
            item.close()
        except BaseException as e:
            if first is None:
                first = e
    if first is not None and sys.exc_info()[0] is None:
        raise first


class Sink:
    """Abstract positioned-write sink with an end-of-file cursor."""

    def __init__(self) -> None:
        self.io = IOStats()
        # pwrite/pread run concurrently (parallel producers; the reader's
        # prefetch + decode pools), so the counters need their own lock
        self._stat_lock = threading.Lock()
        self._end = 0

    # The end-of-file cursor.  NOT thread safe: the caller must hold the
    # writer's critical-section lock while reserving (paper §4.2).
    def reserve(self, size: int) -> int:
        off = self._end
        self._end += size
        return off

    @property
    def size(self) -> int:
        return self._end

    def pwrite(self, offset: int, data: bytes) -> None:
        raise NotImplementedError

    def pwritev(self, offset: int, parts) -> None:
        """Write ``parts`` (a sequence of bytes-like buffers) contiguously
        at ``offset`` — the scatter-gather commit primitive.

        The base implementation is the loop fallback: one ``pwrite`` per
        part at its computed offset, so any custom :class:`Sink` subclass
        (including fault-injection test sinks) works unchanged.  Concrete
        sinks override it with a genuinely vectored path and account the
        call under ``IOStats.writev_calls``.
        """
        pos = offset
        for p in parts:
            n = len(p)
            if n:
                self.pwrite(pos, p)
            pos += n

    def pread(self, offset: int, size: int) -> bytes:
        raise NotImplementedError

    def pread_into(self, offset: int, buf) -> int:
        """Read ``len(buf)`` bytes at ``offset`` into a caller-provided
        writable buffer; returns the byte count.

        The allocation-free read primitive the buffer pool wants: the
        merge fast path and the read engine pass pooled buffers here
        instead of taking a fresh ``bytes`` from :meth:`pread` per call.
        The base implementation copies through :meth:`pread` so every
        sink (including test subclasses) works unchanged; ``FileSink``
        overrides it with ``os.preadv``.  A short read raises — the
        caller's buffer may be recycled pool storage, and silently
        leaving a stale tail would corrupt whatever the bytes feed.
        """
        mv = memoryview(buf)
        data = self.pread(offset, len(mv))
        if len(data) != len(mv):
            raise EOFError(
                f"short read at {offset}: {len(data)} of {len(mv)} bytes"
            )
        mv[:] = data
        return len(data)

    def _count_write(self, calls: int, nbytes: int) -> None:
        with self._stat_lock:
            self.io.write_calls += calls
            self.io.bytes_written += nbytes

    def _count_writev(self, calls: int, nbytes: int) -> None:
        with self._stat_lock:
            self.io.writev_calls += calls
            self.io.bytes_written += nbytes

    def _count_read(self, calls: int, nbytes: int) -> None:
        with self._stat_lock:
            self.io.read_calls += calls
            self.io.bytes_read += nbytes

    # retry accounting (incremented by the I/O engine's retry loop, so
    # the counters travel with the sink's IOStats into Writer/ReaderStats)
    def _count_retry(self) -> None:
        with self._stat_lock:
            self.io.retries += 1

    def _count_giveup(self) -> None:
        with self._stat_lock:
            self.io.giveups += 1

    def _count_fsync_failure(self) -> None:
        with self._stat_lock:
            self.io.fsync_failures += 1

    # remote-transport accounting (DESIGN.md §10): hedged ranged reads and
    # multipart→serial-put degradations, counted by ObjectStoreSink
    def _count_hedge(self) -> None:
        with self._stat_lock:
            self.io.hedges += 1

    def _count_hedge_win(self) -> None:
        with self._stat_lock:
            self.io.hedge_wins += 1

    def _count_degradation(self) -> None:
        with self._stat_lock:
            self.io.degradations += 1

    def fallocate(self, offset: int, size: int) -> None:  # opt-1 hook
        with self._stat_lock:
            self.io.fallocate_calls += 1

    def fsync(self) -> None:
        with self._stat_lock:
            self.io.fsync_calls += 1

    def flush(self) -> None:
        """Push any sink-internal buffering toward durable storage without
        the durability barrier of :meth:`fsync`.  Local sinks have no such
        buffering — the base implementation is a no-op; the remote
        :class:`~repro.core.remote.ObjectStoreSink` uploads every
        completed-but-unsent part."""

    def close(self) -> None:
        pass

    def readable(self) -> bool:
        return False


class FileSink(Sink):
    def __init__(self, path: str, create: bool = True):
        super().__init__()
        self.path = path
        flags = os.O_RDWR | (os.O_CREAT | os.O_TRUNC if create else 0)
        self.fd = os.open(path, flags, 0o644)
        if not create:
            self._end = os.fstat(self.fd).st_size

    def pwrite(self, offset: int, data: bytes) -> None:
        view = memoryview(data)
        pos = 0
        calls = 0
        while pos < len(view):
            n = os.pwrite(self.fd, view[pos:], offset + pos)
            pos += n
            calls += 1
        self._count_write(calls, len(view))

    def pwritev(self, offset: int, parts) -> None:
        """Vectored positioned write: ``os.pwritev`` in ``IOV_MAX`` batches.

        Partial writes resume mid-buffer; falls back to the loop path when
        the platform lacks ``os.pwritev`` — or when a subclass overrides
        ``pwrite`` (instrumentation / fault-injection sinks must keep
        seeing every byte).
        """
        if type(self).pwrite is not FileSink.pwrite or not hasattr(os, "pwritev"):
            return super().pwritev(offset, parts)
        bufs = [memoryview(p) for p in parts if len(p)]
        total = sum(len(b) for b in bufs)
        pos = 0
        calls = 0
        i = 0
        while i < len(bufs):
            n = os.pwritev(self.fd, bufs[i : i + IOV_MAX], offset + pos)
            if n <= 0:  # no progress: raising beats spinning forever
                raise IOError(
                    f"pwritev wrote 0 of {total - pos} bytes at "
                    f"{offset + pos} of {self.path}"
                )
            calls += 1
            pos += n
            # advance past fully written buffers; re-slice a partial one
            while i < len(bufs) and n >= len(bufs[i]):
                n -= len(bufs[i])
                i += 1
            if n:
                bufs[i] = bufs[i][n:]
        self._count_writev(calls, total)

    def pread(self, offset: int, size: int) -> bytes:
        # fast path: the kernel returns the whole extent in one call (the
        # overwhelmingly common case) — hand its buffer back with no copy
        chunk = os.pread(self.fd, size, offset)
        if len(chunk) == size:
            self._count_read(1, size)
            return chunk
        if not chunk and size:
            raise EOFError(f"short read at {offset} of {self.path}")
        out = bytearray(chunk)
        calls = 1
        while len(out) < size:
            chunk = os.pread(self.fd, size - len(out), offset + len(out))
            if not chunk:
                raise EOFError(f"short read at {offset}+{len(out)} of {self.path}")
            out += chunk
            calls += 1
        self._count_read(calls, size)
        return bytes(out)

    def pread_into(self, offset: int, buf) -> int:
        """Zero-allocation positioned read via ``os.preadv`` (short reads
        resumed), used by pooled-buffer readers (merge's raw copies)."""
        if type(self).pread is not FileSink.pread or not hasattr(os, "preadv"):
            return super().pread_into(offset, buf)
        mv = memoryview(buf)
        size = len(mv)
        pos = 0
        calls = 0
        while pos < size:
            n = os.preadv(self.fd, [mv[pos:]], offset + pos)
            if n <= 0:
                raise EOFError(f"short read at {offset}+{pos} of {self.path}")
            pos += n
            calls += 1
        self._count_read(calls, size)
        return size

    def fallocate(self, offset: int, size: int) -> None:
        super().fallocate(offset, size)
        if size <= 0:
            return
        try:
            os.posix_fallocate(self.fd, offset, size)
        except OSError as e:  # pragma: no cover - fs dependent
            if e.errno not in (errno.EOPNOTSUPP, errno.EINVAL, errno.ENOSYS):
                raise

    def fsync(self) -> None:
        super().fsync()
        os.fsync(self.fd)

    def close(self) -> None:
        if self.fd >= 0:
            os.close(self.fd)
            self.fd = -1

    def readable(self) -> bool:
        return True


class AsyncFileSink(FileSink):
    """A :class:`FileSink` that opts into **native ring submission**.

    With write-behind enabled (``WriteOptions.io_inflight_bytes > 0``)
    and ``io_ring`` in auto mode, the I/O engine submits this sink's
    queued extents through an io_uring submission ring when the thin
    ctypes/liburing binding loads (DESIGN.md §6.7) — batched kernel
    submission instead of one completion thread call per write.  On
    platforms without liburing the engine transparently uses its
    emulated ring: same bytes, same accounting, same failure semantics.

    Synchronous operations (header, footer, reads) behave exactly like
    :class:`FileSink` — this class only *advertises* the capability via
    :attr:`native_ring`; a subclass that overrides :meth:`pwrite` or
    :meth:`pwritev` (fault injection, instrumentation) stops advertising
    it, because a kernel ring would bypass the override.
    """

    @property
    def native_ring(self) -> bool:
        return (
            type(self).pwrite is FileSink.pwrite
            and type(self).pwritev is FileSink.pwritev
            and self.fd >= 0
        )


class DevNullSink(Sink):
    """Tracks the file layout but discards bytes — the paper's /dev/null
    configuration isolates the software stack from storage bandwidth."""

    def pwrite(self, offset: int, data: bytes) -> None:
        self._count_write(1, len(data))

    def pwritev(self, offset: int, parts) -> None:
        if type(self).pwrite is not DevNullSink.pwrite:
            return super().pwritev(offset, parts)
        self._count_writev(1, sum(len(p) for p in parts))

    def pread(self, offset: int, size: int) -> bytes:
        raise IOError("DevNullSink is write-only")


class MemorySink(Sink):
    """In-memory file.

    The backing ``bytearray`` grows at :meth:`reserve` time — under the
    writer's critical section, where extent layout is decided — so the
    parallel committers that later ``pwrite``/``pwritev`` those extents
    never serialize on (or race with) a reallocation: in-bounds writes are
    plain disjoint slice assignments with no lock taken.  ``_grow_lock``
    is only acquired on the out-of-bounds fallback path (direct use
    without a prior ``reserve``).
    """

    def __init__(self, capacity: int = 0) -> None:
        super().__init__()
        # a capacity hint preallocates the backing store once (no realloc
        # memmoves during the run — what a benchmark of the commit path
        # wants); without it the buffer doubles geometrically on demand
        self.buf = bytearray(capacity)
        self._grow_lock = threading.Lock()
        self._high_water = 0  # highest unreserved write end (grow path)

    def reserve(self, size: int) -> int:
        off = super().reserve(size)
        self._ensure(off + size)
        return off

    def _ensure(self, end: int) -> None:
        if len(self.buf) < end:
            with self._grow_lock:
                cur = len(self.buf)
                if cur < end:
                    # geometric growth: bytearray's own over-allocation is
                    # too shallow (~1.125x), which turns steady appending
                    # into ~8x the file size in realloc memmoves; doubling
                    # keeps it amortized O(1) per byte.  close() trims the
                    # padding back to the logical size.
                    self.buf.extend(bytes(max(end - cur, cur, 4096)))

    def close(self) -> None:
        # drop the geometric-growth padding: after close, ``buf`` holds
        # exactly the written file (reserved extents + any direct writes)
        with self._grow_lock:
            del self.buf[max(self._end, self._high_water):]

    def _note_unreserved(self, end: int) -> None:
        """Record a write end beyond the reserved extent so close() never
        trims it.  Reserved writes (``end <= _end``, every writer path)
        skip this entirely — the hot path stays lock-free."""
        if end > self._end and end > self._high_water:
            with self._grow_lock:
                if end > self._high_water:
                    self._high_water = end

    def pwrite(self, offset: int, data: bytes) -> None:
        end = offset + len(data)
        if len(self.buf) < end:
            self._ensure(end)
        self._note_unreserved(end)
        self.buf[offset:end] = data
        self._count_write(1, len(data))

    def pwritev(self, offset: int, parts) -> None:
        if type(self).pwrite is not MemorySink.pwrite:
            return super().pwritev(offset, parts)
        total = sum(len(p) for p in parts)
        if len(self.buf) < offset + total:
            self._ensure(offset + total)
        self._note_unreserved(offset + total)
        pos = offset
        for p in parts:
            n = len(p)
            if n:
                self.buf[pos : pos + n] = p
            pos += n
        self._count_writev(1, total)

    def pread(self, offset: int, size: int) -> bytes:
        out = bytes(self.buf[offset : offset + size])
        self._count_read(1, len(out))
        return out

    def pread_into(self, offset: int, buf) -> int:
        if type(self).pread is not MemorySink.pread:
            return super().pread_into(offset, buf)
        mv = memoryview(buf)
        n = len(mv)
        src = memoryview(self.buf)[offset : offset + n]
        if len(src) != n:  # same contract as every other pread_into
            raise EOFError(
                f"short read at {offset}: {len(src)} of {n} bytes"
            )
        mv[:] = src
        self._count_read(1, n)
        return n

    def readable(self) -> bool:
        return True


class LatencyModel:
    """Simulated shared-resource latency: busy-until charge + centered sleep.

    The resource (a disk, a NIC) is modeled as a busy-until timestamp:
    each operation charges ``nbytes / bw`` seconds to the window under a
    lock — concurrent callers serialize at the resource, like a request
    queue — and then sleeps until its own completion time.  A per-op
    latency floor (an RTT) does NOT occupy the shared window: concurrent
    round trips overlap, only bytes contend.  One implementation serves
    both :class:`ThrottledSink` (device bandwidth, paper Figs. 3–4) and
    the remote ``FakeTransport`` (network RTT + shared NIC bandwidth).
    """

    #: time.sleep() on this container overshoots by ~0.1-1 ms, which at
    #: NVMe-class simulated bandwidths would make the modeled device
    #: slower than its nominal bw (a 2 MB extent at 2 GB/s costs 1 ms).
    #: Undershooting the target by half the typical overshoot centers the
    #: per-completion error near zero without burning a core on a
    #: spin-wait; aggregate occupancy stays exact either way — it is
    #: carried by the busy-until timestamp, not by the sleeps.
    SLEEP_SLOP = 0.0005

    def __init__(self, bw: float = 0.0) -> None:
        self.bw = bw  # bytes/second; 0 = unlimited
        self._lock = threading.Lock()
        self._busy_until = time.perf_counter()

    def charge(self, nbytes: int, bw: Optional[float] = None,
               floor_s: float = 0.0) -> float:
        """Extend the busy window by this operation's byte cost; returns
        the completion timestamp the caller must :meth:`settle` to.
        ``floor_s`` is a per-op latency floor (RTT + injected slow-tail
        delay) added *outside* the shared window."""
        eff = self.bw if bw is None else bw
        cost = nbytes / eff if eff else 0.0
        with self._lock:
            now = time.perf_counter()
            start = max(now, self._busy_until)
            done = start + cost
            self._busy_until = done
        return max(done, now + floor_s)

    def settle(self, done: float) -> None:
        delay = done - time.perf_counter()
        if delay > self.SLEEP_SLOP:
            time.sleep(delay - self.SLEEP_SLOP)


class ThrottledSink(Sink):
    """Wraps another sink and enforces a byte bandwidth on writes.

    Used to emulate the fio-measured device limits of the paper's SSD
    (771 / 1075 MB/s) and HDD (217 MB/s) on this container.  When
    ``fallocated`` extents are written, the effective bandwidth is
    ``bw_prealloc`` (the paper's Fig. 3 dashed line), otherwise ``bw``.
    The busy-window timing itself lives in :class:`LatencyModel`, shared
    with the remote transport simulator.
    """

    def __init__(self, inner: Sink, bw: float, bw_prealloc: Optional[float] = None):
        super().__init__()
        self.inner = inner
        self.bw = bw
        self.bw_prealloc = bw_prealloc if bw_prealloc is not None else bw
        self._model = LatencyModel()
        self._tlock = threading.Lock()  # guards _prealloc
        self._prealloc: list = []  # (start, end) fallocated extents

    def reserve(self, size: int) -> int:
        return self.inner.reserve(size)

    @property
    def size(self) -> int:
        return self.inner.size

    def _is_prealloc(self, offset: int, size: int) -> bool:
        with self._tlock:
            for s, e in self._prealloc:
                if offset >= s and offset + size <= e:
                    return True
        return False

    def _charge(self, offset: int, nbytes: int) -> float:
        """Charge this write to the shared device window at the effective
        bandwidth; returns the completion timestamp to settle to."""
        bw = self.bw_prealloc if self._is_prealloc(offset, nbytes) else self.bw
        return self._model.charge(nbytes, bw=bw)

    def _settle(self, done: float) -> None:
        self._model.settle(done)

    def pwrite(self, offset: int, data: bytes) -> None:
        done = self._charge(offset, len(data))
        self.inner.pwrite(offset, data)
        self._settle(done)
        self._count_write(1, len(data))

    def pwritev(self, offset: int, parts) -> None:
        if type(self).pwrite is not ThrottledSink.pwrite:
            return super().pwritev(offset, parts)
        total = sum(len(p) for p in parts)
        done = self._charge(offset, total)
        self.inner.pwritev(offset, parts)
        self._settle(done)
        self._count_writev(1, total)

    def pread(self, offset: int, size: int) -> bytes:
        out = self.inner.pread(offset, size)
        self._count_read(1, len(out))
        return out

    def fallocate(self, offset: int, size: int) -> None:
        super().fallocate(offset, size)
        with self._tlock:
            self._prealloc.append((offset, offset + size))
        self.inner.fallocate(offset, size)

    def fsync(self) -> None:
        super().fsync()
        self.inner.fsync()

    def close(self) -> None:
        self.inner.close()

    def readable(self) -> bool:
        return self.inner.readable()


def open_sink(path, create: bool = True, async_io: bool = False) -> Sink:
    """Resolve a path-ish spec to a sink.

    ``/dev/null``/``devnull``/``null:`` → :class:`DevNullSink`; ``mem:``
    → :class:`MemorySink`; an ``async:`` prefix (or ``async_io=True``)
    → :class:`AsyncFileSink`, which lets the I/O engine use io_uring
    ring submission when available; a ``scheme://bucket/key`` URL (e.g.
    ``mem-s3://bucket/file.rntj``, or ``s3://`` once a real transport is
    registered) → :class:`~repro.core.remote.ObjectStoreSink` over the
    scheme's registered transport (DESIGN.md §10); anything else →
    :class:`FileSink`.
    """
    path = os.fspath(path)  # accept str and os.PathLike alike
    if path in ("/dev/null", "devnull", "null:"):
        return DevNullSink()
    if path == "mem:":
        return MemorySink()
    if "://" in path:
        from .remote import open_remote_sink  # local import: no cycle
        return open_remote_sink(path, create=create)
    if path.startswith("async:"):
        return AsyncFileSink(path[len("async:"):], create=create)
    if async_io:
        return AsyncFileSink(path, create=create)
    return FileSink(path, create=create)
