"""Container sinks: where reserved extents of the file live.

The container format provides exactly the operation the paper needs
(§4.2): *reserve a byte extent of known size* (requires synchronization —
done by the writer's critical section) and *write bytes at an offset*
(no synchronization needed; ``pwrite`` is positioned and thread-safe).

Sinks:
  * :class:`FileSink`      — a real file, ``os.pwrite`` + optional fallocate.
  * :class:`DevNullSink`   — infinitely fast storage (paper Fig. 2).
  * :class:`ThrottledSink` — bandwidth-limited wrapper to emulate the SSD /
    HDD of Figs. 3–4 on this container (token-bucket on write completion).
  * :class:`MemorySink`    — in-memory file for the TBufferMerger analog.
"""

from __future__ import annotations

import errno
import os
import sys
import threading
import time
from typing import Optional

from .stats import IOStats


def close_all(closeables) -> None:
    """Close everything; surface the first close error only when not
    already unwinding another exception (never mask the original).

    The ``exc_info`` check runs OUTSIDE any except block — callers use
    this from ``finally``, where it sees the in-flight exception, if any.
    """
    first = None
    for item in closeables:
        try:
            item.close()
        except BaseException as e:
            if first is None:
                first = e
    if first is not None and sys.exc_info()[0] is None:
        raise first


class Sink:
    """Abstract positioned-write sink with an end-of-file cursor."""

    def __init__(self) -> None:
        self.io = IOStats()
        # pwrite/pread run concurrently (parallel producers; the reader's
        # prefetch + decode pools), so the counters need their own lock
        self._stat_lock = threading.Lock()
        self._end = 0

    # The end-of-file cursor.  NOT thread safe: the caller must hold the
    # writer's critical-section lock while reserving (paper §4.2).
    def reserve(self, size: int) -> int:
        off = self._end
        self._end += size
        return off

    @property
    def size(self) -> int:
        return self._end

    def pwrite(self, offset: int, data: bytes) -> None:
        raise NotImplementedError

    def pread(self, offset: int, size: int) -> bytes:
        raise NotImplementedError

    def _count_write(self, calls: int, nbytes: int) -> None:
        with self._stat_lock:
            self.io.write_calls += calls
            self.io.bytes_written += nbytes

    def _count_read(self, calls: int, nbytes: int) -> None:
        with self._stat_lock:
            self.io.read_calls += calls
            self.io.bytes_read += nbytes

    def fallocate(self, offset: int, size: int) -> None:  # opt-1 hook
        with self._stat_lock:
            self.io.fallocate_calls += 1

    def fsync(self) -> None:
        with self._stat_lock:
            self.io.fsync_calls += 1

    def close(self) -> None:
        pass

    def readable(self) -> bool:
        return False


class FileSink(Sink):
    def __init__(self, path: str, create: bool = True):
        super().__init__()
        self.path = path
        flags = os.O_RDWR | (os.O_CREAT | os.O_TRUNC if create else 0)
        self.fd = os.open(path, flags, 0o644)
        if not create:
            self._end = os.fstat(self.fd).st_size

    def pwrite(self, offset: int, data: bytes) -> None:
        view = memoryview(data)
        pos = 0
        calls = 0
        while pos < len(view):
            n = os.pwrite(self.fd, view[pos:], offset + pos)
            pos += n
            calls += 1
        self._count_write(calls, len(view))

    def pread(self, offset: int, size: int) -> bytes:
        # fast path: the kernel returns the whole extent in one call (the
        # overwhelmingly common case) — hand its buffer back with no copy
        chunk = os.pread(self.fd, size, offset)
        if len(chunk) == size:
            self._count_read(1, size)
            return chunk
        if not chunk and size:
            raise EOFError(f"short read at {offset} of {self.path}")
        out = bytearray(chunk)
        calls = 1
        while len(out) < size:
            chunk = os.pread(self.fd, size - len(out), offset + len(out))
            if not chunk:
                raise EOFError(f"short read at {offset}+{len(out)} of {self.path}")
            out += chunk
            calls += 1
        self._count_read(calls, size)
        return bytes(out)

    def fallocate(self, offset: int, size: int) -> None:
        super().fallocate(offset, size)
        if size <= 0:
            return
        try:
            os.posix_fallocate(self.fd, offset, size)
        except OSError as e:  # pragma: no cover - fs dependent
            if e.errno not in (errno.EOPNOTSUPP, errno.EINVAL, errno.ENOSYS):
                raise

    def fsync(self) -> None:
        super().fsync()
        os.fsync(self.fd)

    def close(self) -> None:
        if self.fd >= 0:
            os.close(self.fd)
            self.fd = -1

    def readable(self) -> bool:
        return True


class DevNullSink(Sink):
    """Tracks the file layout but discards bytes — the paper's /dev/null
    configuration isolates the software stack from storage bandwidth."""

    def pwrite(self, offset: int, data: bytes) -> None:
        self._count_write(1, len(data))

    def pread(self, offset: int, size: int) -> bytes:
        raise IOError("DevNullSink is write-only")


class MemorySink(Sink):
    def __init__(self) -> None:
        super().__init__()
        self.buf = bytearray()
        self._buf_lock = threading.Lock()

    def pwrite(self, offset: int, data: bytes) -> None:
        with self._buf_lock:
            need = offset + len(data)
            if len(self.buf) < need:
                self.buf.extend(b"\x00" * (need - len(self.buf)))
            self.buf[offset : offset + len(data)] = data
        self._count_write(1, len(data))

    def pread(self, offset: int, size: int) -> bytes:
        with self._buf_lock:
            out = bytes(self.buf[offset : offset + size])
        self._count_read(1, len(out))
        return out

    def readable(self) -> bool:
        return True


class ThrottledSink(Sink):
    """Wraps another sink and enforces a byte bandwidth on writes.

    Used to emulate the fio-measured device limits of the paper's SSD
    (771 / 1075 MB/s) and HDD (217 MB/s) on this container.  When
    ``fallocated`` extents are written, the effective bandwidth is
    ``bw_prealloc`` (the paper's Fig. 3 dashed line), otherwise ``bw``.
    """

    def __init__(self, inner: Sink, bw: float, bw_prealloc: Optional[float] = None):
        super().__init__()
        self.inner = inner
        self.bw = bw
        self.bw_prealloc = bw_prealloc if bw_prealloc is not None else bw
        self._tlock = threading.Lock()
        self._busy_until = time.perf_counter()
        self._prealloc: list = []  # (start, end) fallocated extents

    def reserve(self, size: int) -> int:
        return self.inner.reserve(size)

    @property
    def size(self) -> int:
        return self.inner.size

    def _is_prealloc(self, offset: int, size: int) -> bool:
        for s, e in self._prealloc:
            if offset >= s and offset + size <= e:
                return True
        return False

    def pwrite(self, offset: int, data: bytes) -> None:
        bw = self.bw_prealloc if self._is_prealloc(offset, len(data)) else self.bw
        cost = len(data) / bw
        # The device is a single shared resource: model it as a busy-until
        # timestamp; each write extends it and the caller sleeps until its
        # own completion time (writes from many threads serialize at the
        # device, like a request queue).
        with self._tlock:
            now = time.perf_counter()
            start = max(now, self._busy_until)
            done = start + cost
            self._busy_until = done
        self.inner.pwrite(offset, data)
        delay = done - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        self._count_write(1, len(data))

    def pread(self, offset: int, size: int) -> bytes:
        out = self.inner.pread(offset, size)
        self._count_read(1, len(out))
        return out

    def fallocate(self, offset: int, size: int) -> None:
        super().fallocate(offset, size)
        with self._tlock:
            self._prealloc.append((offset, offset + size))
        self.inner.fallocate(offset, size)

    def fsync(self) -> None:
        super().fsync()
        self.inner.fsync()

    def close(self) -> None:
        self.inner.close()

    def readable(self) -> bool:
        return self.inner.readable()


def open_sink(path, create: bool = True) -> Sink:
    path = os.fspath(path)  # accept str and os.PathLike alike
    if path in ("/dev/null", "devnull", "null:"):
        return DevNullSink()
    if path == "mem:":
        return MemorySink()
    return FileSink(path, create=create)
