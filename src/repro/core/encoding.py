"""Column preconditioning encodings (numpy reference implementations).

These mirror RNTuple's on-disk column encodings: *split* (byte-plane
shuffle) for multi-byte primitives and *delta + zigzag + split* for offset
columns.  Preconditioning radically improves the entropy coder's ratio on
monotonic offset columns and on floats with correlated exponents.

The numpy functions here are the canonical host implementations; the Pallas
kernels in ``repro.kernels.{byteshuffle,delta_zigzag,offsets_scan}`` are the
TPU-side ports and are property-tested to be bit-identical against these
(via ``repro.kernels.ref`` which re-exports the same math in jnp).
"""

from __future__ import annotations

import numpy as np

from .schema import ENC_DELTA_ZIGZAG_SPLIT, ENC_NONE, ENC_SPLIT

# ---------------------------------------------------------------------------
# split (byte-plane shuffle)


def split_encode(arr: np.ndarray) -> bytes:
    """Byte-plane split: [b0 of all elems][b1 of all elems]...

    Little-endian byte planes of a contiguous primitive array.
    """
    a = np.ascontiguousarray(arr)
    if a.dtype.byteorder == ">":  # normalize to little-endian
        a = a.astype(a.dtype.newbyteorder("<"))
    nbytes = a.dtype.itemsize
    planes = a.view(np.uint8).reshape(-1, nbytes)
    return planes.T.tobytes()


def split_decode(buf: bytes, dtype: np.dtype, n: int) -> np.ndarray:
    dtype = np.dtype(dtype)
    nbytes = dtype.itemsize
    planes = np.frombuffer(buf, dtype=np.uint8, count=n * nbytes).reshape(nbytes, n)
    return np.ascontiguousarray(planes.T).reshape(-1).view(dtype)[:n].copy()


# ---------------------------------------------------------------------------
# delta + zigzag (for int64 offset columns)


def zigzag_encode(x: np.ndarray) -> np.ndarray:
    """Map signed -> unsigned: 0,-1,1,-2,2 ... -> 0,1,2,3,4."""
    x = x.astype(np.int64, copy=False)
    return ((x << np.int64(1)) ^ (x >> np.int64(63))).view(np.uint64)


def zigzag_decode(u: np.ndarray) -> np.ndarray:
    u = u.view(np.uint64) if u.dtype != np.uint64 else u
    return ((u >> np.uint64(1)).astype(np.int64)) ^ -(u & np.uint64(1)).astype(np.int64)


def delta_encode(x: np.ndarray, first_reference: int = 0) -> np.ndarray:
    """x[i] - x[i-1], with x[-1] := first_reference."""
    x = x.astype(np.int64, copy=False)
    d = np.empty_like(x)
    if len(x):
        d[0] = x[0] - first_reference
        np.subtract(x[1:], x[:-1], out=d[1:])
    return d


def delta_decode(d: np.ndarray, first_reference: int = 0) -> np.ndarray:
    d = d.astype(np.int64, copy=False)
    out = np.cumsum(d, dtype=np.int64)
    if first_reference:
        out = out + np.int64(first_reference)
    return out


def dzs_encode(arr: np.ndarray, first_reference: int = 0) -> bytes:
    """delta -> zigzag -> split; the offset-column encoding."""
    return split_encode(zigzag_encode(delta_encode(arr, first_reference)))


def dzs_decode(buf: bytes, n: int, first_reference: int = 0) -> np.ndarray:
    u = split_decode(buf, np.dtype(np.uint64), n)
    return delta_decode(zigzag_decode(u), first_reference)


# ---------------------------------------------------------------------------
# dispatch


def precondition(arr: np.ndarray, encoding: str) -> bytes:
    if encoding == ENC_NONE:
        return np.ascontiguousarray(arr).tobytes()
    if encoding == ENC_SPLIT:
        return split_encode(arr)
    if encoding == ENC_DELTA_ZIGZAG_SPLIT:
        return dzs_encode(arr)
    raise ValueError(f"unknown encoding {encoding!r}")


def unprecondition(buf: bytes, encoding: str, dtype: np.dtype, n: int) -> np.ndarray:
    dtype = np.dtype(dtype)
    if encoding == ENC_NONE:
        return np.frombuffer(buf, dtype=dtype, count=n).copy()
    if encoding == ENC_SPLIT:
        return split_decode(buf, dtype, n)
    if encoding == ENC_DELTA_ZIGZAG_SPLIT:
        assert dtype == np.dtype(np.int64)
        return dzs_decode(buf, n)
    raise ValueError(f"unknown encoding {encoding!r}")


def sizes_to_offsets(sizes: np.ndarray) -> np.ndarray:
    """Collection sizes -> cluster-relative *end* offsets (inclusive scan).

    This is the on-disk form of an offset column: ``offsets[j]`` is the end
    of collection ``j`` within the cluster; the start is ``offsets[j-1]``
    (or 0).  Being cluster-relative is what makes a sealed cluster
    relocatable (paper §5).
    """
    return np.cumsum(sizes.astype(np.int64, copy=False), dtype=np.int64)


def offsets_to_sizes(offsets: np.ndarray) -> np.ndarray:
    o = offsets.astype(np.int64, copy=False)
    s = np.empty_like(o)
    if len(o):
        s[0] = o[0]
        np.subtract(o[1:], o[:-1], out=s[1:])
    return s
