"""Column preconditioning encodings (numpy reference implementations).

These mirror RNTuple's on-disk column encodings: *split* (byte-plane
shuffle) for multi-byte primitives and *delta + zigzag + split* for offset
columns.  Preconditioning radically improves the entropy coder's ratio on
monotonic offset columns and on floats with correlated exponents.

The numpy functions here are the canonical host implementations; the Pallas
kernels in ``repro.kernels.{byteshuffle,delta_zigzag,offsets_scan}`` are the
TPU-side ports and are property-tested to be bit-identical against these
(via ``repro.kernels.ref`` which re-exports the same math in jnp).
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.kernels.ops import KernelDispatch

from .schema import ENC_DELTA_ZIGZAG_SPLIT, ENC_NONE, ENC_SPLIT

# ---------------------------------------------------------------------------
# split (byte-plane shuffle)


def split_encode(arr: np.ndarray) -> bytes:
    """Byte-plane split: [b0 of all elems][b1 of all elems]...

    Little-endian byte planes of a contiguous primitive array.
    """
    a = np.ascontiguousarray(arr)
    if a.dtype.byteorder == ">":  # normalize to little-endian
        a = a.astype(a.dtype.newbyteorder("<"))
    nbytes = a.dtype.itemsize
    planes = a.view(np.uint8).reshape(-1, nbytes)
    return planes.T.tobytes()


def split_decode(buf: bytes, dtype: np.dtype, n: int) -> np.ndarray:
    dtype = np.dtype(dtype)
    nbytes = dtype.itemsize
    planes = np.frombuffer(buf, dtype=np.uint8, count=n * nbytes).reshape(nbytes, n)
    return np.ascontiguousarray(planes.T).reshape(-1).view(dtype)[:n].copy()


# ---------------------------------------------------------------------------
# delta + zigzag (for int64 offset columns)


def zigzag_encode(x: np.ndarray) -> np.ndarray:
    """Map signed -> unsigned: 0,-1,1,-2,2 ... -> 0,1,2,3,4."""
    x = x.astype(np.int64, copy=False)
    return ((x << np.int64(1)) ^ (x >> np.int64(63))).view(np.uint64)


def zigzag_decode(u: np.ndarray) -> np.ndarray:
    u = u.view(np.uint64) if u.dtype != np.uint64 else u
    return ((u >> np.uint64(1)).astype(np.int64)) ^ -(u & np.uint64(1)).astype(np.int64)


def delta_encode(x: np.ndarray, first_reference: int = 0) -> np.ndarray:
    """x[i] - x[i-1], with x[-1] := first_reference."""
    x = x.astype(np.int64, copy=False)
    d = np.empty_like(x)
    if len(x):
        d[0] = x[0] - first_reference
        np.subtract(x[1:], x[:-1], out=d[1:])
    return d


def delta_decode(d: np.ndarray, first_reference: int = 0) -> np.ndarray:
    d = d.astype(np.int64, copy=False)
    out = np.cumsum(d, dtype=np.int64)
    if first_reference:
        out = out + np.int64(first_reference)
    return out


def dzs_encode(arr: np.ndarray, first_reference: int = 0) -> bytes:
    """delta -> zigzag -> split; the offset-column encoding."""
    return split_encode(zigzag_encode(delta_encode(arr, first_reference)))


def dzs_decode(buf: bytes, n: int, first_reference: int = 0) -> np.ndarray:
    u = split_decode(buf, np.dtype(np.uint64), n)
    return delta_decode(zigzag_decode(u), first_reference)


# ---------------------------------------------------------------------------
# scratch-based preconditioning (the per-page hot path)


class EncodeScratch:
    """Reusable temporaries for :func:`precondition_buffer`.

    One instance per thread (pages.py keeps them thread-local): a page
    build reuses the same scratch arrays instead of allocating fresh
    intermediates for the split transpose and the delta/zigzag stages.

    With a :class:`~repro.core.bufpool.BufferPool` attached (the
    cluster builder's writer-shared pool), scratch storage is drawn
    from — and outgrown buffers returned to — the pool's power-of-two
    size classes, so the scatter-gather seal's detached scratch slots
    recycle instead of reallocating (DESIGN.md §6.8).
    """

    def __init__(self, pool=None) -> None:
        self._bufs: dict = {}
        self._pool = pool

    def array(self, key: str, dtype, n: int) -> np.ndarray:
        dtype = np.dtype(dtype)
        buf = self._bufs.get(key)
        if buf is None or len(buf) < n:
            if self._pool is not None:
                if buf is not None:
                    # outgrown and referenced by nothing durable (detached
                    # slots were popped, compressed payloads are copies)
                    self._pool.put(buf)
                raw = self._pool.take(max(n, 4096) * dtype.itemsize)
                buf = raw.view(dtype)
            else:
                buf = np.empty(max(n, 4096), dtype=dtype)
            self._bufs[key] = buf
        return buf[:n]


def _split_into(a: np.ndarray, out_u8: np.ndarray) -> np.ndarray:
    """Byte-plane split of contiguous ``a`` into preallocated ``out_u8``."""
    if a.dtype.byteorder == ">":  # normalize to little-endian
        a = a.astype(a.dtype.newbyteorder("<"))
    nb = a.dtype.itemsize
    n = len(a)
    planes = a.view(np.uint8).reshape(n, nb)
    out_u8[: n * nb].reshape(nb, n)[:] = planes.T
    return out_u8[: n * nb]


def precondition_buffer(
    arr: np.ndarray, encoding: str, scratch: Optional[EncodeScratch] = None
) -> np.ndarray:
    """Precondition one page of elements with minimal allocation.

    Returns a ``uint8`` array (``len == nbytes``) byte-identical to
    :func:`precondition`.  With a scratch, split/dzs intermediates reuse
    buffers and the ``none`` encoding is a zero-copy reinterpret view of
    the input.  The result may alias ``arr`` or ``scratch``: it is valid
    only until the next call with the same scratch, and callers storing it
    must copy (``bytes(...)``) first.
    """
    a = np.ascontiguousarray(arr)
    if encoding == ENC_NONE:
        return a.view(np.uint8) if len(a) else np.empty(0, np.uint8)
    if scratch is None:
        scratch = EncodeScratch()
    if encoding == ENC_SPLIT:
        out = scratch.array("u8", np.uint8, a.nbytes)
        return _split_into(a, out)
    if encoding == ENC_DELTA_ZIGZAG_SPLIT:
        x = a.astype(np.int64, copy=False)
        n = len(x)
        d = scratch.array("i64a", np.int64, n)
        t = scratch.array("i64b", np.int64, n)
        if n:
            d[0] = x[0]
            np.subtract(x[1:], x[:-1], out=d[1:])
        # zigzag in place: (d << 1) ^ (d >> 63)
        np.right_shift(d, 63, out=t)
        np.left_shift(d, 1, out=d)
        np.bitwise_xor(d, t, out=d)
        out = scratch.array("u8", np.uint8, d.nbytes)
        return _split_into(d.view(np.uint64), out)
    raise ValueError(f"unknown encoding {encoding!r}")


def _batched_split_into(a: np.ndarray, per: int, out_u8: np.ndarray) -> None:
    """Page-wise byte-plane split of a whole column in O(1) numpy calls.

    Writes, for each page of ``per`` elements, that page's plane-split
    bytes contiguously into ``out_u8`` — bit-identical to running
    :func:`split_encode` page by page, but the full pages go through one
    batched strided copy instead of a Python loop.  Large columns
    dispatch the full-pages block to the Pallas ``byteshuffle`` kernel
    when an accelerator backend is available (see
    :func:`_resolve_pallas_shuffle`); the strided numpy copy is the
    fallback and the reference.
    """
    if a.dtype.byteorder == ">":
        a = a.astype(a.dtype.newbyteorder("<"))
    nb = a.dtype.itemsize
    n = len(a)
    n_full = n // per
    head = n_full * per
    if n_full:
        src = a[:head].view(np.uint8).reshape(n_full, per, nb)
        done = False
        if _SHUFFLE.want(head * nb):
            kernel = _SHUFFLE.resolve()
            if kernel:
                try:
                    out_u8[: head * nb].reshape(n_full, nb, per)[:] = kernel(src)
                    done = True
                except Exception:
                    _SHUFFLE.disable()
        if not done:
            np.copyto(
                out_u8[: head * nb].reshape(n_full, nb, per),
                src.transpose(0, 2, 1),
            )
    if head < n:
        _split_into(a[head:], out_u8[head * nb :])


def precondition_column_pages(
    arr: np.ndarray, encoding: str, per: int,
    scratch: Optional[EncodeScratch] = None, out_key: str = "u8",
) -> np.ndarray:
    """Precondition ALL pages of a column at once (the seal fast path).

    Returns a ``uint8`` array holding each page's preconditioned bytes
    back to back: page ``p`` of ``k`` elements occupies the byte range
    ``[p*per*itemsize, p*per*itemsize + k*itemsize)``.  Bit-identical to
    calling :func:`precondition_buffer` per page slice, but the per-page
    Python loop, temporaries and dispatch collapse into a handful of
    vectorized column-wide operations.  The result aliases ``scratch``
    (or ``arr`` for the ``none`` encoding) under the usual rules;
    ``out_key`` selects which scratch buffer holds it, so a caller that
    needs several columns' payloads alive at once (the chunk-parallel
    pooled seal) can give each column its own key.
    """
    a = np.ascontiguousarray(arr)
    if encoding == ENC_NONE:
        return a.view(np.uint8) if len(a) else np.empty(0, np.uint8)
    if scratch is None:
        scratch = EncodeScratch()
    if encoding == ENC_SPLIT:
        out = scratch.array(out_key, np.uint8, a.nbytes)
        _batched_split_into(a, per, out)
        return out
    if encoding == ENC_DELTA_ZIGZAG_SPLIT:
        x = a.astype(np.int64, copy=False)
        n = len(x)
        d = scratch.array("i64a", np.int64, n)
        t = scratch.array("i64b", np.int64, n)
        if n:
            d[0] = x[0]
            np.subtract(x[1:], x[:-1], out=d[1:])
            # per-page delta restarts at each page boundary
            # (first_reference = 0), exactly like the per-page encoder
            d[per::per] = x[per::per]
        np.right_shift(d, 63, out=t)
        np.left_shift(d, 1, out=d)
        np.bitwise_xor(d, t, out=d)
        out = scratch.array(out_key, np.uint8, d.nbytes)
        _batched_split_into(d.view(np.uint64), per, out)
        return out
    raise ValueError(f"unknown encoding {encoding!r}")


# ---------------------------------------------------------------------------
# scratch-based unpreconditioning (the per-page read hot path)


def _unsplit_into(buf, out: np.ndarray) -> None:
    """Inverse byte-plane split of one page into contiguous ``out``.

    Copies plane by plane (contiguous reads, stride-``nb`` writes): on
    this container ~2-4x the bandwidth of the single transposed copy.
    """
    n = len(out)
    if not n:
        return
    nb = out.dtype.itemsize
    planes = np.frombuffer(buf, dtype=np.uint8, count=n * nb).reshape(nb, n)
    o = out.view(np.uint8).reshape(n, nb)
    for k in range(nb):
        o[:, k] = planes[k]


def unprecondition_into(
    raw, encoding: str, out: np.ndarray,
    scratch: Optional[EncodeScratch] = None,
) -> None:
    """Inverse of :func:`precondition_buffer`, decoding into ``out``.

    ``raw`` is the decompressed page payload (bytes-like); ``out`` is the
    page's slice of a preallocated contiguous column array with
    ``len(out) == n_elements``.  Bit-identical to :func:`unprecondition`
    minus its allocations: split pages transpose straight into ``out``
    and offset pages run their delta integration through
    :func:`integrate_sizes` (the same Pallas ``offsets_scan`` dispatch
    the write path uses), with the zigzag/delta intermediates living in
    the per-thread scratch.
    """
    n = len(out)
    if n == 0:
        return
    if encoding == ENC_NONE:
        out[:] = np.frombuffer(raw, dtype=out.dtype, count=n)
        return
    if scratch is None:
        scratch = EncodeScratch()
    if encoding == ENC_SPLIT:
        _unsplit_into(raw, out)
        return
    if encoding == ENC_DELTA_ZIGZAG_SPLIT:
        u = scratch.array("r_u64", np.uint64, n)
        _unsplit_into(raw, u)
        _zigzag_decode_inplace(u, scratch)
        # deltas -> absolute cluster-relative end offsets: the same
        # inclusive scan (and kernel dispatch) the writer integrates with
        integrate_sizes(u.view(np.int64), out=out)
        return
    raise ValueError(f"unknown encoding {encoding!r}")


def _zigzag_decode_inplace(u: np.ndarray, scratch: EncodeScratch) -> None:
    """``u`` (uint64 zigzag) -> signed deltas, in place: (u >> 1) ^ -(u & 1)."""
    t = scratch.array("r_u64b", np.uint64, len(u))
    np.bitwise_and(u, np.uint64(1), out=t)
    np.right_shift(u, np.uint64(1), out=u)
    d = u.view(np.int64)
    s = t.view(np.int64)
    np.negative(s, out=s)
    np.bitwise_xor(d, s, out=d)


def _batched_unsplit_into(raw, per: int, out: np.ndarray) -> None:
    """Inverse of :func:`_batched_split_into`: page-wise byte-plane unsplit
    of a whole column region in O(1) numpy calls.

    ``raw`` holds the plane-split payloads of consecutive pages of
    ``per`` elements each (final page may be partial) back to back.
    """
    nb = out.dtype.itemsize
    n = len(out)
    n_full = n // per
    head = n_full * per
    if n_full:
        src = np.frombuffer(raw, dtype=np.uint8, count=head * nb)
        s = src.reshape(n_full, nb, per)
        o = out[:head].view(np.uint8).reshape(n_full, per, nb)
        # plane-by-plane (contiguous reads) beats one transposed copyto
        # by 2-4x on this container
        for k in range(nb):
            o[:, :, k] = s[:, k, :]
    if head < n:
        _unsplit_into(raw[head * nb :], out[head:])


def unprecondition_pages_into(
    raw, encoding: str, per: int, out: np.ndarray,
    scratch: Optional[EncodeScratch] = None,
) -> None:
    """Decode ALL pages of a column region at once (column-batched).

    ``raw`` holds the preconditioned payloads of consecutive pages of one
    column back to back — page ``p`` of ``k ≤ per`` elements at byte range
    ``[p*per*itemsize, p*per*itemsize + k*itemsize)`` — exactly the layout
    a sealed cluster stores them in for the ``none`` codec.  Bit-identical
    to calling :func:`unprecondition_into` per page, but the per-page
    Python dispatch and temporaries collapse into a handful of vectorized
    column-wide operations (the read-side mirror of
    :func:`precondition_column_pages`).
    """
    n = len(out)
    if n == 0:
        return
    if encoding == ENC_NONE:
        out[:] = np.frombuffer(raw, dtype=out.dtype, count=n)
        return
    if scratch is None:
        scratch = EncodeScratch()
    if encoding == ENC_SPLIT:
        _batched_unsplit_into(raw, per, out)
        return
    if encoding == ENC_DELTA_ZIGZAG_SPLIT:
        u = scratch.array("r_u64", np.uint64, n)
        _batched_unsplit_into(raw, per, u)
        _zigzag_decode_inplace(u, scratch)
        d = u.view(np.int64)
        # the per-page delta restart means each page integrates from 0
        for start in range(0, n, per):
            seg = d[start : start + per]
            integrate_sizes(seg, out=out[start : start + len(seg)])
        return
    raise ValueError(f"unknown encoding {encoding!r}")


# ---------------------------------------------------------------------------
# dispatch


def precondition(arr: np.ndarray, encoding: str) -> bytes:
    return bytes(precondition_buffer(arr, encoding))


def unprecondition(buf: bytes, encoding: str, dtype: np.dtype, n: int) -> np.ndarray:
    dtype = np.dtype(dtype)
    if encoding == ENC_NONE:
        return np.frombuffer(buf, dtype=dtype, count=n).copy()
    if encoding == ENC_SPLIT:
        return split_decode(buf, dtype, n)
    if encoding == ENC_DELTA_ZIGZAG_SPLIT:
        assert dtype == np.dtype(np.int64)
        return dzs_decode(buf, n)
    raise ValueError(f"unknown encoding {encoding!r}")


# Backend dispatch (DESIGN.md §3.3/§7.4): every kernel family shares ONE
# KernelDispatch (repro.kernels.ops).  REPRO_KERNEL_BACKEND sets the global
# default; REPRO_OFFSETS_BACKEND / REPRO_SHUFFLE_BACKEND stay honored as
# per-kernel overrides, with REPRO_*_PALLAS_MIN size floors below which the
# numpy path always wins.  "auto" only selects a kernel on an accelerator
# backend with jax already imported — the CPU interpret path exists for
# correctness tests, not speed.


def _load_offsets_kernel():
    from repro.kernels.offsets_scan import offsets_scan_host

    return offsets_scan_host


def _load_shuffle_kernel():
    from repro.kernels.byteshuffle import byteshuffle_pages_host

    return byteshuffle_pages_host


#: offsets-scan dispatch; ``min`` is in ELEMENTS
_OFFSETS = KernelDispatch("offsets", _load_offsets_kernel, min_default=65536)
#: byteshuffle dispatch; ``min`` is in BYTES
_SHUFFLE = KernelDispatch("shuffle", _load_shuffle_kernel,
                          min_default=256 * 1024)


def integrate_sizes(
    sizes: np.ndarray, base: int = 0, out: Optional[np.ndarray] = None
) -> np.ndarray:
    """Collection sizes -> cluster-relative end offsets, starting at ``base``.

    The write hot path: integrates in place into ``out`` when given (the
    reserved tail of an offset :class:`~repro.core.colbuf.ColumnBuffer`).
    Large columns dispatch to the Pallas ``offsets_scan`` kernel when an
    accelerator backend is available (or ``REPRO_OFFSETS_BACKEND=pallas``
    forces it); the numpy inclusive scan is the fallback and the reference.
    """
    n = len(sizes)
    if out is None:
        out = np.empty(n, dtype=np.int64)
    done = False
    if n and _OFFSETS.want(n):
        kernel = _OFFSETS.resolve()
        # the kernel scans in int32: only dispatch when the total fits
        if kernel and int(np.sum(sizes, dtype=np.int64)) < 2**31:
            try:
                out[:] = kernel(np.asarray(sizes))
                done = True
            except Exception:
                _OFFSETS.disable()
    if not done:
        np.cumsum(
            np.asarray(sizes).astype(np.int64, copy=False),
            dtype=np.int64, out=out,
        )
    if base:
        out += np.int64(base)
    return out


def sizes_to_offsets(sizes: np.ndarray) -> np.ndarray:
    """Collection sizes -> cluster-relative *end* offsets (inclusive scan).

    This is the on-disk form of an offset column: ``offsets[j]`` is the end
    of collection ``j`` within the cluster; the start is ``offsets[j-1]``
    (or 0).  Being cluster-relative is what makes a sealed cluster
    relocatable (paper §5).
    """
    return integrate_sizes(np.asarray(sizes))


def offsets_to_sizes(offsets: np.ndarray) -> np.ndarray:
    o = offsets.astype(np.int64, copy=False)
    s = np.empty_like(o)
    if len(o):
        s[0] = o[0]
        np.subtract(o[1:], o[:-1], out=s[1:])
    return s
