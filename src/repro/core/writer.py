"""Sequential and parallel RNT-J writers — the paper's contribution (§4, §5).

Protocol (paper §4):
  1. Each producer prepares its own *unit of writing* (a cluster, or a page
     in unbuffered mode) — serialization + compression run with **no
     synchronization** because sealed clusters are relocatable.
  2. A short critical section *reserves* a byte extent in the container
     and appends format metadata in commit order (sequential-equivalent).
  3. The bytes are written at the reserved offset — inside the critical
     section by default (paper §5 base implementation), or outside it with
     opt-2 (``write_outside_lock``), after optionally preallocating the
     extent with opt-1 (``fallocate``).

Modes (paper §5 / §6.1):
  * buffered   — unit of writing = cluster; compressed pages buffered in
    memory until the cluster commits.  ~1 lock acquisition per cluster.
  * unbuffered — unit of writing = page; pages stream out under a
    per-page lock; lower memory, collapses under lock contention at high
    thread counts (the paper's 300-vs-27,000 futex observation).
"""

from __future__ import annotations

import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from . import compression as comp
from .cluster import ClusterBuilder, SealedCluster
from .container import Sink, open_sink
from .metadata import (
    ANCHOR_SIZE,
    ClusterMeta,
    build_anchor,
    build_footer,
    build_header,
    build_pagelist,
)
from .pages import DEFAULT_PAGE_SIZE, PageDesc
from .schema import ColumnBatch, Schema
from .stats import CountingLock, WriterStats


@dataclass
class WriteOptions:
    page_size: int = DEFAULT_PAGE_SIZE       # uncompressed bytes per page
    codec: object = "zlib"                   # name or id
    level: int = -1
    cluster_bytes: int = 8 * 1024 * 1024     # uncompressed bytes per cluster
    buffered: bool = True                    # cluster-granular unit of writing
    fallocate: bool = False                  # opt-1: preallocate extents
    write_outside_lock: bool = False         # opt-2: write after the critical section
    imt_workers: int = 0                     # sequential writer: page-compression pool
    checksum: bool = True

    @property
    def codec_id(self) -> int:
        return comp.codec_id(self.codec)

    def as_dict(self) -> dict:
        return {
            "page_size": self.page_size,
            "codec": self.codec_id,
            "cluster_bytes": self.cluster_bytes,
            "buffered": self.buffered,
        }


class _WriterBase:
    """Shared container/metadata handling + close()."""

    def __init__(self, schema: Schema, sink, options: Optional[WriteOptions] = None):
        self.schema = schema
        self.options = options or WriteOptions()
        self.sink: Sink = open_sink(sink) if isinstance(sink, str) else sink
        self.lock = CountingLock()
        self.stats = WriterStats()
        self._clusters: List[ClusterMeta] = []
        self._n_entries = 0
        self._closed = False
        # header goes first; its location is fixed so no lock is needed yet
        hdr = build_header(schema, self.options.as_dict())
        off = self.sink.reserve(len(hdr))
        self.sink.pwrite(off, hdr)
        self._header_loc = (off, len(hdr))

    # -- commit protocol ----------------------------------------------------

    def _commit_cluster(self, sealed: SealedCluster) -> None:
        """The paper's critical section (§4.2/§4.3), buffered mode."""
        opts = self.options
        t0 = time.perf_counter_ns()
        with self.lock:
            off = self.sink.reserve(sealed.size)
            if opts.fallocate:
                self.sink.fallocate(off, sealed.size)
            first_entry = self._n_entries
            self._n_entries += sealed.n_entries
            self._clusters.append(
                ClusterMeta(
                    first_entry=first_entry,
                    n_entries=sealed.n_entries,
                    n_elements=sealed.n_elements,
                    pages=sealed.rebase(off),
                    byte_offset=off,
                    byte_size=sealed.size,
                )
            )
            if not opts.write_outside_lock:
                self.sink.pwrite(off, sealed.blob)
        if opts.write_outside_lock:
            # opt-2: the extent is reserved and the metadata final — the
            # actual bytes go out truly in parallel (paper §5).
            self.sink.pwrite(off, sealed.blob)
        self.stats.commit_ns += time.perf_counter_ns() - t0
        self.stats.seal_ns += sealed.seal_ns
        self.stats.clusters += 1
        self.stats.pages += len(sealed.pages)
        self.stats.entries += sealed.n_entries
        self.stats.uncompressed_bytes += sealed.uncompressed_bytes
        self.stats.compressed_bytes += sealed.size

    def _commit_page(self, payload: bytes, desc: PageDesc) -> PageDesc:
        """Page-granular critical section (unbuffered mode)."""
        with self.lock:
            off = self.sink.reserve(len(payload))
            self.sink.pwrite(off, payload)
        desc.offset = off
        self.stats.pages += 1
        self.stats.compressed_bytes += len(payload)
        return desc

    def _commit_cluster_meta_unbuffered(
        self, n_entries: int, n_elements: List[int], pages: List[PageDesc],
        uncompressed: int,
    ) -> None:
        with self.lock:
            first_entry = self._n_entries
            self._n_entries += n_entries
            self._clusters.append(
                ClusterMeta(first_entry, n_entries, n_elements, list(pages))
            )
        self.stats.clusters += 1
        self.stats.entries += n_entries
        self.stats.uncompressed_bytes += uncompressed

    # -- finalization ---------------------------------------------------------

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        with self.lock:
            pl = build_pagelist(self._clusters, self.schema.n_columns)
            pl_off = self.sink.reserve(len(pl))
            self.sink.pwrite(pl_off, pl)
            ftr = build_footer(self._n_entries, len(self._clusters), (pl_off, len(pl)))
            f_off = self.sink.reserve(len(ftr))
            self.sink.pwrite(f_off, ftr)
            anchor = build_anchor(
                self._header_loc, (f_off, len(ftr)), self._n_entries,
                len(self._clusters),
            )
            a_off = self.sink.reserve(ANCHOR_SIZE)
            self.sink.pwrite(a_off, anchor)
        self.stats.lock.merge(self.lock.stats)
        self.stats.io.merge(self.sink.io)
        self.sink.fsync() if self.sink.readable() else None
        self.sink.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def n_entries(self) -> int:
        return self._n_entries


# ---------------------------------------------------------------------------
# Sequential writer (the baseline RNTuple writer + IMT page compression)


class SequentialWriter(_WriterBase):
    """Single-producer writer.

    With ``options.imt_workers > 0`` page compression of a cluster is
    distributed over a thread pool — ROOT's *implicit multithreading* (IMT)
    model, which the paper shows plateaus around 4 threads (Fig. 5) because
    everything else stays serial.
    """

    def __init__(self, schema: Schema, sink, options: Optional[WriteOptions] = None):
        super().__init__(schema, sink, options)
        o = self.options
        self._builder = ClusterBuilder(
            schema, o.page_size, o.codec_id, o.level, o.checksum
        )
        self._pool = (
            ThreadPoolExecutor(max_workers=o.imt_workers) if o.imt_workers else None
        )

    def fill(self, entry: Dict) -> None:
        self._builder.fill(entry)
        self._maybe_flush()

    def fill_batch(self, batch: ColumnBatch) -> None:
        self._builder.fill_batch(batch)
        self._maybe_flush()

    def _maybe_flush(self) -> None:
        if self._builder.uncompressed_bytes >= self.options.cluster_bytes:
            self.flush_cluster()

    def flush_cluster(self) -> None:
        if self._builder.is_empty:
            return
        if self._pool is None:
            sealed = self._builder.seal()
        else:
            sealed = _seal_with_pool(self._builder, self._pool)
        self._commit_cluster(sealed)

    def close(self) -> None:
        if not self._closed:
            self.flush_cluster()
            if self._pool:
                self._pool.shutdown(wait=True)
        super().close()


def _seal_with_pool(builder: ClusterBuilder, pool: ThreadPoolExecutor) -> SealedCluster:
    """IMT-style seal: pages of one cluster compressed by a pool.

    Mirrors ROOT IMT: parallelism *within* one unit of writing.  The paper
    (§4.1) argues per-producer units scale better; the fig5 benchmark shows
    the same.
    """
    from .pages import build_page, elements_per_page

    t0 = time.perf_counter_ns()
    jobs = []
    for col in builder.schema.columns:
        elems = builder._column_elements(col.index)
        per = builder._page_elems[col.index]
        for start in range(0, len(elems), per):
            jobs.append((col, elems[start : start + per]))
    results = list(
        pool.map(
            lambda cv: build_page(cv[0], cv[1], builder.codec, builder.level,
                                  builder.checksum),
            jobs,
        )
    )
    parts, descs, pos = [], [], 0
    for payload, desc in results:
        desc.offset = pos
        pos += desc.size
        parts.append(payload)
        descs.append(desc)
    sealed = SealedCluster(
        blob=b"".join(parts),
        n_entries=builder.n_entries,
        n_elements=list(builder._n_elements),
        pages=descs,
        uncompressed_bytes=builder.uncompressed_bytes,
        seal_ns=time.perf_counter_ns() - t0,
    )
    builder._reset()
    return sealed


# ---------------------------------------------------------------------------
# Parallel writer (the paper's contribution)


class FillContext:
    """Per-producer context: its own cluster under construction.

    Everything up to the commit happens without synchronization; the commit
    is the short critical section described in paper §4.2/§4.3.
    """

    def __init__(self, writer: "ParallelWriter"):
        self.writer = writer
        o = writer.options
        self.builder = ClusterBuilder(
            writer.schema, o.page_size, o.codec_id, o.level, o.checksum
        )
        self._page_buf: List = []  # unbuffered mode: descs of committed pages

    def fill(self, entry: Dict) -> None:
        self.builder.fill(entry)
        self._maybe_flush()

    def fill_batch(self, batch: ColumnBatch) -> None:
        self.builder.fill_batch(batch)
        self._maybe_flush()

    def _maybe_flush(self) -> None:
        o = self.writer.options
        if not o.buffered:
            for payload, desc in self.builder.drain_full_pages():
                self._page_buf.append(self.writer._commit_page(payload, desc))
        if self.builder.uncompressed_bytes >= o.cluster_bytes:
            self.flush_cluster()

    def flush_cluster(self) -> None:
        if self.builder.is_empty:
            return
        if self.writer.options.buffered:
            sealed = self.builder.seal()
            self.writer._commit_cluster(sealed)
        else:
            for payload, desc in self.builder.drain_rest():
                self._page_buf.append(self.writer._commit_page(payload, desc))
            n_entries, n_elements, unc = self.builder.finish_unbuffered()
            self.writer._commit_cluster_meta_unbuffered(
                n_entries, n_elements, self._page_buf, unc
            )
            self._page_buf = []

    def close(self) -> None:
        self.flush_cluster()


class ParallelWriter(_WriterBase):
    """Multithreaded single-file writer (paper §5).

    Usage::

        with ParallelWriter(schema, path, options) as w:
            # per thread:
            ctx = w.create_fill_context()
            ctx.fill(...); ctx.fill_batch(...)
            ctx.close()
    """

    def __init__(self, schema: Schema, sink, options: Optional[WriteOptions] = None):
        super().__init__(schema, sink, options)
        self._contexts: List[FillContext] = []
        self._ctx_lock = threading.Lock()

    def create_fill_context(self) -> FillContext:
        ctx = FillContext(self)
        with self._ctx_lock:
            self._contexts.append(ctx)
        return ctx

    def close(self) -> None:
        if not self._closed:
            # Flush any contexts the producers did not close themselves.
            with self._ctx_lock:
                for ctx in self._contexts:
                    ctx.flush_cluster()
        super().close()


# ---------------------------------------------------------------------------
# Convenience


def write_entries(
    schema: Schema,
    sink,
    entries: Sequence[Dict],
    options: Optional[WriteOptions] = None,
) -> WriterStats:
    with SequentialWriter(schema, sink, options) as w:
        for e in entries:
            w.fill(e)
        w.flush_cluster()
        stats = w.stats
    return stats
