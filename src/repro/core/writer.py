"""Sequential and parallel RNT-J writers — the paper's contribution (§4, §5).

Protocol (paper §4):
  1. Each producer prepares its own *unit of writing* (a cluster, or a page
     in unbuffered mode) — serialization + compression run with **no
     synchronization** because sealed clusters are relocatable.
  2. A short critical section *reserves* a byte extent in the container
     and appends format metadata in commit order (sequential-equivalent).
  3. The bytes are written at the reserved offset — inside the critical
     section by default (paper §5 base implementation), or outside it with
     opt-2 (``write_outside_lock``), after optionally preallocating the
     extent with opt-1 (``fallocate``).

Modes (paper §5 / §6.1):
  * buffered   — unit of writing = cluster; compressed pages buffered in
    memory until the cluster commits.  ~1 lock acquisition per cluster.
  * unbuffered — unit of writing = page; pages stream out under a
    per-page lock; lower memory, collapses under lock contention at high
    thread counts (the paper's 300-vs-27,000 futex observation).

Throughput machinery (DESIGN.md §2):
  * ``imt_workers`` — a single writer-owned compression pool; every seal
    (sequential IMT and parallel producers alike) runs page compression
    through ``ClusterBuilder.seal(pool)``, the one shared code path.
  * ``pipelined_seal`` — double-buffered sealing: while one cluster
    compresses and commits on a background thread, the producer keeps
    filling the next builder.  The paper's opt-2 moves the *write* out of
    the critical path; this moves the entire seal phase off the producer.

I/O engine (DESIGN.md §6): every commit path funnels through one
:class:`~repro.core.ioengine.IOEngine` per writer — scatter-gather
``pwritev`` commits of un-assembled iovec plans (``scatter_commit``),
striped parallel sub-extent writes (``io_stripe_bytes``), and bounded
write-behind with producer backpressure (``io_inflight_bytes``), plus
the fsync policy knob.  Queued extents submit through an async
submission ring (``io_ring`` — io_uring on an ``AsyncFileSink`` when
liburing loads, a behavior-identical emulation elsewhere, §6.7), and a
writer-owned buffer pool (``buffer_pool_bytes``, §6.8) recycles
detached scatter buffers on write completion, so the steady-state
commit path allocates nothing.  ``close()`` drains the engine before
the footer is ever built, and engine write failures poison finalization
through the same ``_commit_error`` latch as a synchronous failed
``pwrite``.  All knobs: DESIGN.md §7.1.
"""

from __future__ import annotations

import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from . import compression as comp
from .bufpool import Recyclable, make_pool as make_buffer_pool
from .cluster import ClusterBuilder, SealedCluster
from .container import Sink, open_sink
from .ioengine import FSYNC_ON_CLOSE, RING_AUTO, IOEngine, RetryPolicy
from .metadata import (
    ANCHOR_SIZE,
    CLUSTER_ENV_SIZE,
    JREC_BUFFERED,
    ClusterMeta,
    build_anchor,
    build_cluster_envelope,
    build_footer,
    build_header,
    build_journal_body,
    build_member_sidecar,
    build_pagelist,
    encode_zonemaps,
    finish_journal_record,
    journal_record_size,
)
from .pages import DEFAULT_PAGE_SIZE, PageDesc
from .schema import ColumnBatch, Schema
from .stats import CountingLock, WriterStats

_ns = time.perf_counter_ns


@dataclass
class WriteOptions:
    """Every write-side tuning knob; the field comments below are the
    short form — DESIGN.md §7.1 is the single consolidated reference
    (defaults, composition notes, and section pointers per knob)."""

    page_size: int = DEFAULT_PAGE_SIZE       # uncompressed bytes per page
    codec: object = "zlib"                   # name or id
    level: int = -1
    cluster_bytes: int = 8 * 1024 * 1024     # uncompressed bytes per cluster
    buffered: bool = True                    # cluster-granular unit of writing
    fallocate: bool = False                  # opt-1: preallocate extents
    write_outside_lock: bool = False         # opt-2: write after the critical section
    imt_workers: int = 0                     # shared page-compression pool size
    pipelined_seal: bool = False             # double-buffered background seal+commit
    checksum: bool = True
    # -- codec engine (DESIGN.md §5) ----------------------------------------
    # pages whose preconditioned payload exceeds this are compressed as
    # independent concatenated members ("framed chunking"), concurrently
    # on the writer's pool; 0 disables framing
    codec_chunk_bytes: int = 256 * 1024
    # per-column codec overrides: column path -> codec name/id, or a
    # (codec, level) pair; wins over ColumnSpec.codec and options.codec
    column_codecs: Optional[Dict[str, object]] = None
    # adaptive policy: sample each column's first sealed pages and fall
    # back to raw storage (CODEC_NONE, as ROOT does) when the achieved
    # compressed/uncompressed ratio exceeds adaptive_threshold
    adaptive_codec: bool = False
    adaptive_sample_pages: int = 8
    adaptive_threshold: float = 0.9
    # split/delta preconditioning of pages; False stores every column's
    # elements verbatim (recorded in the header so readers decode right)
    precondition: bool = True
    # -- I/O engine (DESIGN.md §6) -------------------------------------------
    # seal clusters to a zero-copy iovec plan committed via pwritev
    # (scatter-gather) instead of assembling a blob; the assembled path
    # stays as the byte-identical reference
    scatter_commit: bool = True
    # clusters above this size split into independent parallel stripe
    # writes at computed offsets inside the reserved extent (0 = off)
    io_stripe_bytes: int = 0
    # write-behind budget: producers seal ahead while up to this many
    # bytes of committed extents drain in the background (0 = synchronous
    # commit, the paper's base protocol)
    io_inflight_bytes: int = 0
    # engine pool size; 0 = auto (4) when striping/write-behind is on
    io_workers: int = 0
    # "on_close" | "every_cluster" | int byte interval between fsyncs
    fsync_policy: object = FSYNC_ON_CLOSE
    # -- async submission + buffer pool (DESIGN.md §6.7/§6.8) ----------------
    # how queued (write-behind) extents are submitted: "auto" uses an
    # io_uring submission ring when liburing loads and the sink is an
    # AsyncFileSink, else the emulated completion-thread ring; "uring"
    # requires the real ring; "emulated" forces the emulation; "off"
    # keeps one executor job per stripe (the PR-4 path)
    io_ring: object = RING_AUTO
    # residency bound of the writer's buffer pool, recycling detached
    # scatter buffers / scratch / merge copy buffers; 0 disables pooling
    buffer_pool_bytes: int = 64 * 1024 * 1024
    # rate-aware adaptive codec: weigh each column's measured savings
    # rate (bytes removed per CPU second) against the sink's observed
    # drain bandwidth — a slow sink keeps compression a fast sink drops
    adaptive_rate_aware: bool = False
    # -- failure model (DESIGN.md §8) ----------------------------------------
    # frame every committed cluster with a self-describing envelope and
    # append a commit-journal record, so recover_container() can rebuild
    # the footer of a torn file from the data region alone; False writes
    # the exact pre-journal (v1-shaped) data region
    journal: bool = True
    # record per-page zone maps (min/max/null-count + entry ranges,
    # DESIGN.md §11) at seal time and persist them in
    # footer.extra["zonemaps"]; False writes a pre-PR-10-shaped footer
    zone_maps: bool = True
    # bounded-retry policy applied by the I/O engine to every write and
    # fsync (None = fail fast, the pre-PR-6 behavior)
    retry_policy: Optional[RetryPolicy] = None
    # -- multi-process writing (DESIGN.md §8.6) ------------------------------
    # lease heartbeat period of a participant writer; a writer silent for
    # 2x this is considered dead and may be fenced by the coordinator
    lease_interval: float = 5.0
    # how long the coordinator's footer-assembly rendezvous waits for
    # stragglers before fencing them and sealing over what is journaled
    rendezvous_timeout: float = 30.0
    # fsync the side-car reservation log on every append (crash-consistent
    # allocation); False trades durability of the log for append latency
    mpw_log_fsync: bool = True

    @property
    def codec_id(self) -> int:
        return comp.codec_id(self.codec)

    def as_dict(self) -> dict:
        return {
            "page_size": self.page_size,
            "codec": self.codec_id,
            "cluster_bytes": self.cluster_bytes,
            "buffered": self.buffered,
            "chunk_bytes": self.codec_chunk_bytes,
            "precondition": self.precondition,
            "journal": self.journal,
        }


class _WriterBase:
    """Shared container/metadata handling, compression pool + close()."""

    # multi-writer participants (repro.core.mpwrite) flip these: they skip
    # the header (the coordinator owns it), stamp journal records with
    # their fencing identity, and take commit seqs from the shared log
    _writes_header = True
    _jrec_writer_id: Optional[int] = None
    _jrec_epoch: int = 0

    def __init__(self, schema: Schema, sink, options: Optional[WriteOptions] = None):
        self.schema = schema
        self.options = options or WriteOptions()
        self.sink: Sink = (
            open_sink(sink) if isinstance(sink, (str, os.PathLike)) else sink
        )
        self.lock = CountingLock()
        self.stats = WriterStats()
        self._clusters: List[ClusterMeta] = []
        # per-cluster zone maps, parallel to _clusters (None = no stats)
        self._zonemaps: List[Optional[dict]] = []
        self._n_entries = 0
        self._closed = False
        # first seal/commit failure: once set, close() releases resources
        # but refuses to finalize — a footer must never reference a
        # cluster whose bytes did not reach the sink
        self._commit_error: Optional[BaseException] = None
        # the writer-owned compression pool: ONE pool shared by every seal
        # (sequential IMT and all parallel producers), sized independently
        # of the producer count
        self._pool = comp.make_pool(self.options.imt_workers, "rntj-compress")
        # codec-engine state shared by every builder of this writer: the
        # per-column (codec, level) resolution and the adaptive policy
        self._column_codecs = self._resolve_column_codecs()
        self._policy = (
            comp.CodecPolicy(
                schema.n_columns,
                self.options.adaptive_sample_pages,
                self.options.adaptive_threshold,
                rate_aware=self.options.adaptive_rate_aware,
            )
            if self.options.adaptive_codec
            else None
        )
        # the writer's buffer pool (DESIGN.md §6.8): detached scatter
        # buffers, seal scratch, pooled page payloads and merge copy
        # buffers all recycle through it; the I/O engine returns an
        # extent's buffers when its last write lands
        self._bufpool = make_buffer_pool(self.options.buffer_pool_bytes)
        # the writer's I/O engine: one per writer, shared by every commit
        # path (clusters, unbuffered pages, merge's raw copies).  Write
        # failures poison finalization through _commit_error; drained
        # bytes feed the rate-aware codec policy its bandwidth signal.
        self._io = IOEngine(
            self.sink,
            workers=self.options.io_workers,
            inflight_bytes=self.options.io_inflight_bytes,
            stripe_bytes=self.options.io_stripe_bytes,
            fsync_policy=self.options.fsync_policy,
            stats=self.stats,
            on_error=self._poison,
            on_drain=(
                self._policy.observe_drain if self._policy is not None else None
            ),
            ring=self.options.io_ring,
            buffer_pool=self._bufpool,
            retry=self.options.retry_policy,
        )
        # crash-consistency framing (DESIGN.md §8.3)
        self._journal = bool(self.options.journal)
        # header goes first; its location is fixed so no lock is needed yet.
        # It records the EFFECTIVE per-column encodings (a reused schema —
        # e.g. one parsed from a precondition=False file — may carry
        # non-default encodings): readers restore them verbatim, so what
        # the builders encode and what readers decode can never diverge.
        if self._writes_header:
            hdr_opts = self.options.as_dict()
            hdr_opts["encodings"] = self.column_encodings()
            hdr = build_header(schema, hdr_opts)
            off = self.sink.reserve(len(hdr))
            self._meta_pwrite(off, hdr)
            self._header_loc = (off, len(hdr))
        else:
            self._header_loc = (0, 0)

    def _meta_pwrite(self, off: int, data: bytes) -> None:
        """Direct metadata write (header/page list/footer/anchor), through
        the engine's retry chokepoint so transient storage errors don't
        fail finalization."""
        self._io._retrying(self.sink.pwrite, off, data)

    def column_encodings(self) -> List[str]:
        """The encodings this writer's pages actually use."""
        if not self.options.precondition:
            return ["none"] * self.schema.n_columns
        return [c.encoding for c in self.schema.columns]

    def _resolve_column_codecs(self):
        """Per-column (codec_id, level): ``WriteOptions.column_codecs`` >
        ``ColumnSpec.codec`` > ``WriteOptions.codec``.  ``None`` when no
        override exists (builders then track the live default)."""
        o = self.options
        overrides = o.column_codecs or {}
        unknown = [p for p in overrides if p not in self.schema.column_of_path]
        if unknown:
            raise KeyError(
                f"column_codecs names unknown column path(s): {unknown}"
            )
        if not overrides and all(c.codec is None for c in self.schema.columns):
            return None
        out = []
        for col in self.schema.columns:
            codec, level = o.codec_id, o.level
            if col.codec is not None:
                codec, level = comp.codec_id(col.codec), col.level
            ov = overrides.get(col.path)
            if ov is not None:
                if isinstance(ov, (tuple, list)):
                    codec, level = comp.codec_id(ov[0]), int(ov[1])
                else:
                    codec, level = comp.codec_id(ov), -1
            out.append((codec, level))
        return out

    def _make_builder(self) -> ClusterBuilder:
        o = self.options
        return ClusterBuilder(self.schema, o.page_size, o.codec_id, o.level,
                              o.checksum,
                              column_codecs=self._column_codecs,
                              chunk_bytes=o.codec_chunk_bytes,
                              policy=self._policy,
                              precondition=o.precondition,
                              scatter=o.scatter_commit,
                              buffer_pool=self._bufpool,
                              zone_maps=o.zone_maps)

    # -- commit protocol ----------------------------------------------------

    def _commit_seq(self) -> int:
        """Sequence number of the cluster being committed (caller holds the
        writer lock, right after the extent reserve).  Multi-writer
        participants override this to return the shared log's global seq."""
        return len(self._clusters)

    def _post_commit(self, ext: int) -> None:
        """Hook after an extent's bytes are handed to the I/O engine.
        Multi-writer participants append the COMMIT record here."""

    def _jrec_size(self, n_columns: int, n_pages: int) -> int:
        return journal_record_size(n_columns, n_pages,
                                   multi=self._jrec_writer_id is not None)

    def _finish_jrec(self, seq, flags, cluster_off, cluster_size, first_entry,
                     n_entries, n_columns, body):
        return finish_journal_record(
            seq, flags, cluster_off, cluster_size, first_entry, n_entries,
            n_columns, body, writer_id=self._jrec_writer_id,
            epoch=self._jrec_epoch,
        )

    def _commit_cluster(self, sealed: SealedCluster) -> None:
        """The paper's critical section (§4.2/§4.3), buffered mode.

        With write-behind (``io_inflight_bytes > 0``) the backpressure
        gate runs BEFORE the critical section — a producer stalled on
        storage never blocks the other producers' commits — and the
        critical section only enqueues the extent; the engine's workers
        drain it while this producer seals ahead.
        """
        opts = self.options
        t0 = _ns()
        # With the journal on, the reserved extent is
        # [envelope][payload][journal record], submitted as ONE vectored
        # engine write — no extra syscall.  The page list's byte_offset
        # still points at the payload, so footer-based readers never see
        # the framing.  The record body (element counts + page records
        # with cluster-relative offsets) serializes OUTSIDE the critical
        # section; only the fixed prefix needs the reserved offset.
        env_len = CLUSTER_ENV_SIZE if self._journal else 0
        if self._journal:
            jbody = build_journal_body(sealed.n_elements, sealed.pages)
            jlen = self._jrec_size(len(sealed.n_elements), len(sealed.pages))
        else:
            jbody, jlen = b"", 0
        total = env_len + sealed.size + jlen
        self._io.admit(total)
        io_ns = 0
        with self.lock:
            ext = self.sink.reserve(total)
            off = ext + env_len
            if opts.fallocate:
                self.sink.fallocate(ext, total)
            first_entry = self._n_entries
            self._n_entries += sealed.n_entries
            seq = self._commit_seq()
            self._clusters.append(
                ClusterMeta(
                    first_entry=first_entry,
                    n_entries=sealed.n_entries,
                    n_elements=sealed.n_elements,
                    pages=sealed.rebase(off),
                    byte_offset=off,
                    byte_size=sealed.size,
                )
            )
            self._zonemaps.append(sealed.zonemaps)
            if self._journal:
                jrec, desc_crc = self._finish_jrec(
                    seq, JREC_BUFFERED, off, sealed.size, first_entry,
                    sealed.n_entries, len(sealed.n_elements), jbody,
                )
                parts = ([build_cluster_envelope(seq, sealed.size, desc_crc)]
                         + sealed.iov_plan() + [jrec])
            else:
                parts = sealed.iov_plan()
            if not opts.write_outside_lock:
                io_ns = self._submit_or_latch(ext, parts, total, owner=sealed)
                self._post_commit(ext)
        if opts.write_outside_lock:
            # opt-2: the extent is reserved and the metadata final — the
            # actual bytes go out truly in parallel (paper §5).
            io_ns = self._submit_or_latch(ext, parts, total, owner=sealed)
            self._post_commit(ext)
        self.stats.add_sealed_cluster(sealed, commit_ns=_ns() - t0, io_ns=io_ns)

    def _poison(self, e: BaseException) -> None:
        """First seal/commit failure latches here; close() then refuses to
        finalize — a footer must never reference bytes that never landed."""
        if self._commit_error is None:
            self._commit_error = e

    def _submit_or_latch(self, off: int, parts, nbytes: int,
                         owner=None) -> int:
        """Hand an extent to the I/O engine; on failure, poison
        finalization.

        The metadata for this extent is already appended (the paper's
        commit protocol publishes it inside the critical section), so a
        failed write must prevent close() from emitting a footer that
        references bytes that never landed.  The engine's own error hook
        covers failures inside the write; this wrapper additionally
        latches anything raised before submission.  Returns the io time
        spent on this thread (0 when the engine queued the write).
        """
        try:
            return self._io.write_extent(off, parts, nbytes, owner=owner)
        except BaseException as e:
            self._poison(e)
            raise

    def _commit_page(self, payload, desc: PageDesc,
                     build_ns: int = 0) -> PageDesc:
        """Page-granular critical section (unbuffered mode).

        A pooled raw payload (a memoryview of a BufferPool array, see
        ``build_page``) rides with a ``Recyclable`` owner so the engine
        returns its buffer once the page's write lands.
        """
        owner = None
        if (
            self._bufpool is not None
            and isinstance(payload, memoryview)
            and isinstance(payload.obj, np.ndarray)
        ):
            owner = Recyclable([payload.obj])
        t0 = _ns()
        self._io.admit(len(payload))
        with self.lock:
            off = self.sink.reserve(len(payload))
            io_ns = self._submit_or_latch(off, [payload], len(payload),
                                          owner=owner)
        desc.offset = off
        self.stats.add_page(len(payload), commit_ns=_ns() - t0, io_ns=io_ns,
                            codec=desc.codec,
                            uncompressed_size=desc.uncompressed_size,
                            build_ns=build_ns)
        return desc

    def _commit_cluster_meta_unbuffered(
        self, n_entries: int, n_elements: List[int], pages: List[PageDesc],
        uncompressed: int, zonemaps: Optional[dict] = None,
    ) -> None:
        # Unbuffered clusters have no contiguous payload to frame, so the
        # journal contribution is a record alone (flags=0: absolute page
        # offsets); recovery validates the scattered pages by their CRCs.
        jlen = (self._jrec_size(len(n_elements), len(pages))
                if self._journal else 0)
        jbody = build_journal_body(n_elements, pages) if self._journal else b""
        if jlen:
            self._io.admit(jlen)
        with self.lock:
            first_entry = self._n_entries
            self._n_entries += n_entries
            self._clusters.append(
                ClusterMeta(first_entry, n_entries, n_elements, list(pages))
            )
            self._zonemaps.append(zonemaps)
            if jlen:
                jrec, _ = self._finish_jrec(
                    len(self._clusters) - 1, 0, 0, 0, first_entry, n_entries,
                    len(n_elements), jbody,
                )
                j_off = self.sink.reserve(jlen)
                self._submit_or_latch(j_off, [jrec], jlen)
        self.stats.add_cluster_meta(n_entries, uncompressed)

    def _commit_raw_cluster(
        self,
        blob,
        n_entries: int,
        n_elements: List[int],
        pages: List[PageDesc],
        base: int,
        owner=None,
        zonemaps: Optional[dict] = None,
    ) -> None:
        """Commit an already-assembled cluster payload byte-verbatim — the
        merge fast path's critical section.  ``pages`` carry offsets
        relative to ``base`` (the payload's offset in its source file);
        the output gets a fresh envelope + journal record, so merged
        files are as recoverable as directly written ones.  ``zonemaps``
        rides the source cluster's zone maps through unchanged (entry
        indices are cluster-relative, so a byte-verbatim copy keeps them
        valid)."""
        nbytes = len(blob)
        rel = [p.rebase(-base) for p in pages] if base else list(pages)
        env_len = CLUSTER_ENV_SIZE if self._journal else 0
        if self._journal:
            jbody = build_journal_body(n_elements, rel)
            jlen = self._jrec_size(len(n_elements), len(rel))
        else:
            jbody, jlen = b"", 0
        total = env_len + nbytes + jlen
        self._io.admit(total)
        with self.lock:
            ext = self.sink.reserve(total)
            off = ext + env_len
            first_entry = self._n_entries
            self._n_entries += n_entries
            seq = self._commit_seq()
            self._clusters.append(
                ClusterMeta(
                    first_entry=first_entry,
                    n_entries=n_entries,
                    n_elements=list(n_elements),
                    pages=[p.rebase(off) for p in rel],
                    byte_offset=off,
                    byte_size=nbytes,
                )
            )
            self._zonemaps.append(zonemaps)
            if self._journal:
                jrec, desc_crc = self._finish_jrec(
                    seq, JREC_BUFFERED, off, nbytes, first_entry, n_entries,
                    len(n_elements), jbody,
                )
                parts = [build_cluster_envelope(seq, nbytes, desc_crc),
                         blob, jrec]
            else:
                parts = [blob]
            self._submit_or_latch(ext, parts, total, owner=owner)
            self._post_commit(ext)
        with self.stats._mu:
            self.stats.clusters += 1
            self.stats.entries += n_entries
            self.stats.pages += len(pages)
            self.stats.compressed_bytes += nbytes

    # -- finalization ---------------------------------------------------------

    def _finalize(self) -> None:
        """Seal the container: page list + footer + anchor + final fsync.
        Runs only on a clean close (engine drained, nothing poisoned).
        Multi-writer participants override this — the coordinator owns the
        footer; a participant just makes its clusters durable and reports
        DONE to the shared log."""
        if (self._journal and self._clusters
                and self._io._fsync_interval
                and not self._io._fsync_every):
            # journal-before-footer barrier (DESIGN.md §8.3):
            # every committed cluster's envelope + journal record
            # is durable before the first finalization byte
            # exists, so a crash during finalization always
            # leaves a journal that covers all committed data.
            # Only the byte-interval policy needs it: every-cluster
            # already synced each extent, and under on_close
            # nothing is durable until the single close fsync
            # below — which then covers journal and footer alike.
            self._io.fsync()
        with self.lock:
            extra = None
            sc = build_member_sidecar(self._clusters)
            if sc is not None:
                sc_off = self.sink.reserve(len(sc))
                self._meta_pwrite(sc_off, sc)
                extra = {"members": [sc_off, len(sc)]}
            zm = encode_zonemaps(self._zonemaps)
            if zm is not None:
                extra = dict(extra or {})
                extra["zonemaps"] = zm
            pl = build_pagelist(self._clusters, self.schema.n_columns)
            pl_off = self.sink.reserve(len(pl))
            self._meta_pwrite(pl_off, pl)
            ftr = build_footer(self._n_entries, len(self._clusters),
                               (pl_off, len(pl)), extra=extra)
            f_off = self.sink.reserve(len(ftr))
            self._meta_pwrite(f_off, ftr)
            anchor = build_anchor(
                self._header_loc, (f_off, len(ftr)), self._n_entries,
                len(self._clusters),
            )
            a_off = self.sink.reserve(ANCHOR_SIZE)
            self._meta_pwrite(a_off, anchor)
        # Durability before close: fsync the sink unconditionally
        # (sinks without a backing fd make it a no-op counter
        # bump).  The seed gated this on readable() — which
        # skipped the fsync exactly for write-only sinks — and as
        # a discarded conditional expression.  Routed through the
        # engine so it is retried and a final failure poisons
        # (and is accounted) like any other I/O error.  The fsync
        # must precede the io-stats snapshot to be counted.
        self._io.fsync()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            if self._pool is not None:
                self._pool.shutdown(wait=True)
            # drain-before-footer: every queued write-behind extent must
            # land (or fail, poisoning _commit_error via the engine's
            # error hook) before any finalization byte is even built
            self._io.drain()
            if self._commit_error is None:
                self._finalize()
        finally:
            # resources are released on every path, even a poisoned one —
            # and even when one release step itself fails
            try:
                self._io.close()
            finally:
                self.stats.merge_lock(self.lock.snapshot())
                if self._bufpool is not None:
                    self.stats.merge_pool(self._bufpool.snapshot())
                # the io-stats snapshot must FOLLOW sink.close(): remote
                # sinks finalize there (multipart complete, tail part
                # uploads), and retries fired inside that window would
                # otherwise vanish from WriterStats
                try:
                    self.sink.close()
                finally:
                    self.stats.merge_io(self.sink.io.snapshot())
        if self._commit_error is not None:
            raise RuntimeError(
                "writer aborted: a cluster failed to seal or commit; the "
                "file was NOT finalized (no footer written)"
            ) from self._commit_error

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def n_entries(self) -> int:
        return self._n_entries


class _PipelinedSealer:
    """Double-buffered background seal+commit for one producer.

    ``submit(builder)`` hands the full builder to a single background
    worker (which seals through the writer's shared compression pool and
    commits) and returns a drained builder to keep filling — the spare
    from the previous round, so exactly two builders alternate and their
    ColumnBuffer storage is reused with no steady-state allocation.

    The single worker preserves per-producer commit order, so a
    one-producer pipelined file is byte-identical to a synchronous one.
    Background exceptions re-raise on the producer thread at the next
    ``submit``/``wait``.
    """

    def __init__(self, writer: "_WriterBase"):
        self._writer = writer
        self._exec = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="rntj-seal"
        )
        self._future = None
        self._spare: Optional[ClusterBuilder] = None

    def _run(self, builder: ClusterBuilder) -> ClusterBuilder:
        try:
            sealed = builder.seal(self._writer._pool)
            self._writer._commit_cluster(sealed)
        except BaseException as e:
            # the cluster's data is lost (its builder was handed off):
            # poison finalization directly, so even a caller that
            # swallows the re-raised error at the next wait() can never
            # close a footer over the missing entries
            self._writer._poison(e)
            raise
        return builder  # drained: its buffers are reusable now

    def submit(self, builder: ClusterBuilder) -> ClusterBuilder:
        self.wait()
        nxt = self._spare if self._spare is not None else self._writer._make_builder()
        self._spare = None
        self._future = self._exec.submit(self._run, builder)
        return nxt

    def wait(self) -> None:
        if self._future is not None:
            fut, self._future = self._future, None
            self._spare = fut.result()

    def close(self) -> None:
        self.wait()
        self._exec.shutdown(wait=True)


# ---------------------------------------------------------------------------
# Sequential writer (the baseline RNTuple writer + IMT page compression)


class SequentialWriter(_WriterBase):
    """Single-producer writer.

    With ``options.imt_workers > 0`` page compression of a cluster is
    distributed over the writer's shared pool — ROOT's *implicit
    multithreading* (IMT) model, which the paper shows plateaus around 4
    threads (Fig. 5) because everything else stays serial.  With
    ``options.pipelined_seal`` the whole seal+commit runs behind the
    producer (double buffering).
    """

    def __init__(self, schema: Schema, sink, options: Optional[WriteOptions] = None):
        super().__init__(schema, sink, options)
        self._builder = self._make_builder()
        self._sealer = (
            _PipelinedSealer(self)
            if self.options.pipelined_seal and self.options.buffered
            else None
        )
        self._fill_ns = 0

    def fill(self, entry: Dict) -> None:
        t0 = _ns()
        self._builder.fill(entry)
        self._fill_ns += _ns() - t0
        self._maybe_flush()

    def fill_batch(self, batch: ColumnBatch) -> None:
        t0 = _ns()
        self._builder.fill_batch(batch)
        self._fill_ns += _ns() - t0
        self._maybe_flush()

    def _maybe_flush(self) -> None:
        if self._builder.uncompressed_bytes >= self.options.cluster_bytes:
            self.flush_cluster()

    def flush_cluster(self) -> None:
        if self._builder.is_empty:
            if self._sealer is not None:
                self._sealer.wait()
            return
        if self._sealer is not None:
            self._builder = self._sealer.submit(self._builder)
        else:
            self._commit_cluster(self._builder.seal(self._pool))

    def close(self) -> None:
        if not self._closed:
            try:
                self.flush_cluster()
                if self._sealer is not None:
                    self._sealer.close()
            except BaseException as e:
                # a cluster was lost: poison finalization, surface via
                # super().close() after resources are released
                if self._commit_error is None:
                    self._commit_error = e
            finally:
                self.stats.add_fill_ns(self._fill_ns)
                self._fill_ns = 0
        super().close()


# ---------------------------------------------------------------------------
# Parallel writer (the paper's contribution)


class FillContext:
    """Per-producer context: its own cluster under construction.

    Everything up to the commit happens without synchronization; the commit
    is the short critical section described in paper §4.2/§4.3.  With
    ``pipelined_seal`` the seal+commit of a full cluster runs on a
    background thread while this producer fills the next builder.
    """

    def __init__(self, writer: "ParallelWriter"):
        self.writer = writer
        o = writer.options
        self.builder = writer._make_builder()
        self._page_buf: List = []  # unbuffered mode: descs of committed pages
        self._sealer = (
            _PipelinedSealer(writer) if o.pipelined_seal and o.buffered else None
        )
        self._fill_ns = 0
        self._ctx_closed = False

    def fill(self, entry: Dict) -> None:
        t0 = _ns()
        self.builder.fill(entry)
        self._fill_ns += _ns() - t0
        self._maybe_flush()

    def fill_batch(self, batch: ColumnBatch) -> None:
        t0 = _ns()
        self.builder.fill_batch(batch)
        self._fill_ns += _ns() - t0
        self._maybe_flush()

    def _maybe_flush(self) -> None:
        o = self.writer.options
        if not o.buffered:
            # the writer pool parallelizes chunk-framed page members; the
            # drain itself runs on this producer thread
            for payload, desc, ns in self.builder.drain_full_pages(self.writer._pool):
                self._page_buf.append(self.writer._commit_page(payload, desc, ns))
        if self.builder.uncompressed_bytes >= o.cluster_bytes:
            self.flush_cluster()

    def flush_cluster(self) -> None:
        if self.builder.is_empty:
            if self._sealer is not None:
                self._sealer.wait()
            return
        if self.writer.options.buffered:
            if self._sealer is not None:
                self.builder = self._sealer.submit(self.builder)
            else:
                self.writer._commit_cluster(self.builder.seal(self.writer._pool))
        else:
            for payload, desc, ns in self.builder.drain_rest(self.writer._pool):
                self._page_buf.append(self.writer._commit_page(payload, desc, ns))
            zm = self.builder.take_zonemaps()
            n_entries, n_elements, unc = self.builder.finish_unbuffered()
            self.writer._commit_cluster_meta_unbuffered(
                n_entries, n_elements, self._page_buf, unc, zonemaps=zm
            )
            self._page_buf = []

    def close(self) -> None:
        if self._ctx_closed:
            return
        self.flush_cluster()
        if self._sealer is not None:
            self._sealer.close()
        # only mark closed after a successful drain: a failed close stays
        # retryable and is never silently dropped by ParallelWriter.close
        self._ctx_closed = True
        self.writer.stats.add_fill_ns(self._fill_ns)
        self._fill_ns = 0


class ParallelWriter(_WriterBase):
    """Multithreaded single-file writer (paper §5).

    Usage::

        with ParallelWriter(schema, path, options) as w:
            # per thread:
            ctx = w.create_fill_context()
            ctx.fill(...); ctx.fill_batch(...)
            ctx.close()
    """

    def __init__(self, schema: Schema, sink, options: Optional[WriteOptions] = None):
        super().__init__(schema, sink, options)
        self._contexts: List[FillContext] = []
        self._ctx_lock = threading.Lock()

    def create_fill_context(self) -> FillContext:
        ctx = FillContext(self)
        with self._ctx_lock:
            self._contexts.append(ctx)
        return ctx

    def close(self) -> None:
        if not self._closed:
            # Flush (and drain the seal pipelines of) any contexts the
            # producers did not close themselves.  One failing context
            # must not stop the others from draining, nor leak the sink —
            # the first error poisons finalization instead.
            with self._ctx_lock:
                for ctx in self._contexts:
                    try:
                        ctx.close()
                    except BaseException as e:
                        if self._commit_error is None:
                            self._commit_error = e
        super().close()


# ---------------------------------------------------------------------------
# Convenience


def write_entries(
    schema: Schema,
    sink,
    entries: Sequence[Dict],
    options: Optional[WriteOptions] = None,
) -> WriterStats:
    with SequentialWriter(schema, sink, options) as w:
        for e in entries:
            w.fill(e)
        w.flush_cluster()
        stats = w.stats
    return stats
