"""Object-store sink: the container's remote transport (DESIGN.md §10).

The paper's parallel-commit protocol (seal without synchronization,
reserve an extent, positioned write) assumes a POSIX file.  This module
maps it onto S3-style object storage instead: an :class:`ObjectStoreSink`
implements the full :class:`~repro.core.container.Sink` contract on top
of an abstract :class:`Transport`, so

* cluster **extents map onto multipart part uploads** — the sink carves
  the file's offset space into fixed-size parts (part index ``k`` covers
  bytes ``[k*part_bytes, (k+1)*part_bytes)``, S3 part number ``k+1``);
  ``pwrite`` buffers into the covering parts and ships each part the
  moment its byte range is fully covered, over a bounded pool of
  parallel connections;
* coalesced reader **preads map onto ranged GETs**, optionally *hedged*:
  if the primary GET has not answered within ``hedge_ms``, a duplicate
  is raced against it and the first success wins (tail-latency cut, at
  the cost of duplicated reads — counted in ``IOStats.hedges`` /
  ``hedge_wins``).

Robustness is the headline.  Every transport operation runs under the
shared :class:`~repro.core.ioengine.Retrier` chokepoint (exponential
backoff + jitter, retryable-errno filter, optional retry-budget
deadline) with an optional **per-attempt deadline** (``deadline_ms`` —
the transport raises ``ETIMEDOUT``, which is retryable).  Part uploads
are **idempotent**: each upload is keyed by ``(part index, CRC32)``, so
a retried or re-driven upload of unchanged bytes is skipped and a
changed part is simply re-uploaded under the same part number (S3
semantics: last upload of a part number wins).  When the multipart
channel degrades — create or part upload still failing after retries —
the sink **falls back to a serial ``put_object``** of the assembled
bytes at close (counted in ``IOStats.degradations``); part buffers are
retained until close precisely so this fallback (and CRC-keyed
re-upload) is always possible.  The memory cost equals the object size,
the same deal :class:`~repro.core.container.MemorySink` makes.

Crash recovery: a writer killed mid-multipart leaves the upload's
completed parts in the store.  :func:`salvage_remote` lists the
interrupted upload, reassembles the contiguous part prefix, runs the
ordinary journal-scan recovery (:func:`~repro.core.recover.recover_container`)
over the bytes in memory, and puts the rebuilt container back as the
final object — the remote analog of salvaging a torn local file.

Everything is hermetic: :class:`FakeTransport` simulates the store
in-process over a shared :class:`ObjectBucket`, with deterministic
fault/latency injection via :class:`~repro.core.faults.FaultSchedule`
(per-op scripted rules + seeded random error rates) and the shared
:class:`~repro.core.container.LatencyModel` (RTT floor + bandwidth
ceiling).  ``open_sink("mem-s3://bucket/file.rntj?rtt_ms=100")`` routes
here; real backends register via :func:`register_transport`.
"""

from __future__ import annotations

import errno
import threading
import time
import zlib
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeout
from concurrent.futures import wait as futures_wait
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple
from urllib.parse import parse_qsl

from .container import LatencyModel, Sink
from .faults import FaultSchedule, ProcessKilled, injected_os_error
from .ioengine import Retrier, RetryPolicy


# ---------------------------------------------------------------------------
# Transport interface
# ---------------------------------------------------------------------------


class Transport:
    """S3-style object-store operations, the minimal surface the sink needs.

    Every method takes an optional ``timeout`` (seconds, per attempt):
    implementations should raise ``OSError(ETIMEDOUT)`` when the attempt
    cannot complete in time — retryable, so the :class:`Retrier` drives
    the attempt loop, not the transport.  Errors must be ``OSError``
    with a meaningful errno (``ENOENT`` for missing keys, ``EIO`` for
    backend failures): that is the vocabulary the shared retry policy
    filters on.
    """

    # -- whole objects ------------------------------------------------------

    def put_object(self, key: str, data: bytes,
                   timeout: Optional[float] = None) -> str:
        """Atomically store ``data`` under ``key``; returns an ETag."""
        raise NotImplementedError

    def object_size(self, key: str, timeout: Optional[float] = None) -> int:
        """Size of the object at ``key``; ``OSError(ENOENT)`` if absent."""
        raise NotImplementedError

    def get_range(self, key: str, offset: int, size: int,
                  timeout: Optional[float] = None) -> bytes:
        """Ranged GET: up to ``size`` bytes at ``offset``.  May return a
        *short* (torn) response under failure — callers must length-check
        and retry."""
        raise NotImplementedError

    # -- multipart uploads --------------------------------------------------

    def create_multipart(self, key: str,
                         timeout: Optional[float] = None) -> str:
        """Start a multipart upload; returns the upload id."""
        raise NotImplementedError

    def upload_part(self, key: str, upload_id: str, part_number: int,
                    data: bytes, timeout: Optional[float] = None) -> str:
        """Upload one part (1-based ``part_number``); returns its ETag.
        Re-uploading a part number replaces it (last writer wins)."""
        raise NotImplementedError

    def complete_multipart(self, key: str, upload_id: str,
                           parts: List[Tuple[int, str]],
                           timeout: Optional[float] = None) -> str:
        """Assemble ``parts`` (``(part_number, etag)``, ascending) into the
        final object; returns the object ETag and retires the upload."""
        raise NotImplementedError

    def abort_multipart(self, key: str, upload_id: str,
                        timeout: Optional[float] = None) -> None:
        """Drop an upload and its parts.  Idempotent."""
        raise NotImplementedError

    # -- recovery surface ---------------------------------------------------

    def list_uploads(self, key: str,
                     timeout: Optional[float] = None) -> List[str]:
        """Upload ids still open against ``key``, oldest first."""
        raise NotImplementedError

    def list_parts(self, key: str, upload_id: str,
                   timeout: Optional[float] = None) -> Dict[int, Tuple[int, str]]:
        """``{part_number: (size, etag)}`` for an open upload."""
        raise NotImplementedError

    def read_part(self, key: str, upload_id: str, part_number: int,
                  timeout: Optional[float] = None) -> bytes:
        """Fetch one uploaded part's bytes (salvage path)."""
        raise NotImplementedError

    def close(self) -> None:
        pass


def _etag(data: bytes) -> str:
    return format(zlib.crc32(data) & 0xFFFFFFFF, "08x")


# ---------------------------------------------------------------------------
# In-memory store + fault-injecting fake transport
# ---------------------------------------------------------------------------


class ObjectBucket:
    """The shared store behind :class:`FakeTransport` instances — the
    in-memory analog of the S3 bucket.  Several transports (several
    simulated processes: a writer that gets killed, then a recovery
    process) can point at the same bucket; a transport dying does not
    lose the bucket's state, which is exactly what makes interrupted
    multipart uploads salvageable."""

    def __init__(self, name: str = "") -> None:
        self.name = name
        self.lock = threading.Lock()
        self.objects: Dict[str, bytes] = {}
        # key -> upload_id -> {part_number: bytes}
        self.uploads: Dict[str, Dict[str, Dict[int, bytes]]] = {}
        self._next_upload = 0

    def new_upload_id(self, key: str) -> str:
        with self.lock:
            self._next_upload += 1
            uid = f"upload-{self._next_upload:04d}"
            self.uploads.setdefault(key, {})[uid] = {}
            return uid


_MEM_BUCKETS: Dict[str, ObjectBucket] = {}
_MEM_BUCKETS_LOCK = threading.Lock()


def mem_bucket(name: str) -> ObjectBucket:
    """The process-wide registry behind ``mem-s3://`` URLs: same bucket
    name → same :class:`ObjectBucket`, so a writer and a later reader (or
    recoverer) opened by URL share state like they would share a real
    bucket."""
    with _MEM_BUCKETS_LOCK:
        b = _MEM_BUCKETS.get(name)
        if b is None:
            b = _MEM_BUCKETS[name] = ObjectBucket(name)
        return b


def reset_mem_buckets() -> None:
    """Drop all registered in-memory buckets (test isolation)."""
    with _MEM_BUCKETS_LOCK:
        _MEM_BUCKETS.clear()


class FakeTransport(Transport):
    """Deterministic in-process object store over an :class:`ObjectBucket`.

    Latency: every operation pays an ``rtt_s`` floor (concurrent
    operations overlap their RTTs — that is the point of parallel
    connections) plus a bandwidth charge through the shared
    :class:`LatencyModel` window (concurrent transfers queue — a link is
    a link).  If the operation's service time exceeds the caller's
    per-attempt ``timeout``, the transport sleeps the timeout and raises
    ``ETIMEDOUT`` — retryable.

    Faults: an optional :class:`FaultSchedule` keyed by transport op
    names — ``"put"``, ``"get"``, ``"size"``, ``"create"``, ``"part"``,
    ``"complete"``, ``"abort"``, ``"list"`` — with the same rule
    vocabulary the local :class:`~repro.core.faults.FaultInjectingSink`
    uses.  ``kind="error"`` raises; ``kind="short"`` on ``"get"``
    returns a torn prefix (the sink length-checks and retries), on
    ``"part"`` stores a torn prefix *and* raises (a retry re-uploads the
    full part over it — idempotent re-upload is what makes this safe),
    on ``"put"`` fails atomically (nothing stored); ``kind="latency"``
    adds ``delay_s`` to the service time (feeding both deadline
    enforcement and hedging); ``kind="kill"`` marks the transport dead —
    every subsequent call raises :class:`ProcessKilled`, modeling the
    writing process dying with its connections.  A *fresh* transport
    over the same bucket is the recovery process's view.

    Unlike real S3 there is no minimum part size and part numbers are
    unbounded; nothing here depends on those limits.
    """

    def __init__(
        self,
        bucket: ObjectBucket,
        schedule: Optional[FaultSchedule] = None,
        rtt_s: float = 0.0,
        bw: float = 0.0,
        latency: Optional[LatencyModel] = None,
    ) -> None:
        self.bucket = bucket
        self.schedule = schedule
        self.rtt_s = rtt_s
        self.latency = latency if latency is not None else LatencyModel(bw)

    # -- fault/latency gate -------------------------------------------------

    def _serve(self, op: str, nbytes: int, offset: int = 0,
               timeout: Optional[float] = None):
        """Run the op through kill/fault/latency/deadline handling.
        Returns the matched torn-response rule (kind ``"short"``) for the
        caller to apply, or ``None``."""
        sched = self.schedule
        extra = 0.0
        rule = None
        if sched is not None:
            sched.check_dead()
            rule, _ = sched.decide(op, offset, nbytes)
            if rule is not None:
                if rule.kind == "latency":
                    sched.stats.latencies += 1
                    extra = rule.delay_s
                    rule = None
                elif rule.kind == "kill":
                    sched.note_kill(sched.persisted_bytes)
                    raise ProcessKilled(
                        f"process killed during remote {op!r}")
        done = self.latency.charge(nbytes, floor_s=self.rtt_s) + extra
        now = time.perf_counter()
        if timeout is not None and timeout > 0 and done - now > timeout:
            # the attempt would blow its deadline: burn the timeout (the
            # caller genuinely waited that long) and fail retryably
            time.sleep(timeout)
            if sched is not None:
                sched.stats.errors += 1
            raise injected_os_error(errno.ETIMEDOUT)
        self.latency.settle(done)
        if rule is not None:
            if rule.kind == "short":
                return rule
            if sched is not None:
                sched.stats.errors += 1
            raise injected_os_error(rule.err)
        return None

    # -- whole objects ------------------------------------------------------

    def put_object(self, key: str, data: bytes,
                   timeout: Optional[float] = None) -> str:
        rule = self._serve("put", len(data), timeout=timeout)
        if rule is not None:
            # a torn PUT is atomic at the store: nothing lands
            self.schedule.stats.short_writes += 1
            raise injected_os_error(rule.err)
        blob = bytes(data)
        with self.bucket.lock:
            self.bucket.objects[key] = blob
        if self.schedule is not None:
            self.schedule.advance(len(blob))
        return _etag(blob)

    def object_size(self, key: str, timeout: Optional[float] = None) -> int:
        self._serve("size", 0, timeout=timeout)
        with self.bucket.lock:
            if key not in self.bucket.objects:
                raise injected_os_error(errno.ENOENT)
            return len(self.bucket.objects[key])

    def get_range(self, key: str, offset: int, size: int,
                  timeout: Optional[float] = None) -> bytes:
        rule = self._serve("get", size, offset=offset, timeout=timeout)
        with self.bucket.lock:
            obj = self.bucket.objects.get(key)
            if obj is None:
                raise injected_os_error(errno.ENOENT)
            data = obj[offset:offset + size]
        if rule is not None:
            self.schedule.stats.short_reads += 1
            return data[: int(len(data) * rule.fraction)]
        return data

    # -- multipart ----------------------------------------------------------

    def create_multipart(self, key: str,
                         timeout: Optional[float] = None) -> str:
        self._serve("create", 0, timeout=timeout)
        return self.bucket.new_upload_id(key)

    def upload_part(self, key: str, upload_id: str, part_number: int,
                    data: bytes, timeout: Optional[float] = None) -> str:
        rule = self._serve("part", len(data), timeout=timeout)
        blob = bytes(data)
        with self.bucket.lock:
            parts = self.bucket.uploads.get(key, {}).get(upload_id)
            if parts is None:
                raise injected_os_error(errno.ENOENT)
            if rule is not None:
                # torn part upload: a prefix lands in the store, the call
                # fails — the retry re-uploads the full part over it
                parts[part_number] = blob[: int(len(blob) * rule.fraction)]
            else:
                parts[part_number] = blob
        if rule is not None:
            self.schedule.stats.short_writes += 1
            raise injected_os_error(rule.err)
        if self.schedule is not None:
            self.schedule.advance(len(blob))
        return _etag(blob)

    def complete_multipart(self, key: str, upload_id: str,
                           parts: List[Tuple[int, str]],
                           timeout: Optional[float] = None) -> str:
        self._serve("complete", 0, timeout=timeout)
        with self.bucket.lock:
            stored = self.bucket.uploads.get(key, {}).get(upload_id)
            if stored is None:
                raise injected_os_error(errno.ENOENT)
            chunks = []
            for num, etag in sorted(parts):
                blob = stored.get(num)
                if blob is None or _etag(blob) != etag:
                    raise injected_os_error(errno.EINVAL)
                chunks.append(blob)
            blob = b"".join(chunks)
            self.bucket.objects[key] = blob
            del self.bucket.uploads[key][upload_id]
        return _etag(blob)

    def abort_multipart(self, key: str, upload_id: str,
                        timeout: Optional[float] = None) -> None:
        self._serve("abort", 0, timeout=timeout)
        with self.bucket.lock:
            self.bucket.uploads.get(key, {}).pop(upload_id, None)

    # -- recovery surface ---------------------------------------------------

    def list_uploads(self, key: str,
                     timeout: Optional[float] = None) -> List[str]:
        self._serve("list", 0, timeout=timeout)
        with self.bucket.lock:
            return sorted(self.bucket.uploads.get(key, {}).keys())

    def list_parts(self, key: str, upload_id: str,
                   timeout: Optional[float] = None) -> Dict[int, Tuple[int, str]]:
        self._serve("list", 0, timeout=timeout)
        with self.bucket.lock:
            parts = self.bucket.uploads.get(key, {}).get(upload_id)
            if parts is None:
                raise injected_os_error(errno.ENOENT)
            return {n: (len(b), _etag(b)) for n, b in parts.items()}

    def read_part(self, key: str, upload_id: str, part_number: int,
                  timeout: Optional[float] = None) -> bytes:
        with self.bucket.lock:
            parts = self.bucket.uploads.get(key, {}).get(upload_id)
            blob = None if parts is None else parts.get(part_number)
        if blob is None:
            raise injected_os_error(errno.ENOENT)
        self._serve("get", len(blob), timeout=timeout)
        return blob


# ---------------------------------------------------------------------------
# Options
# ---------------------------------------------------------------------------


# per-logical-op retry budget generous enough for high-RTT transports;
# max_attempts stays the backstop against permanent failures
DEFAULT_REMOTE_RETRY = RetryPolicy(max_attempts=6, backoff_base=0.005,
                                   backoff_cap=0.5)


@dataclass(frozen=True)
class RemoteOptions:
    """Knobs for :class:`ObjectStoreSink` (DESIGN.md §7, ``remote_*``).

    part_bytes            -- fixed multipart part size; the unit extents
                             map onto (8 MiB default)
    parallel_connections  -- bounded transport connection pool; part
                             uploads and hedged GETs share it
    deadline_ms           -- per-attempt transport deadline; 0 = off.
                             A blown deadline is ``ETIMEDOUT`` (retryable)
    hedge_ms              -- hedge a ranged GET after this long with no
                             answer; 0 = off
    retry_policy          -- :class:`RetryPolicy` for every transport op;
                             None = no retries
    multipart             -- start in multipart mode (degrades to a
                             serial put automatically); False = serial
                             put at close, no parts
    """

    part_bytes: int = 8 << 20
    parallel_connections: int = 4
    deadline_ms: float = 0.0
    hedge_ms: float = 0.0
    retry_policy: Optional[RetryPolicy] = field(default=DEFAULT_REMOTE_RETRY)
    multipart: bool = True

    @property
    def timeout_s(self) -> Optional[float]:
        return self.deadline_ms / 1000.0 if self.deadline_ms > 0 else None


# ---------------------------------------------------------------------------
# The sink
# ---------------------------------------------------------------------------


def _add_interval(ivals: List[Tuple[int, int]], lo: int, hi: int) -> None:
    """Merge ``[lo, hi)`` into a sorted disjoint interval list, in place."""
    out: List[Tuple[int, int]] = []
    placed = False
    for s, e in ivals:
        if e < lo or s > hi:
            if not placed and s > hi:
                out.append((lo, hi))
                placed = True
            out.append((s, e))
        else:
            lo, hi = min(lo, s), max(hi, e)
    if not placed:
        out.append((lo, hi))
    out.sort()
    ivals[:] = out


class ObjectStoreSink(Sink):
    """A :class:`Sink` over a :class:`Transport` (module docstring has the
    full story).  Write mode (``create=True``): pwrites buffer into
    fixed-size parts, completed parts upload over the connection pool,
    ``close`` ships the tail and completes the multipart (or degrades to
    one serial put).  Read mode: preads become retried, optionally
    hedged, ranged GETs.

    Part uploads happen *synchronously inside* ``pwrite`` (the caller's
    thread blocks on its part's turn through the pool), so under the
    write-behind engine the admission budget naturally bounds remote
    inflight the same way it bounds local inflight, and upload failures
    surface on the committing thread where the engine's retry/poison
    machinery already looks for them.
    """

    def __init__(self, transport: Transport, key: str,
                 options: Optional[RemoteOptions] = None,
                 create: bool = True) -> None:
        super().__init__()
        self.transport = transport
        self.key = key
        self.options = options or RemoteOptions()
        self.writable = create
        self._timeout = self.options.timeout_s
        self._retrier = Retrier(self.options.retry_policy,
                                on_retry=self._count_retry,
                                on_giveup=self._count_giveup)
        self._pool = ThreadPoolExecutor(
            max_workers=max(1, self.options.parallel_connections),
            thread_name_prefix="remote")
        self._mu = threading.Lock()
        self._closed = False
        if create:
            self._parts: Dict[int, bytearray] = {}
            self._covered: Dict[int, List[Tuple[int, int]]] = {}
            self._uploaded: Dict[int, Tuple[str, int]] = {}  # idx -> (etag, crc)
            self._sent: set = set()      # idx currently fully shipped
            self._hw = 0                 # high-water mark of written bytes
            self._degraded = False
            self._upload_id: Optional[str] = None
            if self.options.multipart:
                try:
                    self._upload_id = self._retrier.call(
                        self.transport.create_multipart, self.key,
                        self._timeout)
                except ProcessKilled:
                    raise
                except OSError:
                    self._note_degraded()
        else:
            self._object_size = self._retrier.call(
                self.transport.object_size, self.key, self._timeout)
            self._end = self._object_size

    # -- write path ---------------------------------------------------------

    def _note_degraded(self) -> None:
        with self._mu:
            if self._degraded:
                return
            self._degraded = True
        self._count_degradation()

    def _part_buf(self, idx: int) -> bytearray:
        buf = self._parts.get(idx)
        if buf is None:
            buf = self._parts[idx] = bytearray(self.options.part_bytes)
            self._covered[idx] = []
        return buf

    def pwrite(self, offset: int, data) -> None:
        if not self.writable:
            raise injected_os_error(errno.EBADF)
        mv = memoryview(data)
        n = len(mv)
        if n == 0:
            return
        pb = self.options.part_bytes
        ready: List[int] = []
        with self._mu:
            pos = 0
            while pos < n:
                at = offset + pos
                idx, off_in = divmod(at, pb)
                take = min(n - pos, pb - off_in)
                buf = self._part_buf(idx)
                buf[off_in:off_in + take] = mv[pos:pos + take]
                _add_interval(self._covered[idx], off_in, off_in + take)
                if (self._covered[idx] == [(0, pb)]
                        and idx not in self._sent):
                    self._sent.add(idx)
                    ready.append(idx)
                pos += take
            self._hw = max(self._hw, offset + n)
        self._count_write(1, n)
        self._ship_parts(ready, pb)

    def _ship_parts(self, idxs: List[int], length: int) -> None:
        """Upload the given parts through the connection pool, blocking
        the calling thread until all land (admission budget = inflight
        bound).  The whole batch is submitted before any result is
        awaited, so one pwrite spanning several parts pays one round
        trip, not one per part.  A failure after retries degrades the
        sink instead of raising: the bytes are still in the buffer and
        the close-time serial put will carry them."""
        if not idxs or self._degraded or self._upload_id is None:
            return
        futs = [(idx, self._pool.submit(self._upload_part_idx, idx, length))
                for idx in idxs]
        killed = None
        for idx, fut in futs:
            try:
                fut.result()
            except ProcessKilled as e:
                killed = e
            except OSError:
                with self._mu:
                    self._sent.discard(idx)
                self._note_degraded()
        if killed is not None:
            raise killed

    def _upload_part_idx(self, idx: int, length: int) -> None:
        with self._mu:
            payload = bytes(self._parts[idx][:length])
        crc = zlib.crc32(payload)
        prev = self._uploaded.get(idx)
        if prev is not None and prev[1] == crc:
            return  # idempotent re-upload: same bytes already stored
        etag = self._retrier.call(
            self.transport.upload_part, self.key, self._upload_id,
            idx + 1, payload, self._timeout)
        self._uploaded[idx] = (etag, crc)

    def _read_local(self, offset: int, size: int) -> bytes:
        """Write-mode reads come from the retained part buffers (holes
        read as zeros, like a sparse file)."""
        pb = self.options.part_bytes
        out = bytearray(size)
        with self._mu:
            pos = 0
            while pos < size:
                at = offset + pos
                idx, off_in = divmod(at, pb)
                take = min(size - pos, pb - off_in)
                buf = self._parts.get(idx)
                if buf is not None:
                    out[pos:pos + take] = buf[off_in:off_in + take]
                pos += take
        return bytes(out)

    def flush(self) -> None:
        """Ship every fully-covered part that has not gone out yet (the
        write-behind engine calls this at barriers)."""
        if not self.writable or self._degraded or self._upload_id is None:
            return
        pb = self.options.part_bytes
        with self._mu:
            ready = [i for i, iv in self._covered.items()
                     if iv == [(0, pb)] and i not in self._sent]
            self._sent.update(ready)
        self._ship_parts(ready, pb)

    def fsync(self) -> None:
        if self.writable:
            self.flush()
        super().fsync()

    # -- read path ----------------------------------------------------------

    def pread(self, offset: int, size: int) -> bytes:
        if self.writable:
            data = self._read_local(offset, size)
            self._count_read(1, len(data))
            return data
        data = self._retrier.call(self._hedged_get, offset, size)
        self._count_read(1, len(data))
        return data

    def _get_once(self, offset: int, size: int) -> bytes:
        data = self.transport.get_range(self.key, offset, size,
                                        timeout=self._timeout)
        want = max(0, min(offset + size, self._object_size) - offset)
        if len(data) < want:
            # torn ranged response — retryable, a fresh GET may be whole
            raise injected_os_error(errno.EIO)
        return data

    def _hedged_get(self, offset: int, size: int) -> bytes:
        hedge_s = self.options.hedge_ms / 1000.0
        if hedge_s <= 0:
            return self._get_once(offset, size)
        primary = self._pool.submit(self._get_once, offset, size)
        try:
            return primary.result(timeout=hedge_s)
        except FutureTimeout:
            pass  # slow tail: race a duplicate against it
        self._count_hedge()
        secondary = self._pool.submit(self._get_once, offset, size)
        pending = {primary, secondary}
        last_exc: Optional[BaseException] = None
        while pending:
            done, pending = futures_wait(pending,
                                         return_when=FIRST_COMPLETED)
            for fut in done:
                exc = fut.exception()
                if exc is None:
                    if fut is secondary:
                        self._count_hedge_win()
                    return fut.result()
                last_exc = exc
        assert last_exc is not None
        raise last_exc

    # -- teardown -----------------------------------------------------------

    @property
    def size(self) -> int:
        if self.writable:
            return max(self._end, self._hw)
        return self._end

    def readable(self) -> bool:
        return True

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            if self.writable:
                self._finalize()
        except ProcessKilled:
            # the simulated process died: leave the interrupted upload in
            # the store for salvage_remote, release local resources
            pass
        finally:
            self._pool.shutdown(wait=True)
            self.transport.close()

    def _finalize(self) -> None:
        total = self.size
        pb = self.options.part_bytes
        nparts = (total + pb - 1) // pb
        if not self._degraded and self._upload_id is not None and nparts > 0:
            try:
                futs = []
                for idx in range(nparts):
                    with self._mu:
                        self._part_buf(idx)  # holes materialize as zeros
                    length = min(pb, total - idx * pb)
                    # CRC keying inside makes this idempotent: untouched
                    # already-shipped parts are skipped, dirtied ones
                    # (journal rewrites, footer over a reserved tail)
                    # re-upload under the same part number — all through
                    # the connection pool so close pays one RTT per
                    # connection, not one per part
                    futs.append(self._pool.submit(
                        self._upload_part_idx, idx, length))
                for fut in futs:
                    fut.result()
                manifest = [(i + 1, self._uploaded[i][0])
                            for i in range(nparts)]
                self._retrier.call(
                    self.transport.complete_multipart, self.key,
                    self._upload_id, manifest, self._timeout)
                return
            except ProcessKilled:
                raise
            except OSError:
                self._note_degraded()
        # serial-put fallback (or multipart was off / empty object)
        blob = self._read_local(0, total)
        self._retrier.call(self.transport.put_object, self.key, blob,
                           self._timeout)
        if self._upload_id is not None:
            try:
                self.transport.abort_multipart(self.key, self._upload_id)
            except (OSError, ProcessKilled):
                pass  # best-effort housekeeping; the object is durable


# ---------------------------------------------------------------------------
# URL routing
# ---------------------------------------------------------------------------

# scheme -> factory(bucket_name, params_dict) -> Transport
_TRANSPORTS: Dict[str, "callable"] = {}
_TRANSPORTS_LOCK = threading.Lock()


def register_transport(scheme: str, factory) -> None:
    """Register ``factory(bucket, params) -> Transport`` for a URL scheme,
    making ``open_sink("<scheme>://bucket/key")`` work.  This is the
    seam where a real S3/GCS client plugs in without this module growing
    a dependency on it."""
    with _TRANSPORTS_LOCK:
        _TRANSPORTS[scheme] = factory


def _mem_s3_factory(bucket: str, params: Dict[str, str]) -> Transport:
    sched = None
    if "error_rate" in params or "seed" in params:
        sched = FaultSchedule(
            seed=int(params.get("seed", "0")),
            error_rate=float(params.get("error_rate", "0")),
            errnos=(errno.EIO, errno.ETIMEDOUT),
            random_ops=("put", "part", "get"),
        )
    return FakeTransport(
        mem_bucket(bucket),
        schedule=sched,
        rtt_s=float(params.get("rtt_ms", "0")) / 1000.0,
        bw=float(params.get("bw_mbps", "0")) * 1e6,
    )


register_transport("mem-s3", _mem_s3_factory)

_OPTION_PARAMS = {
    "part_bytes": ("part_bytes", int),
    "remote_part_bytes": ("part_bytes", int),
    "parallel_connections": ("parallel_connections", int),
    "remote_parallel_connections": ("parallel_connections", int),
    "deadline_ms": ("deadline_ms", float),
    "remote_deadline_ms": ("deadline_ms", float),
    "hedge_ms": ("hedge_ms", float),
    "remote_hedge_ms": ("hedge_ms", float),
    "multipart": ("multipart", lambda v: v not in ("0", "false", "no")),
}


def parse_remote_url(url: str):
    """``scheme://bucket/key?knob=value`` → (scheme, bucket, key,
    options, params).  Option knobs (with or without the ``remote_``
    prefix DESIGN.md §7 uses) land in :class:`RemoteOptions`; everything
    else is passed to the transport factory (``rtt_ms``, ``bw_mbps``,
    ``error_rate``, ``seed`` for ``mem-s3``)."""
    if "://" not in url:
        raise ValueError(f"not a remote URL: {url!r}")
    scheme, rest = url.split("://", 1)
    query = ""
    if "?" in rest:
        rest, query = rest.split("?", 1)
    if "/" not in rest:
        raise ValueError(f"remote URL needs bucket/key: {url!r}")
    bucket, key = rest.split("/", 1)
    if not bucket or not key:
        raise ValueError(f"remote URL needs bucket/key: {url!r}")
    opts = RemoteOptions()
    params: Dict[str, str] = {}
    for k, v in parse_qsl(query, keep_blank_values=True):
        if k in _OPTION_PARAMS:
            name, conv = _OPTION_PARAMS[k]
            opts = replace(opts, **{name: conv(v)})
        else:
            params[k] = v
    return scheme, bucket, key, opts, params


def resolve_transport(url: str):
    """(transport, key, options) for a remote URL, via the scheme
    registry."""
    scheme, bucket, key, opts, params = parse_remote_url(url)
    with _TRANSPORTS_LOCK:
        factory = _TRANSPORTS.get(scheme)
    if factory is None:
        raise ValueError(
            f"no transport registered for scheme {scheme!r} "
            f"(register one with repro.core.remote.register_transport)")
    return factory(bucket, params), key, opts


def open_remote_sink(url: str, create: bool = True) -> ObjectStoreSink:
    """The ``open_sink`` backend for ``scheme://`` paths."""
    transport, key, opts = resolve_transport(url)
    return ObjectStoreSink(transport, key, options=opts, create=create)


# ---------------------------------------------------------------------------
# Recovery
# ---------------------------------------------------------------------------


def salvage_remote(transport: Transport, key: str, dry_run: bool = False,
                   verify_pages: bool = True, force: bool = False):
    """Salvage a remote container: the object-store analog of running
    :func:`~repro.core.recover.recover_container` on a torn local file.

    Two cases:

    * the final object exists (the writer completed or degraded-put, but
      may have died before sealing) — download it, journal-scan + rebuild
      in memory, put the repaired container back;
    * only an interrupted multipart upload exists — list its parts, take
      the contiguous prefix from part 1 (uniform part size inferred from
      part 1; stop at the first gap or short part, which marks the torn
      frontier), reassemble, journal-scan + rebuild, put the result as
      the final object and abort the dangling upload.

    Returns the :class:`~repro.core.recover.RecoveryReport`, with
    ``report.remote`` describing which case ran and what was salvaged.
    ``dry_run`` scans without writing anything back.
    """
    from .faults import memory_sink_from_bytes
    from .recover import RecoveryError, recover_container

    retrier = Retrier(DEFAULT_REMOTE_RETRY)
    remote_info: Dict[str, object] = {"key": key}
    upload_id = None
    try:
        size = retrier.call(transport.object_size, key)
        data = retrier.call(transport.get_range, key, 0, size)
        if len(data) != size:
            raise injected_os_error(errno.EIO)
        remote_info["mode"] = "object"
        remote_info["bytes"] = size
    except OSError as e:
        if e.errno != errno.ENOENT:
            raise
        uploads = retrier.call(transport.list_uploads, key)
        if not uploads:
            raise RecoveryError(
                f"nothing to salvage at {key!r}: no object, no uploads")
        upload_id = uploads[-1]  # latest attempt wins
        listed = retrier.call(transport.list_parts, key, upload_id)
        if 1 not in listed:
            raise RecoveryError(
                f"upload {upload_id!r} has no part 1; nothing contiguous")
        part_size = listed[1][0]
        chunks: List[bytes] = []
        num = 1
        while num in listed:
            blob = retrier.call(transport.read_part, key, upload_id, num)
            chunks.append(blob)
            if len(blob) < part_size:
                break  # short part = torn frontier; keep its prefix, stop
            num += 1
        data = b"".join(chunks)
        remote_info["mode"] = "multipart"
        remote_info["upload_id"] = upload_id
        remote_info["parts_salvaged"] = len(chunks)
        remote_info["bytes"] = len(data)

    ms = memory_sink_from_bytes(data, slack=1 << 16)
    report = recover_container(ms, dry_run=dry_run,
                               verify_pages=verify_pages, force=force)
    report.remote = remote_info
    if not dry_run and (report.rebuilt or remote_info["mode"] == "multipart"):
        blob = bytes(ms.buf[: ms.size])
        retrier.call(transport.put_object, key, blob)
        remote_info["rebuilt_bytes"] = len(blob)
    if upload_id is not None and not dry_run:
        try:
            transport.abort_multipart(key, upload_id)
        except OSError:
            pass
    return report


def salvage_remote_url(url: str, dry_run: bool = False,
                       verify_pages: bool = True, force: bool = False):
    """URL front door for :func:`salvage_remote` — what
    ``recover_container("mem-s3://bucket/key")`` routes to."""
    transport, key, _opts = resolve_transport(url)
    try:
        return salvage_remote(transport, key, dry_run=dry_run,
                              verify_pages=verify_pages, force=force)
    finally:
        transport.close()
