"""Pages: the unit of compression (paper §3).

Elements of a column are written consecutively into pages; a page is
preconditioned (encoding.py) and compressed (compression.py) as one block.
RNTuple targets 64 KiB of uncompressed elements per page by default
(paper §6.1) — we keep that default.
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from . import compression as comp
from .encoding import precondition, unprecondition
from .schema import ColumnSpec

DEFAULT_PAGE_SIZE = 64 * 1024


@dataclass
class PageDesc:
    """Descriptor of one page; lives in the page list (paper §3).

    ``offset`` is cluster-relative while the cluster is sealed-but-uncommitted
    (that is the relocatability property), and absolute once committed.
    """

    column: int
    n_elements: int
    offset: int
    size: int                # compressed bytes
    uncompressed_size: int
    checksum: int
    codec: int

    def rebase(self, base: int) -> "PageDesc":
        return PageDesc(
            self.column,
            self.n_elements,
            self.offset + base,
            self.size,
            self.uncompressed_size,
            self.checksum,
            self.codec,
        )


def build_page(
    col: ColumnSpec,
    elements: np.ndarray,
    codec: int,
    level: int = -1,
    checksum: bool = True,
) -> (bytes, PageDesc):
    """Precondition + compress one page of elements.

    Runs with NO synchronization — this is the paper's §4.1 observation that
    serialization and compression parallelize perfectly once the unit of
    writing is relocatable.
    """
    raw = precondition(elements, col.encoding)
    # Like ROOT, fall back to storing uncompressed when compression does
    # not shrink the page.
    payload = comp.compress(raw, codec, level)
    used_codec = codec
    if len(payload) >= len(raw):
        payload, used_codec = raw, comp.CODEC_NONE
    crc = zlib.crc32(payload) if checksum else 0
    desc = PageDesc(
        column=col.index,
        n_elements=int(len(elements)),
        offset=-1,
        size=len(payload),
        uncompressed_size=len(raw),
        checksum=crc,
        codec=used_codec,
    )
    return payload, desc


def read_page(buf: bytes, desc: PageDesc, col: ColumnSpec, verify: bool = True) -> np.ndarray:
    if verify and desc.checksum and zlib.crc32(buf) != desc.checksum:
        raise IOError(f"page checksum mismatch (column {col.path!r})")
    raw = comp.decompress(buf, desc.codec, desc.uncompressed_size)
    return unprecondition(raw, col.encoding, col.dtype, desc.n_elements)


def elements_per_page(col: ColumnSpec, page_size: int = DEFAULT_PAGE_SIZE) -> int:
    return max(1, page_size // col.itemsize)
