"""Pages: the unit of compression (paper §3).

Elements of a column are written consecutively into pages; a page is
preconditioned (encoding.py) and compressed (compression.py) as one block.
RNTuple targets 64 KiB of uncompressed elements per page by default
(paper §6.1) — we keep that default.
"""

from __future__ import annotations

import threading
import time
import zlib
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from . import compression as comp
from .encoding import (
    EncodeScratch,
    precondition_buffer,
    unprecondition,
    unprecondition_into,
)
from .schema import ColumnSpec

DEFAULT_PAGE_SIZE = 64 * 1024

# Per-thread reusable preconditioning scratch: build_page runs concurrently
# on compression-pool workers, each of which amortizes its own buffers.
_TLS = threading.local()


def _thread_scratch() -> EncodeScratch:
    scratch = getattr(_TLS, "scratch", None)
    if scratch is None:
        scratch = _TLS.scratch = EncodeScratch()
    return scratch


@dataclass
class PageDesc:
    """Descriptor of one page; lives in the page list (paper §3).

    ``offset`` is cluster-relative while the cluster is sealed-but-uncommitted
    (that is the relocatability property), and absolute once committed.

    ``members``/``member_chunk`` describe the framed-chunk layout of a
    compressed page (DESIGN.md §5.2): the compressed byte size of each
    independent member and the uncompressed bytes every full member
    decodes to.  They are NOT part of the fixed page record — they ride
    in the optional member side-car envelope (metadata.py) so the read
    engine can decompress a page's members as parallel pool jobs; files
    without the side-car (or pages without framing) decode exactly as
    before.
    """

    column: int
    n_elements: int
    offset: int
    size: int                # compressed bytes
    uncompressed_size: int
    checksum: int
    codec: int
    members: Optional[List[int]] = None  # per-member compressed sizes
    member_chunk: int = 0                # uncompressed bytes per full member

    def rebase(self, base: int) -> "PageDesc":
        return PageDesc(
            self.column,
            self.n_elements,
            self.offset + base,
            self.size,
            self.uncompressed_size,
            self.checksum,
            self.codec,
            self.members,
            self.member_chunk,
        )


def build_page(
    col: ColumnSpec,
    elements: np.ndarray,
    codec: int,
    level: int = -1,
    checksum: bool = True,
    chunk_bytes: int = 0,
    pool=None,
    buffer_pool=None,
) -> (bytes, PageDesc):
    """Precondition + compress one page of elements.

    Runs with NO synchronization — this is the paper's §4.1 observation that
    serialization and compression parallelize perfectly once the unit of
    writing is relocatable.

    With ``chunk_bytes > 0``, a page whose preconditioned payload exceeds
    it is compressed as independent concatenated members (framed
    chunking) — concurrently when ``pool`` is given — and the page
    checksum folds over the members incrementally, which equals the
    whole-payload CRC, so the on-disk format is unchanged.

    ``elements`` may be a zero-copy view into a live ColumnBuffer; the
    preconditioned bytes live in a per-thread scratch, so the returned
    payload is always independent of the caller's buffers.  With a
    ``buffer_pool``, a raw-stored payload is a memoryview of a pooled
    buffer instead of a fresh ``bytes`` copy — the unbuffered commit
    path hands it to the I/O engine, which returns the buffer to the
    pool once the page's write lands (DESIGN.md §6.8).
    """

    def materialize(raw_buf):
        if buffer_pool is None:
            return bytes(raw_buf)
        buf = buffer_pool.take(len(raw_buf))
        buf[: len(raw_buf)] = raw_buf
        return memoryview(buf)[: len(raw_buf)]

    raw = precondition_buffer(elements, col.encoding, _thread_scratch())
    uncompressed_size = len(raw)
    used_codec = codec
    members = None
    if codec == comp.CODEC_NONE:
        # materialize: raw aliases the scratch (or the caller's buffer)
        payload = materialize(raw)
        crc = zlib.crc32(payload) if checksum else 0
    else:
        # Like ROOT, fall back to storing uncompressed when compression
        # does not shrink the page.
        parts = comp.compress_parts(raw, codec, level, chunk_bytes, pool)
        size = sum(len(p) for p in parts)
        if size >= uncompressed_size:
            payload, used_codec = materialize(raw), comp.CODEC_NONE
            crc = zlib.crc32(payload) if checksum else 0
        else:
            # per-chunk CRCs fold into the page checksum incrementally
            crc = comp.crc32_parts(parts) if checksum else 0
            payload = parts[0] if len(parts) == 1 else b"".join(parts)
            if len(parts) > 1:
                members = [len(p) for p in parts]
    desc = PageDesc(
        column=col.index,
        n_elements=int(len(elements)),
        offset=-1,
        size=len(payload),
        uncompressed_size=uncompressed_size,
        checksum=crc,
        codec=used_codec,
        members=members,
        member_chunk=chunk_bytes if members else 0,
    )
    return payload, desc


def read_page(buf: bytes, desc: PageDesc, col: ColumnSpec, verify: bool = True) -> np.ndarray:
    if verify and desc.checksum and zlib.crc32(buf) != desc.checksum:
        raise IOError(f"page checksum mismatch (column {col.path!r})")
    raw = comp.decompress(buf, desc.codec, desc.uncompressed_size)
    return unprecondition(raw, col.encoding, col.dtype, desc.n_elements)


def decode_page_into(
    buf, desc: PageDesc, col: ColumnSpec, out: np.ndarray, verify: bool = True
) -> Tuple[int, int]:
    """:func:`read_page` minus its allocations — the read-engine hot path.

    ``buf`` may be a zero-copy memoryview into a coalesced read buffer;
    ``out`` is the page's slice (``len == desc.n_elements``) of a
    preallocated contiguous column array.  Runs with no synchronization
    on decode-pool workers, each reusing its per-thread scratch.  Returns
    ``(decompress_ns, decode_ns)`` for the reader's phase accounting.
    """
    if verify and desc.checksum and zlib.crc32(buf) != desc.checksum:
        raise IOError(f"page checksum mismatch (column {col.path!r})")
    t0 = time.perf_counter_ns()
    raw = comp.decompress(buf, desc.codec, desc.uncompressed_size)
    t1 = time.perf_counter_ns()
    unprecondition_into(raw, col.encoding, out, _thread_scratch())
    return t1 - t0, time.perf_counter_ns() - t1


def elements_per_page(col: ColumnSpec, page_size: int = DEFAULT_PAGE_SIZE) -> int:
    return max(1, page_size // col.itemsize)
