"""Crash recovery: rebuild a torn RNT-J file's footer from its data region.

The writer's footer is written last, so a crash mid-run leaves a file
whose anchor/footer/page list never existed — unreadable to the normal
open path even though every committed cluster's bytes are intact.  With
``WriteOptions.journal`` (v2 files, default) the data region is
self-describing (DESIGN.md §8.3): each buffered cluster extent is

    [32-byte envelope][payload][journal record]

and each unbuffered cluster appended a journal record after its pages.
:func:`scan_container` walks the region front to back, hopping by the
declared lengths and resynchronizing on known magics after corruption;
a cluster is salvaged when its journal record parses, its envelope
agrees (seq, length, descriptor CRC), and its page checksums verify.
:func:`recover_container` then appends a fresh page list + footer +
anchor covering exactly the salvaged clusters — after which the normal
reader decodes every salvaged entry byte-identically.

What is *not* recoverable: the producer's last unsealed cluster (its
entries never reached the sink), any cluster whose extent is torn, and
the framed-member side-car (it is finalization metadata; salvaged
chunk-framed pages decode through the serial whole-page path instead).
Salvage also renumbers entries contiguously when a mid-file cluster is
dropped — entry *ranges* shift, entry *bytes* do not.
"""

from __future__ import annotations

import os
import shutil
import struct
import time
import zlib
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from .container import Sink, open_sink
from .metadata import (
    ANCHOR_SIZE,
    CLUSTER_ENV_MAGIC,
    CLUSTER_ENV_SIZE,
    JOURNAL_MAGIC,
    MAGIC,
    ClusterMeta,
    _ENV_HDR,
    _ENV_MAGIC,
    _JREC_HDR,
    build_anchor,
    build_footer,
    build_pagelist,
    parse_anchor,
    parse_cluster_envelope,
    parse_footer,
    parse_header,
    parse_journal_record,
    parse_pagelist,
)

_RESYNC_CHUNK = 1 << 20
_MAX_JREC = 64 << 20  # sanity bound on a declared journal-record length


class RecoveryError(IOError):
    """The file cannot be salvaged at all (e.g. the header is torn: the
    schema needed to interpret anything else is gone)."""


@dataclass
class RecoveryReport:
    """What a scan/recovery run found and did."""

    file_size: int = 0
    version: int = 0
    footer_valid: bool = False       # the file didn't need recovery
    clusters_salvaged: int = 0
    entries_salvaged: int = 0
    clusters_dropped: List[dict] = field(default_factory=list)
    journal_records: int = 0         # valid records seen in the scan
    envelopes: int = 0               # valid cluster envelopes seen
    resyncs: int = 0                 # magic-search recoveries after corruption
    garbage_bytes: int = 0           # bytes skipped while resynchronizing
    scan_bytes: int = 0              # data-region bytes walked
    scan_seconds: float = 0.0
    rebuilt: bool = False            # a fresh footer was appended
    output: Optional[str] = None
    # multi-writer salvage (side-car reservation log present): per-writer
    # attribution, fenced/done sets, orphaned reservations (DESIGN.md §8.6)
    multiwriter: Optional[dict] = None
    # remote salvage (object-store source): which case ran — final object
    # repaired, or interrupted multipart reassembled (DESIGN.md §10)
    remote: Optional[dict] = None
    # zone-map disposition (DESIGN.md §11): the journal cannot attest
    # page statistics, so a rebuild drops them rather than serve
    # possibly-stale bounds; the reason is recorded here
    zonemaps: Optional[dict] = None

    def as_dict(self) -> dict:
        return {
            "file_size": self.file_size,
            "version": self.version,
            "footer_valid": self.footer_valid,
            "clusters_salvaged": self.clusters_salvaged,
            "entries_salvaged": self.entries_salvaged,
            "clusters_dropped": self.clusters_dropped,
            "journal_records": self.journal_records,
            "envelopes": self.envelopes,
            "resyncs": self.resyncs,
            "garbage_bytes": self.garbage_bytes,
            "scan_bytes": self.scan_bytes,
            "scan_seconds": self.scan_seconds,
            "rebuilt": self.rebuilt,
            "output": self.output,
            "multiwriter": self.multiwriter,
            "remote": self.remote,
            "zonemaps": self.zonemaps,
        }


# ---------------------------------------------------------------------------
# scanning


def _read_exact(sink: Sink, off: int, size: int) -> Optional[bytes]:
    """``size`` bytes at ``off``, or ``None`` when the file is too short
    or the read fails (a torn tail must not abort the scan)."""
    try:
        buf = sink.pread(off, size)
    except (OSError, ValueError, EOFError):
        return None
    return buf if len(buf) == size else None


_MAGICS = (CLUSTER_ENV_MAGIC, JOURNAL_MAGIC, _ENV_MAGIC, MAGIC)


def _resync(sink: Sink, pos: int, size: int, report: RecoveryReport) -> int:
    """Find the next known magic at or after ``pos``; returns its offset
    (or ``size`` when none remains).  Called only after corruption."""
    report.resyncs += 1
    start = pos
    while pos < size:
        chunk = _read_exact(sink, pos, min(_RESYNC_CHUNK + 4, size - pos))
        if chunk is None:
            pos = size
            break
        best = None
        for magic in _MAGICS:
            i = chunk.find(magic)
            if i >= 0 and (best is None or i < best):
                best = i
        if best is not None:
            pos += best
            break
        # overlap by 3 so a magic split across chunks is still found
        step = max(1, len(chunk) - 3)
        pos += step
    report.garbage_bytes += pos - start
    return min(pos, size)


def _parse_header_env(sink: Sink, report: RecoveryReport):
    hdr = _read_exact(sink, 0, _ENV_HDR.size)
    if hdr is None:
        raise RecoveryError("file too short for a header envelope")
    try:
        magic, etype, plen = _ENV_HDR.unpack(hdr)
    except struct.error as e:  # pragma: no cover - size checked above
        raise RecoveryError(str(e))
    if magic != _ENV_MAGIC:
        raise RecoveryError("no header envelope at offset 0 (bad magic)")
    total = _ENV_HDR.size + plen + 4
    buf = _read_exact(sink, 0, total)
    if buf is None:
        raise RecoveryError("header envelope torn (file shorter than header)")
    try:
        schema, options = parse_header(buf)
    except (IOError, ValueError, KeyError) as e:
        raise RecoveryError(f"header envelope corrupt: {e}")
    return schema, options, total


def _verify_cluster_pages(sink: Sink, jr, size: int,
                          verify_pages: bool) -> Optional[str]:
    """None when the cluster's bytes check out, else the drop reason."""
    if jr.buffered:
        end = jr.cluster_off + jr.cluster_size
        if end > size:
            return "payload extends past end of file"
        if not verify_pages:
            return None
        payload = _read_exact(sink, jr.cluster_off, jr.cluster_size)
        if payload is None:
            return "payload unreadable"
        for p in jr.pages:
            rel = p.offset - jr.cluster_off
            if rel < 0 or rel + p.size > len(payload):
                return "page outside payload extent"
            if p.checksum and zlib.crc32(payload[rel:rel + p.size]) != p.checksum:
                return "page checksum mismatch"
        return None
    # unbuffered: pages are scattered; validate each in place
    for p in jr.pages:
        if p.offset + p.size > size:
            return "page extends past end of file"
        if not verify_pages:
            continue
        buf = _read_exact(sink, p.offset, p.size)
        if buf is None:
            return "page unreadable"
        if p.checksum and zlib.crc32(buf) != p.checksum:
            return "page checksum mismatch"
    return None


def scan_container(
    sink: Sink, verify_pages: bool = True, xlog_state=None
) -> Tuple[object, dict, List[ClusterMeta], RecoveryReport]:
    """Scan a (possibly torn) RNT-J file's data region and return
    ``(schema, header_options, salvaged_clusters, report)``.

    The salvaged :class:`ClusterMeta` list is ordered by commit sequence
    with entry ranges renumbered contiguously — exactly what a page list
    wants.  Raises :class:`RecoveryError` only when the header itself is
    unusable; everything else degrades to dropped clusters.

    ``xlog_state`` (a replayed :class:`repro.core.extents.LogState` from
    the multi-writer side-car log) enables **fencing enforcement**: a
    journal record is additionally required to sit inside a reservation
    owned by the same ``(writer_id, epoch)`` — a stale-epoch writer's
    late writes are rejected here even if their CRCs are pristine — and
    the report gains per-writer attribution (``report.multiwriter``)."""
    t0 = time.perf_counter()
    size = sink.size
    report = RecoveryReport(file_size=size)
    schema, options, pos = _parse_header_env(sink, report)
    report.version = 2  # journal framing implies v2

    envelopes = {}   # seq -> {"payload_off", "payload_len", "desc_crc"}
    journals = {}    # seq -> JournalRecord
    while pos + 4 <= size:
        magic = _read_exact(sink, pos, 4)
        if magic is None:
            break
        if magic == CLUSTER_ENV_MAGIC:
            buf = _read_exact(sink, pos, CLUSTER_ENV_SIZE)
            env = None
            if buf is not None:
                try:
                    env = parse_cluster_envelope(buf)
                except IOError:
                    env = None
            if env is None:
                pos = _resync(sink, pos + 1, size, report)
                continue
            report.envelopes += 1
            env["payload_off"] = pos + CLUSTER_ENV_SIZE
            envelopes.setdefault(env["seq"], env)
            # hop over the payload; its tail carries the journal record
            pos += CLUSTER_ENV_SIZE + env["payload_len"]
        elif magic == JOURNAL_MAGIC:
            hdr = _read_exact(sink, pos, _JREC_HDR.size)
            jr = None
            if hdr is not None:
                _m, plen = _JREC_HDR.unpack(hdr)
                total = _JREC_HDR.size + plen + 4
                if 0 < plen <= _MAX_JREC and pos + total <= size:
                    buf = _read_exact(sink, pos, total)
                    if buf is not None:
                        try:
                            jr, _end = parse_journal_record(buf, 0)
                        except IOError:
                            jr = None
            if jr is None:
                pos = _resync(sink, pos + 1, size, report)
                continue
            report.journal_records += 1
            jr.end = pos + _JREC_HDR.size + plen + 4
            journals.setdefault(jr.seq, jr)
            pos = jr.end
        elif magic == _ENV_MAGIC:
            # a finalization envelope (page list / footer / member
            # side-car) from a previous successful close: hop over it
            hdr = _read_exact(sink, pos, _ENV_HDR.size)
            if hdr is None:
                break
            _m, _t, plen = _ENV_HDR.unpack(hdr)
            total = _ENV_HDR.size + plen + 4
            if plen > size or pos + total > size:
                pos = _resync(sink, pos + 1, size, report)
                continue
            pos += total
        elif magic == MAGIC:
            # an anchor (previous finalization); fixed size
            pos += ANCHOR_SIZE
        else:
            pos = _resync(sink, pos, size, report)
    report.scan_bytes = pos

    # -- validate: a cluster survives when journal + envelope agree ---------
    res_by_off = {}
    if xlog_state is not None:
        res_by_off = {r.offset: r for r in xlog_state.reservations.values()}
    per_writer: dict = {}
    clusters: List[ClusterMeta] = []
    for seq in sorted(journals):
        jr = journals[seq]
        reason = None
        if jr.buffered:
            env = envelopes.get(seq)
            if env is None:
                reason = "envelope missing or corrupt"
            elif (env["payload_len"] != jr.cluster_size
                  or env["desc_crc"] != jr.crc
                  or env["payload_off"] != jr.cluster_off):
                reason = "envelope/journal disagree"
        if reason is None and xlog_state is not None and jr.buffered:
            # fencing enforcement: the extent must be a reservation OWNED
            # by this exact (writer_id, epoch).  A fenced writer that
            # rejoined got a fresh epoch, so its stale process's late
            # writes — however intact — fail this check and are dropped.
            r = res_by_off.get(jr.cluster_off - CLUSTER_ENV_SIZE)
            if r is None:
                reason = "extent has no reservation in the side-car log"
            elif (r.writer_id != jr.writer_id or r.epoch != jr.epoch
                  or r.seq != jr.seq):
                reason = "journal record from a fenced epoch"
        if reason is None:
            reason = _verify_cluster_pages(sink, jr, size, verify_pages)
        if reason is not None:
            report.clusters_dropped.append({"seq": seq, "reason": reason})
            continue
        if jr.writer_id:
            pw = per_writer.setdefault(
                jr.writer_id, {"clusters": 0, "entries": 0})
            pw["clusters"] += 1
            pw["entries"] += jr.n_entries
        clusters.append(
            ClusterMeta(
                first_entry=0,  # renumbered below
                n_entries=jr.n_entries,
                n_elements=list(jr.n_elements),
                pages=list(jr.pages),
                byte_offset=jr.cluster_off if jr.buffered else 0,
                byte_size=jr.cluster_size if jr.buffered else 0,
            )
        )
    n = 0
    for cm in clusters:
        cm.first_entry = n
        n += cm.n_entries
    report.clusters_salvaged = len(clusters)
    report.entries_salvaged = n
    if xlog_state is not None:
        salvaged_offs = {cm.byte_offset - CLUSTER_ENV_SIZE
                         for cm in clusters if cm.byte_size}
        orphaned = [
            {"writer": r.writer_id, "offset": r.offset, "size": r.size,
             "committed": r.committed}
            for r in xlog_state.reservations.values()
            if r.offset not in salvaged_offs
        ]
        report.multiwriter = {
            "writers": {str(w.writer_id): dict(
                per_writer.get(w.writer_id, {"clusters": 0, "entries": 0}),
                fenced=w.fenced, done=w.done)
                for w in xlog_state.writers.values()},
            "sealed": xlog_state.sealed,
            "orphaned_reservations": orphaned,
        }
    report.scan_seconds = time.perf_counter() - t0
    return schema, options, clusters, report


# ---------------------------------------------------------------------------
# recovery


def _load_xlog_state(container_path: str):
    """``(state, stale)``: the replayed side-car reservation-log state, or
    ``(None, False)`` when absent (single-writer files) or unreadable
    (recovery must still proceed).

    The log is only trusted when its generation id matches the one in the
    container header — CREATE and the header are stamped with the same id
    by the coordinator.  A mismatch means the log belongs to a *previous*
    file at this path (a crashed or degraded run never unlinks it): its
    fencing state would drop every valid cluster of the current file, so
    it is ignored and reported as ``(None, True)`` instead."""
    from .extents import XLOG_SUFFIX, replay_log
    path = os.fspath(container_path)
    try:
        with open(path + XLOG_SUFFIX, "rb") as f:
            state = replay_log(f.read())
    except OSError:
        return None, False
    expect = None
    try:
        with open(path, "rb") as f:
            hdr = f.read(_ENV_HDR.size)
            if len(hdr) == _ENV_HDR.size:
                magic, _t, plen = _ENV_HDR.unpack(hdr)
                if magic == _ENV_MAGIC:
                    _sch, opts = parse_header(hdr + f.read(plen + 4))
                    expect = opts.get("mpw_gen")
    except (OSError, IOError, ValueError, KeyError, struct.error):
        expect = None
    if state.generation != expect:
        return None, True
    return state, False


def _footer_clusters(sink: Sink) -> Optional[int]:
    """Entry count from a valid anchor+footer chain, or ``None``."""
    try:
        size = sink.size
        if size < ANCHOR_SIZE:
            return None
        anchor = parse_anchor(sink.pread(size - ANCHOR_SIZE, ANCHOR_SIZE))
        f_off, f_size = anchor["footer"]
        footer = parse_footer(sink.pread(f_off, f_size))
        pl_off, pl_size = footer["pagelist"]
        parse_pagelist(sink.pread(pl_off, pl_size))
        return int(anchor["n_entries"])
    except (IOError, ValueError, KeyError, struct.error):
        return None


def recover_container(
    source,
    output: Optional[str] = None,
    dry_run: bool = False,
    verify_pages: bool = True,
    force: bool = False,
) -> RecoveryReport:
    """Salvage a torn RNT-J file and append a fresh footer in place (or
    into a copy at ``output``).

    ``source`` is a path or an open readable :class:`Sink`.  A file whose
    footer chain is already valid is left untouched (``footer_valid`` in
    the report) unless ``force``.  ``dry_run`` scans and reports without
    writing.  Returns the :class:`RecoveryReport`; raises
    :class:`RecoveryError` when even the header is unusable.

    When the source is a path and a multi-writer side-car reservation log
    (``<path>.mpwlog``) sits next to it — a crash before the coordinator's
    rendezvous sealed the file — its replayed state drives fencing
    enforcement and per-writer attribution (see :func:`scan_container`).
    The log must carry the container header's generation id: a stale log
    from a previous file at the same path is ignored (plain scan, no
    fencing) and flagged as ``multiwriter["stale_log_ignored"]``."""
    owns = False
    xlog_state, xlog_stale = None, False
    if isinstance(source, (str, os.PathLike)):
        path = os.fspath(source)
        if "://" in path:
            # remote container: salvage the final object or an interrupted
            # multipart upload, journal-scan in memory, put the rebuilt
            # container back (DESIGN.md §10).  The object IS the output.
            if output is not None:
                raise ValueError("output= is not supported for remote URLs")
            from .remote import salvage_remote_url  # local import: no cycle
            return salvage_remote_url(path, dry_run=dry_run,
                                      verify_pages=verify_pages, force=force)
        xlog_state, xlog_stale = _load_xlog_state(path)
        if output is not None:
            if not dry_run:
                shutil.copyfile(path, output)
                path = output
        sink = open_sink(path, create=False)
        owns = True
    else:
        if output is not None:
            raise ValueError("output= requires a path source")
        sink = source
    try:
        entries = _footer_clusters(sink)
        if entries is not None and not force:
            report = RecoveryReport(file_size=sink.size, footer_valid=True)
            report.entries_salvaged = entries
            report.output = output
            return report
        schema, _options, clusters, report = scan_container(
            sink, verify_pages=verify_pages, xlog_state=xlog_state
        )
        if xlog_stale:
            # a side-car log was present but belongs to a previous file
            # at this path: plain scan ran without fencing enforcement
            report.multiwriter = {"stale_log_ignored": True}
        report.output = output
        if dry_run:
            return report
        _rebuild_footer(sink, schema, clusters, report)
        report.rebuilt = True
        return report
    finally:
        if owns:
            sink.close()


def _rebuild_footer(sink: Sink, schema, clusters: List[ClusterMeta],
                    report: RecoveryReport) -> None:
    """Append page list + footer + anchor covering the salvaged clusters.

    The header at offset 0 is reused verbatim (it already records the
    schema and effective encodings).  The footer's ``extra`` carries the
    salvage provenance so readers/tools can tell a recovered file."""
    n_entries = report.entries_salvaged
    pl = build_pagelist(clusters, schema.n_columns)
    pl_off = sink.reserve(len(pl))
    sink.pwrite(pl_off, pl)
    # Zone maps are finalization metadata (like the framed-member
    # side-car): the journal records the scan trusts never carry them,
    # so a rebuilt footer cannot attest any bounds a previous footer
    # claimed.  Drop them — pruning degrades to a full scan, which is
    # always correct — and say why in the report.  They are recomputed
    # whenever the salvaged clusters re-encode through a merge.
    report.zonemaps = {
        "preserved": False,
        "reason": "journal records carry no page statistics; "
                  "rebuilt footer omits zone maps instead of serving "
                  "unattested bounds",
    }
    extra = {
        "recovered": {
            "clusters_salvaged": report.clusters_salvaged,
            "clusters_dropped": len(report.clusters_dropped),
            "scanned_bytes": report.scan_bytes,
            "zonemaps_dropped": True,
        }
    }
    ftr = build_footer(n_entries, len(clusters), (pl_off, len(pl)), extra=extra)
    f_off = sink.reserve(len(ftr))
    sink.pwrite(f_off, ftr)
    hdr16 = sink.pread(0, _ENV_HDR.size)
    _m, _t, hplen = _ENV_HDR.unpack(hdr16)
    anchor = build_anchor((0, _ENV_HDR.size + hplen + 4), (f_off, len(ftr)),
                          n_entries, len(clusters))
    a_off = sink.reserve(ANCHOR_SIZE)
    sink.pwrite(a_off, anchor)
    sink.fsync()
