"""repro.core — parallel writing of nested data in columnar formats.

The paper's contribution (Hahnfeld, Blomer, Kollegger 2024) as a library:
nested schemas decomposed into offset+leaf columns, pages as units of
compression, relocatable clusters as units of writing, and a multithreaded
single-file writer whose only synchronization is a short reserve+metadata
critical section.
"""

from .schema import (
    Schema,
    Field,
    Leaf,
    Collection,
    Record,
    ColumnSpec,
    ColumnBatch,
    KIND_LEAF,
    KIND_OFFSET,
    decompose_entry,
    recompose_entries,
)
from .writer import (
    WriteOptions,
    SequentialWriter,
    ParallelWriter,
    FillContext,
    write_entries,
)
from .reader import ReadOptions, RNTJReader, slice_entry_range
from .filter import (
    F,
    Expr,
    Zone,
    T_FALSE,
    T_MAYBE,
    T_TRUE,
    required_columns,
)
from .merge import BufferMerger, merge_files
from .container import (
    Sink,
    FileSink,
    AsyncFileSink,
    DevNullSink,
    LatencyModel,
    MemorySink,
    ThrottledSink,
    close_all,
    open_sink,
)
from .stats import ReaderStats, WriterStats, CountingLock
from .colbuf import ColumnBuffer
from .bufpool import BufferPool, PoolStats, Recyclable
from .ioengine import IOEngine, Retrier, RetryPolicy
from .faults import (
    FaultInjectingSink,
    FaultSchedule,
    FaultSpec,
    FaultStats,
    ProcessKilled,
)
from .remote import (
    FakeTransport,
    ObjectBucket,
    ObjectStoreSink,
    RemoteOptions,
    Transport,
    mem_bucket,
    open_remote_sink,
    register_transport,
    salvage_remote,
)
from .recover import (
    RecoveryError,
    RecoveryReport,
    recover_container,
    scan_container,
)
from .extents import ExtentLog, FencedError, StaleLogError, WriterSession
from .mpwrite import (
    MultiWriterCoordinator,
    ParticipantWriter,
    SharedExtentSink,
    join_container,
)
from . import (
    bufpool, compression, encoding, extents, faults, ioengine, metadata,
    mpwrite, pages, cluster, colbuf, recover, remote,
)

__all__ = [
    "Schema", "Field", "Leaf", "Collection", "Record", "ColumnSpec",
    "ColumnBatch", "KIND_LEAF", "KIND_OFFSET", "decompose_entry",
    "recompose_entries", "WriteOptions", "SequentialWriter", "ParallelWriter",
    "FillContext", "write_entries", "RNTJReader", "ReadOptions",
    "slice_entry_range",
    "F", "Expr", "Zone", "T_FALSE", "T_MAYBE", "T_TRUE", "required_columns",
    "BufferMerger", "merge_files", "Sink", "FileSink", "AsyncFileSink",
    "DevNullSink", "LatencyModel", "MemorySink", "ThrottledSink",
    "close_all", "open_sink",
    "WriterStats", "ReaderStats", "CountingLock", "ColumnBuffer",
    "BufferPool", "PoolStats", "Recyclable", "IOEngine", "Retrier",
    "RetryPolicy",
    "FaultInjectingSink", "FaultSchedule", "FaultSpec", "FaultStats",
    "ProcessKilled",
    "FakeTransport", "ObjectBucket", "ObjectStoreSink", "RemoteOptions",
    "Transport", "mem_bucket", "open_remote_sink", "register_transport",
    "salvage_remote",
    "RecoveryError", "RecoveryReport", "recover_container", "scan_container",
    "ExtentLog", "FencedError", "StaleLogError", "WriterSession",
    "MultiWriterCoordinator",
    "ParticipantWriter", "SharedExtentSink", "join_container",
    "bufpool", "compression", "encoding", "extents", "faults", "ioengine",
    "metadata", "mpwrite", "pages", "cluster", "colbuf", "recover", "remote",
]
