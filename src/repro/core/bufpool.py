"""Cluster-sized buffer pool: recycle the commit path's detached buffers.

The scatter-gather seal (DESIGN.md §6.1) made the assembly memcpy
disappear, but left an allocation behind: every raw-stored column's
buffer is *detached* into the sealed cluster's iovec plan, so the next
cluster pays a fresh ``np.empty`` per detached column — and with
write-behind every queued commit holds such buffers until its bytes
land.  At steady state that is a malloc/free pair per column per
cluster, exactly the allocator churn the ROOT I/O parallelism papers
identify as the wall after compression is parallel.

:class:`BufferPool` closes the loop (DESIGN.md §7 lists the knobs):

* **power-of-two size classes** — ``take(nbytes)`` rounds up to the next
  power of two and pops a buffer from that class; a miss allocates the
  class size, so every buffer ever returned fits its class exactly;
* **bounded residency** — ``put`` drops buffers once ``limit_bytes`` of
  storage is resident, so an adversarial size mix cannot hoard memory;
* **completion-driven recycling** — the I/O engine returns a sealed
  cluster's detached buffers the moment its extent's last write lands
  (never earlier: a queued write-behind commit still references them),
  and the reader recycles its decode scratch the same way.

Buffers are flat ``uint8`` numpy arrays internally; :meth:`take` hands
out the raw class-sized array and callers view/slice it as needed
(numpy views keep the base alive, and :meth:`put` walks back to the
base before filing a buffer into its class).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, replace
from typing import Dict, List, Optional

import numpy as np

# residency default: a handful of 8 MiB default clusters' worth of
# detached buffers — enough for double-buffered sealing plus a deep
# write-behind queue without hoarding
DEFAULT_LIMIT_BYTES = 64 * 1024 * 1024

_MIN_CLASS = 4096  # below this, malloc is cheaper than the pool round trip


@dataclass
class PoolStats:
    """Hit/miss accounting, merged into Writer/ReaderStats at close."""

    pool_hits: int = 0
    pool_misses: int = 0
    pool_returns: int = 0
    # returns rejected — residency bound reached, or storage the pool
    # never issued (non-power-of-two); always <= pool_returns
    pool_drops: int = 0

    def merge(self, other: "PoolStats") -> None:
        self.pool_hits += other.pool_hits
        self.pool_misses += other.pool_misses
        self.pool_returns += other.pool_returns
        self.pool_drops += other.pool_drops

    def snapshot(self) -> "PoolStats":
        return replace(self)

    @property
    def hit_rate(self) -> float:
        total = self.pool_hits + self.pool_misses
        return self.pool_hits / total if total else 0.0


def _base_array(arr: np.ndarray) -> np.ndarray:
    """Walk a view chain back to the owning ndarray."""
    while isinstance(arr.base, np.ndarray):
        arr = arr.base
    return arr


def _class_bytes(nbytes: int) -> int:
    """The power-of-two size class serving an ``nbytes`` request."""
    need = max(int(nbytes), _MIN_CLASS)
    return 1 << (need - 1).bit_length()


class BufferPool:
    """Thread-safe power-of-two recycler of flat ``uint8`` buffers.

    One pool per writer (``WriteOptions.buffer_pool_bytes``) or reader
    (``ReadOptions.buffer_pool_bytes``); producers, engine completion
    workers and decode workers all share it, so every method locks.
    """

    def __init__(self, limit_bytes: int = DEFAULT_LIMIT_BYTES):
        self.limit_bytes = int(limit_bytes)
        self._lock = threading.Lock()
        self._classes: Dict[int, List[np.ndarray]] = {}
        self._resident = 0
        self.stats = PoolStats()

    # -- introspection -------------------------------------------------------

    @property
    def resident_bytes(self) -> int:
        """Bytes currently parked in the pool (not handed out)."""
        with self._lock:
            return self._resident

    # -- take / put ----------------------------------------------------------

    def take(self, nbytes: int) -> np.ndarray:
        """A ``uint8`` buffer of at least ``nbytes`` (its size class).

        Pops from the smallest fitting class; a miss allocates the class
        size so the buffer files back into the same class on return.
        """
        cls = _class_bytes(nbytes)
        with self._lock:
            bucket = self._classes.get(cls)
            if bucket:
                self.stats.pool_hits += 1
                self._resident -= cls
                return bucket.pop()
            self.stats.pool_misses += 1
        return np.empty(cls, dtype=np.uint8)

    def take_view(self, nbytes: int) -> memoryview:
        """:meth:`take`, sliced to exactly ``nbytes`` as a memoryview
        (the base array rides along via the view, so :meth:`put` of the
        view's ``obj`` — or of any numpy view of it — recycles it)."""
        return memoryview(self.take(nbytes))[: int(nbytes)]

    def put(self, buf) -> None:
        """Return a buffer (or any view of one) to its size class.

        Accepts numpy arrays and memoryviews; walks views back to the
        owning array, rejects storage it cannot re-issue safely (foreign
        buffers, non-power-of-two sizes), and drops buffers beyond the
        residency bound.  Callers must guarantee nothing references the
        buffer anymore — the I/O engine only calls this after an
        extent's last byte has landed.
        """
        if buf is None:
            return
        if isinstance(buf, memoryview):
            buf = buf.obj
        if not isinstance(buf, np.ndarray):
            return
        arr = _base_array(buf)
        if not arr.flags.owndata or not arr.flags.c_contiguous:
            return
        nbytes = arr.nbytes
        if nbytes < _MIN_CLASS or nbytes & (nbytes - 1):
            # never pooled by take(): filing it would corrupt the class.
            # Counted as a (rejected) return so drops never exceed returns.
            with self._lock:
                self.stats.pool_returns += 1
                self.stats.pool_drops += 1
            return
        if arr.dtype != np.uint8:
            arr = arr.view(np.uint8).reshape(-1)
        elif arr.ndim != 1:
            arr = arr.reshape(-1)
        with self._lock:
            self.stats.pool_returns += 1
            if self._resident + nbytes > self.limit_bytes:
                self.stats.pool_drops += 1
                return
            self._resident += nbytes
            self._classes.setdefault(nbytes, []).append(arr)

    def put_all(self, bufs) -> None:
        for b in bufs:
            self.put(b)

    def snapshot(self) -> PoolStats:
        with self._lock:
            return self.stats.snapshot()


class Recyclable:
    """Owner handed to the I/O engine alongside an extent: ``recycle``
    carries the pooled buffers backing the extent's iovecs, returned to
    the engine's pool when the extent's last write lands (the same
    protocol ``SealedCluster.recycle`` uses)."""

    __slots__ = ("recycle",)

    def __init__(self, buffers):
        self.recycle = list(buffers)


def make_pool(limit_bytes: int) -> Optional[BufferPool]:
    """``BufferPool`` or ``None`` when pooling is disabled (0 bytes)."""
    return BufferPool(limit_bytes) if limit_bytes > 0 else None
