"""Instrumentation: lock, I/O, and per-phase time counters.

The paper diagnoses the buffered-vs-unbuffered scalability gap by counting
futex system calls under strace (§6.1: ~300 vs >27,000 at 64 threads).  On
Linux a futex syscall only happens when a lock is *contended*, so we count
both acquisitions and contended acquisitions, plus time held, and the sinks
count write syscalls and bytes.  These measurements are hardware-independent
and reproduce the paper's diagnosis exactly.

:class:`WriterStats` additionally breaks the write path into phases —
``fill`` (decompose + buffer append), ``seal`` (serialize, wall time),
``compress`` (summed per-page build time, a CPU-time view that exceeds the
seal wall time when a compression pool is active), ``commit`` (reserve +
metadata + write path) and ``io`` (time inside ``pwrite``) — so benchmarks
can attribute wins to the right layer.  All mutation goes through locked
``add_*``/``merge_*`` methods: with pipelined sealing, commits run on
background threads concurrently with producer fills.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional


def _merge_codec_stats(into: Dict[int, List[int]],
                       stats: Optional[Dict[int, List[int]]]) -> None:
    """Fold one ``{codec: [pages, bytes_in, bytes_out, ns]}`` map into
    another (the shared shape of writer and reader per-codec entries)."""
    if not stats:
        return
    for cid, vals in stats.items():
        st = into.setdefault(cid, [0, 0, 0, 0])
        for k in range(4):
            st[k] += vals[k]


def _codec_stats_dict(per_codec: Dict[int, List[int]]) -> dict:
    from . import compression as comp

    return {
        comp.codec_name(cid): {
            "pages": st[0],
            "bytes_in": st[1],
            "bytes_out": st[2],
            "ms": st[3] / 1e6,
        }
        for cid, st in sorted(per_codec.items())
    }


@dataclass
class LockStats:
    acquisitions: int = 0
    contended: int = 0
    held_ns: int = 0
    wait_ns: int = 0

    def merge(self, other: "LockStats") -> None:
        self.acquisitions += other.acquisitions
        self.contended += other.contended
        self.held_ns += other.held_ns
        self.wait_ns += other.wait_ns


class CountingLock:
    """A mutex that records acquisition counts, contention, and held time."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._meta = threading.Lock()  # guards the counters
        self.stats = LockStats()
        self._acquired_at = 0

    def acquire(self) -> None:
        t0 = time.perf_counter_ns()
        fast = self._lock.acquire(blocking=False)
        if not fast:
            self._lock.acquire()
        t1 = time.perf_counter_ns()
        with self._meta:
            self.stats.acquisitions += 1
            if not fast:
                self.stats.contended += 1
                self.stats.wait_ns += t1 - t0
        self._acquired_at = t1

    def release(self) -> None:
        held = time.perf_counter_ns() - self._acquired_at
        self._lock.release()
        with self._meta:
            self.stats.held_ns += held

    def snapshot(self) -> LockStats:
        """Consistent copy of the counters (safe to merge while live)."""
        with self._meta:
            return replace(self.stats)

    def __enter__(self) -> "CountingLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


@dataclass
class IOStats:
    write_calls: int = 0
    writev_calls: int = 0     # vectored (scatter-gather) submissions
    bytes_written: int = 0
    read_calls: int = 0
    bytes_read: int = 0
    fallocate_calls: int = 0
    fsync_calls: int = 0
    # fault handling (DESIGN.md §8.2): operations retried by the I/O
    # engine's RetryPolicy, operations that exhausted their retry budget,
    # and fsyncs that still failed after retrying
    retries: int = 0
    giveups: int = 0
    fsync_failures: int = 0
    # remote transport (DESIGN.md §10): hedged ranged reads launched, races
    # the hedge won, and multipart→serial-put degradations
    hedges: int = 0
    hedge_wins: int = 0
    degradations: int = 0

    def merge(self, other: "IOStats") -> None:
        self.write_calls += other.write_calls
        self.writev_calls += other.writev_calls
        self.bytes_written += other.bytes_written
        self.read_calls += other.read_calls
        self.bytes_read += other.bytes_read
        self.fallocate_calls += other.fallocate_calls
        self.fsync_calls += other.fsync_calls
        self.retries += other.retries
        self.giveups += other.giveups
        self.fsync_failures += other.fsync_failures
        self.hedges += other.hedges
        self.hedge_wins += other.hedge_wins
        self.degradations += other.degradations

    def snapshot(self) -> "IOStats":
        return replace(self)


@dataclass
class WriterStats:
    """Aggregated per-writer statistics, reported by the benchmarks.

    Thread-safe: concurrent producers and background seal/commit threads
    funnel updates through the locked ``add_*`` methods.
    """

    lock: LockStats = field(default_factory=LockStats)
    io: IOStats = field(default_factory=IOStats)
    uncompressed_bytes: int = 0
    compressed_bytes: int = 0
    fill_ns: int = 0         # producer time in decompose + buffer append
    seal_ns: int = 0         # wall time in serialization+compression (no lock held)
    compress_ns: int = 0     # summed per-page build time (CPU view of seal)
    commit_ns: int = 0       # time in commit path (reserve+metadata+write)
    io_ns: int = 0           # time inside pwrite/pwritev (any thread)
    # -- I/O engine (write-behind / striping, DESIGN.md §6) -----------------
    io_stall_ns: int = 0     # producer time blocked on the in-flight budget
    io_jobs: int = 0         # write jobs executed by the engine
    io_queue_peak: int = 0   # max write jobs queued/running at once
    io_inflight_peak: int = 0  # max write-behind bytes in flight at once
    # -- async submission + buffer pool (DESIGN.md §6.7/§6.8) ---------------
    io_submit_ns: int = 0    # producer time spent submitting queued extents
    # -- fault handling / degradation (DESIGN.md §8.2) -----------------------
    io_stripe_fallbacks: int = 0  # striping disabled after a stripe failure
    io_ring_fallbacks: int = 0    # native ring degraded to synchronous ops
    pool_hits: int = 0       # buffer-pool takes served from a size class
    pool_misses: int = 0     # buffer-pool takes that had to allocate
    pool_returns: int = 0    # buffers returned to the pool
    pool_drops: int = 0      # returns rejected (residency bound / foreign)
    entries: int = 0
    clusters: int = 0
    pages: int = 0
    # codec id -> [pages, bytes_in (uncompressed), bytes_out (stored),
    # compress_ns]: the per-codec attribution of the engine's work
    per_codec: Dict[int, List[int]] = field(default_factory=dict)

    def __post_init__(self) -> None:
        self._mu = threading.Lock()

    # -- race-safe mutation -------------------------------------------------

    def add_sealed_cluster(self, sealed, commit_ns: int, io_ns: int = 0) -> None:
        with self._mu:
            self.commit_ns += commit_ns
            self.io_ns += io_ns
            self.seal_ns += sealed.seal_ns
            self.compress_ns += sealed.compress_ns
            self.clusters += 1
            self.pages += len(sealed.pages)
            self.entries += sealed.n_entries
            self.uncompressed_bytes += sealed.uncompressed_bytes
            self.compressed_bytes += sealed.size
            _merge_codec_stats(self.per_codec,
                               getattr(sealed, "codec_stats", None))

    def add_page(self, compressed_size: int, commit_ns: int = 0,
                 io_ns: int = 0, codec: Optional[int] = None,
                 uncompressed_size: int = 0, build_ns: int = 0) -> None:
        with self._mu:
            self.pages += 1
            self.compressed_bytes += compressed_size
            self.commit_ns += commit_ns
            self.io_ns += io_ns
            self.compress_ns += build_ns
            if codec is not None:
                _merge_codec_stats(self.per_codec, {
                    codec: [1, uncompressed_size, compressed_size, build_ns]
                })

    def add_cluster_meta(self, n_entries: int, uncompressed_bytes: int) -> None:
        with self._mu:
            self.clusters += 1
            self.entries += n_entries
            self.uncompressed_bytes += uncompressed_bytes

    def add_fill_ns(self, ns: int) -> None:
        with self._mu:
            self.fill_ns += ns

    def add_io_ns(self, ns: int) -> None:
        """Time inside pwrite/pwritev on an engine worker (write-behind:
        the io phase no longer happens on the committing thread)."""
        with self._mu:
            self.io_ns += ns

    def add_io_stall_ns(self, ns: int) -> None:
        with self._mu:
            self.io_stall_ns += ns

    def add_io_submit_ns(self, ns: int) -> None:
        """Producer time spent handing a queued extent to the engine
        (ring append / pool dispatch) — the submission overhead the async
        engine exists to shrink."""
        with self._mu:
            self.io_submit_ns += ns

    def merge_pool(self, snapshot) -> None:
        """Fold a :class:`~repro.core.bufpool.PoolStats` snapshot in."""
        with self._mu:
            self.pool_hits += snapshot.pool_hits
            self.pool_misses += snapshot.pool_misses
            self.pool_returns += snapshot.pool_returns
            self.pool_drops += snapshot.pool_drops

    def note_stripe_fallback(self) -> None:
        with self._mu:
            self.io_stripe_fallbacks += 1

    def note_ring_fallback(self) -> None:
        with self._mu:
            self.io_ring_fallbacks += 1

    def note_io_job(self, queued: int, inflight: int) -> None:
        """One engine write job observed with ``queued`` jobs outstanding
        and ``inflight`` write-behind bytes admitted."""
        with self._mu:
            self.io_jobs += 1
            if queued > self.io_queue_peak:
                self.io_queue_peak = queued
            if inflight > self.io_inflight_peak:
                self.io_inflight_peak = inflight

    def merge_lock(self, snapshot: LockStats) -> None:
        with self._mu:
            self.lock.merge(snapshot)

    def merge_io(self, snapshot: IOStats) -> None:
        with self._mu:
            self.io.merge(snapshot)

    # -- reporting ----------------------------------------------------------

    def phases_ms(self) -> dict:
        """The per-phase time breakdown, in milliseconds."""
        return {
            "fill": self.fill_ns / 1e6,
            "seal": self.seal_ns / 1e6,
            "compress": self.compress_ns / 1e6,
            "commit": self.commit_ns / 1e6,
            "io": self.io_ns / 1e6,
        }

    def as_dict(self) -> dict:
        return {
            "entries": self.entries,
            "clusters": self.clusters,
            "pages": self.pages,
            "uncompressed_bytes": self.uncompressed_bytes,
            "compressed_bytes": self.compressed_bytes,
            "lock_acquisitions": self.lock.acquisitions,
            "lock_contended": self.lock.contended,
            "lock_held_ms": self.lock.held_ns / 1e6,
            "lock_wait_ms": self.lock.wait_ns / 1e6,
            "fill_ms": self.fill_ns / 1e6,
            "seal_ms": self.seal_ns / 1e6,
            "compress_ms": self.compress_ns / 1e6,
            "commit_ms": self.commit_ns / 1e6,
            "io_ms": self.io_ns / 1e6,
            "io_stall_ms": self.io_stall_ns / 1e6,
            "io_submit_ms": self.io_submit_ns / 1e6,
            "io_jobs": self.io_jobs,
            "io_queue_peak": self.io_queue_peak,
            "io_inflight_peak_bytes": self.io_inflight_peak,
            "pool_hits": self.pool_hits,
            "pool_misses": self.pool_misses,
            "pool_returns": self.pool_returns,
            "pool_drops": self.pool_drops,
            "phases_ms": self.phases_ms(),
            "per_codec": _codec_stats_dict(self.per_codec),
            "write_calls": self.io.write_calls,
            "writev_calls": self.io.writev_calls,
            "bytes_written": self.io.bytes_written,
            "fallocate_calls": self.io.fallocate_calls,
            "io_retries": self.io.retries,
            "io_giveups": self.io.giveups,
            "io_fsync_failures": self.io.fsync_failures,
            "io_hedges": self.io.hedges,
            "io_hedge_wins": self.io.hedge_wins,
            "io_degradations": self.io.degradations,
            "io_stripe_fallbacks": self.io_stripe_fallbacks,
            "io_ring_fallbacks": self.io_ring_fallbacks,
        }


@dataclass
class ReaderStats:
    """Aggregated per-reader statistics — the read-side mirror of
    :class:`WriterStats`.

    Phase breakdown (``phases_ms``):
      * ``io``         — time inside ``pread`` (after coalescing)
      * ``decompress`` — summed per-page entropy-decode time
      * ``decode``     — summed per-page unprecondition + offset-integration
        time (writes straight into the per-column output arrays)
      * ``wait``       — time the consumer blocked on the prefetch pipeline

    ``decompress``/``decode`` are summed per-page times: a CPU-time view
    that exceeds wall time when the decode pool is active (exactly like
    ``WriterStats.compress_ns`` on the write side).  Thread-safe: decode
    workers and the prefetch pipeline funnel updates through the locked
    ``add_*`` methods.
    """

    io: IOStats = field(default_factory=IOStats)
    clusters: int = 0
    pages: int = 0
    coalesced_reads: int = 0  # preads issued for page data after coalescing
    compressed_bytes: int = 0
    uncompressed_bytes: int = 0
    io_ns: int = 0            # time inside pread
    decompress_ns: int = 0    # summed per-page entropy decode
    decode_ns: int = 0        # summed per-page unprecondition/integration
    wait_ns: int = 0          # consumer blocked on the prefetch pipeline
    h2d_ns: int = 0           # staging upload (host->device transfer, §9)
    device_clusters: int = 0  # clusters decoded through the device chain
    pool_hits: int = 0        # reader buffer-pool takes served from a class
    pool_misses: int = 0      # reader buffer-pool takes that allocated
    pool_returns: int = 0
    pool_drops: int = 0
    # read-path retry accounting (DESIGN.md §8.2/§10): preads retried by
    # the reader's RetryPolicy and preads that exhausted their budget.
    # Sink-internal retries (the remote sink's transport loop) live in
    # ``io.retries`` instead, merged at close.
    retries: int = 0
    giveups: int = 0
    # zone-map pruning (DESIGN.md §11): clusters/pages the prune plan
    # skipped before any pread was issued for them
    clusters_pruned: int = 0
    pages_pruned: int = 0
    # codec id -> [pages, bytes_in (stored), bytes_out (decoded),
    # decompress_ns]: the read-side mirror of WriterStats.per_codec
    per_codec: Dict[int, List[int]] = field(default_factory=dict)

    def __post_init__(self) -> None:
        self._mu = threading.Lock()

    # -- race-safe mutation -------------------------------------------------

    def add_cluster_read(
        self,
        pages: int,
        reads: int,
        compressed_bytes: int,
        uncompressed_bytes: int,
        io_ns: int,
        decompress_ns: int,
        decode_ns: int,
        per_codec: Optional[Dict[int, List[int]]] = None,
        clusters: int = 1,
    ) -> None:
        with self._mu:
            self.clusters += clusters
            self.pages += pages
            self.coalesced_reads += reads
            self.compressed_bytes += compressed_bytes
            self.uncompressed_bytes += uncompressed_bytes
            self.io_ns += io_ns
            self.decompress_ns += decompress_ns
            self.decode_ns += decode_ns
            _merge_codec_stats(self.per_codec, per_codec)

    def add_wait_ns(self, ns: int) -> None:
        with self._mu:
            self.wait_ns += ns

    def add_device_cluster(self, h2d_ns: int) -> None:
        with self._mu:
            self.device_clusters += 1
            self.h2d_ns += h2d_ns

    def add_decode_ns(self, ns: int) -> None:
        with self._mu:
            self.decode_ns += ns

    def add_retry(self) -> None:
        with self._mu:
            self.retries += 1

    def add_giveup(self) -> None:
        with self._mu:
            self.giveups += 1

    def add_pruned(self, clusters: int = 0, pages: int = 0) -> None:
        with self._mu:
            self.clusters_pruned += clusters
            self.pages_pruned += pages

    def merge_io(self, snapshot: IOStats) -> None:
        with self._mu:
            self.io.merge(snapshot)

    def merge_pool(self, snapshot) -> None:
        """Fold a :class:`~repro.core.bufpool.PoolStats` snapshot in."""
        with self._mu:
            self.pool_hits += snapshot.pool_hits
            self.pool_misses += snapshot.pool_misses
            self.pool_returns += snapshot.pool_returns
            self.pool_drops += snapshot.pool_drops

    # -- reporting ----------------------------------------------------------

    def phases_ms(self) -> dict:
        return {
            "io": self.io_ns / 1e6,
            "decompress": self.decompress_ns / 1e6,
            "decode": self.decode_ns / 1e6,
            "wait": self.wait_ns / 1e6,
            "h2d": self.h2d_ns / 1e6,
        }

    def as_dict(self) -> dict:
        return {
            "clusters": self.clusters,
            "pages": self.pages,
            "coalesced_reads": self.coalesced_reads,
            "compressed_bytes": self.compressed_bytes,
            "uncompressed_bytes": self.uncompressed_bytes,
            "io_ms": self.io_ns / 1e6,
            "decompress_ms": self.decompress_ns / 1e6,
            "decode_ms": self.decode_ns / 1e6,
            "wait_ms": self.wait_ns / 1e6,
            "h2d_ms": self.h2d_ns / 1e6,
            "device_clusters": self.device_clusters,
            "pool_hits": self.pool_hits,
            "pool_misses": self.pool_misses,
            "pool_returns": self.pool_returns,
            "pool_drops": self.pool_drops,
            "phases_ms": self.phases_ms(),
            "per_codec": _codec_stats_dict(self.per_codec),
            "read_calls": self.io.read_calls,
            "bytes_read": self.io.bytes_read,
            "retries": self.retries,
            "giveups": self.giveups,
            "clusters_pruned": self.clusters_pruned,
            "pages_pruned": self.pages_pruned,
            "io_retries": self.io.retries,
            "io_giveups": self.io.giveups,
            "io_hedges": self.io.hedges,
            "io_hedge_wins": self.io.hedge_wins,
        }
