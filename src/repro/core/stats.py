"""Instrumentation: lock and I/O counters.

The paper diagnoses the buffered-vs-unbuffered scalability gap by counting
futex system calls under strace (§6.1: ~300 vs >27,000 at 64 threads).  On
Linux a futex syscall only happens when a lock is *contended*, so we count
both acquisitions and contended acquisitions, plus time held, and the sinks
count write syscalls and bytes.  These measurements are hardware-independent
and reproduce the paper's diagnosis exactly.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field


@dataclass
class LockStats:
    acquisitions: int = 0
    contended: int = 0
    held_ns: int = 0
    wait_ns: int = 0

    def merge(self, other: "LockStats") -> None:
        self.acquisitions += other.acquisitions
        self.contended += other.contended
        self.held_ns += other.held_ns
        self.wait_ns += other.wait_ns


class CountingLock:
    """A mutex that records acquisition counts, contention, and held time."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._meta = threading.Lock()  # guards the counters
        self.stats = LockStats()
        self._acquired_at = 0

    def acquire(self) -> None:
        t0 = time.perf_counter_ns()
        fast = self._lock.acquire(blocking=False)
        if not fast:
            self._lock.acquire()
        t1 = time.perf_counter_ns()
        with self._meta:
            self.stats.acquisitions += 1
            if not fast:
                self.stats.contended += 1
                self.stats.wait_ns += t1 - t0
        self._acquired_at = t1

    def release(self) -> None:
        held = time.perf_counter_ns() - self._acquired_at
        self._lock.release()
        with self._meta:
            self.stats.held_ns += held

    def __enter__(self) -> "CountingLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


@dataclass
class IOStats:
    write_calls: int = 0
    bytes_written: int = 0
    fallocate_calls: int = 0
    fsync_calls: int = 0

    def merge(self, other: "IOStats") -> None:
        self.write_calls += other.write_calls
        self.bytes_written += other.bytes_written
        self.fallocate_calls += other.fallocate_calls
        self.fsync_calls += other.fsync_calls


@dataclass
class WriterStats:
    """Aggregated per-writer statistics, reported by the benchmarks."""

    lock: LockStats = field(default_factory=LockStats)
    io: IOStats = field(default_factory=IOStats)
    uncompressed_bytes: int = 0
    compressed_bytes: int = 0
    seal_ns: int = 0         # time in serialization+compression (no lock held)
    commit_ns: int = 0       # time in commit path (lock held)
    entries: int = 0
    clusters: int = 0
    pages: int = 0

    def as_dict(self) -> dict:
        return {
            "entries": self.entries,
            "clusters": self.clusters,
            "pages": self.pages,
            "uncompressed_bytes": self.uncompressed_bytes,
            "compressed_bytes": self.compressed_bytes,
            "lock_acquisitions": self.lock.acquisitions,
            "lock_contended": self.lock.contended,
            "lock_held_ms": self.lock.held_ns / 1e6,
            "lock_wait_ms": self.lock.wait_ns / 1e6,
            "seal_ms": self.seal_ns / 1e6,
            "commit_ms": self.commit_ns / 1e6,
            "write_calls": self.io.write_calls,
            "bytes_written": self.io.bytes_written,
            "fallocate_calls": self.io.fallocate_calls,
        }
