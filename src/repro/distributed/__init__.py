"""Distribution substrate: sharding rules, collectives, pipeline parallel."""

from .sharding import (
    AxisRules, axis_rules, auto_param_sharding, current_rules, replicated,
    shard, DEFAULT_RULES,
)

__all__ = [
    "AxisRules", "axis_rules", "auto_param_sharding", "current_rules",
    "replicated", "shard", "DEFAULT_RULES",
]
