"""Collective helpers: hierarchical reductions and overlap-friendly
variants for shard_map code paths.

GSPMD inserts collectives automatically for pjit code; these explicit
helpers are used by shard_map regions (pipeline parallelism, the perf-pass
experiments) and encode the multi-pod hierarchy: reduce-scatter inside the
pod (cheap ICI), all-reduce across pods only on the already-reduced
shard (the pod axis carries 1/16th of the bytes).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax


def hierarchical_psum(x: jax.Array, pod_axis: str = "pod",
                      data_axis: str = "data") -> jax.Array:
    """psum over (pod, data) as scatter(data) -> psum(pod) -> gather(data).

    Equivalent to lax.psum(x, (pod_axis, data_axis)) but the cross-pod hop
    moves 1/|data| of the bytes: the standard hierarchical trick for
    gradient reduction at multi-pod scale.
    """
    n_data = lax.axis_size(data_axis)
    scat = lax.psum_scatter(x, data_axis, scatter_dimension=0, tiled=True)
    red = lax.psum(scat, pod_axis)
    return lax.all_gather(red, data_axis, axis=0, tiled=True)


def reduce_scatter_grads(tree, axis: str):
    """ZeRO-style: every host ends with its shard of the summed gradient."""
    return jax.tree_util.tree_map(
        lambda g: lax.psum_scatter(g, axis, scatter_dimension=0, tiled=True)
        if g.ndim and g.shape[0] % lax.axis_size(axis) == 0
        else lax.psum(g, axis),
        tree,
    )


def ring_permute(x: jax.Array, axis: str, shift: int = 1) -> jax.Array:
    n = lax.axis_size(axis)
    perm = [(i, (i + shift) % n) for i in range(n)]
    return lax.ppermute(x, axis, perm)
