"""Pipeline parallelism: GPipe-style microbatching over a mesh axis.

``pipelined`` runs a homogeneous layer stack as P pipeline stages over the
``stage`` mesh axis inside one shard_map: every stage holds n_layers/P
layers; microbatches stream through with ``ppermute`` boundary transfers.
The classic rotation trick runs stages for (M + P - 1) ticks, each device
computing on the microbatch currently resident — bubble fraction
(P-1)/(M+P-1).

The production configs default to FSDP+TP (every assigned model fits), but
this module is wired into the step builders via ``pp_stages`` and carries
the multi-pod story where a model would NOT fit one pod's HBM: stage the
layer stack across pods ("pod" becomes the stage axis) so each pod holds
1/P of the parameters, trading bubble for memory.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def pipelined(
    layer_fn: Callable,       # (layer_params, x) -> x
    mesh: Mesh,
    stage_axis: str,
    n_microbatches: int,
):
    """Build a pipelined stack applier.

    Returns ``apply(stacked_params, x)`` where ``stacked_params`` leaves
    have leading dim n_layers (n_layers % n_stages == 0) and ``x`` is
    (batch, ...) with batch % n_microbatches == 0.
    """
    n_stages = mesh.shape[stage_axis]

    def stage_body(params_stage, x_stage):
        """Runs inside shard_map: params_stage has this stage's layers."""
        my_stage = lax.axis_index(stage_axis)
        m = n_microbatches
        mb = x_stage.reshape((m, x_stage.shape[0] // m) + x_stage.shape[1:])
        n_ticks = m + n_stages - 1
        outputs = jnp.zeros_like(mb)

        def run_layers(x):
            def body(x, lp):
                return layer_fn(lp, x), None
            x, _ = lax.scan(body, x, params_stage)
            return x

        def tick(carry, t):
            buf, outputs = carry
            # which microbatch is entering stage 0 this tick
            feed = jnp.where(t < m, t, 0)
            x_in = jnp.where(my_stage == 0,
                             mb[feed],
                             buf)
            active = (t - my_stage >= 0) & (t - my_stage < m)
            y = run_layers(x_in)
            y = jnp.where(active, y, x_in)
            # pass to next stage; last stage's output wraps to 0 (ignored)
            nxt = lax.ppermute(
                y, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            out_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
            write = (my_stage == n_stages - 1) & (t >= n_stages - 1)
            outputs = lax.cond(
                write,
                lambda o: o.at[out_idx].set(y),
                lambda o: o,
                outputs,
            )
            return (nxt, outputs), None

        buf0 = jnp.zeros_like(mb[0])
        (_, outputs), _ = lax.scan(tick, (buf0, outputs),
                                   jnp.arange(n_ticks))
        # stack per-stage results; only the last stage's slot is real
        return outputs.reshape(x_stage.shape)[None]

    def apply(stacked_params, x):
        param_specs = jax.tree_util.tree_map(
            lambda _: P(stage_axis), stacked_params)
        fn = shard_map(
            stage_body, mesh=mesh,
            in_specs=(param_specs, P()),
            out_specs=P(stage_axis),
            check_rep=False,
        )
        per_stage = fn(stacked_params, x)   # (n_stages, batch, ...)
        return per_stage[-1]

    return apply
