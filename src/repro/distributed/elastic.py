"""Elastic scaling: restart a run on a different mesh shape.

The pieces that make this work are deliberately boring:
  * checkpoints are mesh-agnostic (single self-describing file; clusters
    re-partition freely — tests/test_checkpoint.py::test_elastic_restart...),
  * the loader cursor is logical (entry index), not host-indexed,
  * param shardings are derived from (shape, mesh) at load time by
    ``auto_param_sharding``, never stored.

``replan`` computes the new mesh + shardings after a resize and reports
what changes (per-device memory, dp degree); the train launcher calls it
on restart.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from .sharding import auto_param_sharding


@dataclass
class ElasticPlan:
    mesh: object
    param_shardings: object
    dp_degree: int
    per_device_param_bytes: int

    def describe(self) -> str:
        return (f"mesh={dict(self.mesh.shape)} dp={self.dp_degree} "
                f"params/device={self.per_device_param_bytes/2**20:.1f} MiB")


def replan(param_shapes, mesh) -> ElasticPlan:
    shardings = auto_param_sharding(param_shapes, mesh)
    total = sum(
        int(np.prod(l.shape)) * l.dtype.itemsize
        for l in jax.tree_util.tree_leaves(param_shapes)
    )
    n_dev = int(np.prod(list(mesh.shape.values())))
    dp = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                      if a in mesh.axis_names]))
    return ElasticPlan(mesh, shardings, dp, total // max(n_dev, 1))


def validate_batch_divisibility(global_batch: int, plan: ElasticPlan) -> Tuple[bool, str]:
    if global_batch % plan.dp_degree:
        return False, (f"global_batch {global_batch} not divisible by new "
                       f"dp degree {plan.dp_degree}; adjust accumulation")
    return True, ""
