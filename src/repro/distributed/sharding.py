"""Logical-axis sharding rules (MaxText-style) + FSDP auto param sharding.

Models annotate activations with *logical* axes via :func:`shard`:

    x = shard(x, "dp", "sp", None)        # (batch, seq, d_model)

A :class:`AxisRules` context maps logical names to mesh axes.  The mapping
is *divisibility-checked per tensor*: if a dimension is not divisible by
the mapped mesh-axis size the constraint silently degrades to replication
for that dim.  That single rule makes every assigned architecture compile
on the production mesh (e.g. gemma's 8 heads or smollm's 15 heads cannot
shard over model=16 and fall back to replicated attention, while their
MLP/vocab dims still shard).

Parameters are sharded by :func:`auto_param_sharding` — ZeRO-3/FSDP style:
for each >=2-D weight, the largest dim shards over the fsdp axes and the
next largest over the tensor-parallel axis, both divisibility-guarded.
Stacked scan-over-layers params skip their leading layer dim.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

AxisName = Union[str, None, Tuple[str, ...]]

# default logical -> mesh mapping for the production mesh
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "dp": ("pod", "data"),      # batch
    "fsdp": ("pod", "data"),    # parameter sharding
    "sp": ("model",),           # sequence (activations between blocks)
    "tp": ("model",),           # heads / d_ff / vocab / experts
    "tp_kv": ("model",),        # kv heads (falls back per-tensor)
    "sp_kv": ("model",),        # kv-cache sequence: shards iff tp_kv fell back
    "ep": ("model",),           # experts
    "ep2": ("model",),          # MoE capacity dim: shards iff ep fell back
    "sp_attn": ("model",),      # attention q-sequence: iff heads fell back
}


class AxisRules:
    def __init__(self, mesh: Mesh, mapping: Optional[Dict[str, Tuple[str, ...]]] = None):
        self.mesh = mesh
        mapping = dict(mapping or DEFAULT_RULES)
        # drop mesh axes that don't exist (e.g. "pod" on the single-pod mesh)
        self.mapping = {
            k: tuple(a for a in v if a in mesh.axis_names)
            for k, v in mapping.items()
        }

    def axis_size(self, logical: str) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.mapping.get(logical, ())] or [1]))

    def spec(self, logical_axes: Sequence[AxisName], shape: Sequence[int]) -> P:
        parts = []
        used: set = set()
        for dim, name in zip(shape, logical_axes):
            if name is None:
                parts.append(None)
                continue
            names = (name,) if isinstance(name, str) else name
            mesh_axes: Tuple[str, ...] = ()
            for n in names:
                mesh_axes += self.mapping.get(n, ())
            mesh_axes = tuple(a for a in mesh_axes if a not in used)
            size = int(np.prod([self.mesh.shape[a] for a in mesh_axes] or [1]))
            if size > 1 and dim % size == 0:
                parts.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
                used.update(mesh_axes)
            else:
                parts.append(None)  # divisibility fallback -> replicate
        return P(*parts)


def current_rules() -> Optional[AxisRules]:
    return getattr(_state, "rules", None)


@contextmanager
def axis_rules(rules: Optional[AxisRules]):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def shard(x: jax.Array, *logical_axes: AxisName) -> jax.Array:
    """Apply a logical sharding constraint (no-op outside an AxisRules ctx).

    If every requested axis degrades to None (divisibility fallback), the
    constraint is SKIPPED entirely: ``with_sharding_constraint(x, P())``
    would *force* replication, actively pessimizing GSPMD's own choice —
    leave the tensor unconstrained instead.
    """
    rules = current_rules()
    if rules is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(
            f"shard(): {len(logical_axes)} axes for rank-{x.ndim} array"
        )
    spec = rules.spec(logical_axes, x.shape)
    if all(p is None for p in spec):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec)
    )


# ---------------------------------------------------------------------------
# FSDP auto sharding of parameter pytrees


def _leaf_spec(
    shape: Tuple[int, ...],
    mesh: Mesh,
    fsdp_axes: Tuple[str, ...],
    tp_axis: Optional[str],
    stacked: bool,
) -> P:
    parts: list = [None] * len(shape)
    dims = list(range(len(shape)))
    if stacked and len(shape) >= 3:
        dims = dims[1:]  # never shard the scan/layer dim
    if not dims or len(shape) < 2:
        return P(*parts)
    fsdp_size = int(np.prod([mesh.shape[a] for a in fsdp_axes] or [1]))
    tp_size = int(mesh.shape[tp_axis]) if tp_axis and tp_axis in mesh.axis_names else 1
    order = sorted(dims, key=lambda d: -shape[d])
    # largest shardable dim -> fsdp
    fsdp_dim = next((d for d in order if fsdp_size > 1 and shape[d] % fsdp_size == 0), None)
    if fsdp_dim is not None:
        parts[fsdp_dim] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
    # next largest shardable dim -> tp
    tp_dim = next(
        (d for d in order
         if d != fsdp_dim and tp_size > 1 and shape[d] % tp_size == 0),
        None,
    )
    if tp_dim is not None:
        parts[tp_dim] = tp_axis
    return P(*parts)


def auto_param_sharding(
    params_shapes,
    mesh: Mesh,
    fsdp_axes: Optional[Tuple[str, ...]] = None,
    tp_axis: str = "model",
):
    """NamedSharding pytree for a parameter pytree (of ShapeDtypeStructs)."""
    if fsdp_axes is None:
        fsdp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def one(path, leaf):
        stacked = any(
            getattr(k, "key", None) in ("layers", "groups")
            for k in path
        )
        spec = _leaf_spec(tuple(leaf.shape), mesh, fsdp_axes, tp_axis, stacked)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def replicated(tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree
    )
