"""jax-free half of the checkpoint layer: schema, batch packing, and the
multiprocessing save worker.

This module deliberately imports only ``repro.core`` + numpy so that a
``multiprocessing`` *spawn* child running :func:`run_save_worker` never
pays the jax import (seconds per process) — the parent pickles the shard
payloads, the child only needs the container writer.  ``checkpoint.py``
re-exports everything here, so the public surface is unchanged.
"""

from __future__ import annotations

import os
from typing import Dict, List, Optional

import numpy as np

from repro.core import Collection, ColumnBatch, Leaf, Schema, WriteOptions
from repro.core.mpwrite import join_container

CKPT_SCHEMA = Schema([
    Leaf("param_id", "int32"),
    Leaf("shard_index", "int32"),
    Collection("shape", Leaf("_0", "int64")),
    Leaf("row_start", "int64"),
    Leaf("row_end", "int64"),
    Collection("data", Leaf("_0", "uint8")),
])


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:  # bfloat16 etc. live in ml_dtypes
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _entry_batch(entries: List[Dict]) -> ColumnBatch:
    n = len(entries)
    by_path = {
        "param_id": np.array([e["param_id"] for e in entries], np.int32),
        "shard_index": np.array([e["shard_index"] for e in entries], np.int32),
        "shape": np.array([len(e["shape"]) for e in entries], np.int64),
        "shape._0": np.concatenate(
            [np.asarray(e["shape"], np.int64) for e in entries]
        ) if entries else np.empty(0, np.int64),
        "row_start": np.array([e["row_start"] for e in entries], np.int64),
        "row_end": np.array([e["row_end"] for e in entries], np.int64),
        "data": np.array([len(e["data"]) for e in entries], np.int64),
        "data._0": np.concatenate(
            [np.frombuffer(e["data"], np.uint8) for e in entries]
        ) if entries else np.empty(0, np.uint8),
    }
    return ColumnBatch.from_arrays(CKPT_SCHEMA, n, by_path)


def run_save_worker(
    path: str,
    shards: List[Dict],
    flush_bytes: int,
    options: Optional[WriteOptions] = None,
    crash_after_units: Optional[int] = None,
) -> None:
    """Process entry point: join the shared container and write ``shards``.

    Each shard dict is one checkpoint entry (param_id, shard_index, shape,
    row_start, row_end, data-bytes).  Entries accumulate into batches of
    roughly ``flush_bytes`` before handing off to the fill context, which
    clusters them by ``options.cluster_bytes`` as usual.

    ``crash_after_units`` is the chaos hook: after that many entries the
    worker force-flushes whatever it has and ``os._exit``\\ s without DONE
    or close — from the coordinator's side this is indistinguishable from
    SIGKILL, and everything journaled up to the crash must be salvaged.
    """
    w = join_container(path, schema=CKPT_SCHEMA, options=options)
    try:
        ctx = w.create_fill_context()
        batch: List[Dict] = []
        size = written = 0
        for e in shards:
            batch.append(e)
            size += len(e["data"])
            written += 1
            if size >= flush_bytes:
                ctx.fill_batch(_entry_batch(batch))
                batch, size = [], 0
            if crash_after_units is not None and written >= crash_after_units:
                if batch:
                    ctx.fill_batch(_entry_batch(batch))
                ctx.flush_cluster()
                os._exit(1)  # hard crash: lease left dangling, no DONE
        if batch:
            ctx.fill_batch(_entry_batch(batch))
        ctx.close()
    finally:
        if crash_after_units is None:
            w.close()
