"""Parallel single-file distributed checkpointing (the paper's technique
applied to training state).

Exports resolve lazily (PEP 562): ``repro.ckpt._mpworker`` — the
multiprocessing save worker — must be importable in a *spawn* child
without dragging in jax, and an eager ``from .checkpoint import ...``
here would do exactly that.
"""

import importlib

_EXPORTS = {
    "CKPT_SCHEMA": "_mpworker",
    "run_save_worker": "_mpworker",
    "load_checkpoint": "checkpoint",
    "save_checkpoint": "checkpoint",
    "save_checkpoint_mp": "checkpoint",
    "CheckpointManager": "manager",
}

__all__ = list(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        mod = importlib.import_module("." + _EXPORTS[name], __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
