"""Parallel single-file distributed checkpointing (the paper's technique
applied to training state)."""

from .checkpoint import CKPT_SCHEMA, load_checkpoint, save_checkpoint
from .manager import CheckpointManager

__all__ = ["CKPT_SCHEMA", "load_checkpoint", "save_checkpoint",
           "CheckpointManager"]
