"""Checkpoint manager: atomic commits, rotation, async saves, restart.

Fault-tolerance contract:
  * a checkpoint only becomes visible via atomic ``os.rename`` of the
    finished file — a crash mid-write leaves a ``.tmp`` that restart
    ignores and garbage-collects;
  * ``latest_step``/``restore`` always pick the newest *committed* step;
  * ``save_async`` runs the parallel writer on a background thread (the
    paper's opt-2 applies: the training loop only blocks on the metadata
    hand-off, i.e. the np.asarray snapshot).
"""

from __future__ import annotations

import json
import os
import re
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from .checkpoint import load_checkpoint, save_checkpoint

_STEP_RE = re.compile(r"^step_(\d+)\.rntj$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, n_writers: int = 4):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.n_writers = n_writers
        self._async_thread: Optional[threading.Thread] = None
        self._async_error: Optional[BaseException] = None
        self.gc_tmp()

    # -- paths ---------------------------------------------------------------

    def path_for(self, step: int) -> Path:
        return self.dir / f"step_{step:010d}.rntj"

    def steps(self) -> List[int]:
        out = []
        for f in self.dir.iterdir():
            m = _STEP_RE.match(f.name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def gc_tmp(self) -> None:
        for f in self.dir.glob("*.tmp"):
            f.unlink()  # crash leftovers: never committed, safe to drop

    # -- save ----------------------------------------------------------------

    def save(self, step: int, tree, metadata: Optional[Dict] = None) -> Dict:
        tmp = self.dir / f"step_{step:010d}.rntj.tmp"
        meta = {"step": step, **(metadata or {})}
        stats = save_checkpoint(str(tmp), tree, n_writers=self.n_writers,
                                metadata=meta)
        os.replace(tmp, self.path_for(step))  # atomic commit
        self._prune()
        return stats

    def save_async(self, step: int, tree, metadata: Optional[Dict] = None) -> None:
        """Snapshot now (host copies), write in the background."""
        self.wait()
        snapshot = jax.tree_util.tree_map(
            lambda x: np.array(np.asarray(x), copy=True), tree)

        def run():
            try:
                self.save(step, snapshot, metadata)
            except BaseException as e:  # surfaced on next wait()
                self._async_error = e

        self._async_thread = threading.Thread(target=run, daemon=True)
        self._async_thread.start()

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None
        if self._async_error is not None:
            err, self._async_error = self._async_error, None
            raise err

    def _prune(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep]:
            self.path_for(s).unlink()

    # -- restore ---------------------------------------------------------------

    def restore(self, step: Optional[int] = None, target_tree=None,
                shardings=None):
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.dir}")
        tree, meta = load_checkpoint(str(self.path_for(step)),
                                     target_tree=target_tree,
                                     shardings=shardings)
        return tree, meta
