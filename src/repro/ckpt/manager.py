"""Checkpoint manager: atomic commits, rotation, async saves, restart.

Fault-tolerance contract:
  * a checkpoint only becomes visible via atomic ``os.rename`` of the
    finished file — a crash mid-write leaves a ``.tmp`` that restart
    ignores and garbage-collects;
  * every rename/unlink is followed by an fsync of the *directory*: the
    commit is durable only once the directory entry is on disk, and a
    prune is final only once the unlink is (otherwise a power cut can
    resurrect a pruned step or lose a committed one);
  * ``latest_step``/``restore`` always pick the newest *committed* step;
  * ``save_async`` runs the parallel writer on a background thread (the
    paper's opt-2 applies: the training loop only blocks on the metadata
    hand-off, i.e. the np.asarray snapshot); ``restore``/``steps`` first
    synchronize with any in-flight async save so they never race the
    rename/prune it performs;
  * ``processes > 0`` routes saves through the multi-process writer
    (DESIGN.md §8.6): N real processes share one container file.  A
    degraded seal (a worker died mid-save) is *not* committed unless
    ``allow_degraded=True`` — a salvaged checkpoint is only ever visible
    by explicit opt-in, and restores from it need ``strict=False``.
"""

from __future__ import annotations

import json
import os
import re
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from .checkpoint import load_checkpoint, save_checkpoint, save_checkpoint_mp

_STEP_RE = re.compile(r"^step_(\d+)\.rntj$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, n_writers: int = 4,
                 processes: int = 0, allow_degraded: bool = False,
                 mp_options=None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.n_writers = n_writers
        self.processes = processes
        self.allow_degraded = allow_degraded
        self.mp_options = mp_options
        self._async_thread: Optional[threading.Thread] = None
        self._async_error: Optional[BaseException] = None
        self.gc_tmp()

    # -- paths ---------------------------------------------------------------

    def path_for(self, step: int) -> Path:
        return self.dir / f"step_{step:010d}.rntj"

    def steps(self) -> List[int]:
        self.wait()  # an in-flight async save may be mid-rename/prune
        out = []
        for f in self.dir.iterdir():
            m = _STEP_RE.match(f.name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def gc_tmp(self) -> None:
        removed = False
        for pat in ("*.tmp", "*.tmp.mpwlog"):
            for f in self.dir.glob(pat):
                f.unlink()  # crash leftovers: never committed, safe to drop
                removed = True
        if removed:
            self._fsync_dir()

    def _fsync_dir(self) -> None:
        """Make the directory's own entries durable.  ``os.replace`` and
        ``unlink`` mutate the directory, not the file — without this a
        crash after "commit" can roll the directory back to a state where
        the rename (or the prune) never happened."""
        fd = os.open(self.dir, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    # -- save ----------------------------------------------------------------

    def save(self, step: int, tree, metadata: Optional[Dict] = None) -> Dict:
        tmp = self.dir / f"step_{step:010d}.rntj.tmp"
        meta = {"step": step, **(metadata or {})}
        if self.processes:
            stats = save_checkpoint_mp(
                str(tmp), tree, n_processes=self.processes,
                options=self.mp_options, metadata=meta)
            # degraded seal keeps its side-car for forensics; the tmp is
            # either committed (self-contained, footer valid) or dropped,
            # so the log must not outlive this decision
            Path(str(tmp) + ".mpwlog").unlink(missing_ok=True)
            if stats.get("degraded") and not self.allow_degraded:
                tmp.unlink(missing_ok=True)
                raise IOError(
                    f"step {step}: degraded multi-process save "
                    f"(report: {stats}); refusing to commit — pass "
                    f"allow_degraded=True to keep salvaged checkpoints")
        else:
            stats = save_checkpoint(str(tmp), tree, n_writers=self.n_writers,
                                    metadata=meta)
        os.replace(tmp, self.path_for(step))  # atomic commit
        self._fsync_dir()  # rename is durable only once the dir entry is
        self._prune()
        return stats

    def save_async(self, step: int, tree, metadata: Optional[Dict] = None) -> None:
        """Snapshot now (host copies), write in the background."""
        self.wait()
        snapshot = jax.tree_util.tree_map(
            lambda x: np.array(np.asarray(x), copy=True), tree)

        def run():
            try:
                self.save(step, snapshot, metadata)
            except BaseException as e:  # surfaced on next wait()
                self._async_error = e

        self._async_thread = threading.Thread(target=run, daemon=True)
        self._async_thread.start()

    def wait(self) -> None:
        t = self._async_thread
        if t is not None:
            if t is threading.current_thread():
                # save() -> _prune() -> steps() runs ON the async thread;
                # joining ourselves would deadlock, and there is nothing
                # to wait for — the save in flight is this very call
                return
            t.join()
            self._async_thread = None
        if self._async_error is not None:
            err, self._async_error = self._async_error, None
            raise err

    def _prune(self) -> None:
        steps = self.steps()
        removed = False
        for s in steps[: -self.keep]:
            self.path_for(s).unlink()
            removed = True
        if removed:
            self._fsync_dir()  # a pruned step must not resurrect after a crash

    # -- restore ---------------------------------------------------------------

    def restore(self, step: Optional[int] = None, target_tree=None,
                shardings=None, strict: bool = True):
        self.wait()  # never read behind an in-flight async save's rename
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.dir}")
        tree, meta = load_checkpoint(str(self.path_for(step)),
                                     target_tree=target_tree,
                                     shardings=shardings, strict=strict)
        return tree, meta
